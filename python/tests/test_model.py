"""L2 model invariants: encoder shapes, Pallas/ref path agreement, causality
through the full stack, and the Eq. (2) likelihood."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import config, model


def _batch(rng, b, l, k, max_t=50.0):
    times = np.sort(rng.uniform(0, max_t, size=(b, l)), axis=1).astype(np.float32)
    times[:, 0] = 0.0
    types = rng.integers(0, k, size=(b, l)).astype(np.int32)
    types[:, 0] = config.BOS_ID
    length = rng.integers(2, l + 1, size=b).astype(np.int32)
    return jnp.asarray(times), jnp.asarray(types), jnp.asarray(length)


@pytest.mark.parametrize("encoder", config.ENCODERS)
def test_forward_shapes_and_pallas_agreement(encoder):
    size = config.SIZES["draft"]
    params = model.init_params(encoder, size, seed=0)
    names, vals = model.params_names(params), model.params_values(params)
    rng = np.random.default_rng(0)
    times, types, length = _batch(rng, 2, 64, 2)
    outs_p = model.forward(encoder, size, vals, names, times, types, length)
    outs_r = model.forward(
        encoder, size, vals, names, times, types, length, use_pallas=False
    )
    assert [o.shape for o in outs_p] == [
        (2, 64, size.n_mix),
        (2, 64, size.n_mix),
        (2, 64, size.n_mix),
        (2, 64, config.K_MAX),
    ]
    for p, r in zip(outs_p, outs_r):
        np.testing.assert_allclose(p, r, atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("encoder", config.ENCODERS)
def test_forward_is_causal(encoder):
    """Output rows before position j must not depend on event j."""
    size = config.SIZES["draft"]
    params = model.init_params(encoder, size, seed=1)
    names, vals = model.params_names(params), model.params_values(params)
    rng = np.random.default_rng(1)
    times, types, length = _batch(rng, 1, 64, 2)
    length = jnp.asarray([64], jnp.int32)
    base = model.forward(encoder, size, vals, names, times, types, length)
    times2 = times.at[0, 40].set(times[0, 40] + 0.01)
    types2 = types.at[0, 40].set((types[0, 40] + 1) % 2)
    pert = model.forward(encoder, size, vals, names, times2, types2, length)
    for b, p in zip(base, pert):
        np.testing.assert_allclose(b[0, :39], p[0, :39], atol=1e-5)
    assert not np.allclose(base[0][0, 40:], pert[0][0, 40:], atol=1e-6)


def test_param_order_is_deterministic():
    for enc in config.ENCODERS:
        a = model.init_params(enc, config.SIZES["target"], seed=0)
        b = model.init_params(enc, config.SIZES["target"], seed=0)
        assert model.params_names(a) == model.params_names(b)
        for (_, x), (_, y) in zip(a, b):
            np.testing.assert_array_equal(x, y)


@settings(max_examples=6, deadline=None)
@given(
    encoder=st.sampled_from(config.ENCODERS),
    seed=st.integers(0, 2**31 - 1),
)
def test_loglik_finite_and_scales(encoder, seed):
    size = config.SIZES["draft"]
    params = model.init_params(encoder, size, seed=2)
    names, vals = model.params_names(params), model.params_values(params)
    rng = np.random.default_rng(seed)
    times, types, length = _batch(rng, 2, 64, 2)
    t_end = jnp.asarray(np.full(2, 60.0, np.float32))
    ll = model.log_likelihood(
        encoder, size, vals, names, times, types, length, t_end
    )
    assert np.isfinite(float(ll))


def test_survival_term_decreases_loglik_with_horizon():
    """A longer empty horizon after the last event must not increase Eq.(2)."""
    encoder, size = "thp", config.SIZES["draft"]
    params = model.init_params(encoder, size, seed=3)
    names, vals = model.params_names(params), model.params_values(params)
    rng = np.random.default_rng(3)
    times, types, length = _batch(rng, 1, 64, 2)
    lls = []
    for t_end in (50.0, 200.0):
        lls.append(
            float(
                model.log_likelihood(
                    encoder, size, vals, names, times, types, length,
                    jnp.asarray([t_end], jnp.float32),
                )
            )
        )
    assert lls[1] <= lls[0]


def test_temporal_encodings_differ_across_encoders():
    rng = np.random.default_rng(4)
    t = jnp.asarray(rng.uniform(0, 100, size=(1, 16)).astype(np.float32))
    d = 32
    pd = {"time_freq": jnp.asarray(np.linspace(0.1, 1, d).astype(np.float32))}
    zs = {e: model.temporal_encoding(e, t, d, pd) for e in config.ENCODERS}
    assert not np.allclose(zs["thp"], zs["sahp"])
    assert not np.allclose(zs["thp"], zs["attnhp"])
    for z in zs.values():
        assert np.abs(np.asarray(z)).max() <= 1.0 + 1e-6  # sin/cos bounded
