"""Data substrate: thinning simulators against analytic statistics, and the
Eq. (1) ground-truth likelihoods (cross-checked with the time-rescaling
identity)."""

import numpy as np
import pytest

from compile import config, data


def test_poisson_count_matches_integrated_intensity():
    cfg = config.DATASETS["poisson"]
    rng = np.random.default_rng(0)
    counts = [len(data.simulate(cfg, rng)[0]) for _ in range(100)]
    A, b, om = cfg.params["A"], cfg.params["b"], cfg.params["omega"]
    w = om * np.pi
    expect = A * (b * cfg.t_end + (1 - np.cos(w * cfg.t_end)) / w)
    se = np.sqrt(expect / len(counts))
    assert abs(np.mean(counts) - expect) < 4 * se + 1


def test_hawkes_stationary_rate():
    cfg = config.DATASETS["hawkes"]
    rng = np.random.default_rng(1)
    counts = [len(data.simulate(cfg, rng)[0]) for _ in range(40)]
    # μ/(1−α/β) = 2.5/(1−0.5) = 5 events per unit time
    assert abs(np.mean(counts) / cfg.t_end - 5.0) < 0.4


def test_multihawkes_type_marginals():
    cfg = config.DATASETS["multihawkes"]
    rng = np.random.default_rng(2)
    times, types = data.simulate(cfg, rng)
    # dim 0 gets more excitation (α row [1, .5] vs [.1, 1])
    assert (types == 0).sum() > (types == 1).sum()


def test_realsim_datasets_have_expected_types_and_rate():
    for name in config.REAL_SIM:
        cfg = config.DATASETS[name]
        rng = np.random.default_rng(3)
        times, types = data.simulate(cfg, rng)
        assert types.max() < cfg.num_types
        assert 20 < len(times) < 1000, (name, len(times))
        assert np.all(np.diff(times) > 0)


@pytest.mark.parametrize("name", ["poisson", "hawkes", "multihawkes"])
def test_loglik_prefers_true_parameters(name):
    """Ground-truth Eq.(1) log-lik should on average beat a perturbed model."""
    cfg = config.DATASETS[name]
    rng = np.random.default_rng(4)
    diffs = []
    for _ in range(10):
        times, types = data.simulate(cfg, rng)
        ll_true = data.ground_truth_loglik(cfg, times, types)
        # perturb: double base rates
        import dataclasses
        p2 = dict(cfg.params)
        if name == "poisson":
            p2["A"] = cfg.params["A"] * 1.5
        elif name == "hawkes":
            p2["mu"] = cfg.params["mu"] * 1.7
        else:
            p2["mu"] = [m * 1.9 for m in cfg.params["mu"]]
        cfg2 = dataclasses.replace(cfg, params=p2)
        ll_wrong = data.ground_truth_loglik(cfg2, times, types)
        diffs.append(ll_true - ll_wrong)
    assert np.mean(diffs) > 0


def test_rescaling_identity_hawkes():
    """z_i = Λ(t_{i-1}, t_i) are Exp(1) under the true Hawkes model."""
    cfg = config.DATASETS["hawkes"]
    p = cfg.params
    rng = np.random.default_rng(5)
    zs = []
    for _ in range(5):
        times, _ = data.simulate(cfg, rng)
        s, prev = 0.0, 0.0
        for t in times:
            # Λ(prev, t) = μΔ + (α/β)·S(prev)·(1−e^{−βΔ})
            delta = t - prev
            zs.append(
                p["mu"] * delta
                + p["alpha"] / p["beta"] * s * (1 - np.exp(-p["beta"] * delta))
            )
            s = s * np.exp(-p["beta"] * delta) + 1.0
            prev = t
    zs = np.asarray(zs)
    assert abs(zs.mean() - 1.0) < 0.06
    assert abs(zs.std() - 1.0) < 0.1


def test_crops_to_batch_layout():
    rng = np.random.default_rng(6)
    seqs = [
        (np.array([1.0, 2.0, 3.0]), np.array([0, 1, 0])),
        (np.sort(rng.uniform(0, 50, size=300)), rng.integers(0, 2, size=300)),
    ]
    times, types, length, t_end = data.crops_to_batch(
        seqs, np.array([0, 1]), crop_len=64, bos_id=config.BOS_ID, rng=rng
    )
    assert times.shape == (2, 64) and types.shape == (2, 64)
    # short sequence: all events + BOS
    assert length[0] == 4
    assert types[0, 0] == config.BOS_ID
    assert np.allclose(times[0, 1:4], [1.0, 2.0, 3.0])
    assert t_end[0] > 3.0
    # long sequence: crop of 63 events, survival horizon = next event
    assert length[1] == 64
    assert t_end[1] >= times[1, 63]


def test_export_json_contains_everything():
    import json

    j = json.loads(config.export_json())
    assert j["k_max"] == config.K_MAX
    assert set(j["datasets"]) == set(config.DATASETS)
    assert set(j["sizes"]) == set(config.SIZES)
    assert j["datasets"]["taobao_sim"]["num_types"] == 17
