"""Training substrate: hand-rolled Adam decreases the Eq. (2) loss, and the
weights npz round-trips with the canonical parameter order."""

import os
import tempfile

import numpy as np
import jax.numpy as jnp
import pytest

from compile import config, data, model, train


@pytest.fixture(scope="module")
def tiny_dataset():
    cfg = config.DATASETS["hawkes"]
    return data.simulate_dataset(cfg, 8, seed=0)


def test_adam_decreases_loss(tiny_dataset):
    tc = config.TrainCfg(steps=30, batch=4, crop_len=64)
    named, log = train.train_model("thp", config.SIZES["draft"], tiny_dataset, tc, log_every=0)
    assert log["loss_last"] < log["loss_first"], log
    for _, v in named:
        assert np.isfinite(np.asarray(v)).all()


def test_adam_update_moves_toward_gradient():
    tc = config.TrainCfg(lr=0.1)
    params = [jnp.asarray([1.0, -2.0])]
    grads = [jnp.asarray([0.5, -0.5])]
    state = train.adam_init(params)
    new, state = train.adam_update(params, grads, state, tc)
    # first step ≈ -lr * sign(grad)
    np.testing.assert_allclose(
        np.asarray(new[0]), [1.0 - 0.1, -2.0 + 0.1], atol=1e-3
    )
    assert int(state["t"]) == 1


def test_weights_roundtrip_preserves_order():
    params = model.init_params("attnhp", config.SIZES["draft"], seed=5)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.npz")
        train.save_weights(path, params)
        loaded = train.load_weights(path)
    assert [n for n, _ in loaded] == [n for n, _ in params]
    for (_, a), (_, b) in zip(params, loaded):
        np.testing.assert_array_equal(np.asarray(a), b)


def test_weights_keys_sort_to_positional_order():
    """The Rust loader sorts npz keys lexicographically — the zero-padded
    index prefix must make that equal to positional order beyond 10 params."""
    params = model.init_params("thp", config.SIZES["target"], seed=0)
    assert len(params) > 30  # enough to catch 1 vs 10 ordering bugs
    keys = [f"{i:03d}|{n}" for i, (n, _) in enumerate(params)]
    assert sorted(keys) == keys
