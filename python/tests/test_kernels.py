"""L1 correctness: Pallas kernels vs pure-jnp oracles (the core signal).

Hypothesis sweeps shapes, prefix lengths and the AttNHP denominator variant;
interpret-mode Pallas must match ref.py to float32 tolerance everywhere.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import causal_attention, causal_attention_bhld, mixture_head, ref

TOL = 5e-5


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=12, deadline=None)
@given(
    lq=st.sampled_from([64, 128, 192]),
    dh=st.sampled_from([4, 8, 16]),
    frac=st.floats(0.05, 1.0),
    plus_one=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(lq, dh, frac, plus_one, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, lq, dh) for _ in range(3))
    length = jnp.asarray(max(1, int(frac * lq)), jnp.int32)
    got = causal_attention(q, k, v, length, plus_one=plus_one)
    want = ref.causal_attention_ref(q, k, v, length, plus_one=plus_one)
    np.testing.assert_allclose(got, want, atol=TOL, rtol=TOL)


@settings(max_examples=6, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    plus_one=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_batched_heads(b, h, plus_one, seed):
    rng = np.random.default_rng(seed)
    L, dh = 64, 8
    q, k, v = (_rand(rng, b, h, L, dh) for _ in range(3))
    length = jnp.asarray(rng.integers(1, L + 1, size=b), jnp.int32)
    got = causal_attention_bhld(q, k, v, length, plus_one=plus_one)
    for bi in range(b):
        for hi in range(h):
            want = ref.causal_attention_ref(
                q[bi, hi], k[bi, hi], v[bi, hi], length[bi], plus_one=plus_one
            )
            np.testing.assert_allclose(got[bi, hi], want, atol=TOL, rtol=TOL)


def test_attention_respects_causality():
    """Changing a future event must not change earlier outputs."""
    rng = np.random.default_rng(0)
    L, dh = 64, 8
    q, k, v = (_rand(rng, L, dh) for _ in range(3))
    length = jnp.asarray(L, jnp.int32)
    base = causal_attention(q, k, v, length)
    k2 = k.at[40].set(99.0)
    v2 = v.at[40].set(-99.0)
    pert = causal_attention(q, k2, v2, length)
    np.testing.assert_allclose(base[:40], pert[:40], atol=1e-6)
    assert not np.allclose(base[40:], pert[40:])


def test_attention_padding_rows_are_finite():
    rng = np.random.default_rng(1)
    L, dh = 64, 8
    q, k, v = (_rand(rng, L, dh) for _ in range(3))
    out = causal_attention(q, k, v, jnp.asarray(3, jnp.int32))
    assert np.isfinite(np.asarray(out)).all()


def test_plus_one_shrinks_attention_mass():
    """The AttNHP +1 denominator strictly shrinks output magnitude at row 0
    (single key: softmax gives weight 1, plus-one gives exp(s)/(exp(s)+1))."""
    rng = np.random.default_rng(2)
    L, dh = 64, 4
    q, k, v = (_rand(rng, L, dh) for _ in range(3))
    length = jnp.asarray(L, jnp.int32)
    soft = causal_attention(q, k, v, length, plus_one=False)
    plus = causal_attention(q, k, v, length, plus_one=True)
    assert np.linalg.norm(plus[0]) < np.linalg.norm(soft[0])


def _head_params(rng, d, m, kk):
    r = lambda *s: _rand(rng, *s)
    return {
        "e_w": r(d, 3 * d), "e_b": r(3 * d),
        "v_w": r(d, m), "b_w": r(m),
        "v_mu": r(d, m), "b_mu": r(m),
        "v_sig": r(d, m), "b_sig": r(m),
        "k1": r(d, d), "k1_b": r(d),
        "k2": r(d, kk), "k2_b": r(kk),
    }


@settings(max_examples=10, deadline=None)
@given(
    l=st.sampled_from([64, 128]),
    d=st.sampled_from([16, 32]),
    m=st.sampled_from([4, 8]),
    kk=st.sampled_from([2, 24]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mixture_head_matches_ref(l, d, m, kk, seed):
    rng = np.random.default_rng(seed)
    params = _head_params(rng, d, m, kk)
    h = _rand(rng, l, d)
    got = mixture_head(h, params)
    want = ref.mixture_head_ref(h, params)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=TOL, rtol=TOL)


def test_mixture_head_outputs_normalized_and_clipped():
    rng = np.random.default_rng(3)
    params = _head_params(rng, 32, 8, 24)
    h = 50.0 * _rand(rng, 64, 32)  # extreme inputs
    log_w, mu, log_sig, logits = mixture_head(h, params)
    np.testing.assert_allclose(
        np.exp(np.asarray(log_w)).sum(-1), 1.0, atol=1e-4
    )
    assert np.asarray(log_sig).max() <= 5.0 + 1e-6
    assert np.asarray(log_sig).min() >= -8.0 - 1e-6
    assert np.isfinite(np.asarray(logits)).all()


def test_lognormal_mixture_pdf_integrates_to_one():
    rng = np.random.default_rng(4)
    m = 4
    log_w = jnp.log(jnp.asarray([0.1, 0.2, 0.3, 0.4], jnp.float32))
    mu = _rand(rng, m)
    log_sigma = jnp.clip(_rand(rng, m), -1.0, 0.5)
    taus = jnp.linspace(1e-4, 80.0, 200_000)
    pdf = jnp.exp(ref.lognormal_mixture_logpdf(taus, log_w, mu, log_sigma))
    integral = float(jnp.trapezoid(pdf, taus))
    assert abs(integral - 1.0) < 5e-3, integral


def test_lognormal_cdf_consistent_with_pdf():
    log_w = jnp.log(jnp.asarray([0.5, 0.5], jnp.float32))
    mu = jnp.asarray([0.0, 1.0], jnp.float32)
    log_sigma = jnp.asarray([-0.5, 0.2], jnp.float32)
    taus = jnp.linspace(1e-4, 30.0, 100_000)
    pdf = jnp.exp(ref.lognormal_mixture_logpdf(taus, log_w, mu, log_sigma))
    cdf_num = jnp.cumsum(pdf) * (taus[1] - taus[0])
    cdf_ana = ref.lognormal_mixture_cdf(taus, log_w, mu, log_sigma)
    np.testing.assert_allclose(cdf_num[::10_000], cdf_ana[::10_000], atol=5e-3)
