"""AOT export: the lowered HLO text + manifest must describe exactly the
computation the Rust runtime expects (interface pinned by these tests +
`rust/src/bin/validate_artifact.rs` for the numeric round-trip)."""

import json
import os
import tempfile

import pytest

from compile import aot, config


@pytest.mark.parametrize("encoder", config.ENCODERS)
def test_lowering_produces_hlo_and_manifest(encoder):
    hlo, man = aot.lower_forward_hlo(encoder, config.SIZES["draft"], 64, 1)
    assert "HloModule" in hlo
    assert man["encoder"] == encoder
    assert man["bucket"] == 64 and man["batch"] == 1
    # inputs: params + times/types/length
    n_params = len(man["params"])
    assert n_params > 5
    # parameter count in HLO text matches manifest + 3 data inputs
    assert hlo.count("parameter(") >= n_params + 3
    assert [o["name"] for o in man["outputs"]] == [
        "log_w",
        "mu",
        "log_sigma",
        "type_logits",
    ]


def test_export_writes_files_with_stamped_names():
    with tempfile.TemporaryDirectory() as d:
        stem = aot.export_forward(d, "thp", config.SIZES["draft"], 64, 1)
        assert stem == "fwd_thp_draft_L64_B1"
        hlo = os.path.join(d, stem + ".hlo.txt")
        man = os.path.join(d, stem + ".manifest.json")
        assert os.path.getsize(hlo) > 1000
        m = json.load(open(man))
        assert m["size"]["n_layers"] == config.SIZES["draft"].n_layers
        assert m["k_max"] == config.K_MAX


def test_pallas_and_ref_lowerings_have_same_interface():
    h1, m1 = aot.lower_forward_hlo("thp", config.SIZES["draft"], 64, 1, use_pallas=True)
    h2, m2 = aot.lower_forward_hlo("thp", config.SIZES["draft"], 64, 1, use_pallas=False)
    assert [p["name"] for p in m1["params"]] == [p["name"] for p in m2["params"]]
    assert m1["outputs"] == m2["outputs"]
    assert m1["impl"] == "pallas" and m2["impl"] == "ref"


def test_batched_bucket_shapes():
    _, man = aot.lower_forward_hlo("sahp", config.SIZES["draft"], 128, 8)
    assert man["inputs"][0]["shape"] == [8, 128]
    assert man["outputs"][0]["shape"] == [8, 128, config.SIZES["draft"].n_mix]
