"""Layer-2 JAX model: the CDF-based Transformer TPP (paper §4.2).

``M = {E, g(τ|·), f(k|·)}``:

* encoder ``E``  — THP / SAHP / AttNHP Transformer backbone (App. D.2),
  calling the Layer-1 Pallas attention kernel;
* decoder       — log-normal mixture over inter-event intervals + categorical
  type head, via the fused Layer-1 ``mixture_head`` kernel;
* loss          — CDF-form log-likelihood, paper Eq. (2).

A BOS event ``(t=0, type=BOS_ID)`` occupies position 0, so output row *i*
parameterizes the distribution of event *i+1* given history ``≤ i``.

Parameters are kept as an **ordered list** ``[(name, array), ...]`` — the
exact positional order of the HLO parameters in the AOT artifact and of the
entries in the weights ``.npz`` (see aot.py / the Rust ``runtime`` module).
"""

from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import config
from .config import ModelSize
from .kernels import causal_attention_bhld, mixture_head, ref

Params = List[Tuple[str, jnp.ndarray]]


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def init_params(encoder: str, size: ModelSize, seed: int = 0) -> Params:
    """Initialize all learnable parameters in canonical order."""
    assert encoder in config.ENCODERS, encoder
    rng = np.random.default_rng(seed)
    d, m = size.d_model, size.n_mix
    out: Params = []

    def add(name: str, shape, scale=None):
        if scale is None:
            scale = 1.0 / math.sqrt(shape[0]) if len(shape) > 1 else 0.0
        if scale == 0.0:
            arr = np.zeros(shape, np.float32)
        else:
            arr = rng.normal(0.0, scale, size=shape).astype(np.float32)
        out.append((name, jnp.asarray(arr)))

    # Event-type embedding (vocab = K_MAX + 1 for BOS).
    add("emb_type", (config.K_MAX + 1, d), scale=0.02)
    if encoder == "sahp":
        # Learned time-encoding frequencies w_j (Eq. 28).
        out.append(
            ("time_freq", jnp.asarray(rng.uniform(0.1, 1.0, size=(d,)).astype(np.float32)))
        )

    for l in range(size.n_layers):
        p = f"layers.{l}."
        if encoder == "attnhp":
            # Q/K/V act on concat(1, z, h) ∈ R^{2D+1} (Eq. 32-34).
            add(p + "wq", (2 * d + 1, d))
            add(p + "wk", (2 * d + 1, d))
            add(p + "wv", (2 * d + 1, d))
            add(p + "wo", (d, d))
        else:
            add(p + "ln1_s", (d,), scale=0.0)
            add(p + "ln1_b", (d,), scale=0.0)
            add(p + "wq", (d, d))
            add(p + "wk", (d, d))
            add(p + "wv", (d, d))
            add(p + "wo", (d, d))
            add(p + "ln2_s", (d,), scale=0.0)
            add(p + "ln2_b", (d,), scale=0.0)
            add(p + "ff1", (d, size.d_ff))
            add(p + "ff1_b", (size.d_ff,), scale=0.0)
            add(p + "ff2", (size.d_ff, d))
            add(p + "ff2_b", (d,), scale=0.0)

    # Decoder (paper §4.2): E ∈ R^{3D×D} + three M×D heads + type MLP.
    add("dec.e_w", (d, 3 * d))
    add("dec.e_b", (3 * d,), scale=0.0)
    add("dec.v_w", (d, m))
    add("dec.b_w", (m,), scale=0.0)
    add("dec.v_mu", (d, m))
    # Spread initial mixture means so components differentiate early.
    out.append(("dec.b_mu", jnp.asarray(np.linspace(-2.0, 1.0, m).astype(np.float32))))
    add("dec.v_sig", (d, m))
    add("dec.b_sig", (m,), scale=0.0)
    add("dec.k1", (d, d))
    add("dec.k1_b", (d,), scale=0.0)
    add("dec.k2", (d, config.K_MAX))
    add("dec.k2_b", (config.K_MAX,), scale=0.0)
    return out


def params_dict(params: Params) -> Dict[str, jnp.ndarray]:
    return dict(params)


def params_values(params: Params) -> List[jnp.ndarray]:
    return [v for _, v in params]


def params_names(params: Params) -> List[str]:
    return [n for n, _ in params]


# ---------------------------------------------------------------------------
# Temporal encodings (paper Eq. 27-29)
# ---------------------------------------------------------------------------


def temporal_encoding(
    encoder: str, times: jnp.ndarray, d: int, pd: Dict[str, jnp.ndarray]
) -> jnp.ndarray:
    """``times [B, L]`` → ``z [B, L, D]``.

    THP (Eq. 27): interleaved sin/cos of ``t / 10000^{j/D}``.
    SAHP (Eq. 28): phase ``j/10000^{j/D}`` plus learned frequency ``w_j t``.
    AttNHP (Eq. 29): sin-only, geometric timescales spanning ``[m, 5·M̄]``
      with ``M̄ = 100`` (the sampling window) and ``m = 1``.  The paper's
      formula reads as frequencies *growing* with j, which collapses to noise
      for large j; we use the official-AttNHP decreasing-frequency form
      (documented deviation, DESIGN.md §2).
    """
    t = times[..., None]  # [B, L, 1]
    j = jnp.arange(d, dtype=jnp.float32)  # [D]
    even = (jnp.arange(d) % 2 == 0)
    if encoder == "thp":
        jj = jnp.where(even, j, j - 1)
        angle = t / jnp.power(10000.0, jj / d)
        return jnp.where(even, jnp.sin(angle), jnp.cos(angle))
    if encoder == "sahp":
        jj = jnp.where(even, j, j - 1)
        phase = jj / jnp.power(10000.0, jj / d)
        angle = phase + pd["time_freq"] * t
        return jnp.where(even, jnp.sin(angle), jnp.cos(angle))
    # attnhp
    m_lo, m_hi = 1.0, 5.0 * 100.0
    jj = jnp.where(even, j, j - 1)
    period = m_lo * jnp.power(m_hi / m_lo, jj / d)
    return jnp.sin(t / period + jnp.where(even, 0.0, 0.5 * jnp.pi))


# ---------------------------------------------------------------------------
# Encoder blocks
# ---------------------------------------------------------------------------


def _layer_norm(x: jnp.ndarray, s: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * (1.0 + s) + b


def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, l, d = x.shape
    return x.reshape(b, l, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, l, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, l, h * dh)


def encode(
    encoder: str,
    size: ModelSize,
    pd: Dict[str, jnp.ndarray],
    times: jnp.ndarray,
    types: jnp.ndarray,
    length: jnp.ndarray,
    *,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Run the Transformer backbone. Returns ``h [B, L, D]``."""
    d = size.d_model
    z = temporal_encoding(encoder, times, d, pd)  # [B, L, D]
    x = pd["emb_type"][types] + z  # fusion f(KW, Z) = sum (paper §4.2)
    h = x

    def attn(q, k, v, plus_one):
        if use_pallas:
            return causal_attention_bhld(q, k, v, length, plus_one=plus_one)
        fn = lambda q1, k1, v1, ln: ref.causal_attention_ref(
            q1, k1, v1, ln, plus_one=plus_one
        )
        per_head = jax.vmap(fn, in_axes=(0, 0, 0, None))
        return jax.vmap(per_head, in_axes=(0, 0, 0, 0))(q, k, v, length)

    for l in range(size.n_layers):
        p = f"layers.{l}."
        if encoder == "attnhp":
            # Eq. 31: h ← h + tanh(attn(concat(1, z, h))) with 1+Σexp denom.
            ones = jnp.ones(h.shape[:-1] + (1,), h.dtype)
            cat = jnp.concatenate([ones, z, h], axis=-1)  # [B, L, 2D+1]
            q = _split_heads(cat @ pd[p + "wq"], size.n_heads)
            k = _split_heads(cat @ pd[p + "wk"], size.n_heads)
            v = _split_heads(cat @ pd[p + "wv"], size.n_heads)
            a = _merge_heads(attn(q, k, v, plus_one=True)) @ pd[p + "wo"]
            h = h + jnp.tanh(a)
        else:
            # Eq. 30 with pre-LN and an FFN sublayer (standard THP/SAHP impl).
            n = _layer_norm(h, pd[p + "ln1_s"], pd[p + "ln1_b"])
            q = _split_heads(n @ pd[p + "wq"], size.n_heads)
            k = _split_heads(n @ pd[p + "wk"], size.n_heads)
            v = _split_heads(n @ pd[p + "wv"], size.n_heads)
            a = _merge_heads(attn(q, k, v, plus_one=False)) @ pd[p + "wo"]
            h = h + a
            n = _layer_norm(h, pd[p + "ln2_s"], pd[p + "ln2_b"])
            f = jax.nn.relu(n @ pd[p + "ff1"] + pd[p + "ff1_b"])
            h = h + f @ pd[p + "ff2"] + pd[p + "ff2_b"]
    return h


# ---------------------------------------------------------------------------
# Full forward pass (the exported computation)
# ---------------------------------------------------------------------------


def _dec_params(pd: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    return {k[len("dec.") :]: v for k, v in pd.items() if k.startswith("dec.")}


def forward(
    encoder: str,
    size: ModelSize,
    params: Sequence[jnp.ndarray],
    names: Sequence[str],
    times: jnp.ndarray,
    types: jnp.ndarray,
    length: jnp.ndarray,
    *,
    use_pallas: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The AOT-exported computation.

    Args:
      params: flat list of parameter arrays (canonical order).
      names: matching names (static).
      times: ``[B, L]`` absolute event times (position 0 = BOS at window
        start).
      types: ``[B, L]`` int32 event types (position 0 = BOS_ID).
      length: ``[B]`` int32 valid prefix lengths (including BOS).

    Returns ``(log_w, mu, log_sigma, type_logits)`` of shapes
    ``[B, L, M] ×3`` and ``[B, L, K_MAX]``.  Row *i* parameterizes the
    distribution of event *i+1*.
    """
    pd = dict(zip(names, params))
    h = encode(encoder, size, pd, times, types, length, use_pallas=use_pallas)
    dec = _dec_params(pd)
    if use_pallas:
        head = jax.vmap(lambda hb: mixture_head(hb, dec))
    else:
        head = jax.vmap(lambda hb: ref.mixture_head_ref(hb, dec))
    return head(h)


# ---------------------------------------------------------------------------
# Log-likelihood (paper Eq. 2) — the training objective
# ---------------------------------------------------------------------------


def log_likelihood(
    encoder: str,
    size: ModelSize,
    params: Sequence[jnp.ndarray],
    names: Sequence[str],
    times: jnp.ndarray,
    types: jnp.ndarray,
    length: jnp.ndarray,
    t_end: jnp.ndarray,
    *,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Mean per-sequence CDF-form log-likelihood Eq. (2).

    ``times/types`` include the BOS row; ``length`` counts it.  ``t_end [B]``
    is the right edge of the observation window (for the survival term
    ``log(1 − G(T − t_N | h_N))``).  Training uses the pure-jnp reference
    path (faster to trace; the Pallas path is what gets exported — pytest
    asserts they agree).
    """
    b, l = times.shape
    log_w, mu, log_sig, logits = forward(
        encoder, size, params, names, times, types, length, use_pallas=use_pallas
    )
    # Event i (1-indexed) lives at row i; its distribution comes from row i-1.
    tau = times[:, 1:] - times[:, :-1]  # [B, L-1]
    lw, m_, ls = log_w[:, :-1], mu[:, :-1], log_sig[:, :-1]
    log_g = ref.lognormal_mixture_logpdf(tau, lw, m_, ls)  # [B, L-1]
    lsm = jax.nn.log_softmax(logits[:, :-1], axis=-1)  # [B, L-1, K]
    log_f = jnp.take_along_axis(lsm, types[:, 1:, None], axis=-1)[..., 0]

    idx = jnp.arange(1, l)[None, :]  # event positions
    valid = idx < length[:, None]  # [B, L-1]
    ll_events = jnp.sum(jnp.where(valid, log_g + log_f, 0.0), axis=-1)  # [B]

    # Survival term at the last observed event.
    last = length - 1  # row of last event
    bidx = jnp.arange(b)
    t_last = times[bidx, last]
    rem = jnp.maximum(t_end - t_last, 1e-6)
    cdf = ref.lognormal_mixture_cdf(
        rem, log_w[bidx, last], mu[bidx, last], log_sig[bidx, last]
    )
    ll_surv = jnp.log1p(-jnp.clip(cdf, 0.0, 1.0 - 1e-6))
    return jnp.mean(ll_events + ll_surv)
