"""Build-time training: hand-rolled Adam on the Eq. (2) log-likelihood.

optax is not available in this offline container, so Adam is implemented
directly (Kingma & Ba 2017); it is ~15 lines and exercised by pytest
(loss must decrease on a smoke problem).
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import config, data, model
from .config import DatasetCfg, ModelSize, TrainCfg


def adam_init(params: List[jnp.ndarray]):
    zeros = [jnp.zeros_like(p) for p in params]
    return {"m": zeros, "v": [jnp.zeros_like(p) for p in params], "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, cfg: TrainCfg):
    t = state["t"] + 1
    b1, b2 = cfg.b1, cfg.b2
    m = [b1 * m_ + (1 - b1) * g for m_, g in zip(state["m"], grads)]
    v = [b2 * v_ + (1 - b2) * g * g for v_, g in zip(state["v"], grads)]
    # bias correction
    c1 = 1.0 - b1 ** t.astype(jnp.float32)
    c2 = 1.0 - b2 ** t.astype(jnp.float32)
    new = [
        p - cfg.lr * (m_ / c1) / (jnp.sqrt(v_ / c2) + cfg.eps)
        for p, m_, v_ in zip(params, m, v)
    ]
    return new, {"m": m, "v": v, "t": t}


def train_model(
    encoder: str,
    size: ModelSize,
    seqs: List[data.Seq],
    cfg: TrainCfg = config.TRAIN,
    seed: int = 0,
    log_every: int = 100,
) -> Tuple[List[Tuple[str, jnp.ndarray]], Dict]:
    """Train one model; returns (named params, training log)."""
    params = model.init_params(encoder, size, seed=seed)
    names = model.params_names(params)
    values = model.params_values(params)

    def loss_fn(values, times, types, length, t_end):
        ll = model.log_likelihood(
            encoder, size, values, names, times, types, length, t_end
        )
        return -ll

    loss_grad = jax.jit(jax.value_and_grad(loss_fn))

    state = adam_init(values)
    rng = np.random.default_rng(seed + 1)
    losses = []
    t0 = time.time()
    n = len(seqs)
    for step in range(cfg.steps):
        idxs = rng.integers(0, n, size=cfg.batch)
        times, types, length, t_end = data.crops_to_batch(
            seqs, idxs, cfg.crop_len, config.BOS_ID, rng
        )
        loss, grads = loss_grad(
            values,
            jnp.asarray(times),
            jnp.asarray(types),
            jnp.asarray(length),
            jnp.asarray(t_end),
        )
        values, state = adam_update(values, grads, state, cfg)
        losses.append(float(loss))
        if log_every and step % log_every == 0:
            print(f"    step {step:4d} loss {float(loss):10.3f}", flush=True)
    log = {
        "encoder": encoder,
        "size": size.name,
        "steps": cfg.steps,
        "loss_first": losses[0] if losses else None,
        "loss_last": float(np.mean(losses[-20:])) if losses else None,
        "seconds": time.time() - t0,
    }
    return list(zip(names, values)), log


def save_weights(path: str, named_params: List[Tuple[str, jnp.ndarray]]) -> None:
    """Write an .npz whose keys encode the canonical parameter order.

    Keys are ``{idx:03d}|{name}`` — the Rust loader sorts by key to recover
    positional order (``Literal::read_npz`` gives no order guarantee).
    """
    arrays = {
        f"{i:03d}|{name}": np.asarray(v) for i, (name, v) in enumerate(named_params)
    }
    np.savez(path, **arrays)


def load_weights(path: str) -> List[Tuple[str, np.ndarray]]:
    with np.load(path) as z:
        items = sorted(z.items())
    return [(k.split("|", 1)[1], v) for k, v in items]
