"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: ``pytest python/tests`` asserts the
Pallas kernels (interpret mode) match these to tight tolerances across
hypothesis-driven shape sweeps.  They are also used directly by the training
loss (training is build-time; only the exported forward must be fast).
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def causal_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    length: jnp.ndarray,
    *,
    plus_one: bool = False,
    scale: float | None = None,
) -> jnp.ndarray:
    """Reference causal attention for one (batch, head) slice.

    Args:
      q, k, v: ``[L, Dh]``.
      length: scalar int32 — number of valid positions (prefix).
      plus_one: AttNHP variant (Eq. 31): the softmax denominator carries an
        extra ``+1`` term, equivalent to a phantom key with score 0 attending
        to a zero value.
      scale: logit scale; defaults to ``1/sqrt(Dh)``.

    Rows at positions ``>= length`` attend to themselves only (keeps the
    output finite; the consumer masks them out).
    """
    L, dh = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    logits = (q @ k.T) * scale  # [L, L]
    rows = jnp.arange(L)[:, None]
    cols = jnp.arange(L)[None, :]
    mask = (cols <= rows) & ((cols < length) | (cols == rows))
    logits = jnp.where(mask, logits, NEG_INF)
    if plus_one:
        # Append the phantom key: score 0, value 0.
        m = jnp.maximum(jnp.max(logits, axis=-1, keepdims=True), 0.0)
        p = jnp.exp(logits - m)
        denom = jnp.sum(p, axis=-1, keepdims=True) + jnp.exp(-m)
        return (p / denom) @ v
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    return (p / jnp.sum(p, axis=-1, keepdims=True)) @ v


def mixture_head_ref(
    h: jnp.ndarray,
    params: dict,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Reference CDF decoder head (paper §4.2).

    Args:
      h: ``[L, D]`` history embeddings.
      params: dict with ``e_w [D, 3D]``, ``e_b [3D]``, ``v_w/v_mu/v_sig
        [D, M]``, ``b_w/b_mu/b_sig [M]``, ``k1 [D, Dk]``, ``k1_b [Dk]``,
        ``k2 [Dk, K]``, ``k2_b [K]``.

    Returns ``(log_w, mu, log_sigma, type_logits)`` with shapes
    ``[L, M] ×3`` and ``[L, K]``.  ``log_sigma`` is clipped to ``[-8, 5]``
    for sampling stability on both sides of the FFI boundary.
    """
    d = h.shape[-1]
    e = h @ params["e_w"] + params["e_b"]  # [L, 3D]
    e1, e2, e3 = e[:, :d], e[:, d : 2 * d], e[:, 2 * d :]
    logits_w = e1 @ params["v_w"] + params["b_w"]
    log_w = logits_w - jnp.max(logits_w, axis=-1, keepdims=True)
    log_w = log_w - jnp.log(jnp.sum(jnp.exp(log_w), axis=-1, keepdims=True))
    mu = e2 @ params["v_mu"] + params["b_mu"]
    log_sigma = jnp.clip(e3 @ params["v_sig"] + params["b_sig"], -8.0, 5.0)
    t = jnp.tanh(h @ params["k1"] + params["k1_b"])
    type_logits = t @ params["k2"] + params["k2_b"]
    return log_w, mu, log_sigma, type_logits


def lognormal_mixture_logpdf(
    tau: jnp.ndarray, log_w: jnp.ndarray, mu: jnp.ndarray, log_sigma: jnp.ndarray
) -> jnp.ndarray:
    """log g(τ) of a log-normal mixture; broadcasting over leading dims.

    ``tau``: [...], ``log_w/mu/log_sigma``: [..., M].
    """
    tau = jnp.maximum(tau, 1e-10)
    log_tau = jnp.log(tau)[..., None]
    z = (log_tau - mu) * jnp.exp(-log_sigma)
    comp = (
        log_w
        - log_tau
        - log_sigma
        - 0.5 * jnp.log(2.0 * jnp.pi)
        - 0.5 * z * z
    )
    m = jnp.max(comp, axis=-1, keepdims=True)
    return (m + jnp.log(jnp.sum(jnp.exp(comp - m), axis=-1, keepdims=True)))[..., 0]


def lognormal_mixture_cdf(
    tau: jnp.ndarray, log_w: jnp.ndarray, mu: jnp.ndarray, log_sigma: jnp.ndarray
) -> jnp.ndarray:
    """G(τ) = Σ_m w_m Φ((log τ − μ_m)/σ_m)."""
    from jax.scipy.stats import norm

    tau = jnp.maximum(tau, 1e-10)
    z = (jnp.log(tau)[..., None] - mu) * jnp.exp(-log_sigma)
    return jnp.sum(jnp.exp(log_w) * norm.cdf(z), axis=-1)
