"""Layer-1 Pallas kernel: flash-style causal attention for Transformer TPPs.

TPU-shaped even though this container executes it in interpret mode (the CPU
PJRT plugin cannot run Mosaic custom-calls):

* the grid tiles **query blocks**; keys/values stream through VMEM in
  ``block_k``-sized chunks with a running (max, denominator, accumulator)
  triple — the classic flash-attention recurrence, which is also the right
  HBM→VMEM schedule for the MXU;
* the AttNHP ``1+Σexp`` denominator (paper Eq. 31) is folded into the
  *initial state* (m₀=0, l₀=1, acc₀=0) instead of a phantom key, costing no
  extra memory traffic;
* padding rows (≥ ``length``) keep their diagonal unmasked so no row ever
  normalizes over an empty set (finite outputs, masked by the consumer).

VMEM budget per program instance (see DESIGN.md §10):
``block_q·Dh + 2·block_k·Dh + block_q·block_k`` floats — ≤ 2 MiB for every
exported configuration, leaving double-buffering headroom on a 16 MiB core.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    len_ref,
    o_ref,
    *,
    block_q: int,
    block_k: int,
    seq_len: int,
    plus_one: bool,
    scale: float,
):
    qi = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32) * scale  # [block_q, Dh]
    length = len_ref[0]

    row = qi * block_q + jax.lax.iota(jnp.int32, block_q)  # [block_q]

    if plus_one:
        m0 = jnp.zeros((block_q,), jnp.float32)
        l0 = jnp.ones((block_q,), jnp.float32)
    else:
        m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
        l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, q.shape[1]), jnp.float32)

    # Causality: key block kb is only needed while kb*block_k <= row_max.
    num_kb = (qi * block_q + block_q + block_k - 1) // block_k
    num_kb = jnp.minimum(num_kb, seq_len // block_k)

    def body(kb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        col = kb * block_k + jax.lax.iota(jnp.int32, block_k)  # [block_k]
        s = q @ k.astype(jnp.float32).T  # [block_q, block_k]
        mask = (col[None, :] <= row[:, None]) & (
            (col[None, :] < length) | (col[None, :] == row[:, None])
        )
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def causal_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    length: jnp.ndarray,
    *,
    plus_one: bool = False,
    scale: float | None = None,
    block_q: int = 64,
    block_k: int = 64,
    interpret: bool = True,
) -> jnp.ndarray:
    """Causal attention over one ``[L, Dh]`` (batch, head) slice.

    ``L`` must be divisible by ``block_q`` and ``block_k`` (the exported
    buckets are multiples of 64).  Batch/head dims are handled by ``vmap``
    in the model layer.  ``length`` is a scalar int32 prefix length.
    """
    L, dh = q.shape
    block_q = min(block_q, L)
    block_k = min(block_k, L)
    assert L % block_q == 0 and L % block_k == 0, (L, block_q, block_k)
    if scale is None:
        scale = 1.0 / float(dh) ** 0.5
    length = jnp.reshape(length.astype(jnp.int32), (1,))
    kernel = functools.partial(
        _attn_kernel,
        block_q=block_q,
        block_k=block_k,
        seq_len=L,
        plus_one=plus_one,
        scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(L // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, dh), lambda i: (i, 0)),
            pl.BlockSpec((L, dh), lambda i: (0, 0)),
            pl.BlockSpec((L, dh), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_q, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, dh), q.dtype),
        interpret=interpret,
    )(q, k, v, length)


def causal_attention_bhld(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    length: jnp.ndarray,
    **kw,
) -> jnp.ndarray:
    """vmap wrapper: ``q/k/v [B, H, L, Dh]``, ``length [B]`` → ``[B, H, L, Dh]``."""
    fn = functools.partial(causal_attention, **kw)
    per_head = jax.vmap(fn, in_axes=(0, 0, 0, None))  # over H
    return jax.vmap(per_head, in_axes=(0, 0, 0, 0))(q, k, v, length)  # over B
