"""Layer-1 Pallas kernels (build-time only; lowered into the exported HLO)."""

from .attention import causal_attention, causal_attention_bhld
from .mixture_head import mixture_head
from . import ref

__all__ = ["causal_attention", "causal_attention_bhld", "mixture_head", "ref"]
