"""Layer-1 Pallas kernel: fused CDF decoder head (paper §4.2).

One pass per position block computes everything the sampler needs from the
history embedding ``h(t_i)``:

  e = E·h + b          → sliced into (e₁, e₂, e₃)
  log w = log_softmax(V_w e₁ + b_w)        (mixture log-weights)
  μ     = V_μ e₂ + b_μ                     (mixture means)
  log σ = clip(V_σ e₃ + b_σ, −8, 5)        (mixture log-scales)
  type_logits = V₂ tanh(V₁ h + b₁) + b₂    (categorical head)

Fusing the five matmuls into one kernel keeps ``h`` resident in VMEM for all
heads instead of re-streaming it from HBM five times; the weight operands are
small enough (< 64 KiB at the default config) to live in VMEM for the whole
grid.  Executed with ``interpret=True`` on CPU (see attention.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _head_kernel(
    h_ref,
    e_w_ref,
    e_b_ref,
    v_w_ref,
    b_w_ref,
    v_mu_ref,
    b_mu_ref,
    v_sig_ref,
    b_sig_ref,
    k1_ref,
    k1_b_ref,
    k2_ref,
    k2_b_ref,
    logw_ref,
    mu_ref,
    logsig_ref,
    logits_ref,
    *,
    d_model: int,
):
    h = h_ref[...].astype(jnp.float32)  # [block, D]
    d = d_model
    e = h @ e_w_ref[...] + e_b_ref[...]  # [block, 3D]
    e1, e2, e3 = e[:, :d], e[:, d : 2 * d], e[:, 2 * d :]

    lw = e1 @ v_w_ref[...] + b_w_ref[...]  # [block, M]
    lw = lw - jnp.max(lw, axis=-1, keepdims=True)
    lw = lw - jnp.log(jnp.sum(jnp.exp(lw), axis=-1, keepdims=True))
    logw_ref[...] = lw.astype(logw_ref.dtype)

    mu_ref[...] = (e2 @ v_mu_ref[...] + b_mu_ref[...]).astype(mu_ref.dtype)
    logsig_ref[...] = jnp.clip(
        e3 @ v_sig_ref[...] + b_sig_ref[...], -8.0, 5.0
    ).astype(logsig_ref.dtype)

    t = jnp.tanh(h @ k1_ref[...] + k1_b_ref[...])
    logits_ref[...] = (t @ k2_ref[...] + k2_b_ref[...]).astype(logits_ref.dtype)


def mixture_head(
    h: jnp.ndarray,
    params: dict,
    *,
    block: int = 64,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused decoder head over ``h [L, D]``.

    ``params`` uses the same keys as :func:`ref.mixture_head_ref`.  Returns
    ``(log_w [L,M], mu [L,M], log_sigma [L,M], type_logits [L,K])``.
    """
    L, d = h.shape
    block = min(block, L)
    assert L % block == 0, (L, block)
    m = params["v_w"].shape[1]
    kk = params["k2"].shape[1]
    dk = params["k1"].shape[1]

    grid = (L // block,)
    full = lambda *dims: pl.BlockSpec(dims, lambda i: tuple(0 for _ in dims))
    out_shapes = (
        jax.ShapeDtypeStruct((L, m), h.dtype),
        jax.ShapeDtypeStruct((L, m), h.dtype),
        jax.ShapeDtypeStruct((L, m), h.dtype),
        jax.ShapeDtypeStruct((L, kk), h.dtype),
    )
    kernel = functools.partial(_head_kernel, d_model=d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            full(d, 3 * d),
            full(3 * d),
            full(d, m),
            full(m),
            full(d, m),
            full(m),
            full(d, m),
            full(m),
            full(d, dk),
            full(dk),
            full(dk, kk),
            full(kk),
        ],
        out_specs=(
            pl.BlockSpec((block, m), lambda i: (i, 0)),
            pl.BlockSpec((block, m), lambda i: (i, 0)),
            pl.BlockSpec((block, m), lambda i: (i, 0)),
            pl.BlockSpec((block, kk), lambda i: (i, 0)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(
        h,
        params["e_w"],
        params["e_b"],
        params["v_w"],
        params["b_w"],
        params["v_mu"],
        params["b_mu"],
        params["v_sig"],
        params["b_sig"],
        params["k1"],
        params["k1_b"],
        params["k2"],
        params["k2_b"],
    )
