"""`make artifacts` entrypoint: simulate → train → AOT-export, incrementally.

Every step is cached on a content stamp (a hash of the relevant config), so
re-running after a no-op edit is free and after a config change rebuilds only
what depends on it.

Usage:
    python -m compile.build_all [--out ../artifacts] [--steps N] [--quick]

``--quick`` trains a reduced matrix (synthetic datasets only, fewer steps) —
used by CI-style smoke runs; the default builds everything DESIGN.md §5
lists.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import numpy as np

from . import aot, config, data, train


def _stamp(path: str, key: str) -> bool:
    """True if ``path`` exists and was built with the same ``key``."""
    s = path + ".stamp"
    return (
        os.path.exists(path)
        and os.path.exists(s)
        and open(s).read().strip() == key
    )


def _write_stamp(path: str, key: str) -> None:
    with open(path + ".stamp", "w") as f:
        f.write(key)


def _hash(*parts) -> str:
    h = hashlib.sha256()
    for p in parts:
        h.update(repr(p).encode())
    return h.hexdigest()[:16]


def save_seqs(path: str, seqs) -> None:
    times = np.concatenate([s[0] for s in seqs]) if seqs else np.zeros(0)
    types = np.concatenate([s[1] for s in seqs]) if seqs else np.zeros(0, np.int64)
    offsets = np.cumsum([0] + [len(s[0]) for s in seqs])
    np.savez(path, times=times, types=types, offsets=offsets)


def load_seqs(path: str):
    with np.load(path) as z:
        times, types, offsets = z["times"], z["types"], z["offsets"]
    return [
        (times[a:b], types[a:b]) for a, b in zip(offsets[:-1], offsets[1:])
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--impl", choices=["pallas", "ref"], default="pallas")
    args = ap.parse_args()

    out = os.path.abspath(args.out)
    for sub in ("data", "weights", "hlo"):
        os.makedirs(os.path.join(out, sub), exist_ok=True)

    t_start = time.time()

    # ------------------------------------------------------------------ data
    datasets = list(config.SYNTHETIC) + ([] if args.quick else list(config.REAL_SIM))
    seq_cache = {}
    for ds in datasets:
        cfg = config.DATASETS[ds]
        n = cfg.n_train if not args.quick else max(24, cfg.n_train // 4)
        path = os.path.join(out, "data", f"{ds}.npz")
        key = _hash("data-v1", cfg, n)
        if not _stamp(path, key):
            t0 = time.time()
            seqs = data.simulate_dataset(cfg, n, seed=1234 + cfg.num_types)
            save_seqs(path, seqs)
            _write_stamp(path, key)
            print(
                f"[data] {ds}: {n} seqs, "
                f"{np.mean([len(s[0]) for s in seqs]):.0f} events/seq, "
                f"{time.time()-t0:.1f}s",
                flush=True,
            )
        seq_cache[ds] = path

    # ------------------------------------------------------------- training
    tcfg = config.TrainCfg(steps=args.steps if not args.quick else 80)
    jobs = [
        j
        for j in config.training_matrix()
        if j[0] in datasets
    ]
    logs = []
    for ds, enc, size_name in jobs:
        size = config.SIZES[size_name]
        wpath = os.path.join(out, "weights", f"{ds}_{enc}_{size_name}.npz")
        key = _hash("train-v1", config.DATASETS[ds], enc, size, tcfg)
        if _stamp(wpath, key):
            continue
        print(f"[train] {ds} / {enc} / {size_name}", flush=True)
        seqs = load_seqs(seq_cache[ds])
        named, log = train.train_model(
            enc, size, seqs, tcfg, seed=7, log_every=0
        )
        train.save_weights(wpath, named)
        _write_stamp(wpath, key)
        log["dataset"] = ds
        logs.append(log)
        print(
            f"        loss {log['loss_first']:.1f} -> {log['loss_last']:.1f} "
            f"({log['seconds']:.0f}s)",
            flush=True,
        )
    if logs:
        logp = os.path.join(out, "train_log.json")
        old = json.load(open(logp)) if os.path.exists(logp) else []
        json.dump(old + logs, open(logp, "w"), indent=1)

    # ------------------------------------------------------------------ HLO
    sizes = set(s for _, _, s in jobs)
    n_hlo = 0
    for enc in config.ENCODERS:
        for size_name in sorted(sizes):
            size = config.SIZES[size_name]
            for bucket in config.BUCKETS:
                for batch in config.BATCH_SIZES:
                    stem = aot.artifact_stem(enc, size_name, bucket, batch)
                    path = os.path.join(out, "hlo", stem + ".hlo.txt")
                    key = _hash("hlo-v1", enc, size, bucket, batch, args.impl)
                    if _stamp(path, key):
                        continue
                    t0 = time.time()
                    aot.export_forward(
                        os.path.join(out, "hlo"),
                        enc,
                        size,
                        bucket,
                        batch,
                        use_pallas=args.impl == "pallas",
                    )
                    _write_stamp(path, key)
                    n_hlo += 1
                    print(
                        f"[hlo] {stem} ({time.time()-t0:.1f}s)", flush=True
                    )

    # -------------------------------------------------------------- registry
    with open(os.path.join(out, "datasets.json"), "w") as f:
        f.write(config.export_json())

    print(
        f"[done] artifacts in {out} "
        f"({len(jobs)} models, {n_hlo} new HLO files, "
        f"{time.time()-t_start:.0f}s total)",
        flush=True,
    )


if __name__ == "__main__":
    main()
