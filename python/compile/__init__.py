"""Build-time Python package: Pallas kernels (L1), JAX model (L2), training
and AOT export.  Never imported at serving time."""
