"""Build-time dataset simulation via the classical thinning algorithm.

The three synthetic processes use the paper's exact parameters (App. B.1);
the four "real" datasets are K-dimensional Hawkes stand-ins (DESIGN.md §3).
The same process definitions exist in Rust (``rust/src/processes``) — both
sides are exercised against analytic statistics in their test suites, and the
Rust side additionally reads ``artifacts/datasets.json`` exported from
``config.py`` so parameters can never drift.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from .config import DatasetCfg

Seq = Tuple[np.ndarray, np.ndarray]  # (times f64[N], types i64[N])


# ---------------------------------------------------------------------------
# Thinning simulators (Lewis & Shedler 1979; Ogata 1981)
# ---------------------------------------------------------------------------


def simulate_inhom_poisson(
    rng: np.random.Generator, A: float, b: float, omega: float, t_end: float
) -> Seq:
    """λ(t) = A·(b + sin(ω·π·t)); dominating rate λ̄ = A·(b+1)."""
    lam_bar = A * (b + 1.0)
    t, times = 0.0, []
    while True:
        t += rng.exponential(1.0 / lam_bar)
        if t > t_end:
            break
        lam = A * (b + np.sin(omega * np.pi * t))
        if rng.uniform() * lam_bar < lam:
            times.append(t)
    ts = np.asarray(times)
    return ts, np.zeros(len(ts), np.int64)


def simulate_hawkes(
    rng: np.random.Generator, mu: float, alpha: float, beta: float, t_end: float
) -> Seq:
    """Univariate exponential Hawkes via Ogata thinning.

    Uses the O(1) recursion ``S(t) = Σ_{t_i<t} exp(-β(t-t_i))``.
    """
    t, s, times = 0.0, 0.0, []
    while True:
        lam_bar = mu + alpha * s  # intensity is non-increasing between events
        t_next = t + rng.exponential(1.0 / lam_bar)
        if t_next > t_end:
            break
        s_next = s * np.exp(-beta * (t_next - t))
        lam = mu + alpha * s_next
        t, s = t_next, s_next
        if rng.uniform() * lam_bar < lam:
            times.append(t)
            s += 1.0
    ts = np.asarray(times)
    return ts, np.zeros(len(ts), np.int64)


def simulate_multi_hawkes(
    rng: np.random.Generator,
    mu: np.ndarray,
    alpha: np.ndarray,
    beta: float,
    t_end: float,
) -> Seq:
    """K-dimensional exponential Hawkes via Ogata thinning.

    ``λ_j(t) = μ_j + Σ_i α_{ji} S_i(t)`` with per-source decay states
    ``S_i(t) = Σ_{t^i_k < t} exp(-β (t - t^i_k))``  (α indexed [effect, cause];
    the paper's α_{ij} from cause i to dimension j maps to alpha[j][i]).
    """
    k = len(mu)
    s = np.zeros(k)  # decay state per *cause* dimension
    t, times, types = 0.0, [], []
    mu = np.asarray(mu, float)
    alpha = np.asarray(alpha, float)
    while True:
        lam_vec = mu + alpha @ s
        lam_bar = float(np.sum(lam_vec))  # non-increasing between events
        t_next = t + rng.exponential(1.0 / lam_bar)
        if t_next > t_end:
            break
        decay = np.exp(-beta * (t_next - t))
        s_next = s * decay
        lam_vec = mu + alpha @ s_next
        lam = float(np.sum(lam_vec))
        t, s = t_next, s_next
        if rng.uniform() * lam_bar < lam:
            j = rng.choice(k, p=lam_vec / lam)
            times.append(t)
            types.append(j)
            s[j] += 1.0
    return np.asarray(times), np.asarray(types, np.int64)


def simulate(cfg: DatasetCfg, rng: np.random.Generator) -> Seq:
    p = cfg.params
    if cfg.kind == "poisson":
        return simulate_inhom_poisson(rng, p["A"], p["b"], p["omega"], cfg.t_end)
    if cfg.kind == "hawkes":
        return simulate_hawkes(rng, p["mu"], p["alpha"], p["beta"], cfg.t_end)
    if cfg.kind == "multihawkes":
        return simulate_multi_hawkes(
            rng, np.asarray(p["mu"]), np.asarray(p["alpha"]), p["beta"], cfg.t_end
        )
    raise ValueError(cfg.kind)


def simulate_dataset(cfg: DatasetCfg, n: int, seed: int) -> List[Seq]:
    rng = np.random.default_rng(seed)
    return [simulate(cfg, rng) for _ in range(n)]


# ---------------------------------------------------------------------------
# Ground-truth log-likelihood Eq. (1)  (used for ΔL_syn and by pytest)
# ---------------------------------------------------------------------------


def loglik_inhom_poisson(times, A, b, omega, t_end):
    lam = A * (b + np.sin(omega * np.pi * times))
    big_l = A * (b * t_end + (1.0 - np.cos(omega * np.pi * t_end)) / (omega * np.pi))
    return float(np.sum(np.log(np.maximum(lam, 1e-12))) - big_l)


def loglik_hawkes(times, mu, alpha, beta, t_end):
    ll, s, prev = 0.0, 0.0, 0.0
    for t in times:
        s *= np.exp(-beta * (t - prev))
        ll += np.log(max(mu + alpha * s, 1e-12))
        s += 1.0
        prev = t
    comp = mu * t_end + (alpha / beta) * np.sum(1.0 - np.exp(-beta * (t_end - times)))
    return float(ll - comp)


def loglik_multi_hawkes(times, types, mu, alpha, beta, t_end):
    mu = np.asarray(mu, float)
    alpha = np.asarray(alpha, float)
    k = len(mu)
    s = np.zeros(k)
    ll, prev = 0.0, 0.0
    for t, j in zip(times, types):
        s = s * np.exp(-beta * (t - prev))
        lam_j = mu[j] + float(alpha[j] @ s)
        ll += np.log(max(lam_j, 1e-12))
        s[j] += 1.0
        prev = t
    comp = float(np.sum(mu) * t_end)
    # ∫ Σ_j α_{ji} e^{-β(t-t_i)} dt = (Σ_j α_{ji})/β · (1 - e^{-β(T-t_i)})
    col = alpha.sum(axis=0)  # total outgoing excitation per cause
    for t, j in zip(times, types):
        comp += col[j] / beta * (1.0 - np.exp(-beta * (t_end - t)))
    return float(ll - comp)


def ground_truth_loglik(cfg: DatasetCfg, times, types) -> float:
    p = cfg.params
    if cfg.kind == "poisson":
        return loglik_inhom_poisson(times, p["A"], p["b"], p["omega"], cfg.t_end)
    if cfg.kind == "hawkes":
        return loglik_hawkes(times, p["mu"], p["alpha"], p["beta"], cfg.t_end)
    return loglik_multi_hawkes(
        times, types, np.asarray(p["mu"]), np.asarray(p["alpha"]), p["beta"], cfg.t_end
    )


# ---------------------------------------------------------------------------
# Batching into fixed-shape training tensors
# ---------------------------------------------------------------------------


def crops_to_batch(
    seqs: List[Seq],
    idxs: np.ndarray,
    crop_len: int,
    bos_id: int,
    rng: np.random.Generator,
):
    """Random contiguous crops of ``crop_len - 1`` events + BOS row.

    Returns ``times f32[B, crop_len]``, ``types i32[B, crop_len]``,
    ``length i32[B]`` (incl. BOS), ``t_end f32[B]``.

    The BOS carries the crop's start time so absolute-time encodings stay in
    the window's range; the survival horizon is the next event after the crop
    (or the sequence end for suffix crops).
    """
    b = len(idxs)
    times = np.zeros((b, crop_len), np.float32)
    types = np.full((b, crop_len), bos_id, np.int32)
    length = np.zeros(b, np.int32)
    t_end = np.zeros(b, np.float32)
    for r, i in enumerate(idxs):
        ts, ks = seqs[i]
        n = len(ts)
        max_events = crop_len - 1
        if n <= max_events:
            lo, hi = 0, n
        else:
            lo = int(rng.integers(0, n - max_events + 1))
            hi = lo + max_events
        m = hi - lo
        bos_t = ts[lo - 1] if lo > 0 else 0.0
        times[r, 0] = bos_t
        times[r, 1 : m + 1] = ts[lo:hi]
        types[r, 1 : m + 1] = ks[lo:hi]
        length[r] = m + 1
        if hi < n:
            t_end[r] = ts[hi]  # censor at the next event
        else:
            t_end[r] = max(ts[-1] if n else 0.0, bos_t) + 1e-3
    return times, types, length, t_end
