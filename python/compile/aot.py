"""AOT export: lower the L2 forward pass to HLO **text** + manifest JSON.

HLO text (not a serialized ``HloModuleProto``) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
(the version behind the Rust ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

One HLO file per *shape* configuration ``(encoder, size, bucket, batch)``;
model weights are HLO **parameters** supplied at run time from the trained
``.npz``, so 42 trained models share 48 compiled graphs.
"""

from __future__ import annotations

import json
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import config, model
from .config import ModelSize


def lower_forward_hlo(
    encoder: str,
    size: ModelSize,
    bucket: int,
    batch: int,
    *,
    use_pallas: bool = True,
) -> Tuple[str, Dict]:
    """Lower ``forward`` for one shape config; returns (hlo_text, manifest)."""
    params = model.init_params(encoder, size, seed=0)
    names = model.params_names(params)
    specs = [jax.ShapeDtypeStruct(v.shape, v.dtype) for _, v in params]

    def fn(*args):
        vals = args[: len(names)]
        times, types, length = args[len(names) :]
        return model.forward(
            encoder, size, vals, names, times, types, length, use_pallas=use_pallas
        )

    in_specs = specs + [
        jax.ShapeDtypeStruct((batch, bucket), jnp.float32),
        jax.ShapeDtypeStruct((batch, bucket), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    ]
    lowered = jax.jit(fn).lower(*in_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    hlo_text = comp.as_hlo_text()

    manifest = {
        "kind": "forward",
        "encoder": encoder,
        "size": {
            "name": size.name,
            "n_layers": size.n_layers,
            "n_heads": size.n_heads,
            "d_model": size.d_model,
            "n_mix": size.n_mix,
            "d_ff": size.d_ff,
        },
        "bucket": bucket,
        "batch": batch,
        "k_max": config.K_MAX,
        "bos_id": config.BOS_ID,
        "impl": "pallas" if use_pallas else "ref",
        "params": [
            {"name": n, "shape": list(v.shape), "dtype": str(v.dtype)}
            for n, v in params
        ],
        "inputs": [
            {"name": "times", "shape": [batch, bucket], "dtype": "float32"},
            {"name": "types", "shape": [batch, bucket], "dtype": "int32"},
            {"name": "length", "shape": [batch], "dtype": "int32"},
        ],
        "outputs": [
            {"name": "log_w", "shape": [batch, bucket, size.n_mix]},
            {"name": "mu", "shape": [batch, bucket, size.n_mix]},
            {"name": "log_sigma", "shape": [batch, bucket, size.n_mix]},
            {"name": "type_logits", "shape": [batch, bucket, config.K_MAX]},
        ],
    }
    return hlo_text, manifest


def artifact_stem(encoder: str, size_name: str, bucket: int, batch: int) -> str:
    return f"fwd_{encoder}_{size_name}_L{bucket}_B{batch}"


def export_forward(
    out_dir: str,
    encoder: str,
    size: ModelSize,
    bucket: int,
    batch: int,
    *,
    use_pallas: bool = True,
) -> str:
    import os

    hlo, manifest = lower_forward_hlo(
        encoder, size, bucket, batch, use_pallas=use_pallas
    )
    stem = artifact_stem(encoder, size.name, bucket, batch)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, stem + ".hlo.txt"), "w") as f:
        f.write(hlo)
    with open(os.path.join(out_dir, stem + ".manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return stem
