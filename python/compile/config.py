"""Configuration single-source-of-truth for the TPP-SD build pipeline.

Everything the Rust coordinator needs to know about model shapes, datasets
and artifact layout is defined here and exported to ``artifacts/*.json`` by
``build_all.py`` so the two languages can never drift apart.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

# ---------------------------------------------------------------------------
# Global shape constants
# ---------------------------------------------------------------------------

#: Event-type dimension every artifact is padded to.  Rust soft-maxes only the
#: first ``K`` logits of a dataset with ``K`` real types.
K_MAX = 24

#: BOS (beginning-of-sequence) token id.  The type vocabulary therefore has
#: ``K_MAX + 1`` entries.
BOS_ID = K_MAX

#: Sequence-length buckets the forward pass is AOT-compiled for.  The Rust
#: executor picks the smallest bucket that fits the current context.
BUCKETS = (64, 128, 256, 512)

#: Batch sizes the forward pass is AOT-compiled for.  B=1 serves the latency
#: path, B=8 the coordinator's batched executor.
BATCH_SIZES = (1, 8)

ENCODERS = ("thp", "sahp", "attnhp")


# ---------------------------------------------------------------------------
# Model size configurations
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelSize:
    """Shape configuration of one CDF-based Transformer TPP.

    The paper trains an 8-head/20-layer target and a 1-head/1-layer draft
    (D=64, M=64) on an RTX 4090; on this single-core CPU container we keep
    the same *draft/target asymmetry* at reduced scale (see DESIGN.md §3).
    """

    name: str
    n_layers: int
    n_heads: int
    d_model: int
    n_mix: int  # M: log-normal mixture components
    d_ff: int  # FFN hidden width (THP/SAHP blocks only)

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


#: Default size ladder.  ``target`` vs ``draft`` drives the headline speedup;
#: ``draft2``/``draft3`` reproduce the draft-model-size ablation (Table 3/4).
SIZES: Dict[str, ModelSize] = {
    "target": ModelSize("target", n_layers=6, n_heads=4, d_model=32, n_mix=8, d_ff=64),
    "draft": ModelSize("draft", n_layers=1, n_heads=1, d_model=16, n_mix=8, d_ff=32),
    "draft2": ModelSize("draft2", n_layers=2, n_heads=2, d_model=16, n_mix=8, d_ff=32),
    "draft3": ModelSize("draft3", n_layers=4, n_heads=4, d_model=32, n_mix=8, d_ff=64),
}

#: Paper-scale configuration (documented, not built by default on CPU).
PAPER_SIZES: Dict[str, ModelSize] = {
    "target": ModelSize("target", 20, 8, 64, 64, 256),
    "draft": ModelSize("draft", 1, 1, 64, 64, 256),
}


# ---------------------------------------------------------------------------
# Dataset configurations
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DatasetCfg:
    """One dataset: either a paper synthetic process or a simulated stand-in
    for a paper real-world dataset (repro substitution, DESIGN.md §3)."""

    name: str
    kind: str  # "poisson" | "hawkes" | "multihawkes"
    num_types: int
    t_end: float
    params: Dict[str, object] = field(default_factory=dict)
    #: number of training sequences simulated (paper: 1000; reduced for CPU)
    n_train: int = 120
    n_val: int = 16


def _kd_hawkes(name: str, k: int, seed: int, total_rate: float) -> DatasetCfg:
    """Simulated stand-in for a real dataset: a K-dim Hawkes process with
    heterogeneous (power-law-ish) base rates and a sparse excitation matrix.

    Deterministic given ``seed`` — the same parameters are re-created inside
    Rust from the exported JSON, so ground-truth computations agree.
    """
    # Power-law type masses, normalized so the *base* rate sums to
    # ``0.6 * total_rate`` (excitation supplies the rest, branching ratio .4).
    masses = [(i + 1.0) ** -0.8 for i in range(k)]
    s = sum(masses)
    mu = [0.6 * total_rate * m / s for m in masses]
    # Sparse excitation: self-excitation for every type plus a ring coupling.
    beta = 3.0
    alpha = [[0.0] * k for _ in range(k)]
    for i in range(k):
        alpha[i][i] = 0.3 * beta  # branching contribution 0.3 from self
        alpha[(i + 1) % k][i] = 0.1 * beta  # and 0.1 from the next type
    return DatasetCfg(
        name=name,
        kind="multihawkes",
        num_types=k,
        t_end=100.0,
        params={"mu": mu, "alpha": alpha, "beta": beta, "seed": seed},
    )


DATASETS: Dict[str, DatasetCfg] = {
    # --- paper synthetic datasets (Appendix B.1, exact parameters) ---
    "poisson": DatasetCfg(
        "poisson", "poisson", 1, 100.0, {"A": 5.0, "b": 1.0, "omega": 1.0 / 50.0}
    ),
    "hawkes": DatasetCfg(
        "hawkes", "hawkes", 1, 100.0, {"mu": 2.5, "alpha": 1.0, "beta": 2.0}
    ),
    "multihawkes": DatasetCfg(
        "multihawkes",
        "multihawkes",
        2,
        100.0,
        {
            "mu": [0.4, 0.4],
            "alpha": [[1.0, 0.5], [0.1, 1.0]],
            "beta": 2.0,
        },
    ),
    # --- simulated stand-ins for the paper's real datasets (DESIGN.md §3) ---
    "taobao_sim": _kd_hawkes("taobao_sim", 17, seed=17, total_rate=2.5),
    "amazon_sim": _kd_hawkes("amazon_sim", 16, seed=16, total_rate=2.0),
    "taxi_sim": _kd_hawkes("taxi_sim", 10, seed=10, total_rate=2.0),
    "stackoverflow_sim": _kd_hawkes("stackoverflow_sim", 22, seed=22, total_rate=1.5),
}

SYNTHETIC = ("poisson", "hawkes", "multihawkes")
REAL_SIM = ("taobao_sim", "amazon_sim", "taxi_sim", "stackoverflow_sim")

#: (dataset, size) pairs trained by the default build.  Every dataset gets a
#: target + draft per encoder; the ablation datasets additionally get the
#: bigger draft configurations of Table 3/4.
def training_matrix() -> List[Tuple[str, str, str]]:
    jobs: List[Tuple[str, str, str]] = []
    for ds in list(SYNTHETIC) + list(REAL_SIM):
        for enc in ENCODERS:
            jobs.append((ds, enc, "target"))
            jobs.append((ds, enc, "draft"))
    for ds in ("multihawkes", "taobao_sim"):  # Table 3/4 ablation
        for enc in ENCODERS:
            jobs.append((ds, enc, "draft2"))
            jobs.append((ds, enc, "draft3"))
    return jobs


# ---------------------------------------------------------------------------
# Training hyper-parameters
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainCfg:
    steps: int = 400
    batch: int = 4
    crop_len: int = 160  # training crops; export length is per-bucket
    lr: float = 1e-3
    seed: int = 0
    # Adam moments
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8


TRAIN = TrainCfg()


# ---------------------------------------------------------------------------
# JSON export helpers
# ---------------------------------------------------------------------------


def export_json() -> str:
    """The blob written to ``artifacts/datasets.json`` for the Rust side."""
    out = {
        "k_max": K_MAX,
        "bos_id": BOS_ID,
        "buckets": list(BUCKETS),
        "batch_sizes": list(BATCH_SIZES),
        "encoders": list(ENCODERS),
        "sizes": {k: dataclasses.asdict(v) for k, v in SIZES.items()},
        "datasets": {k: dataclasses.asdict(v) for k, v in DATASETS.items()},
    }
    return json.dumps(out, indent=1, sort_keys=True)
