//! Rolling context window: Transformer TPPs condition on unbounded history,
//! but the AOT graphs have a maximum bucket. When a sequence outgrows the
//! largest bucket (minus the draft margin), the oldest half of the window is
//! dropped and the BOS row inherits the last dropped event's timestamp — the
//! standard sliding-window approximation, applied identically to AR and SD
//! so their comparison stays apples-to-apples.

use crate::events::Event;
use crate::runtime::{SeqDelta, SeqInput};

/// The rolling context window shared by AR and SD sampling.
#[derive(Debug, Clone)]
pub struct Context {
    /// time carried by the BOS row (start of the current window)
    pub t0: f64,
    /// events inside the window (absolute times)
    pub window: Vec<Event>,
    /// max model positions = bucket capacity (incl. BOS)
    capacity: usize,
    /// positions reserved for draft candidates (γ for SD, 0 for AR)
    margin: usize,
    /// total events ever pushed (window may be smaller)
    pub total_events: usize,
    /// number of window truncations performed
    pub truncations: usize,
}

impl Context {
    /// Empty window with `capacity` model positions, `margin` of which are
    /// reserved for draft candidates.
    pub fn new(capacity: usize, margin: usize) -> Context {
        assert!(capacity >= 2 * (margin + 2), "capacity too small for margin");
        Context {
            t0: 0.0,
            window: Vec::new(),
            capacity,
            margin,
            total_events: 0,
            truncations: 0,
        }
    }

    /// Last event time (or window start if empty).
    pub fn last_time(&self) -> f64 {
        self.window.last().map(|e| e.t).unwrap_or(self.t0)
    }

    /// Events currently in the window.
    pub fn len(&self) -> usize {
        self.window.len()
    }

    /// True when no events are in the window.
    pub fn is_empty(&self) -> bool {
        self.window.is_empty()
    }

    /// Append one accepted event, sliding the window if the *next* round
    /// (current events + BOS + margin + 1) would overflow the capacity.
    pub fn push(&mut self, e: Event) {
        debug_assert!(e.t >= self.last_time());
        self.window.push(e);
        self.total_events += 1;
        if self.window.len() + 1 + self.margin + 1 > self.capacity {
            self.slide();
        }
    }

    /// The explicit window-slide story (DESIGN.md §12): drop the oldest
    /// half of the window and hand the BOS row the last dropped event's
    /// timestamp. Every slide bumps [`Context::epoch`] — cached-forward
    /// cursors watch it, because a slide renumbers window positions and
    /// moves `t0`, invalidating every stream checkpoint at once.
    fn slide(&mut self) {
        let keep_from = self.window.len() / 2;
        self.t0 = self.window[keep_from - 1].t;
        self.window.drain(..keep_from);
        self.truncations += 1;
    }

    /// Number of window slides so far. Monotone; sessions snapshot it to
    /// detect that their incremental-forward cursors went stale.
    pub fn epoch(&self) -> usize {
        self.truncations
    }

    /// Model input for the current window plus `extra` candidate events.
    pub fn seq_input(&self, extra: &[Event]) -> SeqInput {
        let mut times = Vec::with_capacity(self.window.len() + extra.len());
        let mut types = Vec::with_capacity(self.window.len() + extra.len());
        for e in self.window.iter().chain(extra) {
            times.push(e.t);
            types.push(e.k);
        }
        SeqInput { t0: self.t0, times, types }
    }

    /// Delta form of [`Context::seq_input`] against a stream that has
    /// already committed the first `base_len` events of (window ++ extra):
    /// carries only the events past `base_len`. O(new events), which is
    /// what makes cached sampling O(1) per event.
    pub fn seq_delta(&self, extra: &[Event], base_len: usize) -> SeqDelta {
        let mut out = SeqDelta::default();
        self.seq_delta_into(extra, base_len, &mut out);
        out
    }

    /// [`Context::seq_delta`] into a caller-owned scratch delta, reusing
    /// its `times`/`types` capacity — the steady-state sampling loops call
    /// this once per wave, so the per-event hot path allocates nothing
    /// (DESIGN.md §14). Field-for-field identical to `seq_delta`.
    pub fn seq_delta_into(&self, extra: &[Event], base_len: usize, out: &mut SeqDelta) {
        let w = self.window.len();
        debug_assert!(base_len <= w + extra.len(), "cursor {base_len} beyond input");
        out.base_len = base_len;
        out.t0 = self.t0;
        out.times.clear();
        out.types.clear();
        let it = self
            .window
            .iter()
            .skip(base_len.min(w))
            .chain(extra.iter().skip(base_len.saturating_sub(w)));
        for e in it {
            out.times.push(e.t);
            out.types.push(e.k);
        }
    }

    /// Output row that parameterizes the next event's distribution when
    /// `extra` candidates are appended: row (BOS + window + extra) − 1.
    pub fn next_row(&self, n_extra: usize) -> usize {
        self.window.len() + n_extra
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_then_truncates() {
        let mut c = Context::new(16, 2);
        for i in 0..14 {
            c.push(Event::new(i as f64 + 1.0, 0));
        }
        assert!(c.window.len() + 1 + 2 + 1 <= 16);
        assert!(c.truncations >= 1);
        assert_eq!(c.total_events, 14);
        // t0 = last dropped event's time
        assert!(c.t0 > 0.0);
        assert!(c.window[0].t > c.t0);
    }

    #[test]
    fn seq_input_layout() {
        let mut c = Context::new(64, 4);
        c.push(Event::new(1.0, 3));
        c.push(Event::new(2.0, 1));
        let s = c.seq_input(&[Event::new(2.5, 0)]);
        assert_eq!(s.times, vec![1.0, 2.0, 2.5]);
        assert_eq!(s.types, vec![3, 1, 0]);
        assert_eq!(s.t0, 0.0);
        assert_eq!(c.next_row(1), 3);
        assert_eq!(s.len_with_bos(), 4);
    }

    #[test]
    fn seq_delta_carries_only_new_events() {
        let mut c = Context::new(64, 4);
        c.push(Event::new(1.0, 3));
        c.push(Event::new(2.0, 1));
        let extra = [Event::new(2.5, 0), Event::new(3.0, 2)];
        // cursor inside the window
        let d = c.seq_delta(&extra, 1);
        assert_eq!(d.base_len, 1);
        assert_eq!(d.times, vec![2.0, 2.5, 3.0]);
        assert_eq!(d.types, vec![1, 0, 2]);
        // cursor inside the extras
        let d = c.seq_delta(&extra, 3);
        assert_eq!(d.times, vec![3.0]);
        assert_eq!(d.types, vec![2]);
        // cursor at the full length: empty delta
        let d = c.seq_delta(&extra, 4);
        assert!(d.times.is_empty());
        assert_eq!(d.full_len(), 4);
        // consistency with the full input
        let full = c.seq_input(&extra);
        let d0 = c.seq_delta(&extra, 0);
        assert_eq!(d0.times, full.times);
        assert_eq!(d0.t0, full.t0);
    }

    #[test]
    fn epoch_counts_slides() {
        let mut c = Context::new(16, 2);
        assert_eq!(c.epoch(), 0);
        for i in 0..14 {
            c.push(Event::new(i as f64 + 1.0, 0));
        }
        assert!(c.epoch() >= 1);
        assert_eq!(c.epoch(), c.truncations);
    }

    #[test]
    fn last_time_tracks_window_start_after_truncation() {
        let mut c = Context::new(12, 1);
        for i in 0..20 {
            c.push(Event::new(i as f64, 0));
        }
        assert!(c.last_time() >= c.t0);
    }
}
