//! Sampling engines: baseline autoregressive sampling (`ar`), speculative
//! decoding (`sd`, the paper's contribution), the rolling context window
//! shared by both, and the fleet engine (`engine`) that drives many
//! resumable sampling sessions in lockstep over batched forwards.
//!
//! The classical thinning sampler — the third algorithm the paper discusses
//! (§2.2, App. D.1) — lives with the ground-truth processes as
//! [`crate::processes::GroundTruth::simulate`]: thinning needs a CIF, which
//! the analytic processes have and the CDF-parameterized Transformer model
//! deliberately does not (that is the paper's App. D.1 argument).

pub mod ar;
pub mod context;
pub mod engine;
pub mod sd;

pub use ar::{sample_ar, ArSession, SampleCfg};
pub use context::Context;
pub use engine::{
    fleet_seeds, sample_ar_fleet, sample_sd_fleet, AnySession, FleetRuns, FleetSession,
    FleetStats, ModelRole, Retired, SessionPool,
};
pub use sd::{sample_sd, Gamma, SdCfg, SdPhase, SdSession};

use std::time::Duration;

/// Counters every sampling run reports (speedup, acceptance rate α,
/// forward-pass budgets — the quantities in Tables 1–4).
#[derive(Debug, Clone, Default)]
pub struct SampleStats {
    /// events generated inside the window
    pub events: usize,
    /// SD rounds (or AR iterations) executed
    pub rounds: usize,
    /// forward passes of the target model
    pub target_forwards: usize,
    /// forward passes of the draft model
    pub draft_forwards: usize,
    /// candidates proposed by the draft model
    pub drafted: usize,
    /// candidates fully accepted (τ and k)
    pub accepted: usize,
    /// events re-drawn from adjusted distributions
    pub resampled: usize,
    /// bonus events after all-accepted rounds
    pub bonus: usize,
    /// proposals consumed by Theorem-1 rejection loops
    pub adjust_proposals: usize,
    /// wall-clock time of the run
    pub wall: Duration,
}

impl SampleStats {
    /// Paper §5.4: α = #accepted / #drafted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            f64::NAN
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Merge counters from another run (for per-dataset aggregation).
    pub fn merge(&mut self, other: &SampleStats) {
        self.events += other.events;
        self.rounds += other.rounds;
        self.target_forwards += other.target_forwards;
        self.draft_forwards += other.draft_forwards;
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.resampled += other.resampled;
        self.bonus += other.bonus;
        self.adjust_proposals += other.adjust_proposals;
        self.wall += other.wall;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acceptance_rate_and_merge() {
        let mut a = SampleStats { drafted: 10, accepted: 7, ..Default::default() };
        let b = SampleStats { drafted: 10, accepted: 3, ..Default::default() };
        assert!((a.acceptance_rate() - 0.7).abs() < 1e-12);
        a.merge(&b);
        assert!((a.acceptance_rate() - 0.5).abs() < 1e-12);
        assert!(SampleStats::default().acceptance_rate().is_nan());
    }
}
