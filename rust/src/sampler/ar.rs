//! Baseline autoregressive (AR) sampling from the target model (paper
//! §4.2 "Naïve autoregressive sampling"): one target forward pass per
//! generated event.
//!
//! Since the fleet-engine refactor (DESIGN.md §11) the sampling loop is a
//! resumable state machine, [`ArSession`]: it *yields* the [`SeqInput`] its
//! next step needs instead of calling the model, and [`ArSession::advance`]
//! consumes the forward result. [`sample_ar`] is the blocking single-
//! sequence driver over that state machine;
//! [`super::engine::sample_ar_fleet`] drives many sessions in lockstep,
//! co-batching their forwards.

use std::time::Instant;

use anyhow::Result;

use crate::events::Event;
use crate::model::mixture::{Mixture, TypeDist};
use crate::runtime::{Forward, SeqDelta, SeqInput, SlotOut, StreamGuard};
use crate::telemetry::{self, Stage};
use crate::util::rng::Rng;

use super::context::Context;
use super::SampleStats;

/// Configuration shared by the samplers.
#[derive(Debug, Clone)]
pub struct SampleCfg {
    /// number of real event types of the dataset (≤ K_MAX)
    pub num_types: usize,
    /// sampling window end T
    pub t_end: f64,
    /// hard cap on generated events (guards runaway intensity)
    pub max_events: usize,
}

impl Default for SampleCfg {
    fn default() -> Self {
        SampleCfg { num_types: 1, t_end: 100.0, max_events: 4096 }
    }
}

/// Resumable AR sampling state machine for ONE sequence: yields the model
/// input it needs via [`ArSession::pending_input`], consumes the forward
/// result via [`ArSession::advance`]. The session owns its RNG, so N
/// sessions driven in any interleaving produce exactly the event streams N
/// sequential [`sample_ar`] runs would.
#[derive(Debug)]
pub struct ArSession {
    cfg: SampleCfg,
    rng: Rng,
    ctx: Context,
    out: Vec<Event>,
    stats: SampleStats,
    done: bool,
    started: Instant,
    /// wall-clock of the last emitted event — feeds the `event_latency`
    /// telemetry stage (DESIGN.md §15); never read by sampling logic and
    /// never touches an RNG stream
    last_emit: Instant,
    /// events of the current window a cached-forward stream has committed
    /// (DESIGN.md §12); 0 until the first forward and after every slide
    cursor: usize,
    /// [`Context::epoch`] snapshot — a mismatch means the window slid and
    /// the stream must rebase
    seen_epoch: usize,
    /// scratch mixture the forward row is decoded into each step (reused
    /// capacity — the per-event hot path allocates nothing, DESIGN.md §14)
    mix: Mixture,
    /// scratch type distribution, same lifecycle as `mix`
    td: TypeDist,
}

impl ArSession {
    /// New session sampling one sequence; `cap` is the model's bucket
    /// capacity ([`Forward::max_bucket`]).
    pub fn new(cfg: SampleCfg, cap: usize, rng: Rng) -> ArSession {
        let mut s = ArSession {
            ctx: Context::new(cap, 0),
            out: Vec::new(),
            stats: SampleStats::default(),
            done: false,
            started: Instant::now(),
            last_emit: Instant::now(),
            cursor: 0,
            seen_epoch: 0,
            mix: Mixture::default(),
            td: TypeDist::default(),
            cfg,
            rng,
        };
        if s.cfg.max_events == 0 {
            s.finish();
        }
        s
    }

    /// The target-model input the next step needs, or `None` once done.
    pub fn pending_input(&self) -> Option<SeqInput> {
        if self.done {
            None
        } else {
            Some(self.ctx.seq_input(&[]))
        }
    }

    /// Delta form of [`ArSession::pending_input`] against the session's
    /// target stream: only the events the stream has not committed yet —
    /// O(1) per step on the cached path. `None` once done.
    pub fn pending_delta(&self) -> Option<SeqDelta> {
        if self.done {
            None
        } else {
            Some(self.ctx.seq_delta(&[], self.cursor))
        }
    }

    /// [`ArSession::pending_delta`] into a caller-owned scratch delta,
    /// reusing its capacity. Returns `false` (leaving `d` untouched) once
    /// done.
    pub fn pending_delta_into(&self, d: &mut SeqDelta) -> bool {
        if self.done {
            false
        } else {
            self.ctx.seq_delta_into(&[], self.cursor, d);
            true
        }
    }

    /// True once the sampling window closed or the event cap was hit.
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Feed the forward result for the pending input and run one AR step.
    /// No-op once done.
    pub fn advance(&mut self, fwd: &SlotOut) {
        if self.done {
            return;
        }
        self.stats.target_forwards += 1;
        // The forward consumed the whole pending input: on the cached
        // path, the stream is now committed through the current window.
        self.cursor = self.ctx.len();
        let row = self.ctx.next_row(0);
        fwd.mixture_into(row, &mut self.mix);
        fwd.type_dist_into(row, self.cfg.num_types, &mut self.td);
        let tau = self.mix.sample(&mut self.rng);
        let k = self.td.sample(&mut self.rng) as u32;
        let t = self.ctx.last_time() + tau;
        if t > self.cfg.t_end {
            self.finish();
            return;
        }
        let e = Event::new(t, k);
        self.out.push(e);
        self.ctx.push(e);
        // Telemetry (DESIGN.md §15): wall-clock gap between emitted
        // events. Only `Instant` + atomics — no sampler RNG is touched.
        if telemetry::enabled() {
            let now = Instant::now();
            telemetry::record_ns(
                Stage::EventLatency,
                now.duration_since(self.last_emit).as_nanos() as u64,
            );
            self.last_emit = now;
        }
        if self.ctx.epoch() != self.seen_epoch {
            // Window slid: stream checkpoints are stale — rebase from 0.
            self.seen_epoch = self.ctx.epoch();
            self.cursor = 0;
        }
        if self.out.len() >= self.cfg.max_events {
            self.finish();
        }
    }

    /// The session's RNG (used by [`sample_ar`] to hand the advanced
    /// stream back to its caller).
    pub fn rng(&self) -> &Rng {
        &self.rng
    }

    /// Forget everything the target stream had committed — the stream was
    /// lost or errored and its replacement starts empty (DESIGN.md §13).
    /// The next [`ArSession::pending_delta`] then carries `base_len == 0`
    /// and the full window: a *rebase*, the same move a window slide
    /// forces. Recovery consumes no RNG and recomputes identical rows, so
    /// sampled events are unchanged.
    pub fn rebase_stream(&mut self) {
        self.cursor = 0;
    }

    /// Consume the finished (or abandoned) session into its event stream
    /// and counters.
    pub fn into_output(mut self) -> (Vec<Event>, SampleStats) {
        if !self.done {
            self.finish();
        }
        (self.out, self.stats)
    }

    fn finish(&mut self) {
        self.stats.events = self.out.len();
        self.stats.wall = self.started.elapsed();
        self.done = true;
    }
}

/// Per-step cap on lost/errored-stream recovery attempts in the blocking
/// samplers before degrading to the uncached path (DESIGN.md §13).
pub(super) const STREAM_RECOVER_ATTEMPTS: usize = 4;

/// Sample one sequence autoregressively from `target` (blocking driver
/// over [`ArSession`]). Uses the backend's incremental stream when it has
/// one ([`Forward::cached`]), making each AR step O(1) instead of O(L);
/// the outputs are bit-identical either way (`rust/tests/cached_forward.rs`).
///
/// Fault tolerance (DESIGN.md §13): a lost or errored stream is replaced
/// by a fresh one and rebased from the session's full window; repeated
/// failures degrade the run to full-window forwards. Either way the rows
/// — and therefore the sampled events — are bit-identical to the
/// fault-free run.
pub fn sample_ar<F: Forward + ?Sized>(
    target: &F,
    cfg: &SampleCfg,
    rng: &mut Rng,
) -> Result<(Vec<Event>, SampleStats)> {
    let mut session = ArSession::new(cfg.clone(), target.max_bucket(), rng.clone());
    let mut stream = StreamGuard::open(target).unwrap_or(None);
    let mut dbuf = SeqDelta::default();
    while !session.is_done() {
        let mut tries = 0;
        let fwd_span = telemetry::Span::start(Stage::VerifyForward);
        let fwd = loop {
            match &stream {
                Some(g) => {
                    let filled = session.pending_delta_into(&mut dbuf);
                    assert!(filled, "pending delta");
                    match g.forward_delta(&dbuf) {
                        Ok(f) => break f,
                        Err(_) => {
                            // Stream lost/errored: rebase on a fresh
                            // stream, degrading to uncached when the
                            // failures persist.
                            let _recover = telemetry::Span::start(Stage::StreamRecovery);
                            tries += 1;
                            session.rebase_stream();
                            stream = if tries < STREAM_RECOVER_ATTEMPTS {
                                StreamGuard::open(target).unwrap_or(None)
                            } else {
                                None
                            };
                        }
                    }
                }
                None => break target.forward1(session.pending_input().expect("pending input"))?,
            }
        };
        drop(fwd_span);
        session.advance(&fwd);
    }
    *rng = session.rng().clone();
    Ok(session.into_output())
}
