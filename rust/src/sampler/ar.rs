//! Baseline autoregressive (AR) sampling from the target model (paper
//! §4.2 "Naïve autoregressive sampling"): one target forward pass per
//! generated event.

use anyhow::Result;

use crate::events::Event;
use crate::runtime::Forward;
use crate::util::rng::Rng;

use super::context::Context;
use super::SampleStats;

/// Configuration shared by the samplers.
#[derive(Debug, Clone)]
pub struct SampleCfg {
    /// number of real event types of the dataset (≤ K_MAX)
    pub num_types: usize,
    /// sampling window end T
    pub t_end: f64,
    /// hard cap on generated events (guards runaway intensity)
    pub max_events: usize,
}

impl Default for SampleCfg {
    fn default() -> Self {
        SampleCfg { num_types: 1, t_end: 100.0, max_events: 4096 }
    }
}

/// Sample one sequence autoregressively from `target`.
pub fn sample_ar<F: Forward + ?Sized>(
    target: &F,
    cfg: &SampleCfg,
    rng: &mut Rng,
) -> Result<(Vec<Event>, SampleStats)> {
    let mut ctx = Context::new(target.max_bucket(), 0);
    let mut out = Vec::new();
    let mut stats = SampleStats::default();
    let t_start = std::time::Instant::now();

    while out.len() < cfg.max_events {
        let fwd = target.forward1(ctx.seq_input(&[]))?;
        stats.target_forwards += 1;
        let row = ctx.next_row(0);
        let tau = fwd.mixture(row).sample(rng);
        let k = fwd.type_dist(row, cfg.num_types).sample(rng) as u32;
        let t = ctx.last_time() + tau;
        if t > cfg.t_end {
            break;
        }
        let e = Event::new(t, k);
        out.push(e);
        ctx.push(e);
    }
    stats.events = out.len();
    stats.wall = t_start.elapsed();
    Ok((out, stats))
}
