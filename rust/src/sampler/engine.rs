//! Fleet sampling engine (DESIGN.md §11): drive N per-sequence sampling
//! state machines in lockstep, co-batching their model forwards.
//!
//! The blocking samplers ([`sample_ar`](super::sample_ar),
//! [`sample_sd`](super::sample_sd)) issue one
//! [`crate::runtime::Forward::forward1`] per step, so a host serving many
//! sequences fills its B=8 batch capacity only
//! by accidental collisions between independent clients. The engine makes
//! the sampler itself batchable — the vLLM-style continuous-batching move,
//! transplanted to TPP sampling: each sequence is a resumable session
//! ([`SdSession`] / [`ArSession`]) that *yields* the [`SeqInput`] its next
//! phase needs, and each engine step gathers all live sessions' pending
//! inputs, groups them by the model that must run them (draft steps
//! co-batched across sequences, verify passes co-batched across
//! sequences), issues ONE [`BatchForward::forward_batch`] call per group
//! (chunked at the model's batch capacity), and fans the slots back into
//! the sessions.
//!
//! **RNG isolation** (the bit-for-bit argument): every session owns its
//! proposal and decision streams, seeded per sequence, and the backend
//! contract guarantees batched rows equal single-sequence rows exactly —
//! so the fleet's per-sequence outputs and [`SampleStats`] are identical
//! to running the blocking samplers sequentially with the same seeds, for
//! every fleet size and interleaving. Property-tested in
//! `rust/tests/fleet.rs`.
//!
//! **Incremental streams** (DESIGN.md §12): when a role's model exposes
//! [`CachedForward`], the engine opens one stream per session and ships
//! [`SeqDelta`]s instead of full windows — each draft step then carries
//! one event rather than the whole history, and the deltas of a wave
//! co-batch just like full inputs. Rows are bit-identical on both paths
//! (`rust/tests/cached_forward.rs`), so caching never moves a
//! probability either.
//!
//! **Fault tolerance** (DESIGN.md §13): a failed wave is isolated — each
//! member re-runs alone — and a lost or errored stream is replaced and
//! rebased from the session's full window ([`recover_delta`]); sessions
//! whose streams keep dying degrade to full-window forwards. All of it is
//! invisible in the outputs (forwards are pure and consume no sampler
//! randomness) and visible in [`FleetStats::stream_recoveries`] /
//! [`FleetStats::degraded_uncached`]. Property-tested in
//! `rust/tests/chaos.rs`.

use anyhow::{ensure, Result};

use crate::events::Event;
use crate::runtime::{
    pool, BatchForward, CachedForward, Forward as _, SeqDelta, SeqInput, SlotOut, StreamId,
};
use crate::telemetry;
use crate::util::rng::Rng;

use super::ar::{ArSession, SampleCfg, STREAM_RECOVER_ATTEMPTS};
use super::sd::{SdCfg, SdSession};
use super::SampleStats;

/// Which of the two models a session's pending forward must run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelRole {
    /// the small drafting model
    Draft,
    /// the big verified model
    Target,
}

/// A resumable per-sequence sampling state machine the engine can drive:
/// it yields inputs (full or delta form), names the model that must run
/// them, and consumes the forward results. Implemented by [`SdSession`]
/// and [`ArSession`].
pub trait FleetSession {
    /// Which model the pending input is for (only consulted while the
    /// session is not done).
    fn role(&self) -> ModelRole;

    /// True once the session needs no more forwards.
    fn is_done(&self) -> bool;

    /// The model input the next step needs, or `None` once done.
    fn pending_input(&self) -> Option<SeqInput>;

    /// The pending input as a delta against the [`FleetSession::role`]
    /// model's incremental stream (only consulted when that model has
    /// one), or `None` once done.
    fn pending_delta(&self) -> Option<SeqDelta>;

    /// Feed the forward result for the pending input and advance.
    fn advance(&mut self, fwd: &SlotOut);

    /// Forget everything `role`'s incremental stream had committed (the
    /// stream was lost or errored; its replacement starts empty): the
    /// next [`FleetSession::pending_delta`] for that role must rebase
    /// with `base_len == 0` and the full window (DESIGN.md §13).
    fn rebase(&mut self, role: ModelRole);

    /// Consume the session into its event stream and counters.
    fn into_output(self) -> (Vec<Event>, SampleStats);
}

impl FleetSession for SdSession {
    fn role(&self) -> ModelRole {
        SdSession::role(self)
    }

    fn is_done(&self) -> bool {
        SdSession::is_done(self)
    }

    fn pending_input(&self) -> Option<SeqInput> {
        SdSession::pending_input(self)
    }

    fn pending_delta(&self) -> Option<SeqDelta> {
        SdSession::pending_delta(self)
    }

    fn advance(&mut self, fwd: &SlotOut) {
        SdSession::advance(self, fwd)
    }

    fn rebase(&mut self, role: ModelRole) {
        SdSession::rebase_stream(self, role)
    }

    fn into_output(self) -> (Vec<Event>, SampleStats) {
        SdSession::into_output(self)
    }
}

impl FleetSession for ArSession {
    fn role(&self) -> ModelRole {
        ModelRole::Target
    }

    fn is_done(&self) -> bool {
        ArSession::is_done(self)
    }

    fn pending_input(&self) -> Option<SeqInput> {
        ArSession::pending_input(self)
    }

    fn pending_delta(&self) -> Option<SeqDelta> {
        ArSession::pending_delta(self)
    }

    fn advance(&mut self, fwd: &SlotOut) {
        ArSession::advance(self, fwd)
    }

    fn rebase(&mut self, _role: ModelRole) {
        ArSession::rebase_stream(self)
    }

    fn into_output(self) -> (Vec<Event>, SampleStats) {
        ArSession::into_output(self)
    }
}

/// Engine-level counters of one fleet run: how well the per-sequence
/// forwards co-batched. (The per-sequence [`SampleStats`] still count
/// *logical* forwards — what the sequence consumed — so they aggregate
/// identically to sequential runs; the difference between the two views is
/// exactly the batching win.)
#[derive(Debug, Clone, Default)]
pub struct FleetStats {
    /// engine steps (gather → batch → fan-out cycles)
    pub steps: usize,
    /// batched draft-model calls issued (full-input and delta waves)
    pub draft_batches: usize,
    /// Σ sequences over draft batches
    pub draft_seqs: usize,
    /// batched target-model calls issued (full-input and delta waves)
    pub target_batches: usize,
    /// Σ sequences over target batches
    pub target_seqs: usize,
    /// of the batches above, how many were delta waves on incremental
    /// streams (the cached path; 0 on backends without [`CachedForward`])
    pub delta_batches: usize,
    /// Σ sequences over delta waves
    pub delta_seqs: usize,
    /// lost or errored incremental streams successfully replaced and
    /// rebased mid-run (DESIGN.md §13); the affected sequences' outputs
    /// are bit-identical to the fault-free run
    pub stream_recoveries: usize,
    /// sessions permanently degraded to full-window forwards after
    /// repeated stream failures — graceful degradation, not an error
    pub degraded_uncached: usize,
    /// worker-pool group dispatches during this run (DESIGN.md §14). The
    /// pool counters are process-wide, so concurrent fleet runs may
    /// cross-attribute; within a single run the delta is exact.
    pub pool_dispatches: usize,
    /// worker-pool job steals during this run
    pub pool_steals: usize,
    /// recycled output buffers served during this run
    pub buffers_reused: usize,
    /// freshly allocated output buffers during this run
    pub buffers_allocated: usize,
}

impl FleetStats {
    /// Mean sequences per batched draft call.
    pub fn draft_occupancy(&self) -> f64 {
        if self.draft_batches == 0 {
            0.0
        } else {
            self.draft_seqs as f64 / self.draft_batches as f64
        }
    }

    /// Mean sequences per batched target call.
    pub fn target_occupancy(&self) -> f64 {
        if self.target_batches == 0 {
            0.0
        } else {
            self.target_seqs as f64 / self.target_batches as f64
        }
    }
}

/// Per-sequence seeds of a fleet run: sequence `i` gets `base + i`, so
/// fleet sequence `i` is bit-for-bit the sequential run seeded `base + i`.
pub fn fleet_seeds(base: u64, n: usize) -> Vec<u64> {
    (0..n as u64).map(|i| base.wrapping_add(i)).collect()
}

/// One fleet run's per-sequence `(events, stats)` outputs, in seed order.
pub type FleetRuns = Vec<(Vec<Event>, SampleStats)>;

/// Sample `seeds.len()` sequences with TPP-SD on the fleet engine. Returns
/// one `(events, stats)` per seed (in order) — each bit-for-bit identical
/// to `sample_sd(target, draft, cfg, &mut Rng::new(seed))` — plus the
/// engine's batching counters.
pub fn sample_sd_fleet<FT, FD>(
    target: &FT,
    draft: &FD,
    cfg: &SdCfg,
    seeds: &[u64],
) -> Result<(FleetRuns, FleetStats)>
where
    FT: BatchForward + ?Sized,
    FD: BatchForward + ?Sized,
{
    let cap = target.max_bucket().min(draft.max_bucket());
    let mut sessions: Vec<SdSession> = seeds
        .iter()
        .map(|&s| SdSession::new(cfg.clone(), cap, Rng::new(s)))
        .collect();
    let fleet = drive(target, Some(draft), &mut sessions)?;
    Ok((sessions.into_iter().map(FleetSession::into_output).collect(), fleet))
}

/// Sample `seeds.len()` sequences autoregressively on the fleet engine.
/// Returns one `(events, stats)` per seed (in order) — each bit-for-bit
/// identical to `sample_ar(target, cfg, &mut Rng::new(seed))` — plus the
/// engine's batching counters.
pub fn sample_ar_fleet<FT>(
    target: &FT,
    cfg: &SampleCfg,
    seeds: &[u64],
) -> Result<(FleetRuns, FleetStats)>
where
    FT: BatchForward + ?Sized,
{
    let cap = target.max_bucket();
    let mut sessions: Vec<ArSession> = seeds
        .iter()
        .map(|&s| ArSession::new(cfg.clone(), cap, Rng::new(s)))
        .collect();
    let fleet = drive(target, None::<&FT>, &mut sessions)?;
    Ok((sessions.into_iter().map(FleetSession::into_output).collect(), fleet))
}

/// Per-session stream ids of one model role in a fleet run, opened lazily
/// on a [`CachedForward`] model. Streams of finished sessions are closed
/// eagerly; the `Drop` impl closes whatever is left, so an aborted drive
/// (forward error) cannot leak backend state.
///
/// Fault tolerance (DESIGN.md §13): opens retry up to
/// [`STREAM_RECOVER_ATTEMPTS`] times; a session whose stream keeps
/// failing is marked `dead` and degrades to full-window forwards for the
/// rest of the run (`degraded`), while successful replacements count into
/// `recovered`. Both tallies surface in [`FleetStats`].
struct RoleStreams<'a> {
    cached: Option<&'a dyn CachedForward>,
    ids: Vec<Option<StreamId>>,
    /// sessions degraded to full-window forwards; never retried
    dead: Vec<bool>,
    /// lost/errored streams successfully replaced and rebased
    recovered: usize,
    /// sessions that fell into `dead`
    degraded: usize,
}

impl<'a> RoleStreams<'a> {
    fn new(cached: Option<&'a dyn CachedForward>, n: usize) -> RoleStreams<'a> {
        RoleStreams {
            cached,
            ids: vec![None; n],
            dead: vec![false; n],
            recovered: 0,
            degraded: 0,
        }
    }

    /// Session `i`'s stream id, opening one on first use (with bounded
    /// retries); `None` when the role's model has no incremental-stream
    /// support or the session has degraded to the uncached path.
    fn stream_for(&mut self, i: usize) -> Option<StreamId> {
        let c = self.cached?;
        if self.dead[i] {
            return None;
        }
        if self.ids[i].is_none() {
            for _ in 0..STREAM_RECOVER_ATTEMPTS {
                if let Ok(id) = c.open_stream() {
                    self.ids[i] = Some(id);
                    break;
                }
            }
            if self.ids[i].is_none() {
                self.mark_dead(i);
            }
        }
        self.ids[i]
    }

    /// Release session `i`'s stream (idempotent).
    fn close(&mut self, i: usize) {
        if let (Some(c), Some(id)) = (self.cached, self.ids[i].take()) {
            c.close_stream(id);
        }
    }

    /// Degrade session `i` to full-window forwards for the rest of the
    /// run (idempotent).
    fn mark_dead(&mut self, i: usize) {
        self.close(i);
        if !self.dead[i] {
            self.dead[i] = true;
            self.degraded += 1;
        }
    }
}

impl Drop for RoleStreams<'_> {
    fn drop(&mut self) {
        if let Some(c) = self.cached {
            for id in self.ids.iter_mut().filter_map(Option::take) {
                c.close_stream(id);
            }
        }
    }
}

/// The engine loop: gather pending inputs from all live sessions, batch
/// them per model role, fan the slots back, repeat until every session is
/// done. `draft` may be `None` for fleets whose sessions only ever ask for
/// target forwards (AR).
///
/// Models exposing [`CachedForward`] are driven through per-session
/// incremental streams: each live session contributes a [`SeqDelta`]
/// instead of its full window, and the deltas of a role co-batch into
/// waves exactly like full inputs do (`delta_batches`/`delta_seqs` in
/// [`FleetStats`]). Backends without the trait — including the XLA
/// executor — fall back to full [`SeqInput`] forwards per session.
pub fn drive<FT, FD, S>(
    target: &FT,
    draft: Option<&FD>,
    sessions: &mut [S],
) -> Result<FleetStats>
where
    FT: BatchForward + ?Sized,
    FD: BatchForward + ?Sized,
    S: FleetSession,
{
    let mut fleet = FleetStats::default();
    let pool_before = pool::stats();
    let mut t_streams = RoleStreams::new(target.cached(), sessions.len());
    let mut d_streams = RoleStreams::new(draft.and_then(|d| d.cached()), sessions.len());
    // Gather buffers live across engine steps so the steady-state loop
    // reuses their capacity instead of reallocating every wave (§14).
    let mut draft_ids: Vec<usize> = Vec::new();
    let mut draft_in: Vec<SeqInput> = Vec::new();
    let mut draft_delta_ids: Vec<usize> = Vec::new();
    let mut draft_delta_in: Vec<(StreamId, SeqDelta)> = Vec::new();
    let mut target_ids: Vec<usize> = Vec::new();
    let mut target_in: Vec<SeqInput> = Vec::new();
    let mut target_delta_ids: Vec<usize> = Vec::new();
    let mut target_delta_in: Vec<(StreamId, SeqDelta)> = Vec::new();
    loop {
        draft_ids.clear();
        draft_in.clear();
        draft_delta_ids.clear();
        draft_delta_in.clear();
        target_ids.clear();
        target_in.clear();
        target_delta_ids.clear();
        target_delta_in.clear();
        for (i, s) in sessions.iter().enumerate() {
            if s.is_done() {
                t_streams.close(i);
                d_streams.close(i);
                continue;
            }
            match s.role() {
                ModelRole::Draft => match d_streams.stream_for(i) {
                    Some(sid) => {
                        draft_delta_ids.push(i);
                        draft_delta_in.push((sid, s.pending_delta().expect("pending delta")));
                    }
                    None => {
                        draft_ids.push(i);
                        draft_in.push(s.pending_input().expect("pending input"));
                    }
                },
                ModelRole::Target => match t_streams.stream_for(i) {
                    Some(sid) => {
                        target_delta_ids.push(i);
                        target_delta_in.push((sid, s.pending_delta().expect("pending delta")));
                    }
                    None => {
                        target_ids.push(i);
                        target_in.push(s.pending_input().expect("pending input"));
                    }
                },
            }
        }
        if draft_ids.is_empty()
            && draft_delta_ids.is_empty()
            && target_ids.is_empty()
            && target_delta_ids.is_empty()
        {
            fleet.stream_recoveries = t_streams.recovered + d_streams.recovered;
            fleet.degraded_uncached = t_streams.degraded + d_streams.degraded;
            let pd = pool::stats().since(&pool_before);
            fleet.pool_dispatches = pd.pool_dispatches;
            fleet.pool_steals = pd.pool_steals;
            fleet.buffers_reused = pd.buffers_reused;
            fleet.buffers_allocated = pd.buffers_allocated;
            return Ok(fleet);
        }
        fleet.steps += 1;
        if !draft_ids.is_empty() || !draft_delta_ids.is_empty() {
            let d = match draft {
                Some(d) => d,
                None => anyhow::bail!("sessions need a draft model, but the fleet has none"),
            };
            let role = run_role(
                d,
                &mut d_streams,
                ModelRole::Draft,
                &draft_ids,
                &mut draft_in,
                &draft_delta_ids,
                &mut draft_delta_in,
                sessions,
            )?;
            fleet.draft_batches += role.batches;
            fleet.draft_seqs += role.seqs;
            fleet.delta_batches += role.delta_batches;
            fleet.delta_seqs += role.delta_seqs;
        }
        if !target_ids.is_empty() || !target_delta_ids.is_empty() {
            let role = run_role(
                target,
                &mut t_streams,
                ModelRole::Target,
                &target_ids,
                &mut target_in,
                &target_delta_ids,
                &mut target_delta_in,
                sessions,
            )?;
            fleet.target_batches += role.batches;
            fleet.target_seqs += role.seqs;
            fleet.delta_batches += role.delta_batches;
            fleet.delta_seqs += role.delta_seqs;
        }
    }
}

/// The telemetry stage a role's forward waves are timed under.
fn role_stage(role: ModelRole) -> telemetry::Stage {
    match role {
        ModelRole::Draft => telemetry::Stage::DraftForward,
        ModelRole::Target => telemetry::Stage::VerifyForward,
    }
}

/// One engine step's batch counters for a single model role.
#[derive(Default)]
struct RoleCounters {
    batches: usize,
    seqs: usize,
    delta_batches: usize,
    delta_seqs: usize,
}

/// Run one role's gathered work — full inputs as batched forwards, deltas
/// as stream waves — and advance the owning sessions. One copy for both
/// roles, so their fan-out and accounting can never drift apart.
fn run_role<B, S>(
    model: &B,
    streams: &mut RoleStreams,
    role: ModelRole,
    full_ids: &[usize],
    full_in: &mut Vec<SeqInput>,
    delta_ids: &[usize],
    delta_in: &mut Vec<(StreamId, SeqDelta)>,
    sessions: &mut [S],
) -> Result<RoleCounters>
where
    B: BatchForward + ?Sized,
    S: FleetSession,
{
    let mut out = RoleCounters::default();
    if !full_ids.is_empty() {
        let (b, n) = fan_out(model, role, full_ids, full_in, sessions)?;
        out.batches += b;
        out.seqs += n;
    }
    if !delta_ids.is_empty() {
        let (b, n) = fan_out_delta(model, streams, role, delta_ids, delta_in, sessions)?;
        out.batches += b;
        out.seqs += n;
        out.delta_batches += b;
        out.delta_seqs += n;
    }
    Ok(out)
}

/// Run one role's gathered inputs through the model in `max_batch`-sized
/// chunks and advance the owning sessions. Returns (batches issued,
/// sequences forwarded).
///
/// A failed wave is isolated: each of its sequences re-runs alone with
/// bounded retries, so one faulty forward cannot sink its batchmates.
/// Forwards are pure (DESIGN.md §13), so re-run rows are bit-identical.
/// The gathered inputs move into the model un-cloned; the failure path
/// re-derives each one from its session (which has not advanced, so
/// [`FleetSession::pending_input`] rebuilds the identical input).
fn fan_out<B, S>(
    model: &B,
    role: ModelRole,
    ids: &[usize],
    inputs: &mut Vec<SeqInput>,
    sessions: &mut [S],
) -> Result<(usize, usize)>
where
    B: BatchForward + ?Sized,
    S: FleetSession,
{
    let cap = model.max_batch().max(1);
    let mut batches = 0;
    let mut start = 0;
    while start < ids.len() {
        let take = cap.min(ids.len() - start);
        let chunk: Vec<SeqInput> = inputs.drain(..take).collect();
        let t0 = telemetry::now_if_enabled();
        let served = model.forward_batch(chunk);
        telemetry::record_since(t0, &[role_stage(role)]);
        match served {
            Ok(outs) => {
                ensure!(
                    outs.len() == take,
                    "forward_batch returned {} slots for {} sequences",
                    outs.len(),
                    take
                );
                for (j, out) in outs.iter().enumerate() {
                    sessions[ids[start + j]].advance(out);
                }
            }
            Err(_) => {
                for j in 0..take {
                    let i = ids[start + j];
                    let seq = sessions[i].pending_input().expect("pending input");
                    let out = forward1_retry(model, seq)?;
                    sessions[i].advance(&out);
                }
            }
        }
        batches += 1;
        start += take;
    }
    Ok((batches, ids.len()))
}

/// `forward1` with up to [`STREAM_RECOVER_ATTEMPTS`] attempts, absorbing
/// transient faults on the direct (executor-less) path. Forwards are pure
/// and consume no sampler randomness, so every attempt computes the same
/// rows and a retry cannot move a probability.
fn forward1_retry<B>(model: &B, seq: SeqInput) -> Result<SlotOut>
where
    B: BatchForward + ?Sized,
{
    let mut last = None;
    for _ in 0..STREAM_RECOVER_ATTEMPTS {
        match model.forward1(seq.clone()) {
            Ok(out) => return Ok(out),
            Err(e) => last = Some(e),
        }
    }
    Err(last.expect("at least one forward attempt"))
}

/// Run one role's gathered stream deltas in `max_batch`-sized waves and
/// advance the owning sessions. A wave goes through
/// [`CachedForward::forward_delta_batch`], so the serving-path handle
/// enqueues it whole and the executor thread coalesces the deltas like a
/// batch. Returns (waves issued, sequences forwarded).
///
/// A failed wave is isolated per delta — deltas are idempotent (rewind to
/// `base_len`, then append), so re-running the ones the aborted wave had
/// already applied is safe. A delta that still fails alone means its
/// stream is lost; [`recover_delta`] replaces the stream, rebases the
/// session, and degrades to full-window forwards if streams keep dying.
fn fan_out_delta<B, S>(
    model: &B,
    streams: &mut RoleStreams,
    role: ModelRole,
    ids: &[usize],
    inputs: &mut Vec<(StreamId, SeqDelta)>,
    sessions: &mut [S],
) -> Result<(usize, usize)>
where
    B: BatchForward + ?Sized,
    S: FleetSession,
{
    let c = streams.cached.expect("delta gathered without a cached model");
    let cap = BatchForward::max_batch(model).max(1);
    let mut batches = 0;
    let mut start = 0;
    while start < ids.len() {
        let take = cap.min(ids.len() - start);
        let chunk: Vec<(StreamId, SeqDelta)> = inputs.drain(..take).collect();
        // The wave moves into the model un-cloned. If it fails, each
        // (stream, delta) pair is re-derived from its session: sessions
        // have not advanced and streams were not touched mid-wave, so
        // `stream_for` returns the same id and `pending_delta` rebuilds
        // the identical delta the wave carried.
        let t0 = telemetry::now_if_enabled();
        let served = c.forward_delta_batch(chunk);
        // One measured wave, recorded under both the issuing role's
        // forward stage and the shared delta-wave stage.
        telemetry::record_since(t0, &[role_stage(role), telemetry::Stage::DeltaWave]);
        match served {
            Ok(outs) => {
                ensure!(
                    outs.len() == take,
                    "forward_delta_batch returned {} slots for {} sequences",
                    outs.len(),
                    take
                );
                for (j, out) in outs.iter().enumerate() {
                    sessions[ids[start + j]].advance(out);
                }
            }
            Err(_) => {
                for j in 0..take {
                    let i = ids[start + j];
                    let sid = streams.stream_for(i).expect("stream lost mid-wave");
                    let delta = sessions[i].pending_delta().expect("pending delta");
                    let out = match c.forward_delta(sid, &delta) {
                        Ok(out) => out,
                        Err(_) => recover_delta(model, streams, role, i, sessions)?,
                    };
                    sessions[i].advance(&out);
                }
            }
        }
        batches += 1;
        start += take;
    }
    Ok((batches, ids.len()))
}

/// Recover session `i` after its `role` stream was lost or errored:
/// replace the stream, rebase the session onto it (`base_len == 0`, the
/// full window — the same move a window slide forces), and re-run the
/// forward. Streams that keep dying degrade the session to full-window
/// forwards for the rest of the run. Recovery consumes no sampler
/// randomness and forwards are pure, so the returned row — and therefore
/// every sampled event — is bit-identical to the fault-free run
/// (DESIGN.md §13; property-tested in `rust/tests/chaos.rs`).
fn recover_delta<B, S>(
    model: &B,
    streams: &mut RoleStreams,
    role: ModelRole,
    i: usize,
    sessions: &mut [S],
) -> Result<SlotOut>
where
    B: BatchForward + ?Sized,
    S: FleetSession,
{
    let _span = telemetry::Span::start(telemetry::Stage::StreamRecovery);
    streams.close(i);
    for _ in 0..STREAM_RECOVER_ATTEMPTS {
        let Some(sid) = streams.stream_for(i) else {
            break;
        };
        sessions[i].rebase(role);
        let delta = sessions[i].pending_delta().expect("pending delta");
        let c = streams.cached.expect("recovering a stream without a cached model");
        if let Ok(out) = c.forward_delta(sid, &delta) {
            streams.recovered += 1;
            return Ok(out);
        }
        streams.close(i);
    }
    streams.mark_dead(i);
    sessions[i].rebase(role);
    forward1_retry(model, sessions[i].pending_input().expect("pending input"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mock::MockModel;
    use crate::sampler::{sample_ar, sample_sd, Gamma};

    fn cfg() -> SdCfg {
        SdCfg {
            sample: SampleCfg { num_types: 4, t_end: 20.0, max_events: 2048 },
            gamma: Gamma::Fixed(5),
            ..Default::default()
        }
    }

    #[test]
    fn fleet_sd_equals_sequential_on_mocks() {
        let target = MockModel::default();
        let draft = MockModel { bias: 0.3, type_shift: 1, ..Default::default() };
        let seeds = fleet_seeds(11, 5);
        let (runs, fleet) = sample_sd_fleet(&target, &draft, &cfg(), &seeds).unwrap();
        assert_eq!(runs.len(), 5);
        assert!(fleet.steps > 0 && fleet.target_batches > 0);
        for (i, (ev, st)) in runs.iter().enumerate() {
            let mut rng = Rng::new(seeds[i]);
            let (ev_seq, st_seq) = sample_sd(&target, &draft, &cfg(), &mut rng).unwrap();
            assert_eq!(ev, &ev_seq, "sequence {i}");
            assert_eq!(st.rounds, st_seq.rounds);
            assert_eq!(st.drafted, st_seq.drafted);
            assert_eq!(st.accepted, st_seq.accepted);
        }
    }

    #[test]
    fn fleet_ar_equals_sequential_on_mocks() {
        let target = MockModel::default();
        let scfg = SampleCfg { num_types: 4, t_end: 20.0, max_events: 2048 };
        let seeds = fleet_seeds(3, 4);
        let (runs, _) = sample_ar_fleet(&target, &scfg, &seeds).unwrap();
        for (i, (ev, st)) in runs.iter().enumerate() {
            let mut rng = Rng::new(seeds[i]);
            let (ev_seq, st_seq) = sample_ar(&target, &scfg, &mut rng).unwrap();
            assert_eq!(ev, &ev_seq, "sequence {i}");
            assert_eq!(st.target_forwards, st_seq.target_forwards);
        }
    }

    #[test]
    fn empty_fleet_is_a_noop() {
        let target = MockModel::default();
        let (runs, fleet) =
            sample_ar_fleet(&target, &SampleCfg::default(), &[]).unwrap();
        assert!(runs.is_empty());
        assert_eq!(fleet.steps, 0);
    }
}
