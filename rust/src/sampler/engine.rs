//! Fleet sampling engine (DESIGN.md §11): drive N per-sequence sampling
//! state machines in lockstep, co-batching their model forwards.
//!
//! The blocking samplers ([`sample_ar`](super::sample_ar),
//! [`sample_sd`](super::sample_sd)) issue one
//! [`crate::runtime::Forward::forward1`] per step, so a host serving many
//! sequences fills its B=8 batch capacity only
//! by accidental collisions between independent clients. The engine makes
//! the sampler itself batchable — the vLLM-style continuous-batching move,
//! transplanted to TPP sampling: each sequence is a resumable session
//! ([`SdSession`] / [`ArSession`]) that *yields* the [`SeqInput`] its next
//! phase needs, and each engine step gathers all live sessions' pending
//! inputs, groups them by the model that must run them (draft steps
//! co-batched across sequences, verify passes co-batched across
//! sequences), issues ONE [`BatchForward::forward_batch`] call per group
//! (chunked at the model's batch capacity), and fans the slots back into
//! the sessions.
//!
//! Sessions live in a [`SessionPool`]: a rolling membership that sessions
//! join and leave mid-flight. [`drive`] is the closed-fleet special case
//! (admit everything, step until empty); the serving scheduler
//! (`coordinator::scheduler`) keeps one long-lived pool and admits
//! sequences from concurrent requests into it, so forwards co-batch
//! *across requests*, not just within one.
//!
//! **RNG isolation** (the bit-for-bit argument): every session owns its
//! proposal and decision streams, seeded per sequence, and the backend
//! contract guarantees batched rows equal single-sequence rows exactly —
//! so the fleet's per-sequence outputs and [`SampleStats`] are identical
//! to running the blocking samplers sequentially with the same seeds, for
//! every fleet size, membership and interleaving. Property-tested in
//! `rust/tests/fleet.rs` (closed fleets) and `rust/tests/scheduler.rs`
//! (cross-request pools).
//!
//! **Incremental streams** (DESIGN.md §12): when a role's model exposes
//! [`CachedForward`], the engine opens one stream per session and ships
//! [`SeqDelta`]s instead of full windows — each draft step then carries
//! one event rather than the whole history, and the deltas of a wave
//! co-batch just like full inputs. Rows are bit-identical on both paths
//! (`rust/tests/cached_forward.rs`), so caching never moves a
//! probability either.
//!
//! **Fault tolerance** (DESIGN.md §13): a failed wave is isolated — each
//! member re-runs alone — and a lost or errored stream is replaced and
//! rebased from the session's full window (the stream-recovery ladder);
//! sessions whose streams keep dying degrade to full-window forwards. All
//! of it is invisible in the outputs (forwards are pure and consume no
//! sampler randomness) and visible in [`FleetStats::stream_recoveries`] /
//! [`FleetStats::degraded_uncached`]. Property-tested in
//! `rust/tests/chaos.rs`.
//!
//! # Example
//!
//! Drive a three-sequence TPP-SD fleet over the in-crate mock model —
//! the minimal embed-the-engine flow:
//!
//! ```
//! use tpp_sd::model::MockModel;
//! use tpp_sd::sampler::{fleet_seeds, sample_sd_fleet, Gamma, SampleCfg, SdCfg};
//!
//! let target = MockModel::default();
//! let draft = MockModel { bias: 0.3, type_shift: 1, ..Default::default() };
//! let cfg = SdCfg {
//!     sample: SampleCfg { num_types: 4, t_end: 10.0, max_events: 512 },
//!     gamma: Gamma::Fixed(4),
//!     ..Default::default()
//! };
//! let (runs, fleet) = sample_sd_fleet(&target, &draft, &cfg, &fleet_seeds(7, 3)).unwrap();
//! assert_eq!(runs.len(), 3);
//! // Sequence i is bit-for-bit `sample_sd` seeded 7 + i; the fleet's win
//! // is occupancy: several sequences share each batched forward.
//! assert!(fleet.target_occupancy() >= 1.0);
//! ```

use anyhow::{ensure, Result};

use crate::events::Event;
use crate::runtime::{
    pool, BatchForward, CachedForward, Forward as _, PoolStats, SeqDelta, SeqInput, SlotOut,
    StreamId,
};
use crate::telemetry;
use crate::util::rng::Rng;

use super::ar::{ArSession, SampleCfg, STREAM_RECOVER_ATTEMPTS};
use super::sd::{SdCfg, SdSession};
use super::SampleStats;

/// Which of the two models a session's pending forward must run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelRole {
    /// the small drafting model
    Draft,
    /// the big verified model
    Target,
}

/// A resumable per-sequence sampling state machine the engine can drive:
/// it yields inputs (full or delta form), names the model that must run
/// them, and consumes the forward results. Implemented by [`SdSession`]
/// and [`ArSession`] (and [`AnySession`], which erases the two for mixed
/// pools).
pub trait FleetSession {
    /// Which model the pending input is for (only consulted while the
    /// session is not done).
    fn role(&self) -> ModelRole;

    /// True once the session needs no more forwards.
    fn is_done(&self) -> bool;

    /// The model input the next step needs, or `None` once done.
    fn pending_input(&self) -> Option<SeqInput>;

    /// The pending input as a delta against the [`FleetSession::role`]
    /// model's incremental stream (only consulted when that model has
    /// one), or `None` once done.
    fn pending_delta(&self) -> Option<SeqDelta>;

    /// Feed the forward result for the pending input and advance.
    fn advance(&mut self, fwd: &SlotOut);

    /// Forget everything `role`'s incremental stream had committed (the
    /// stream was lost or errored; its replacement starts empty): the
    /// next [`FleetSession::pending_delta`] for that role must rebase
    /// with `base_len == 0` and the full window (DESIGN.md §13).
    fn rebase(&mut self, role: ModelRole);

    /// Consume the session into its event stream and counters.
    fn into_output(self) -> (Vec<Event>, SampleStats);
}

impl FleetSession for SdSession {
    fn role(&self) -> ModelRole {
        SdSession::role(self)
    }

    fn is_done(&self) -> bool {
        SdSession::is_done(self)
    }

    fn pending_input(&self) -> Option<SeqInput> {
        SdSession::pending_input(self)
    }

    fn pending_delta(&self) -> Option<SeqDelta> {
        SdSession::pending_delta(self)
    }

    fn advance(&mut self, fwd: &SlotOut) {
        SdSession::advance(self, fwd)
    }

    fn rebase(&mut self, role: ModelRole) {
        SdSession::rebase_stream(self, role)
    }

    fn into_output(self) -> (Vec<Event>, SampleStats) {
        SdSession::into_output(self)
    }
}

impl FleetSession for ArSession {
    fn role(&self) -> ModelRole {
        ModelRole::Target
    }

    fn is_done(&self) -> bool {
        ArSession::is_done(self)
    }

    fn pending_input(&self) -> Option<SeqInput> {
        ArSession::pending_input(self)
    }

    fn pending_delta(&self) -> Option<SeqDelta> {
        ArSession::pending_delta(self)
    }

    fn advance(&mut self, fwd: &SlotOut) {
        ArSession::advance(self, fwd)
    }

    fn rebase(&mut self, _role: ModelRole) {
        ArSession::rebase_stream(self)
    }

    fn into_output(self) -> (Vec<Event>, SampleStats) {
        ArSession::into_output(self)
    }
}

/// A type-erased session, so one [`SessionPool`] can co-batch AR and SD
/// requests: the scheduler's pool holds `AnySession`s and never cares
/// which method a request asked for. Boxed so the enum stays pointer-sized
/// regardless of how the two session types grow.
pub enum AnySession {
    /// an autoregressive baseline session
    Ar(Box<ArSession>),
    /// a speculative-decoding session
    Sd(Box<SdSession>),
}

impl FleetSession for AnySession {
    fn role(&self) -> ModelRole {
        match self {
            AnySession::Ar(s) => FleetSession::role(&**s),
            AnySession::Sd(s) => FleetSession::role(&**s),
        }
    }

    fn is_done(&self) -> bool {
        match self {
            AnySession::Ar(s) => FleetSession::is_done(&**s),
            AnySession::Sd(s) => FleetSession::is_done(&**s),
        }
    }

    fn pending_input(&self) -> Option<SeqInput> {
        match self {
            AnySession::Ar(s) => FleetSession::pending_input(&**s),
            AnySession::Sd(s) => FleetSession::pending_input(&**s),
        }
    }

    fn pending_delta(&self) -> Option<SeqDelta> {
        match self {
            AnySession::Ar(s) => FleetSession::pending_delta(&**s),
            AnySession::Sd(s) => FleetSession::pending_delta(&**s),
        }
    }

    fn advance(&mut self, fwd: &SlotOut) {
        match self {
            AnySession::Ar(s) => FleetSession::advance(&mut **s, fwd),
            AnySession::Sd(s) => FleetSession::advance(&mut **s, fwd),
        }
    }

    fn rebase(&mut self, role: ModelRole) {
        match self {
            AnySession::Ar(s) => FleetSession::rebase(&mut **s, role),
            AnySession::Sd(s) => FleetSession::rebase(&mut **s, role),
        }
    }

    fn into_output(self) -> (Vec<Event>, SampleStats) {
        match self {
            AnySession::Ar(s) => FleetSession::into_output(*s),
            AnySession::Sd(s) => FleetSession::into_output(*s),
        }
    }
}

/// Engine-level counters of one fleet run: how well the per-sequence
/// forwards co-batched. (The per-sequence [`SampleStats`] still count
/// *logical* forwards — what the sequence consumed — so they aggregate
/// identically to sequential runs; the difference between the two views is
/// exactly the batching win.)
#[derive(Debug, Clone, Default)]
pub struct FleetStats {
    /// engine steps (gather → batch → fan-out cycles)
    pub steps: usize,
    /// batched draft-model calls issued (full-input and delta waves)
    pub draft_batches: usize,
    /// Σ sequences over draft batches
    pub draft_seqs: usize,
    /// batched target-model calls issued (full-input and delta waves)
    pub target_batches: usize,
    /// Σ sequences over target batches
    pub target_seqs: usize,
    /// of the batches above, how many were delta waves on incremental
    /// streams (the cached path; 0 on backends without [`CachedForward`])
    pub delta_batches: usize,
    /// Σ sequences over delta waves
    pub delta_seqs: usize,
    /// lost or errored incremental streams successfully replaced and
    /// rebased mid-run (DESIGN.md §13); the affected sequences' outputs
    /// are bit-identical to the fault-free run
    pub stream_recoveries: usize,
    /// sessions permanently degraded to full-window forwards after
    /// repeated stream failures — graceful degradation, not an error
    pub degraded_uncached: usize,
    /// worker-pool group dispatches during this run (DESIGN.md §14). The
    /// pool counters are process-wide, so concurrent fleet runs may
    /// cross-attribute; within a single run the delta is exact.
    pub pool_dispatches: usize,
    /// worker-pool job steals during this run
    pub pool_steals: usize,
    /// recycled output buffers served during this run
    pub buffers_reused: usize,
    /// freshly allocated output buffers during this run
    pub buffers_allocated: usize,
}

impl FleetStats {
    /// Mean sequences per batched draft call.
    pub fn draft_occupancy(&self) -> f64 {
        if self.draft_batches == 0 {
            0.0
        } else {
            self.draft_seqs as f64 / self.draft_batches as f64
        }
    }

    /// Mean sequences per batched target call.
    pub fn target_occupancy(&self) -> f64 {
        if self.target_batches == 0 {
            0.0
        } else {
            self.target_seqs as f64 / self.target_batches as f64
        }
    }

    /// Counter deltas since a `base` snapshot (saturating, per field). The
    /// scheduler snapshots its running totals when a request is admitted
    /// and reports `totals.since(&snapshot)` when it completes: the pool
    /// activity during the request's residency.
    pub fn since(&self, base: &FleetStats) -> FleetStats {
        FleetStats {
            steps: self.steps.saturating_sub(base.steps),
            draft_batches: self.draft_batches.saturating_sub(base.draft_batches),
            draft_seqs: self.draft_seqs.saturating_sub(base.draft_seqs),
            target_batches: self.target_batches.saturating_sub(base.target_batches),
            target_seqs: self.target_seqs.saturating_sub(base.target_seqs),
            delta_batches: self.delta_batches.saturating_sub(base.delta_batches),
            delta_seqs: self.delta_seqs.saturating_sub(base.delta_seqs),
            stream_recoveries: self.stream_recoveries.saturating_sub(base.stream_recoveries),
            degraded_uncached: self.degraded_uncached.saturating_sub(base.degraded_uncached),
            pool_dispatches: self.pool_dispatches.saturating_sub(base.pool_dispatches),
            pool_steals: self.pool_steals.saturating_sub(base.pool_steals),
            buffers_reused: self.buffers_reused.saturating_sub(base.buffers_reused),
            buffers_allocated: self.buffers_allocated.saturating_sub(base.buffers_allocated),
        }
    }
}

/// Per-sequence seeds of a fleet run: sequence `i` gets `base + i`, so
/// fleet sequence `i` is bit-for-bit the sequential run seeded `base + i`.
pub fn fleet_seeds(base: u64, n: usize) -> Vec<u64> {
    (0..n as u64).map(|i| base.wrapping_add(i)).collect()
}

/// One fleet run's per-sequence `(events, stats)` outputs, in seed order.
pub type FleetRuns = Vec<(Vec<Event>, SampleStats)>;

/// Sessions a [`SessionPool::step`] retired this wave, as
/// `(ticket, events, stats)` triples in no particular order.
pub type Retired = Vec<(u64, Vec<Event>, SampleStats)>;

/// Sample `seeds.len()` sequences with TPP-SD on the fleet engine. Returns
/// one `(events, stats)` per seed (in order) — each bit-for-bit identical
/// to `sample_sd(target, draft, cfg, &mut Rng::new(seed))` — plus the
/// engine's batching counters.
pub fn sample_sd_fleet<FT, FD>(
    target: &FT,
    draft: &FD,
    cfg: &SdCfg,
    seeds: &[u64],
) -> Result<(FleetRuns, FleetStats)>
where
    FT: BatchForward + ?Sized,
    FD: BatchForward + ?Sized,
{
    let cap = target.max_bucket().min(draft.max_bucket());
    let sessions: Vec<SdSession> = seeds
        .iter()
        .map(|&s| SdSession::new(cfg.clone(), cap, Rng::new(s)))
        .collect();
    drive(target, Some(draft), sessions)
}

/// Sample `seeds.len()` sequences autoregressively on the fleet engine.
/// Returns one `(events, stats)` per seed (in order) — each bit-for-bit
/// identical to `sample_ar(target, cfg, &mut Rng::new(seed))` — plus the
/// engine's batching counters.
pub fn sample_ar_fleet<FT>(
    target: &FT,
    cfg: &SampleCfg,
    seeds: &[u64],
) -> Result<(FleetRuns, FleetStats)>
where
    FT: BatchForward + ?Sized,
{
    let cap = target.max_bucket();
    let sessions: Vec<ArSession> = seeds
        .iter()
        .map(|&s| ArSession::new(cfg.clone(), cap, Rng::new(s)))
        .collect();
    drive(target, None::<&FT>, sessions)
}

/// Per-session stream ids of one model role in a pool, opened lazily on a
/// [`CachedForward`] model. The table is positional — entry `i` belongs to
/// the pool's `i`-th live session — and moves in tandem with the session
/// vector (`push`/`swap_remove`). The pool closes streams eagerly when a
/// session retires and via [`SessionPool::abort`] on a failed run, so the
/// backend cannot leak stream state.
///
/// Fault tolerance (DESIGN.md §13): opens retry up to
/// [`STREAM_RECOVER_ATTEMPTS`] times; a session whose stream keeps
/// failing is marked `dead` and degrades to full-window forwards for the
/// rest of the run (`degraded`), while successful replacements count into
/// `recovered`. Both tallies surface in [`FleetStats`].
#[derive(Default)]
struct RoleStreams {
    ids: Vec<Option<StreamId>>,
    /// sessions degraded to full-window forwards; never retried
    dead: Vec<bool>,
    /// lost/errored streams successfully replaced and rebased
    recovered: usize,
    /// sessions that fell into `dead`
    degraded: usize,
}

impl RoleStreams {
    /// Append the slot of a newly admitted session. `dead: true` opts the
    /// session out of incremental streams from the start (the request
    /// asked for full-window forwards) without counting it as degraded.
    fn push(&mut self, dead: bool) {
        self.ids.push(None);
        self.dead.push(dead);
    }

    /// Drop session `i`'s slot (closing its stream), keeping the table in
    /// tandem with a `Vec::swap_remove` on the session vector.
    fn swap_remove(&mut self, i: usize, cached: Option<&dyn CachedForward>) {
        self.close(i, cached);
        self.ids.swap_remove(i);
        self.dead.swap_remove(i);
    }

    /// Session `i`'s stream id, opening one on first use (with bounded
    /// retries); `None` when the role's model has no incremental-stream
    /// support or the session has degraded to the uncached path.
    fn stream_for(&mut self, i: usize, cached: Option<&dyn CachedForward>) -> Option<StreamId> {
        let c = cached?;
        if self.dead[i] {
            return None;
        }
        if self.ids[i].is_none() {
            for _ in 0..STREAM_RECOVER_ATTEMPTS {
                if let Ok(id) = c.open_stream() {
                    self.ids[i] = Some(id);
                    break;
                }
            }
            if self.ids[i].is_none() {
                self.mark_dead(i, cached);
            }
        }
        self.ids[i]
    }

    /// Release session `i`'s stream (idempotent).
    fn close(&mut self, i: usize, cached: Option<&dyn CachedForward>) {
        if let (Some(c), Some(id)) = (cached, self.ids[i].take()) {
            c.close_stream(id);
        }
    }

    /// Degrade session `i` to full-window forwards for the rest of the
    /// run (idempotent).
    fn mark_dead(&mut self, i: usize, cached: Option<&dyn CachedForward>) {
        self.close(i, cached);
        if !self.dead[i] {
            self.dead[i] = true;
            self.degraded += 1;
        }
    }

    /// Close every open stream and clear the table (abort path).
    fn close_all(&mut self, cached: Option<&dyn CachedForward>) {
        if let Some(c) = cached {
            for id in self.ids.iter_mut().filter_map(Option::take) {
                c.close_stream(id);
            }
        }
        self.ids.clear();
        self.dead.clear();
    }
}

/// Reusable gather buffers of one engine step, split by role and path
/// (full-window vs incremental delta). Living across steps, they keep the
/// steady-state loop allocation-free (§14).
#[derive(Default)]
struct GatherBufs {
    draft_ids: Vec<usize>,
    draft_in: Vec<SeqInput>,
    draft_delta_ids: Vec<usize>,
    draft_delta_in: Vec<(StreamId, SeqDelta)>,
    target_ids: Vec<usize>,
    target_in: Vec<SeqInput>,
    target_delta_ids: Vec<usize>,
    target_delta_in: Vec<(StreamId, SeqDelta)>,
}

impl GatherBufs {
    fn clear(&mut self) {
        self.draft_ids.clear();
        self.draft_in.clear();
        self.draft_delta_ids.clear();
        self.draft_delta_in.clear();
        self.target_ids.clear();
        self.target_in.clear();
        self.target_delta_ids.clear();
        self.target_delta_in.clear();
    }

    fn has(&self, role: ModelRole) -> bool {
        match role {
            ModelRole::Draft => !self.draft_ids.is_empty() || !self.draft_delta_ids.is_empty(),
            ModelRole::Target => !self.target_ids.is_empty() || !self.target_delta_ids.is_empty(),
        }
    }

    #[allow(clippy::type_complexity)]
    fn role_mut(
        &mut self,
        role: ModelRole,
    ) -> (&[usize], &mut Vec<SeqInput>, &[usize], &mut Vec<(StreamId, SeqDelta)>) {
        match role {
            ModelRole::Draft => (
                &self.draft_ids,
                &mut self.draft_in,
                &self.draft_delta_ids,
                &mut self.draft_delta_in,
            ),
            ModelRole::Target => (
                &self.target_ids,
                &mut self.target_in,
                &self.target_delta_ids,
                &mut self.target_delta_in,
            ),
        }
    }
}

/// A rolling pool of live sampling sessions — the continuous-batching
/// core. Sessions join mid-flight ([`SessionPool::admit`]) and leave the
/// moment they finish (their `(ticket, events, stats)` comes back from
/// [`SessionPool::step`]), and every step co-batches the forwards of
/// *whoever is resident* — across requests, methods and cache modes.
///
/// Bit-exactness: membership only decides which rows share a batched
/// forward, and the backend contract makes batched rows equal
/// single-sequence rows exactly; sessions own their RNG streams and
/// (per-role) incremental-stream cursors, so a session's output is
/// independent of who it shared the pool with (`rust/tests/scheduler.rs`).
///
/// [`drive`] is the closed-pool convenience: admit a fixed fleet, step
/// until empty. The serving scheduler keeps one pool per model pair and
/// feeds it from a bounded admission queue.
pub struct SessionPool<S> {
    sessions: Vec<S>,
    tickets: Vec<u64>,
    t_streams: RoleStreams,
    d_streams: RoleStreams,
    bufs: GatherBufs,
    pool_base: PoolStats,
}

impl<S: FleetSession> SessionPool<S> {
    /// An empty pool.
    pub fn new() -> SessionPool<S> {
        SessionPool {
            sessions: Vec::new(),
            tickets: Vec::new(),
            t_streams: RoleStreams::default(),
            d_streams: RoleStreams::default(),
            bufs: GatherBufs::default(),
            pool_base: pool::stats(),
        }
    }

    /// Number of live (admitted, not yet retired) sessions.
    pub fn live(&self) -> usize {
        self.sessions.len()
    }

    /// True when no session is resident.
    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Admit a session mid-flight. `ticket` is an opaque caller tag
    /// returned with the session's output when it retires.
    /// `use_streams: false` pins the session to full-window forwards even
    /// on a [`CachedForward`] model (the wire's `cached:false` knob) —
    /// the events are bit-identical either way, and the opt-out is not
    /// counted as a degradation.
    pub fn admit(&mut self, session: S, ticket: u64, use_streams: bool) {
        self.sessions.push(session);
        self.tickets.push(ticket);
        self.t_streams.push(!use_streams);
        self.d_streams.push(!use_streams);
    }

    /// One engine cycle over the resident sessions: retire finished ones,
    /// gather the rest's pending inputs, run one batched wave per model
    /// role, and retire whoever finished on it. Returns the retired
    /// sessions' outputs; batching counters accumulate into `fleet`
    /// (monotone — snapshot and [`FleetStats::since`] for a window).
    ///
    /// On `Err` the wave failed beyond the per-sequence retry and
    /// stream-recovery ladders; the pool's remaining sessions cannot make
    /// progress — call [`SessionPool::abort`] to release their streams.
    pub fn step<FT, FD>(
        &mut self,
        target: &FT,
        draft: Option<&FD>,
        fleet: &mut FleetStats,
    ) -> Result<Retired>
    where
        FT: BatchForward + ?Sized,
        FD: BatchForward + ?Sized,
    {
        let t_cached = target.cached();
        let d_cached = draft.and_then(|d| d.cached());
        let mut done = self.reap(t_cached, d_cached);
        self.bufs.clear();
        for (i, s) in self.sessions.iter().enumerate() {
            match s.role() {
                ModelRole::Draft => match self.d_streams.stream_for(i, d_cached) {
                    Some(sid) => {
                        self.bufs.draft_delta_ids.push(i);
                        self.bufs.draft_delta_in.push((sid, s.pending_delta().expect("pending delta")));
                    }
                    None => {
                        self.bufs.draft_ids.push(i);
                        self.bufs.draft_in.push(s.pending_input().expect("pending input"));
                    }
                },
                ModelRole::Target => match self.t_streams.stream_for(i, t_cached) {
                    Some(sid) => {
                        self.bufs.target_delta_ids.push(i);
                        self.bufs.target_delta_in.push((sid, s.pending_delta().expect("pending delta")));
                    }
                    None => {
                        self.bufs.target_ids.push(i);
                        self.bufs.target_in.push(s.pending_input().expect("pending input"));
                    }
                },
            }
        }
        if !self.bufs.has(ModelRole::Draft) && !self.bufs.has(ModelRole::Target) {
            self.sync(fleet);
            return Ok(done);
        }
        fleet.steps += 1;
        if self.bufs.has(ModelRole::Draft) {
            let d = match draft {
                Some(d) => d,
                None => anyhow::bail!("sessions need a draft model, but the pool has none"),
            };
            let role = run_role(
                d,
                &mut self.d_streams,
                d_cached,
                ModelRole::Draft,
                &mut self.bufs,
                &mut self.sessions,
            )?;
            fleet.draft_batches += role.batches;
            fleet.draft_seqs += role.seqs;
            fleet.delta_batches += role.delta_batches;
            fleet.delta_seqs += role.delta_seqs;
        }
        if self.bufs.has(ModelRole::Target) {
            let role = run_role(
                target,
                &mut self.t_streams,
                t_cached,
                ModelRole::Target,
                &mut self.bufs,
                &mut self.sessions,
            )?;
            fleet.target_batches += role.batches;
            fleet.target_seqs += role.seqs;
            fleet.delta_batches += role.delta_batches;
            fleet.delta_seqs += role.delta_seqs;
        }
        done.extend(self.reap(t_cached, d_cached));
        self.sync(fleet);
        Ok(done)
    }

    /// Release every stream and drop every session (failed-run path).
    pub fn abort<FT, FD>(&mut self, target: &FT, draft: Option<&FD>)
    where
        FT: BatchForward + ?Sized,
        FD: BatchForward + ?Sized,
    {
        self.t_streams.close_all(target.cached());
        self.d_streams.close_all(draft.and_then(|d| d.cached()));
        self.sessions.clear();
        self.tickets.clear();
    }

    /// Retire every finished session: close its streams, remove it from
    /// the pool (tables move in tandem) and collect its output.
    fn reap(
        &mut self,
        t_cached: Option<&dyn CachedForward>,
        d_cached: Option<&dyn CachedForward>,
    ) -> Retired {
        let mut out = Retired::new();
        let mut i = 0;
        while i < self.sessions.len() {
            if self.sessions[i].is_done() {
                self.t_streams.swap_remove(i, t_cached);
                self.d_streams.swap_remove(i, d_cached);
                let session = self.sessions.swap_remove(i);
                let ticket = self.tickets.swap_remove(i);
                let (events, stats) = session.into_output();
                out.push((ticket, events, stats));
            } else {
                i += 1;
            }
        }
        out
    }

    /// Refresh `fleet`'s derived tallies (recoveries, degradations, pool
    /// counters) from the pool's own monotone state.
    fn sync(&self, fleet: &mut FleetStats) {
        fleet.stream_recoveries = self.t_streams.recovered + self.d_streams.recovered;
        fleet.degraded_uncached = self.t_streams.degraded + self.d_streams.degraded;
        let pd = pool::stats().since(&self.pool_base);
        fleet.pool_dispatches = pd.pool_dispatches;
        fleet.pool_steals = pd.pool_steals;
        fleet.buffers_reused = pd.buffers_reused;
        fleet.buffers_allocated = pd.buffers_allocated;
    }
}

impl<S: FleetSession> Default for SessionPool<S> {
    fn default() -> Self {
        SessionPool::new()
    }
}

/// The closed-fleet engine loop: admit every session into a fresh
/// [`SessionPool`], step until the pool drains, and return the outputs in
/// admission order. `draft` may be `None` for fleets whose sessions only
/// ever ask for target forwards (AR).
///
/// Models exposing [`CachedForward`] are driven through per-session
/// incremental streams: each live session contributes a [`SeqDelta`]
/// instead of its full window, and the deltas of a role co-batch into
/// waves exactly like full inputs do (`delta_batches`/`delta_seqs` in
/// [`FleetStats`]). Backends without the trait — including the XLA
/// executor — fall back to full [`SeqInput`] forwards per session.
pub fn drive<FT, FD, S>(
    target: &FT,
    draft: Option<&FD>,
    sessions: Vec<S>,
) -> Result<(FleetRuns, FleetStats)>
where
    FT: BatchForward + ?Sized,
    FD: BatchForward + ?Sized,
    S: FleetSession,
{
    let n = sessions.len();
    let mut pool = SessionPool::new();
    for (k, s) in sessions.into_iter().enumerate() {
        pool.admit(s, k as u64, true);
    }
    let mut fleet = FleetStats::default();
    let mut out: Vec<Option<(Vec<Event>, SampleStats)>> = (0..n).map(|_| None).collect();
    while !pool.is_empty() {
        let done = match pool.step(target, draft, &mut fleet) {
            Ok(done) => done,
            Err(e) => {
                pool.abort(target, draft);
                return Err(e);
            }
        };
        for (ticket, events, stats) in done {
            out[ticket as usize] = Some((events, stats));
        }
    }
    Ok((
        out.into_iter()
            .map(|r| r.expect("every admitted session retires"))
            .collect(),
        fleet,
    ))
}

/// The telemetry stage a role's forward waves are timed under.
fn role_stage(role: ModelRole) -> telemetry::Stage {
    match role {
        ModelRole::Draft => telemetry::Stage::DraftForward,
        ModelRole::Target => telemetry::Stage::VerifyForward,
    }
}

/// One engine step's batch counters for a single model role.
#[derive(Default)]
struct RoleCounters {
    batches: usize,
    seqs: usize,
    delta_batches: usize,
    delta_seqs: usize,
}

/// Run one role's gathered work — full inputs as batched forwards, deltas
/// as stream waves — and advance the owning sessions. One copy for both
/// roles, so their fan-out and accounting can never drift apart.
fn run_role<B, S>(
    model: &B,
    streams: &mut RoleStreams,
    cached: Option<&dyn CachedForward>,
    role: ModelRole,
    bufs: &mut GatherBufs,
    sessions: &mut [S],
) -> Result<RoleCounters>
where
    B: BatchForward + ?Sized,
    S: FleetSession,
{
    let (full_ids, full_in, delta_ids, delta_in) = bufs.role_mut(role);
    let mut out = RoleCounters::default();
    if !full_ids.is_empty() {
        let (b, n) = fan_out(model, role, full_ids, full_in, sessions)?;
        out.batches += b;
        out.seqs += n;
    }
    if !delta_ids.is_empty() {
        let c = cached.expect("delta gathered without a cached model");
        let (b, n) = fan_out_delta(model, streams, c, role, delta_ids, delta_in, sessions)?;
        out.batches += b;
        out.seqs += n;
        out.delta_batches += b;
        out.delta_seqs += n;
    }
    Ok(out)
}

/// Run one role's gathered inputs through the model in `max_batch`-sized
/// chunks and advance the owning sessions. Returns (batches issued,
/// sequences forwarded).
///
/// A failed wave is isolated: each of its sequences re-runs alone with
/// bounded retries, so one faulty forward cannot sink its batchmates.
/// Forwards are pure (DESIGN.md §13), so re-run rows are bit-identical.
/// The gathered inputs move into the model un-cloned; the failure path
/// re-derives each one from its session (which has not advanced, so
/// [`FleetSession::pending_input`] rebuilds the identical input).
fn fan_out<B, S>(
    model: &B,
    role: ModelRole,
    ids: &[usize],
    inputs: &mut Vec<SeqInput>,
    sessions: &mut [S],
) -> Result<(usize, usize)>
where
    B: BatchForward + ?Sized,
    S: FleetSession,
{
    let cap = model.max_batch().max(1);
    let mut batches = 0;
    let mut start = 0;
    while start < ids.len() {
        let take = cap.min(ids.len() - start);
        let chunk: Vec<SeqInput> = inputs.drain(..take).collect();
        let t0 = telemetry::now_if_enabled();
        let served = model.forward_batch(chunk);
        telemetry::record_since(t0, &[role_stage(role)]);
        match served {
            Ok(outs) => {
                ensure!(
                    outs.len() == take,
                    "forward_batch returned {} slots for {} sequences",
                    outs.len(),
                    take
                );
                for (j, out) in outs.iter().enumerate() {
                    sessions[ids[start + j]].advance(out);
                }
            }
            Err(_) => {
                for j in 0..take {
                    let i = ids[start + j];
                    let seq = sessions[i].pending_input().expect("pending input");
                    let out = forward1_retry(model, seq)?;
                    sessions[i].advance(&out);
                }
            }
        }
        batches += 1;
        start += take;
    }
    Ok((batches, ids.len()))
}

/// `forward1` with up to [`STREAM_RECOVER_ATTEMPTS`] attempts, absorbing
/// transient faults on the direct (executor-less) path. Forwards are pure
/// and consume no sampler randomness, so every attempt computes the same
/// rows and a retry cannot move a probability.
fn forward1_retry<B>(model: &B, seq: SeqInput) -> Result<SlotOut>
where
    B: BatchForward + ?Sized,
{
    let mut last = None;
    for _ in 0..STREAM_RECOVER_ATTEMPTS {
        match model.forward1(seq.clone()) {
            Ok(out) => return Ok(out),
            Err(e) => last = Some(e),
        }
    }
    Err(last.expect("at least one forward attempt"))
}

/// Run one role's gathered stream deltas in `max_batch`-sized waves and
/// advance the owning sessions. A wave goes through
/// [`CachedForward::forward_delta_batch`], so the serving-path handle
/// enqueues it whole and the executor thread coalesces the deltas like a
/// batch. Returns (waves issued, sequences forwarded).
///
/// A failed wave is isolated per delta — deltas are idempotent (rewind to
/// `base_len`, then append), so re-running the ones the aborted wave had
/// already applied is safe. A delta that still fails alone means its
/// stream is lost; `recover_delta` replaces the stream, rebases the
/// session, and degrades to full-window forwards if streams keep dying.
fn fan_out_delta<B, S>(
    model: &B,
    streams: &mut RoleStreams,
    c: &dyn CachedForward,
    role: ModelRole,
    ids: &[usize],
    inputs: &mut Vec<(StreamId, SeqDelta)>,
    sessions: &mut [S],
) -> Result<(usize, usize)>
where
    B: BatchForward + ?Sized,
    S: FleetSession,
{
    let cap = BatchForward::max_batch(model).max(1);
    let mut batches = 0;
    let mut start = 0;
    while start < ids.len() {
        let take = cap.min(ids.len() - start);
        let chunk: Vec<(StreamId, SeqDelta)> = inputs.drain(..take).collect();
        // The wave moves into the model un-cloned. If it fails, each
        // (stream, delta) pair is re-derived from its session: sessions
        // have not advanced and streams were not touched mid-wave, so
        // `stream_for` returns the same id and `pending_delta` rebuilds
        // the identical delta the wave carried.
        let t0 = telemetry::now_if_enabled();
        let served = c.forward_delta_batch(chunk);
        // One measured wave, recorded under both the issuing role's
        // forward stage and the shared delta-wave stage.
        telemetry::record_since(t0, &[role_stage(role), telemetry::Stage::DeltaWave]);
        match served {
            Ok(outs) => {
                ensure!(
                    outs.len() == take,
                    "forward_delta_batch returned {} slots for {} sequences",
                    outs.len(),
                    take
                );
                for (j, out) in outs.iter().enumerate() {
                    sessions[ids[start + j]].advance(out);
                }
            }
            Err(_) => {
                for j in 0..take {
                    let i = ids[start + j];
                    let sid = streams.stream_for(i, Some(c)).expect("stream lost mid-wave");
                    let delta = sessions[i].pending_delta().expect("pending delta");
                    let out = match c.forward_delta(sid, &delta) {
                        Ok(out) => out,
                        Err(_) => recover_delta(model, streams, c, role, i, sessions)?,
                    };
                    sessions[i].advance(&out);
                }
            }
        }
        batches += 1;
        start += take;
    }
    Ok((batches, ids.len()))
}

/// Recover session `i` after its `role` stream was lost or errored:
/// replace the stream, rebase the session onto it (`base_len == 0`, the
/// full window — the same move a window slide forces), and re-run the
/// forward. Streams that keep dying degrade the session to full-window
/// forwards for the rest of the run. Recovery consumes no sampler
/// randomness and forwards are pure, so the returned row — and therefore
/// every sampled event — is bit-identical to the fault-free run
/// (DESIGN.md §13; property-tested in `rust/tests/chaos.rs`).
fn recover_delta<B, S>(
    model: &B,
    streams: &mut RoleStreams,
    c: &dyn CachedForward,
    role: ModelRole,
    i: usize,
    sessions: &mut [S],
) -> Result<SlotOut>
where
    B: BatchForward + ?Sized,
    S: FleetSession,
{
    let _span = telemetry::Span::start(telemetry::Stage::StreamRecovery);
    streams.close(i, Some(c));
    for _ in 0..STREAM_RECOVER_ATTEMPTS {
        let Some(sid) = streams.stream_for(i, Some(c)) else {
            break;
        };
        sessions[i].rebase(role);
        let delta = sessions[i].pending_delta().expect("pending delta");
        if let Ok(out) = c.forward_delta(sid, &delta) {
            streams.recovered += 1;
            return Ok(out);
        }
        streams.close(i, Some(c));
    }
    streams.mark_dead(i, Some(c));
    sessions[i].rebase(role);
    forward1_retry(model, sessions[i].pending_input().expect("pending input"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mock::MockModel;
    use crate::sampler::{sample_ar, sample_sd, Gamma};

    fn cfg() -> SdCfg {
        SdCfg {
            sample: SampleCfg { num_types: 4, t_end: 20.0, max_events: 2048 },
            gamma: Gamma::Fixed(5),
            ..Default::default()
        }
    }

    #[test]
    fn fleet_sd_equals_sequential_on_mocks() {
        let target = MockModel::default();
        let draft = MockModel { bias: 0.3, type_shift: 1, ..Default::default() };
        let seeds = fleet_seeds(11, 5);
        let (runs, fleet) = sample_sd_fleet(&target, &draft, &cfg(), &seeds).unwrap();
        assert_eq!(runs.len(), 5);
        assert!(fleet.steps > 0 && fleet.target_batches > 0);
        for (i, (ev, st)) in runs.iter().enumerate() {
            let mut rng = Rng::new(seeds[i]);
            let (ev_seq, st_seq) = sample_sd(&target, &draft, &cfg(), &mut rng).unwrap();
            assert_eq!(ev, &ev_seq, "sequence {i}");
            assert_eq!(st.rounds, st_seq.rounds);
            assert_eq!(st.drafted, st_seq.drafted);
            assert_eq!(st.accepted, st_seq.accepted);
        }
    }

    #[test]
    fn fleet_ar_equals_sequential_on_mocks() {
        let target = MockModel::default();
        let scfg = SampleCfg { num_types: 4, t_end: 20.0, max_events: 2048 };
        let seeds = fleet_seeds(3, 4);
        let (runs, _) = sample_ar_fleet(&target, &scfg, &seeds).unwrap();
        for (i, (ev, st)) in runs.iter().enumerate() {
            let mut rng = Rng::new(seeds[i]);
            let (ev_seq, st_seq) = sample_ar(&target, &scfg, &mut rng).unwrap();
            assert_eq!(ev, &ev_seq, "sequence {i}");
            assert_eq!(st.target_forwards, st_seq.target_forwards);
        }
    }

    #[test]
    fn empty_fleet_is_a_noop() {
        let target = MockModel::default();
        let (runs, fleet) =
            sample_ar_fleet(&target, &SampleCfg::default(), &[]).unwrap();
        assert!(runs.is_empty());
        assert_eq!(fleet.steps, 0);
    }

    /// Mid-flight admission (the continuous-batching move): sessions
    /// admitted while others are half-done still produce bit-identical
    /// outputs, and mixed AR/SD membership co-batches in one pool.
    #[test]
    fn pool_admits_mid_flight_without_moving_outputs() {
        let target = MockModel::default();
        let draft = MockModel { bias: 0.3, type_shift: 1, ..Default::default() };
        let cap = target.max_bucket().min(draft.max_bucket());
        let scfg = SampleCfg { num_types: 4, t_end: 15.0, max_events: 2048 };

        let mut pool: SessionPool<AnySession> = SessionPool::new();
        let mut fleet = FleetStats::default();
        let mut got: std::collections::BTreeMap<u64, (Vec<Event>, SampleStats)> =
            std::collections::BTreeMap::new();
        pool.admit(
            AnySession::Sd(Box::new(SdSession::new(cfg(), cap, Rng::new(21)))),
            0,
            true,
        );
        pool.admit(
            AnySession::Ar(Box::new(ArSession::new(scfg.clone(), cap, Rng::new(22)))),
            1,
            true,
        );
        let mut steps = 0usize;
        let mut late_admitted = false;
        while !pool.is_empty() {
            for (t, ev, st) in pool.step(&target, Some(&draft), &mut fleet).unwrap() {
                got.insert(t, (ev, st));
            }
            steps += 1;
            if steps == 3 && !late_admitted {
                // join mid-flight, while tickets 0/1 are in progress
                pool.admit(
                    AnySession::Sd(Box::new(SdSession::new(cfg(), cap, Rng::new(23)))),
                    2,
                    true,
                );
                pool.admit(
                    AnySession::Ar(Box::new(ArSession::new(scfg.clone(), cap, Rng::new(24)))),
                    3,
                    false,
                );
                late_admitted = true;
            }
        }
        assert!(late_admitted, "fleet drained before the mid-flight admission");
        assert_eq!(got.len(), 4);

        let (ev_sd, _) = sample_sd(&target, &draft, &cfg(), &mut Rng::new(21)).unwrap();
        assert_eq!(got[&0].0, ev_sd);
        let (ev_ar, _) = sample_ar(&target, &scfg, &mut Rng::new(22)).unwrap();
        assert_eq!(got[&1].0, ev_ar);
        let (ev_sd2, _) = sample_sd(&target, &draft, &cfg(), &mut Rng::new(23)).unwrap();
        assert_eq!(got[&2].0, ev_sd2);
        let (ev_ar2, _) = sample_ar(&target, &scfg, &mut Rng::new(24)).unwrap();
        assert_eq!(got[&3].0, ev_ar2);
    }
}
