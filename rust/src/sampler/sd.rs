//! TPP-SD (paper §4.3, Algorithm 1): speculative decoding for Transformer
//! TPP sampling.
//!
//! Per round: (1) **draft** γ candidate events autoregressively from the
//! small model, recording its interval densities g_D and type pmfs f_D;
//! (2) **verify** all candidates with ONE parallel forward pass of the
//! target model; accept candidate l while all previous ones were accepted
//! and u < g_T/g_D (interval) then u < f_T/f_D (type); (3) on first
//! rejection, **resample from the adjusted distribution** — Theorem 1's
//! acceptance–rejection scheme for the continuous interval (sample g_T,
//! accept w.p. max(0, g_T−g_D)/g_T), `norm(max(0, f_T−f_D))` for the type;
//! (4) if everything was accepted, sample a **bonus event** from the
//! target's extra row. Output distribution provably equals AR sampling
//! from the target (paper App. A.2).
//!
//! Rejection handling is the *strictly correct* variant (DESIGN.md §9):
//! τ rejected ⇒ τ′ ~ g′ and k ~ f_T fresh; τ accepted but k rejected ⇒
//! keep τ̂ and k′ ~ f′.
//!
//! RNG discipline (DESIGN.md §9.3): *proposal* draws (drafted candidates
//! and the bonus event) consume the caller's `rng` in exactly the order AR
//! sampling would, while accept/reject uniforms and adjusted-distribution
//! redraws run on a stream derived via [`Rng::derive`]. Consequence:
//! with `draft == target` every candidate is accepted (density ratios are
//! exactly 1) and `sample_sd` reproduces `sample_ar`'s event stream
//! bit-for-bit from the same seed — the degenerate-acceptance regression
//! test in `rust/tests/native_backend.rs`.

use anyhow::Result;

use crate::events::Event;
use crate::model::mixture::{sample_adjusted_interval, TypeDist};
use crate::runtime::Forward;
use crate::util::rng::Rng;

use super::ar::SampleCfg;
use super::context::Context;
use super::SampleStats;

/// Draft-length policy.
#[derive(Debug, Clone, Copy)]
pub enum Gamma {
    /// the paper's fixed draft length
    Fixed(usize),
    /// extension (paper §6 future work): per-round adaptation from the
    /// rejection position — AIMD-style, clamped to [min, max]
    Adaptive {
        /// first round's draft length
        init: usize,
        /// lower clamp
        min: usize,
        /// upper clamp
        max: usize,
    },
}

impl Gamma {
    /// The first round's draft length under this policy.
    pub fn initial(&self) -> usize {
        match *self {
            Gamma::Fixed(g) => g,
            Gamma::Adaptive { init, .. } => init,
        }
    }
}

/// Configuration of one TPP-SD run.
#[derive(Debug, Clone)]
pub struct SdCfg {
    /// window/type/cap knobs shared with AR sampling
    pub sample: SampleCfg,
    /// draft-length policy
    pub gamma: Gamma,
    /// cap for Theorem-1 rejection loops (g_T ≈ g_D degeneracy guard)
    pub max_adjust_tries: usize,
}

impl Default for SdCfg {
    fn default() -> Self {
        SdCfg {
            sample: SampleCfg::default(),
            gamma: Gamma::Fixed(10),
            max_adjust_tries: 64,
        }
    }
}

/// Sample one sequence with TPP-SD; distributionally identical to
/// [`super::ar::sample_ar`] on the target model.
pub fn sample_sd<FT: Forward + ?Sized, FD: Forward + ?Sized>(
    target: &FT,
    draft: &FD,
    cfg: &SdCfg,
    rng: &mut Rng,
) -> Result<(Vec<Event>, SampleStats)> {
    let scfg = &cfg.sample;
    // Decision stream: accept/reject uniforms and adjusted redraws, kept
    // separate from the proposal stream (see the module docs).
    let mut vrng = rng.derive(0xACCE_97);
    let mut gamma = cfg.gamma.initial().max(1);
    let cap = target.max_bucket().min(draft.max_bucket());
    let max_gamma = match cfg.gamma {
        Gamma::Fixed(g) => g,
        Gamma::Adaptive { max, .. } => max,
    };
    let mut ctx = Context::new(cap, max_gamma.max(1));
    let mut out: Vec<Event> = Vec::new();
    let mut stats = SampleStats::default();
    let t_start = std::time::Instant::now();

    'outer: while out.len() < scfg.max_events {
        stats.rounds += 1;
        // ------------------------------------------------------- drafting
        let mut cand: Vec<Event> = Vec::with_capacity(gamma);
        let mut d_mix = Vec::with_capacity(gamma);
        let mut d_type = Vec::with_capacity(gamma);
        for l in 0..gamma {
            let fwd = draft.forward1(ctx.seq_input(&cand))?;
            stats.draft_forwards += 1;
            let row = ctx.next_row(l);
            let mix = fwd.mixture(row);
            let td = fwd.type_dist(row, scfg.num_types);
            let tau = mix.sample(rng);
            let k = td.sample(rng) as u32;
            let prev = cand.last().map(|e| e.t).unwrap_or(ctx.last_time());
            cand.push(Event::new(prev + tau, k));
            d_mix.push(mix);
            d_type.push(td);
        }
        stats.drafted += gamma;

        // ---------------------------------------------------- verification
        let fwd_t = target.forward1(ctx.seq_input(&cand))?;
        stats.target_forwards += 1;

        // Row indices into fwd_t follow the layout at verification time
        // (BOS + window + candidates); pin them before pushes mutate ctx.
        let base_row = ctx.next_row(0);
        let round_start_time = ctx.last_time();

        let mut rejected_at: Option<usize> = None;
        for l in 0..gamma {
            let row = base_row + l;
            let t_mix = fwd_t.mixture(row);
            let t_td = fwd_t.type_dist(row, scfg.num_types);
            let prev = if l == 0 { round_start_time } else { cand[l - 1].t };
            let tau_hat = cand[l].t - prev;

            // interval test: u < g_T(τ̂)/g_D(τ̂)
            let log_ratio = t_mix.logpdf(tau_hat) - d_mix[l].logpdf(tau_hat);
            let tau_ok = vrng.uniform().ln() < log_ratio;
            if !tau_ok {
                // τ̂ rejected → τ′ ~ g′ (Theorem 1), k ~ f_T fresh.
                let (tau2, tries) =
                    sample_adjusted_interval(&t_mix, &d_mix[l], &mut vrng, cfg.max_adjust_tries);
                stats.adjust_proposals += tries;
                let k2 = t_td.sample(&mut vrng) as u32;
                let e = Event::new(prev + tau2, k2);
                stats.resampled += 1;
                rejected_at = Some(l);
                if !push_event(&mut out, &mut ctx, e, scfg.t_end) {
                    break 'outer;
                }
                break;
            }
            // type test: u < f_T(k̂)/f_D(k̂)
            let k_hat = cand[l].k as usize;
            let type_ok =
                vrng.uniform() * d_type[l].pmf(k_hat) < t_td.pmf(k_hat);
            if !type_ok {
                // k̂ rejected → keep τ̂, k′ ~ f′ = norm(max(0, f_T − f_D)).
                let adj = TypeDist::adjusted(&t_td, &d_type[l]);
                let k2 = adj.sample(&mut vrng) as u32;
                let e = Event::new(cand[l].t, k2);
                stats.resampled += 1;
                rejected_at = Some(l);
                if !push_event(&mut out, &mut ctx, e, scfg.t_end) {
                    break 'outer;
                }
                break;
            }
            // candidate fully accepted
            stats.accepted += 1;
            if !push_event(&mut out, &mut ctx, cand[l], scfg.t_end) {
                break 'outer;
            }
        }

        // -------------------------------------------------------- bonus
        // All γ accepted → one extra event from the target's (γ+1)-th row
        // (fwd_t is fixed, so the pinned row stays valid even if pushes
        // truncated the context window).
        if rejected_at.is_none() {
            let row = base_row + gamma;
            let mix = fwd_t.mixture(row);
            let td = fwd_t.type_dist(row, scfg.num_types);
            let tau = mix.sample(rng);
            let k = td.sample(rng) as u32;
            let e = Event::new(cand.last().map(|e| e.t).unwrap_or(round_start_time) + tau, k);
            stats.bonus += 1;
            if !push_event(&mut out, &mut ctx, e, scfg.t_end) {
                break 'outer;
            }
        }

        // --------------------------------------------------- adapt gamma
        if let Gamma::Adaptive { min, max, .. } = cfg.gamma {
            gamma = match rejected_at {
                None => (gamma + 1).min(max),
                Some(l) => (l.max(1)).max(min).min(max),
            };
        }
    }

    stats.events = out.len();
    stats.wall = t_start.elapsed();
    Ok((out, stats))
}

/// Append an accepted event unless it crosses the window end. Returns
/// `false` when sampling must stop (event beyond T is discarded — same
/// stopping rule as AR sampling).
fn push_event(out: &mut Vec<Event>, ctx: &mut Context, e: Event, t_end: f64) -> bool {
    if e.t > t_end {
        return false;
    }
    out.push(e);
    ctx.push(e);
    true
}
