//! TPP-SD (paper §4.3, Algorithm 1): speculative decoding for Transformer
//! TPP sampling.
//!
//! Per round: (1) **draft** γ candidate events autoregressively from the
//! small model, recording its interval densities g_D and type pmfs f_D;
//! (2) **verify** all candidates with ONE parallel forward pass of the
//! target model; accept candidate l while all previous ones were accepted
//! and u < g_T/g_D (interval) then u < f_T/f_D (type); (3) on first
//! rejection, **resample from the adjusted distribution** — Theorem 1's
//! acceptance–rejection scheme for the continuous interval (sample g_T,
//! accept w.p. max(0, g_T−g_D)/g_T), `norm(max(0, f_T−f_D))` for the type;
//! (4) if everything was accepted, sample a **bonus event** from the
//! target's extra row. Output distribution provably equals AR sampling
//! from the target (paper App. A.2).
//!
//! Rejection handling is the *strictly correct* variant (DESIGN.md §9):
//! τ rejected ⇒ τ′ ~ g′ and k ~ f_T fresh; τ accepted but k rejected ⇒
//! keep τ̂ and k′ ~ f′.
//!
//! RNG discipline (DESIGN.md §9.3): *proposal* draws (drafted candidates
//! and the bonus event) consume the session's proposal `rng` in exactly the
//! order AR sampling would, while accept/reject uniforms and adjusted-
//! distribution redraws run on a stream derived via [`Rng::derive`].
//! Consequence: with `draft == target` every candidate is accepted (density
//! ratios are exactly 1) and `sample_sd` reproduces `sample_ar`'s event
//! stream bit-for-bit from the same seed — the degenerate-acceptance
//! regression test in `rust/tests/native_backend.rs`.
//!
//! Since the fleet-engine refactor (DESIGN.md §11) the round loop is a
//! resumable state machine, [`SdSession`], with explicit phases
//! ([`SdPhase`]: `Drafting(l)` → `Verifying` → next round / `Done`): the
//! session *yields* the [`SeqInput`] its next forward needs instead of
//! calling the model. [`sample_sd`] is the blocking single-sequence driver
//! over that state machine; [`super::engine::sample_sd_fleet`] drives many
//! sessions in lockstep, co-batching draft steps and verify passes across
//! sequences. Both paths execute the identical per-session code and RNG
//! streams, so they are bit-for-bit interchangeable.

use std::time::Instant;

use anyhow::Result;

use crate::events::Event;
use crate::model::mixture::{sample_adjusted_interval, Mixture, TypeDist};
use crate::runtime::{Forward, SeqDelta, SeqInput, SlotOut, StreamGuard};
use crate::telemetry::{self, Stage};
use crate::util::rng::Rng;

use super::ar::SampleCfg;
use super::context::Context;
use super::engine::ModelRole;
use super::SampleStats;

/// Draft-length policy.
#[derive(Debug, Clone, Copy)]
pub enum Gamma {
    /// the paper's fixed draft length
    Fixed(usize),
    /// extension (paper §6 future work): per-round adaptation from the
    /// rejection position — AIMD-style, clamped to [min, max]
    Adaptive {
        /// first round's draft length
        init: usize,
        /// lower clamp
        min: usize,
        /// upper clamp
        max: usize,
    },
}

impl Gamma {
    /// The first round's draft length under this policy.
    pub fn initial(&self) -> usize {
        match *self {
            Gamma::Fixed(g) => g,
            Gamma::Adaptive { init, .. } => init,
        }
    }
}

/// Configuration of one TPP-SD run.
#[derive(Debug, Clone)]
pub struct SdCfg {
    /// window/type/cap knobs shared with AR sampling
    pub sample: SampleCfg,
    /// draft-length policy
    pub gamma: Gamma,
    /// cap for Theorem-1 rejection loops (g_T ≈ g_D degeneracy guard)
    pub max_adjust_tries: usize,
}

impl Default for SdCfg {
    fn default() -> Self {
        SdCfg {
            sample: SampleCfg::default(),
            gamma: Gamma::Fixed(10),
            max_adjust_tries: 64,
        }
    }
}

/// Where an [`SdSession`] is inside its current speculative round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SdPhase {
    /// waiting for the draft forward of candidate `l` (0-based)
    Drafting(usize),
    /// waiting for the target's parallel verification forward
    Verifying,
    /// sampling finished (window closed or event cap hit)
    Done,
}

/// Resumable TPP-SD state machine for ONE sequence. The session yields the
/// model input its next phase needs ([`SdSession::pending_input`] +
/// [`SdSession::role`] say *which* model must run it) and consumes the
/// forward result via [`SdSession::advance`]. It owns both RNG streams
/// (proposal + derived decision stream), so N sessions driven in any
/// interleaving produce exactly the event streams N sequential
/// [`sample_sd`] runs would — the fleet-equivalence property test in
/// `rust/tests/fleet.rs`.
#[derive(Debug)]
pub struct SdSession {
    cfg: SdCfg,
    /// proposal stream (drafted candidates + bonus events)
    rng: Rng,
    /// decision stream (accept/reject uniforms, adjusted redraws)
    vrng: Rng,
    gamma: usize,
    ctx: Context,
    cand: Vec<Event>,
    /// drafted interval mixtures, slot `l` for candidate `l`. Slots are
    /// REUSED across rounds (never cleared — DESIGN.md §14): only
    /// `0..gamma` are meaningful in a round, and each is overwritten by
    /// [`SdSession::advance_draft`] before verification reads it.
    d_mix: Vec<Mixture>,
    /// drafted type pmfs, same slot lifecycle as `d_mix`
    d_type: Vec<TypeDist>,
    /// scratch mixture the target's verify rows decode into (reused
    /// capacity, one row at a time)
    t_mix: Mixture,
    /// scratch type pmf, same lifecycle as `t_mix`
    t_td: TypeDist,
    out: Vec<Event>,
    stats: SampleStats,
    phase: SdPhase,
    started: Instant,
    /// wall-clock of the last event-emitting advance — feeds the
    /// `event_latency` telemetry stage (DESIGN.md §15); never read by
    /// sampling logic and never touches an RNG stream
    last_emit: Instant,
    /// events of (window ++ candidates) the DRAFT model's cached-forward
    /// stream has committed (DESIGN.md §12); rewound on rejection, zeroed
    /// on window slide
    d_cursor: usize,
    /// same cursor for the TARGET model's stream
    t_cursor: usize,
    /// [`Context::epoch`] snapshot — a mismatch means the window slid and
    /// both streams must rebase
    seen_epoch: usize,
}

impl SdSession {
    /// New session sampling one sequence; `cap` is the smaller of the two
    /// models' bucket capacities
    /// (`target.max_bucket().min(draft.max_bucket())`).
    pub fn new(cfg: SdCfg, cap: usize, rng: Rng) -> SdSession {
        // Decision stream: accept/reject uniforms and adjusted redraws,
        // kept separate from the proposal stream (see the module docs).
        let vrng = rng.derive(0xACCE_97);
        let gamma = cfg.gamma.initial().max(1);
        // The context margin must cover the largest draft the session can
        // ever run — including a first-round `init` above the adaptive
        // clamp, which only takes effect from the second round.
        let max_gamma = match cfg.gamma {
            Gamma::Fixed(g) => g,
            Gamma::Adaptive { max, .. } => max.max(gamma),
        };
        let mut s = SdSession {
            rng,
            vrng,
            gamma,
            ctx: Context::new(cap, max_gamma.max(1)),
            cand: Vec::new(),
            d_mix: Vec::new(),
            d_type: Vec::new(),
            t_mix: Mixture::default(),
            t_td: TypeDist::default(),
            out: Vec::new(),
            stats: SampleStats::default(),
            phase: SdPhase::Done,
            started: Instant::now(),
            last_emit: Instant::now(),
            d_cursor: 0,
            t_cursor: 0,
            seen_epoch: 0,
            cfg,
        };
        s.begin_round();
        s
    }

    /// Current phase of the round state machine.
    pub fn phase(&self) -> SdPhase {
        self.phase
    }

    /// Which model must run the pending input (draft while drafting, target
    /// while verifying). Meaningless once done.
    pub fn role(&self) -> ModelRole {
        match self.phase {
            SdPhase::Drafting(_) => ModelRole::Draft,
            _ => ModelRole::Target,
        }
    }

    /// The model input the next phase needs (history window + candidates so
    /// far), or `None` once done.
    pub fn pending_input(&self) -> Option<SeqInput> {
        match self.phase {
            SdPhase::Done => None,
            _ => Some(self.ctx.seq_input(&self.cand)),
        }
    }

    /// Delta form of [`SdSession::pending_input`] against the stream of
    /// the model [`SdSession::role`] names: only the events that stream
    /// has not committed yet. A draft step ships one event, a verify pass
    /// ships the candidates plus whatever the last round's rejection
    /// rewound — O(γ) instead of O(L). `None` once done.
    pub fn pending_delta(&self) -> Option<SeqDelta> {
        match self.phase {
            SdPhase::Done => None,
            SdPhase::Drafting(_) => Some(self.ctx.seq_delta(&self.cand, self.d_cursor)),
            SdPhase::Verifying => Some(self.ctx.seq_delta(&self.cand, self.t_cursor)),
        }
    }

    /// [`SdSession::pending_delta`] into a caller-owned scratch delta,
    /// reusing its capacity. Returns `false` (leaving `d` untouched) once
    /// done.
    pub fn pending_delta_into(&self, d: &mut SeqDelta) -> bool {
        match self.phase {
            SdPhase::Done => false,
            SdPhase::Drafting(_) => {
                self.ctx.seq_delta_into(&self.cand, self.d_cursor, d);
                true
            }
            SdPhase::Verifying => {
                self.ctx.seq_delta_into(&self.cand, self.t_cursor, d);
                true
            }
        }
    }

    /// True once the sampling window closed or the event cap was hit.
    pub fn is_done(&self) -> bool {
        self.phase == SdPhase::Done
    }

    /// Feed the forward result for the pending input and advance one phase.
    /// No-op once done.
    pub fn advance(&mut self, fwd: &SlotOut) {
        match self.phase {
            SdPhase::Drafting(l) => self.advance_draft(l, fwd),
            SdPhase::Verifying => self.advance_verify(fwd),
            SdPhase::Done => {}
        }
    }

    /// The session's proposal RNG (used by [`sample_sd`] to hand the
    /// advanced stream back to its caller).
    pub fn rng(&self) -> &Rng {
        &self.rng
    }

    /// Forget everything `role`'s incremental stream had committed — the
    /// stream was lost or errored and its replacement starts empty
    /// (DESIGN.md §13). The next [`SdSession::pending_delta`] for that
    /// role then carries `base_len == 0` and the full window: a *rebase*,
    /// the same move a window slide forces. Recovery consumes no RNG and
    /// recomputes identical rows, so sampled events are unchanged.
    pub fn rebase_stream(&mut self, role: ModelRole) {
        match role {
            ModelRole::Draft => self.d_cursor = 0,
            ModelRole::Target => self.t_cursor = 0,
        }
    }

    /// Consume the finished (or abandoned) session into its event stream
    /// and counters.
    pub fn into_output(mut self) -> (Vec<Event>, SampleStats) {
        if self.phase != SdPhase::Done {
            self.finish();
        }
        (self.out, self.stats)
    }

    /// Start the next round, or finish when the event cap is reached —
    /// the state-machine form of the blocking loop's `while out.len() <
    /// max_events` header.
    fn begin_round(&mut self) {
        if self.out.len() >= self.cfg.sample.max_events {
            self.finish();
            return;
        }
        self.stats.rounds += 1;
        self.cand.clear();
        // d_mix/d_type are NOT cleared: their slots (and the Vec capacity
        // inside each) are reused round over round — see the field docs.
        self.phase = SdPhase::Drafting(0);
    }

    /// Drafting phase step: sample candidate `l` from the draft forward.
    fn advance_draft(&mut self, l: usize, fwd: &SlotOut) {
        self.stats.draft_forwards += 1;
        // The draft forward consumed window + l candidates: the draft
        // stream (cached path) is now committed through that prefix. The
        // candidate sampled BELOW is not committed until the next step.
        self.d_cursor = self.ctx.len() + l;
        let row = self.ctx.next_row(l);
        if self.d_mix.len() <= l {
            self.d_mix.push(Mixture::default());
            self.d_type.push(TypeDist::default());
        }
        fwd.mixture_into(row, &mut self.d_mix[l]);
        fwd.type_dist_into(row, self.cfg.sample.num_types, &mut self.d_type[l]);
        let tau = self.d_mix[l].sample(&mut self.rng);
        let k = self.d_type[l].sample(&mut self.rng) as u32;
        let prev = self.cand.last().map(|e| e.t).unwrap_or(self.ctx.last_time());
        self.cand.push(Event::new(prev + tau, k));
        if l + 1 < self.gamma {
            self.phase = SdPhase::Drafting(l + 1);
        } else {
            self.stats.drafted += self.gamma;
            self.phase = SdPhase::Verifying;
        }
    }

    /// Verification phase: judge all γ candidates against the target's
    /// parallel forward, resample on first rejection, bonus event on
    /// all-accept, then adapt γ and begin the next round.
    fn advance_verify(&mut self, fwd_t: &SlotOut) {
        self.stats.target_forwards += 1;
        let accepted_before = self.stats.accepted;
        let out_before = self.out.len();
        let num_types = self.cfg.sample.num_types;
        let t_end = self.cfg.sample.t_end;
        let gamma = self.gamma;

        // Row indices into fwd_t follow the layout at verification time
        // (BOS + window + candidates); pin them before pushes mutate ctx.
        let base_row = self.ctx.next_row(0);
        let round_start_time = self.ctx.last_time();
        // The verify forward consumed window + all γ candidates: the
        // target stream (cached path) is committed through that prefix.
        self.t_cursor = base_row + gamma;

        let mut rejected_at: Option<usize> = None;
        let mut stopped = false;
        for l in 0..gamma {
            let row = base_row + l;
            fwd_t.mixture_into(row, &mut self.t_mix);
            fwd_t.type_dist_into(row, num_types, &mut self.t_td);
            let prev = if l == 0 { round_start_time } else { self.cand[l - 1].t };
            let tau_hat = self.cand[l].t - prev;

            // interval test: u < g_T(τ̂)/g_D(τ̂)
            let log_ratio = self.t_mix.logpdf(tau_hat) - self.d_mix[l].logpdf(tau_hat);
            let tau_ok = self.vrng.uniform().ln() < log_ratio;
            if !tau_ok {
                // τ̂ rejected → τ′ ~ g′ (Theorem 1), k ~ f_T fresh.
                let (tau2, tries) = sample_adjusted_interval(
                    &self.t_mix,
                    &self.d_mix[l],
                    &mut self.vrng,
                    self.cfg.max_adjust_tries,
                );
                self.stats.adjust_proposals += tries;
                let k2 = self.t_td.sample(&mut self.vrng) as u32;
                let e = Event::new(prev + tau2, k2);
                self.stats.resampled += 1;
                rejected_at = Some(l);
                if !push_event(&mut self.out, &mut self.ctx, e, t_end) {
                    stopped = true;
                }
                break;
            }
            // type test: u < f_T(k̂)/f_D(k̂)
            let k_hat = self.cand[l].k as usize;
            let type_ok = self.vrng.uniform() * self.d_type[l].pmf(k_hat) < self.t_td.pmf(k_hat);
            if !type_ok {
                // k̂ rejected → keep τ̂, k′ ~ f′ = norm(max(0, f_T − f_D)).
                let adj = TypeDist::adjusted(&self.t_td, &self.d_type[l]);
                let k2 = adj.sample(&mut self.vrng) as u32;
                let e = Event::new(self.cand[l].t, k2);
                self.stats.resampled += 1;
                rejected_at = Some(l);
                if !push_event(&mut self.out, &mut self.ctx, e, t_end) {
                    stopped = true;
                }
                break;
            }
            // candidate fully accepted
            self.stats.accepted += 1;
            if !push_event(&mut self.out, &mut self.ctx, self.cand[l], t_end) {
                stopped = true;
                break;
            }
        }

        // All γ accepted → one bonus event from the target's (γ+1)-th row
        // (fwd_t is fixed, so the pinned row stays valid even if pushes
        // truncated the context window).
        if !stopped && rejected_at.is_none() {
            let row = base_row + gamma;
            fwd_t.mixture_into(row, &mut self.t_mix);
            fwd_t.type_dist_into(row, num_types, &mut self.t_td);
            let tau = self.t_mix.sample(&mut self.rng);
            let k = self.t_td.sample(&mut self.rng) as u32;
            let e =
                Event::new(self.cand.last().map(|e| e.t).unwrap_or(round_start_time) + tau, k);
            self.stats.bonus += 1;
            if !push_event(&mut self.out, &mut self.ctx, e, t_end) {
                stopped = true;
            }
        }

        // Cached-forward cursor discipline (DESIGN.md §12): on a rejection
        // at candidate j the streams' committed content diverges from the
        // new history at position (round start + j) — the resampled event
        // replaced candidate j — so both cursors rewind to the agreed
        // prefix; on all-accept every committed position still matches
        // (the bonus event was never committed). A window slide trumps
        // either case: positions renumbered, both streams must rebase.
        if let Some(j) = rejected_at {
            self.d_cursor = self.d_cursor.min(base_row + j);
            self.t_cursor = self.t_cursor.min(base_row + j);
        }
        if self.ctx.epoch() != self.seen_epoch {
            self.seen_epoch = self.ctx.epoch();
            self.d_cursor = 0;
            self.t_cursor = 0;
        }

        // Telemetry (DESIGN.md §15): acceptance accounting per role plus
        // the wall-clock gap between event-emitting verify passes. Only
        // `Instant` + atomics — no sampler RNG is touched.
        let acc_round = self.stats.accepted - accepted_before;
        telemetry::record_round(gamma, acc_round, rejected_at.is_none() && acc_round == gamma);
        if self.out.len() > out_before && telemetry::enabled() {
            let now = Instant::now();
            telemetry::record_ns(
                Stage::EventLatency,
                now.duration_since(self.last_emit).as_nanos() as u64,
            );
            self.last_emit = now;
        }

        if stopped {
            self.finish();
            return;
        }
        if let Gamma::Adaptive { min, max, .. } = self.cfg.gamma {
            self.gamma = match rejected_at {
                None => (self.gamma + 1).min(max),
                Some(l) => (l.max(1)).max(min).min(max),
            };
        }
        self.begin_round();
    }

    fn finish(&mut self) {
        self.stats.events = self.out.len();
        self.stats.wall = self.started.elapsed();
        self.phase = SdPhase::Done;
    }
}

/// Sample one sequence with TPP-SD (blocking driver over [`SdSession`]);
/// distributionally identical to [`super::ar::sample_ar`] on the target
/// model. Each model that exposes an incremental stream
/// ([`Forward::cached`]) is driven through per-event deltas — a draft
/// step then costs O(1) and a verify pass O(γ) instead of O(L) — with
/// bit-identical outputs either way (`rust/tests/cached_forward.rs`).
/// Fault tolerance (DESIGN.md §13): either role's lost or errored stream
/// is replaced by a fresh one and rebased from the session's full window;
/// repeated failures degrade that role to full-window forwards. Either
/// way the rows — and therefore the sampled events — are bit-identical to
/// the fault-free run.
pub fn sample_sd<FT: Forward + ?Sized, FD: Forward + ?Sized>(
    target: &FT,
    draft: &FD,
    cfg: &SdCfg,
    rng: &mut Rng,
) -> Result<(Vec<Event>, SampleStats)> {
    let cap = target.max_bucket().min(draft.max_bucket());
    let mut session = SdSession::new(cfg.clone(), cap, rng.clone());
    let mut t_stream = StreamGuard::open(target).unwrap_or(None);
    let mut d_stream = StreamGuard::open(draft).unwrap_or(None);
    let mut dbuf = SeqDelta::default();
    while !session.is_done() {
        let role = session.role();
        let mut tries = 0;
        let fwd_span = telemetry::Span::start(match role {
            ModelRole::Draft => Stage::DraftForward,
            ModelRole::Target => Stage::VerifyForward,
        });
        let fwd = loop {
            let stream = match role {
                ModelRole::Draft => &d_stream,
                ModelRole::Target => &t_stream,
            };
            match stream {
                Some(g) => {
                    let filled = session.pending_delta_into(&mut dbuf);
                    assert!(filled, "pending delta");
                    match g.forward_delta(&dbuf) {
                        Ok(f) => break f,
                        Err(_) => {
                            // Stream lost/errored: rebase the role on a
                            // fresh stream, degrading it to uncached when
                            // the failures persist.
                            let _recover = telemetry::Span::start(Stage::StreamRecovery);
                            tries += 1;
                            session.rebase_stream(role);
                            let fresh = if tries < super::ar::STREAM_RECOVER_ATTEMPTS {
                                match role {
                                    ModelRole::Draft => StreamGuard::open(draft).unwrap_or(None),
                                    ModelRole::Target => StreamGuard::open(target).unwrap_or(None),
                                }
                            } else {
                                None
                            };
                            match role {
                                ModelRole::Draft => d_stream = fresh,
                                ModelRole::Target => t_stream = fresh,
                            }
                        }
                    }
                }
                None => {
                    let input = session.pending_input().expect("pending input");
                    break match role {
                        ModelRole::Draft => draft.forward1(input)?,
                        ModelRole::Target => target.forward1(input)?,
                    };
                }
            }
        };
        drop(fwd_span);
        session.advance(&fwd);
    }
    *rng = session.rng().clone();
    Ok(session.into_output())
}

/// Append an accepted event unless it crosses the window end. Returns
/// `false` when sampling must stop (event beyond T is discarded — same
/// stopping rule as AR sampling).
fn push_event(out: &mut Vec<Event>, ctx: &mut Context, e: Event, t_end: f64) -> bool {
    if e.t > t_end {
        return false;
    }
    out.push(e);
    ctx.push(e);
    true
}
