//! Interface validation: load one HLO artifact + npz weights, execute with
//! golden inputs, compare against jax-produced golden outputs.
//!
//! Built only with `--features xla` (see `rust/Cargo.toml`
//! `required-features`); against the vendored API stub it compiles but
//! reports the stub error at runtime.
//!
//! Usage: validate_artifact <hlo.txt> <weights.npz> <golden_io.npz>

#[cfg(feature = "xla")]
mod real {
    use anyhow::{bail, Context, Result};
    use xla::FromRawBytes;

    pub fn run() -> Result<()> {
        let mut args = std::env::args().skip(1);
        let hlo = args.next().context("hlo path")?;
        let weights = args.next().context("weights path")?;
        let golden = args.next().context("golden path")?;

        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(&hlo)?;
        let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;

        let mut w: Vec<(String, xla::Literal)> = xla::Literal::read_npz(&weights, &())?;
        w.sort_by(|a, b| a.0.cmp(&b.0));

        let mut g: Vec<(String, xla::Literal)> = xla::Literal::read_npz(&golden, &())?;
        g.sort_by(|a, b| a.0.cmp(&b.0));
        let get = |name: &str| -> &xla::Literal {
            &g.iter().find(|(n, _)| n == name).unwrap().1
        };

        let mut inputs: Vec<&xla::Literal> = w.iter().map(|(_, l)| l).collect();
        let times = get("times");
        let types = get("types");
        let length = get("length");
        inputs.push(times);
        inputs.push(types);
        inputs.push(length);

        let result = exe.execute::<&xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 4 {
            bail!("expected 4 outputs, got {}", outs.len());
        }
        for (out, name) in outs.iter().zip(["log_w", "mu", "log_sigma", "logits"]) {
            let got = out.to_vec::<f32>()?;
            let want = get(name).to_vec::<f32>()?;
            let max_err = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            println!("{name}: n={} max_err={max_err:e}", got.len());
            if max_err > 2e-4 {
                bail!("{name} mismatch: {max_err}");
            }
        }
        println!("validate_artifact OK");
        Ok(())
    }
}

// `required-features = ["xla"]` in Cargo.toml means this target is never
// built without the feature, so no fallback `main` is needed.
#[cfg(feature = "xla")]
fn main() -> anyhow::Result<()> {
    real::run()
}

#[cfg(not(feature = "xla"))]
fn main() {}
