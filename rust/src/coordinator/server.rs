//! TCP sampling server: line-protocol front-end over the router, the
//! per-pair continuous-batching schedulers, and the batching executors.
//! One lightweight thread per connection (sessions); sampling work is
//! handed to the pair's [`Scheduler`](super::scheduler::Scheduler), whose
//! single rolling pool co-batches forwards across concurrent requests
//! (DESIGN.md §16). Overload is answered, not absorbed: a full admission
//! queue or a passed deadline yields `{"ok":false,"err":...}` structured
//! rejections.
//!
//! Fault injection (DESIGN.md §13): a request carrying a non-empty
//! `"chaos"` spec is served by a dedicated router whose backend is wrapped
//! in [`ChaosBackend`], built lazily per distinct spec (bounded by
//! [`MAX_CHAOS_ROUTERS`]). The fault-free router — and every other
//! client's traffic — is untouched. Recoverable plans ride the executor
//! handles' retry/backoff and the fleet engine's stream recovery, so their
//! responses are bit-identical to fault-free ones; unrecoverable plans
//! surface as `{"ok":false,...}` structured errors, never a hang
//! (`rust/tests/chaos.rs`).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::protocol::{
    batcher_stats_json, error_response, fleet_ok_response, ok_response, ErrCode, Request,
    SampleRequest,
};
use super::router::Router;
use super::scheduler::{build_sessions, SchedReject, SchedulerCfg};
use crate::runtime::{Backend, ChaosBackend, FaultPlan};
use crate::sampler::{fleet_seeds, SampleCfg};
use crate::telemetry;
use crate::util::json::{obj, Json};

/// Cap on distinct chaos specs a server builds routers for — each one
/// spawns its own executor threads, and chaos is a testing facility, not a
/// production path. Further specs are rejected with `{"ok":false,...}`.
pub const MAX_CHAOS_ROUTERS: usize = 8;

/// Everything a connection thread needs: the fault-free router plus the
/// makings of per-spec chaos routers.
struct Ctx {
    backend: Arc<dyn Backend>,
    router: Arc<Router>,
    max_batch: usize,
    batch_window: Duration,
    chaos: Mutex<BTreeMap<String, Arc<Router>>>,
    sessions: AtomicUsize,
}

impl Ctx {
    /// The router serving a request with fault spec `spec`: the shared
    /// fault-free router for `""`/no-op specs, else a lazily-built (and
    /// cached) router over a [`ChaosBackend`] for the spec.
    fn router_for(&self, spec: &str) -> Result<Arc<Router>> {
        let plan = FaultPlan::parse(spec)?;
        if plan.is_noop() {
            return Ok(self.router.clone());
        }
        let mut map = self.chaos.lock().unwrap();
        if let Some(r) = map.get(spec) {
            return Ok(r.clone());
        }
        anyhow::ensure!(
            map.len() < MAX_CHAOS_ROUTERS,
            "too many distinct chaos specs (cap {MAX_CHAOS_ROUTERS})"
        );
        let wrapped: Arc<dyn Backend> = Arc::new(ChaosBackend::new(self.backend.clone(), plan));
        // Chaos routers inherit the server's admission limits, so overload
        // behaviour is testable under injected faults too.
        let r = Arc::new(Router::with_scheduler(
            wrapped,
            self.max_batch,
            self.batch_window,
            self.router.sched_cfg,
        )?);
        map.insert(spec.to_string(), r.clone());
        Ok(r)
    }
}

/// The TCP sampling server: accept loop + per-connection session threads.
pub struct Server {
    /// the bound address (useful with port 0)
    pub addr: std::net::SocketAddr,
    listener: TcpListener,
    ctx: Arc<Ctx>,
}

impl Server {
    /// Bind (use port 0 for an ephemeral port) and build the router over
    /// the given model registry, with default scheduler admission limits.
    pub fn bind(
        backend: Arc<dyn crate::runtime::Backend>,
        host_port: &str,
        max_batch: usize,
        batch_window: Duration,
    ) -> Result<Server> {
        Server::bind_with_scheduler(
            backend,
            host_port,
            max_batch,
            batch_window,
            SchedulerCfg::default(),
        )
    }

    /// Bind with explicit scheduler admission limits
    /// (`tppsd serve --max-live N --queue-depth Q`).
    pub fn bind_with_scheduler(
        backend: Arc<dyn crate::runtime::Backend>,
        host_port: &str,
        max_batch: usize,
        batch_window: Duration,
        sched_cfg: SchedulerCfg,
    ) -> Result<Server> {
        let router =
            Arc::new(Router::with_scheduler(backend.clone(), max_batch, batch_window, sched_cfg)?);
        let listener = TcpListener::bind(host_port)?;
        let addr = listener.local_addr()?;
        let ctx = Arc::new(Ctx {
            backend,
            router,
            max_batch,
            batch_window,
            chaos: Mutex::new(BTreeMap::new()),
            sessions: AtomicUsize::new(0),
        });
        Ok(Server { addr, listener, ctx })
    }

    /// Shared handle to the router (pre-routing, stats).
    pub fn router(&self) -> Arc<Router> {
        self.ctx.router.clone()
    }

    /// Accept loop; blocks forever. Call from a dedicated thread when
    /// embedding (see `examples/serve.rs`).
    pub fn serve(&self) -> Result<()> {
        for stream in self.listener.incoming() {
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let ctx = self.ctx.clone();
            std::thread::spawn(move || {
                ctx.sessions.fetch_add(1, Ordering::Relaxed);
                let _ = handle_conn(stream, &ctx);
                ctx.sessions.fetch_sub(1, Ordering::Relaxed);
            });
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, ctx: &Ctx) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    // Per-connection delta baseline: `{"op":"metrics","delta":true}`
    // reports only the activity since this connection's previous metrics
    // call (every metrics call moves the baseline, delta or not).
    let mut metrics_base = telemetry::snapshot();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // connection closed
        }
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Request::parse(&line) {
            Ok(Request::Ping) => r#"{"ok":true,"pong":true}"#.to_string(),
            Ok(Request::Stats) => stats_response(ctx),
            Ok(Request::Metrics { delta }) => {
                let now = telemetry::snapshot();
                let view = if delta { now.since(&metrics_base) } else { now.clone() };
                metrics_base = now;
                metrics_response(ctx, &view)
            }
            // v2 merged op: events-shaped at n_seq == 1, sequences-shaped
            // beyond; the v1 `sample_fleet` alias is always
            // sequences-shaped, exactly as v1 clients expect.
            Ok(Request::Sample(req)) => dispatch_sample(ctx, &req, false),
            Ok(Request::SampleFleet(req)) => dispatch_sample(ctx, &req, true),
            Err(e) => error_response(ErrCode::BadRequest, &format!("{e:#}")),
        };
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

/// Map a scheduler rejection to its wire form: a structured
/// `{"ok":false,"err":code,"detail":msg,...}` the client can branch on.
fn reject_response(rej: &SchedReject) -> String {
    error_response(rej.code(), rej.message())
}

/// Hard cap on sequences per request (keeps one connection from
/// monopolizing the executors). Requests beyond it are rejected with
/// `err=bad_request`, not silently truncated.
const MAX_FLEET_SEQ: usize = 64;

/// Route + run one sample request and map every failure class onto its
/// [`ErrCode`]: request-content problems (bad chaos spec, unknown
/// dataset/encoder/method, over-cap `n_seq`) are `bad_request` — every
/// replica would reject them identically, so a proxy must not retry them
/// — while scheduler rejections keep their own codes.
fn dispatch_sample(ctx: &Ctx, req: &SampleRequest, fleet_shape: bool) -> String {
    let router = match ctx.router_for(&req.chaos) {
        Ok(r) => r,
        Err(e) => return error_response(ErrCode::BadRequest, &format!("{e:#}")),
    };
    match run_sample(&router, req, fleet_shape) {
        Ok(resp) => resp,
        Err(e) => error_response(ErrCode::BadRequest, &format!("{e:#}")),
    }
}

/// The one dispatch path of both request shapes: build one session per
/// seed and submit the whole request to the pair's continuous-batching
/// scheduler. `n_seq == 1` is just the 1-seed case — fleet(N=1) is
/// bit-for-bit the blocking sampler (`rust/tests/fleet.rs`,
/// `rust/tests/scheduler.rs`), so every concurrent request co-batches in
/// the same pool whatever its size; `fleet_shape` only picks the response
/// rendering (the v1 `sample_fleet` alias is always sequences-shaped).
///
/// `cached: false` admits the request's sessions without incremental
/// streams, forcing full-window forwards — the wire-level A/B knob; the
/// events are bit-identical either way.
fn run_sample(router: &Router, req: &SampleRequest, fleet_shape: bool) -> Result<String> {
    if req.n_seq > MAX_FLEET_SEQ {
        anyhow::bail!("n_seq {} exceeds the per-request cap {MAX_FLEET_SEQ}", req.n_seq);
    }
    let pair = router.route(&req.dataset, &req.encoder, &req.draft_size)?;
    let cfg = SampleCfg {
        num_types: pair.num_types,
        t_end: req.t_end,
        max_events: 16 * 1024,
    };
    let seeds = fleet_seeds(req.seed, req.n_seq.max(1));
    let sessions = build_sessions(&pair, &req.method, req.gamma, cfg, &seeds)?;
    let sched = router.scheduler(&req.dataset, &req.encoder, &req.draft_size)?;
    let deadline = (req.deadline_ms > 0).then(|| Duration::from_millis(req.deadline_ms));
    match sched.submit(sessions, req.cached, deadline) {
        Ok((mut runs, fleet)) => {
            if fleet_shape || req.n_seq > 1 {
                Ok(fleet_ok_response(&runs, &fleet))
            } else {
                let (events, stats) = runs.pop().expect("one run per seed");
                Ok(ok_response(&events, &stats))
            }
        }
        Err(rej) => Ok(reject_response(&rej)),
    }
}

/// Every routed executor's batcher counters, two entries per model pair
/// (target then draft). Shared by `stats` and `metrics` so the two
/// surfaces report identical numbers.
fn executors_json(router: &Router) -> Json {
    let mut out = Vec::new();
    for ((dataset, encoder, draft_size), pair) in router.pairs() {
        for handle in [&pair.target, &pair.draft] {
            out.push(obj(vec![
                ("name", Json::Str(handle.name.clone())),
                ("pair", Json::Str(format!("{dataset}/{encoder}/{draft_size}"))),
                ("stats", batcher_stats_json(&handle.stats)),
            ]));
        }
    }
    Json::Arr(out)
}

/// Every spawned scheduler's admission counters and gauges, across the
/// fault-free router and every chaos router (`"chaos"` names the spec,
/// `""` for the fault-free one). Shared by `stats` and `metrics`, and the
/// ground truth the overload tests reconcile client outcomes against.
fn schedulers_json(ctx: &Ctx) -> Json {
    let mut routers: Vec<(String, Arc<Router>)> = vec![(String::new(), ctx.router.clone())];
    for (spec, r) in ctx.chaos.lock().unwrap().iter() {
        routers.push((spec.clone(), r.clone()));
    }
    let mut out = Vec::new();
    for (spec, router) in routers {
        for ((dataset, encoder, draft_size), sched) in router.schedulers() {
            out.push(obj(vec![
                ("pair", Json::Str(format!("{dataset}/{encoder}/{draft_size}"))),
                ("chaos", Json::Str(spec.clone())),
                ("stats", sched.stats_json()),
            ]));
        }
    }
    Json::Arr(out)
}

fn stats_response(ctx: &Ctx) -> String {
    obj(vec![
        ("ok", Json::Bool(true)),
        ("sessions", Json::Num(ctx.sessions.load(Ordering::Relaxed) as f64)),
        (
            "chaos_routers",
            Json::Num(ctx.chaos.lock().unwrap().len() as f64),
        ),
        (
            "datasets",
            Json::Arr(ctx.router.datasets().into_iter().map(Json::Str).collect()),
        ),
        // The batcher retry/timeout/pool/occupancy counters — the old
        // handler silently dropped all of these.
        ("executors", executors_json(&ctx.router)),
        ("schedulers", schedulers_json(ctx)),
    ])
    .to_string()
}

/// `{"op":"metrics"}` response: the (possibly delta-windowed) telemetry
/// snapshot (DESIGN.md §15) plus every executor's batcher counters.
fn metrics_response(ctx: &Ctx, view: &telemetry::Snapshot) -> String {
    obj(vec![
        ("ok", Json::Bool(true)),
        ("telemetry", view.to_json()),
        ("executors", executors_json(&ctx.router)),
        ("schedulers", schedulers_json(ctx)),
    ])
    .to_string()
}

/// Default read timeout of a [`Client`]: generous enough for release-mode
/// fleet requests, but finite — a wedged server fails the call instead of
/// hanging the test suite forever.
pub const CLIENT_READ_TIMEOUT: Duration = Duration::from_secs(120);

/// Minimal blocking client for tests and the serve example.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to a running server. The connection starts with a
    /// [`CLIENT_READ_TIMEOUT`] read timeout (tune it with
    /// [`Client::set_read_timeout`]).
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(CLIENT_READ_TIMEOUT))?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Connect with a bounded connect wait — the proxy tier's upstream
    /// dials go through this so a dead replica costs `timeout`, not the
    /// OS's (much longer) SYN retry ladder.
    pub fn connect_timeout(addr: std::net::SocketAddr, timeout: Duration) -> Result<Client> {
        let stream = TcpStream::connect_timeout(&addr, timeout)?;
        stream.set_read_timeout(Some(CLIENT_READ_TIMEOUT))?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Adjust the read timeout (`None` blocks forever). The reader and
    /// writer share one socket, so this covers [`Client::call`]'s reply
    /// wait.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(timeout)?;
        Ok(())
    }

    /// Send one request and read one response line.
    ///
    /// A zero-byte read means the server hung up before replying; that is
    /// a structured error here, not `Ok("")` — the old behaviour made
    /// downstream JSON parsing misreport a dead server as a protocol
    /// error.
    pub fn call(&mut self, req: &Request) -> Result<String> {
        self.call_line(&req.to_line())
    }

    /// Send one raw request line and read one response line — the
    /// forwarding primitive of the proxy tier, which relays an
    /// already-serialized request without re-interpreting it.
    pub fn call_line(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        anyhow::ensure!(n > 0, "connection closed: server hung up before sending a response");
        Ok(resp)
    }
}
