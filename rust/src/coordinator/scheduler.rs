//! Continuous-batching scheduler with admission control (DESIGN.md §16):
//! one rolling [`SessionPool`] per routed model pair, fed from a bounded
//! FIFO admission queue, so concurrent `sample`/`sample_fleet` requests
//! co-batch their draft and target forwards *across requests* — the
//! vLLM-style serving move — instead of each request driving an isolated
//! fleet at partial wave occupancy.
//!
//! One scheduler thread owns the pool. Connection threads build their
//! sessions ([`build_sessions`]) and [`Scheduler::submit`] them; the
//! scheduler admits whole requests in strict FIFO order whenever the
//! head-of-queue request fits under the `max_live` session cap, then
//! steps the pool — sessions of newly admitted requests join mid-wave,
//! and finished sessions leave the moment they retire. The head request
//! never waits on anyone admitted after it (no overtaking), so a stream
//! of small requests cannot starve a large one.
//!
//! **Admission control / load shedding**: the pending queue is bounded
//! (`queue_depth`); a submit that finds it full is shed immediately with
//! a structured [`SchedReject::Overloaded`] — the wire's
//! `{"ok":false,"err":"overloaded",...}` — rather than queued without
//! bound. A request carrying a deadline that has already passed when its
//! turn comes is rejected as [`SchedReject::Expired`] instead of being
//! admitted to do work nobody is waiting for. Every submit ends in
//! exactly one of `{completed, shed, expired, failed}` — the
//! [`SchedStats`] counters reconcile with client-observed outcomes to the
//! unit (`rust/tests/scheduler.rs`).
//!
//! **Bit-exactness**: admission order, pool membership and wave
//! composition decide only *which rows share a batched forward*. The
//! backend contract makes batched rows equal single-sequence rows
//! exactly, each session owns its RNG streams, and each (session, role)
//! owns its incremental-stream cursor — so a request's events are
//! bit-for-bit what a sequential per-request run with the same seeds
//! would produce, under any cross-request interleaving. That property is
//! the core oracle of `rust/tests/scheduler.rs`.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::router::ModelPair;
use crate::events::Event;
use crate::sampler::{
    AnySession, ArSession, FleetRuns, FleetStats, Gamma, SampleCfg, SampleStats, SdCfg, SdSession,
    SessionPool,
};
use crate::telemetry;
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

/// Admission-control limits of a [`Scheduler`].
///
/// `#[non_exhaustive]` so future admission knobs never break downstream
/// constructors — build one with [`SchedulerCfg::builder`]:
///
/// ```
/// use tpp_sd::coordinator::SchedulerCfg;
/// let cfg = SchedulerCfg::builder().max_live(2).queue_depth(4).build();
/// assert_eq!(cfg.max_live, 2);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub struct SchedulerCfg {
    /// Most sessions resident in the pool at once. A request is admitted
    /// only when all of its sessions fit under the cap (whole requests
    /// are admitted atomically, so a fleet is never half-resident).
    pub max_live: usize,
    /// Most requests waiting in the pending queue. A submit that finds
    /// the queue full is shed with [`SchedReject::Overloaded`].
    pub queue_depth: usize,
}

impl Default for SchedulerCfg {
    fn default() -> Self {
        SchedulerCfg { max_live: 64, queue_depth: 128 }
    }
}

impl SchedulerCfg {
    /// A builder starting from the defaults (the only way to construct
    /// one outside this crate — the struct is `#[non_exhaustive]`).
    pub fn builder() -> SchedulerCfgBuilder {
        SchedulerCfgBuilder::default()
    }
}

/// Builder for [`SchedulerCfg`] — starts from the defaults; every setter
/// is optional and chainable.
#[derive(Debug, Clone, Default)]
pub struct SchedulerCfgBuilder {
    cfg: SchedulerCfg,
}

impl SchedulerCfgBuilder {
    /// Cap on co-resident sessions (clamped ≥ 1).
    pub fn max_live(mut self, v: usize) -> Self {
        self.cfg.max_live = v.max(1);
        self
    }
    /// Bound on the pending admission queue (clamped ≥ 1).
    pub fn queue_depth(mut self, v: usize) -> Self {
        self.cfg.queue_depth = v.max(1);
        self
    }
    /// Finish the builder.
    pub fn build(self) -> SchedulerCfg {
        self.cfg
    }
}

/// Why [`Scheduler::submit`] did not return results. `Overloaded` and
/// `Expired` are admission verdicts (the work never ran); `Failed` means
/// the pool could not finish the request (a wave failed beyond every
/// retry and recovery ladder).
#[derive(Debug, Clone)]
pub enum SchedReject {
    /// shed at submit: the pending queue is full (or the request can
    /// never fit under `max_live`)
    Overloaded(String),
    /// rejected at admission: the request's deadline had already passed
    /// when its turn came
    Expired(String),
    /// the pool failed mid-run; partial work is discarded
    Failed(String),
}

impl SchedReject {
    /// The stable machine-readable code of the wire's `"err"` field
    /// (serialized via [`super::protocol::error_response`]).
    pub fn code(&self) -> super::protocol::ErrCode {
        match self {
            SchedReject::Overloaded(_) => super::protocol::ErrCode::Overloaded,
            SchedReject::Expired(_) => super::protocol::ErrCode::Expired,
            SchedReject::Failed(_) => super::protocol::ErrCode::Failed,
        }
    }

    /// The human-readable detail of the wire's `"detail"` field.
    pub fn message(&self) -> &str {
        match self {
            SchedReject::Overloaded(m) | SchedReject::Expired(m) | SchedReject::Failed(m) => m,
        }
    }
}

/// Lock-free scheduler counters and gauges. Every submit ends in exactly
/// one of `{completed, shed, expired, failed}`, and
/// `admitted == completed + failed + in-flight` — the reconciliation
/// invariant `rust/tests/scheduler.rs` pins against client-observed
/// outcomes.
#[derive(Debug, Default)]
pub struct SchedStats {
    /// requests admitted into the pool (FIFO, whole-request)
    pub admitted: AtomicUsize,
    /// requests shed at submit (queue full / can never fit)
    pub shed: AtomicUsize,
    /// requests whose deadline passed before admission
    pub expired: AtomicUsize,
    /// admitted requests that returned full results
    pub completed: AtomicUsize,
    /// admitted requests discarded by a pool failure
    pub failed: AtomicUsize,
    /// gauge: sessions resident in the pool right now
    pub live_sessions: AtomicUsize,
    /// gauge: requests waiting in the pending queue right now
    pub queued: AtomicUsize,
    /// high-water mark of `live_sessions` (bounded by `max_live`)
    pub max_live_seen: AtomicUsize,
}

/// What a request's `submit` call resolves to.
type Outcome = std::result::Result<(FleetRuns, FleetStats), SchedReject>;

/// One pending request: its ready-to-run sessions plus the reply channel
/// its connection thread is blocked on.
struct Job {
    sessions: Vec<AnySession>,
    use_streams: bool,
    deadline: Option<Instant>,
    enqueued: Instant,
    reply: mpsc::Sender<Outcome>,
}

/// An admitted request the scheduler is still collecting outputs for.
struct Active {
    out: Vec<Option<(Vec<Event>, SampleStats)>>,
    left: usize,
    /// totals snapshot at admission; the reply reports `totals.since`
    base: FleetStats,
    reply: mpsc::Sender<Outcome>,
}

/// State shared between submitters and the scheduler thread.
struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    stats: SchedStats,
}

/// A continuous-batching scheduler over one routed model pair: a single
/// scheduler thread drives one rolling [`SessionPool`], admitting queued
/// requests (FIFO, whole-request, capped at `max_live` sessions) between
/// engine waves. See the module docs for the admission policy and the
/// bit-exactness argument.
pub struct Scheduler {
    shared: Arc<Shared>,
    cfg: SchedulerCfg,
}

impl Scheduler {
    /// Spawn the scheduler thread for a routed pair.
    pub fn spawn(pair: ModelPair, cfg: SchedulerCfg) -> Arc<Scheduler> {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            stats: SchedStats::default(),
        });
        let thread_shared = shared.clone();
        std::thread::spawn(move || run_loop(pair, cfg, thread_shared));
        Arc::new(Scheduler { shared, cfg })
    }

    /// The scheduler's admission limits.
    pub fn cfg(&self) -> SchedulerCfg {
        self.cfg
    }

    /// The scheduler's counters and gauges.
    pub fn stats(&self) -> &SchedStats {
        &self.shared.stats
    }

    /// Submit a request and block until it resolves: results, or a
    /// structured rejection. `use_streams: false` pins the request's
    /// sessions to full-window forwards (the wire's `cached:false`);
    /// `deadline` bounds the time the request may spend waiting — a
    /// request whose deadline passes before admission is rejected as
    /// [`SchedReject::Expired`] instead of admitted.
    ///
    /// The returned [`FleetStats`] window covers the pool's activity
    /// during the request's residency; when other requests were
    /// co-resident, their waves count too (that sharing is the point —
    /// per-sequence [`SampleStats`] remain exact per request).
    pub fn submit(
        &self,
        sessions: Vec<AnySession>,
        use_streams: bool,
        deadline: Option<Duration>,
    ) -> Outcome {
        let n = sessions.len();
        if n == 0 {
            return Ok((FleetRuns::new(), FleetStats::default()));
        }
        if n > self.cfg.max_live {
            self.shared.stats.shed.fetch_add(1, Ordering::Relaxed);
            return Err(SchedReject::Overloaded(format!(
                "request needs {n} sessions but max_live is {} — it can never be admitted",
                self.cfg.max_live
            )));
        }
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.len() >= self.cfg.queue_depth {
                self.shared.stats.shed.fetch_add(1, Ordering::Relaxed);
                return Err(SchedReject::Overloaded(format!(
                    "admission queue full ({} pending, depth {})",
                    q.len(),
                    self.cfg.queue_depth
                )));
            }
            q.push_back(Job {
                sessions,
                use_streams,
                deadline: deadline.map(|d| Instant::now() + d),
                enqueued: Instant::now(),
                reply: tx,
            });
            self.shared.stats.queued.store(q.len(), Ordering::Relaxed);
        }
        self.shared.cv.notify_one();
        match rx.recv() {
            Ok(outcome) => outcome,
            Err(_) => Err(SchedReject::Failed("scheduler thread terminated".to_string())),
        }
    }

    /// The scheduler's limits, counters and gauges as one JSON object
    /// (the `stats`/`metrics` responses embed this per routed pair).
    pub fn stats_json(&self) -> Json {
        let s = &self.shared.stats;
        let load = |a: &AtomicUsize| Json::Num(a.load(Ordering::Relaxed) as f64);
        obj(vec![
            ("max_live", Json::Num(self.cfg.max_live as f64)),
            ("queue_depth", Json::Num(self.cfg.queue_depth as f64)),
            ("admitted", load(&s.admitted)),
            ("shed", load(&s.shed)),
            ("expired", load(&s.expired)),
            ("completed", load(&s.completed)),
            ("failed", load(&s.failed)),
            ("live_sessions", load(&s.live_sessions)),
            ("queued", load(&s.queued)),
            ("max_live_seen", load(&s.max_live_seen)),
        ])
    }
}

/// The scheduler thread: admit every fitting head-of-queue request, step
/// the pool one wave, deliver retired outputs, repeat. Parks on the
/// condvar when both the pool and the queue are empty.
fn run_loop(pair: ModelPair, cfg: SchedulerCfg, shared: Arc<Shared>) {
    let mut pool: SessionPool<AnySession> = SessionPool::new();
    let mut totals = FleetStats::default();
    let mut active: BTreeMap<u64, Active> = BTreeMap::new();
    let mut next_req: u64 = 0;
    loop {
        // Admission: strict FIFO — pop the head while it fits under
        // max_live; a head that does not fit blocks everything behind it
        // (no overtaking, so big requests cannot starve).
        loop {
            let job = {
                let mut q = shared.queue.lock().unwrap();
                loop {
                    let head_fits =
                        q.front().map(|j| pool.live() + j.sessions.len() <= cfg.max_live);
                    match head_fits {
                        Some(true) => {
                            let j = q.pop_front().expect("non-empty queue");
                            shared.stats.queued.store(q.len(), Ordering::Relaxed);
                            break Some(j);
                        }
                        Some(false) => break None,
                        None if pool.is_empty() => {
                            q = shared.cv.wait(q).unwrap();
                        }
                        None => break None,
                    }
                }
            };
            let Some(job) = job else { break };
            telemetry::record_duration(telemetry::Stage::QueueWait, job.enqueued.elapsed());
            if job.deadline.is_some_and(|dl| Instant::now() >= dl) {
                shared.stats.expired.fetch_add(1, Ordering::Relaxed);
                let _ = job.reply.send(Err(SchedReject::Expired(format!(
                    "deadline passed after {:?} in the admission queue",
                    job.enqueued.elapsed()
                ))));
                continue;
            }
            shared.stats.admitted.fetch_add(1, Ordering::Relaxed);
            let id = next_req;
            next_req += 1;
            let n = job.sessions.len();
            for (idx, s) in job.sessions.into_iter().enumerate() {
                pool.admit(s, (id << 16) | idx as u64, job.use_streams);
            }
            active.insert(
                id,
                Active {
                    out: (0..n).map(|_| None).collect(),
                    left: n,
                    base: totals.clone(),
                    reply: job.reply,
                },
            );
            let live = pool.live();
            shared.stats.live_sessions.store(live, Ordering::Relaxed);
            shared.stats.max_live_seen.fetch_max(live, Ordering::Relaxed);
        }
        if pool.is_empty() {
            continue; // woke with nothing admitted (e.g. every job expired)
        }
        match pool.step(&pair.target, Some(&pair.draft), &mut totals) {
            Ok(done) => {
                for (ticket, events, stats) in done {
                    let id = ticket >> 16;
                    let Some(a) = active.get_mut(&id) else { continue };
                    a.out[(ticket & 0xffff) as usize] = Some((events, stats));
                    a.left -= 1;
                    if a.left == 0 {
                        let a = active.remove(&id).expect("active request");
                        let window = totals.since(&a.base);
                        shared.stats.completed.fetch_add(1, Ordering::Relaxed);
                        let runs: FleetRuns = a
                            .out
                            .into_iter()
                            .map(|o| o.expect("every session retired"))
                            .collect();
                        let _ = a.reply.send(Ok((runs, window)));
                    }
                }
                shared.stats.live_sessions.store(pool.live(), Ordering::Relaxed);
            }
            Err(e) => {
                // A wave failed beyond the retry and stream-recovery
                // ladders: no resident session can make progress. Fail
                // every active request with the cause, release every
                // stream, and keep serving the queue.
                pool.abort(&pair.target, Some(&pair.draft));
                shared.stats.live_sessions.store(0, Ordering::Relaxed);
                let msg = format!("{e:#}");
                for (_, a) in std::mem::take(&mut active) {
                    shared.stats.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = a.reply.send(Err(SchedReject::Failed(msg.clone())));
                }
            }
        }
    }
}

/// Build the ready-to-run sessions of one wire request: one per seed, on
/// the method the request named. This is the single method-dispatch point
/// of the serving path — `sample` is the 1-seed case of `sample_fleet`,
/// and both feed the same scheduler pool.
pub fn build_sessions(
    pair: &ModelPair,
    method: &str,
    gamma: usize,
    cfg: SampleCfg,
    seeds: &[u64],
) -> Result<Vec<AnySession>> {
    match method {
        "ar" => {
            let cap = pair.target.max_bucket();
            Ok(seeds
                .iter()
                .map(|&s| AnySession::Ar(Box::new(ArSession::new(cfg.clone(), cap, Rng::new(s)))))
                .collect())
        }
        "sd" | "sd-adaptive" => {
            let cap = pair.target.max_bucket().min(pair.draft.max_bucket());
            let policy = if method == "sd" {
                Gamma::Fixed(gamma)
            } else {
                Gamma::Adaptive { init: gamma, min: 2, max: 4 * gamma.max(1) }
            };
            let sd = SdCfg { sample: cfg, gamma: policy, ..Default::default() };
            Ok(seeds
                .iter()
                .map(|&s| {
                    AnySession::Sd(Box::new(SdSession::new(sd.clone(), cap, Rng::new(s))))
                })
                .collect())
        }
        other => anyhow::bail!("unknown method '{other}' (ar|sd|sd-adaptive)"),
    }
}
