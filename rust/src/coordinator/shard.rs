//! Shard/replica tier (DESIGN.md §17): `tppsd proxy` — a wire-compatible
//! front-end that routes each request to one of N backend `tppsd serve`
//! replicas.
//!
//! The proxy speaks exactly the protocol of `serve` (a client cannot tell
//! which it is talking to, except for the extra fields in `ping`/`stats`
//! responses) and adds four behaviours:
//!
//! - **Consistent routing**: sample requests hash their
//!   `(dataset, encoder, draft_size)` routing key ([`route_key`], FNV-1a)
//!   to a *home* replica, so each replica's continuous-batching scheduler
//!   keeps co-batching the same model pair and its executors stay hot.
//!   Routing never touches sampler RNG — a seeded request returns
//!   bit-identical events whichever replica serves it
//!   (`rust/tests/shard.rs`).
//! - **Spill-to-least-loaded**: a home replica answering
//!   `err=overloaded` (its admission queue is full — the scheduler's own
//!   load-shedding signal) has the request re-sent once per attempt to
//!   the least-loaded healthy replica instead of being bounced back to
//!   the client. Only when *no* other replica is available does the
//!   overload verdict surface.
//! - **Health checks**: a background prober `ping`s every replica each
//!   [`ShardCfg::health_interval`]; [`ShardCfg::eject_after`] consecutive
//!   failures (probe or transport) eject the replica from routing, and
//!   probes keep running while ejected — one success re-admits it.
//! - **Transparent failover**: sample requests are idempotent (seeded),
//!   so a replica that fails mid-run (`err=failed`/`unavailable`, or a
//!   transport error) has the request retried on another healthy replica
//!   under the existing [`RetryPolicy`] budget (attempts, exponential
//!   backoff, deadline). `err=expired` and `err=bad_request` are returned
//!   verbatim — every replica would answer those identically, so retrying
//!   only burns budget. When the budget runs dry the client gets
//!   `err=upstream_exhausted`.
//!
//! `stats`/`metrics` fan out to every replica and return an aggregated
//! response: a per-backend section (each replica's full response,
//! embedded), the summed scheduler counters across replicas
//! (`schedulers_merged` — gauges `max_live`/`queue_depth`/`max_live_seen`
//! take the max instead), and the proxy's own [`ShardStats`]. Each
//! upstream round-trip is timed under
//! [`Stage::ProxyUpstream`](crate::telemetry::Stage::ProxyUpstream).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::RetryPolicy;
use super::protocol::{
    error_response, response_detail, response_err_code, ErrCode, Request, SampleRequest,
};
use super::server::{Client, CLIENT_READ_TIMEOUT};
use crate::telemetry::{self, Stage};
use crate::util::json::{obj, Json};

/// Health-check and failover knobs of a [`Shard`].
///
/// `#[non_exhaustive]` like every wire-adjacent config struct (ADR-008) —
/// build one with [`ShardCfg::builder`]:
///
/// ```
/// use std::time::Duration;
/// use tpp_sd::coordinator::ShardCfg;
/// let cfg = ShardCfg::builder().eject_after(2).health_interval(Duration::from_millis(50)).build();
/// assert_eq!(cfg.eject_after, 2);
/// ```
#[derive(Debug, Clone)]
#[non_exhaustive]
pub struct ShardCfg {
    /// period of the background `ping` prober ([`Duration::ZERO`]
    /// disables the prober — tests drive health transitions directly)
    pub health_interval: Duration,
    /// consecutive probe/transport failures that eject a replica from
    /// routing (≥ 1); one successful probe re-admits it
    pub eject_after: u32,
    /// failover budget of one sample request: `max_attempts` replicas
    /// tried, exponential `backoff` between failover retries (spills
    /// re-dispatch immediately), all under `deadline`
    pub retry: RetryPolicy,
    /// bound on each upstream TCP dial (a dead replica costs this, not
    /// the OS's SYN retry ladder)
    pub connect_timeout: Duration,
    /// read timeout of pooled upstream connections (covers one full
    /// sample round-trip)
    pub read_timeout: Duration,
}

impl Default for ShardCfg {
    fn default() -> Self {
        ShardCfg {
            health_interval: Duration::from_millis(250),
            eject_after: 3,
            retry: RetryPolicy::default(),
            connect_timeout: Duration::from_secs(2),
            read_timeout: CLIENT_READ_TIMEOUT,
        }
    }
}

impl ShardCfg {
    /// A builder starting from the defaults (the only way to construct
    /// one outside this crate — the struct is `#[non_exhaustive]`).
    pub fn builder() -> ShardCfgBuilder {
        ShardCfgBuilder::default()
    }
}

/// Builder for [`ShardCfg`] — starts from the defaults; every setter is
/// optional and chainable.
#[derive(Debug, Clone, Default)]
pub struct ShardCfgBuilder {
    cfg: ShardCfg,
}

impl ShardCfgBuilder {
    /// period of the background `ping` prober (`Duration::ZERO` disables)
    pub fn health_interval(mut self, v: Duration) -> Self {
        self.cfg.health_interval = v;
        self
    }
    /// consecutive failures that eject a replica (clamped ≥ 1)
    pub fn eject_after(mut self, v: u32) -> Self {
        self.cfg.eject_after = v.max(1);
        self
    }
    /// failover budget (attempts / backoff / deadline)
    pub fn retry(mut self, v: RetryPolicy) -> Self {
        self.cfg.retry = v;
        self
    }
    /// bound on each upstream TCP dial
    pub fn connect_timeout(mut self, v: Duration) -> Self {
        self.cfg.connect_timeout = v;
        self
    }
    /// read timeout of pooled upstream connections
    pub fn read_timeout(mut self, v: Duration) -> Self {
        self.cfg.read_timeout = v;
        self
    }
    /// Finish the builder.
    pub fn build(self) -> ShardCfg {
        self.cfg
    }
}

/// Lock-free proxy-tier counters, the shard's reconciliation surface
/// (`rust/tests/shard.rs` pins them against client-observed outcomes).
/// Serialized into every aggregated `stats`/`metrics` response and
/// printed by [`crate::bench::shard_report`].
#[derive(Debug, Default)]
pub struct ShardStats {
    /// sample requests dispatched through the shard (each counted once,
    /// however many attempts it took)
    pub routed: AtomicUsize,
    /// re-dispatches to the least-loaded replica after a home
    /// `err=overloaded` verdict
    pub spilled: AtomicUsize,
    /// failover retries on another replica after a replica failure
    /// (structured `failed`/`unavailable` or a transport error)
    pub failovers: AtomicUsize,
    /// replicas ejected from routing after consecutive failures
    pub ejections: AtomicUsize,
    /// ejected replicas re-admitted by a successful probe
    pub readmissions: AtomicUsize,
    /// individual upstream attempts that failed (transport or structured
    /// replica failure)
    pub upstream_errors: AtomicUsize,
    /// `stats`/`metrics` fan-outs served
    pub fanouts: AtomicUsize,
}

/// Grow cap of each backend's idle-connection free list.
const CONN_POOL_CAP: usize = 4;

/// Backoff growth cap between failover retries.
const MAX_FAILOVER_BACKOFF: Duration = Duration::from_millis(100);

/// Mutable slot state: health + the idle-connection free list.
struct SlotState {
    healthy: bool,
    consecutive_failures: u32,
    pool: Vec<Client>,
}

/// One backend replica: its address, health state, idle-connection pool
/// and per-backend counters.
pub struct BackendSlot {
    /// the `host:port` string the proxy was configured with
    pub label: String,
    /// the resolved socket address
    pub addr: SocketAddr,
    state: Mutex<SlotState>,
    in_flight: AtomicUsize,
    /// successful sample responses served by this replica
    pub served: AtomicUsize,
    /// failed upstream attempts against this replica
    pub errors: AtomicUsize,
}

impl BackendSlot {
    fn new(label: &str) -> Result<BackendSlot> {
        let addr = label
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow::anyhow!("cannot resolve backend address {label}"))?;
        Ok(BackendSlot {
            label: label.to_string(),
            addr,
            state: Mutex::new(SlotState {
                healthy: true,
                consecutive_failures: 0,
                pool: Vec::new(),
            }),
            in_flight: AtomicUsize::new(0),
            served: AtomicUsize::new(0),
            errors: AtomicUsize::new(0),
        })
    }

    /// Is this replica currently in the routing set?
    pub fn healthy(&self) -> bool {
        self.state.lock().unwrap().healthy
    }

    /// Upstream calls in flight right now (the spill target picks the
    /// minimum of these).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// Consecutive probe/transport failures so far.
    pub fn consecutive_failures(&self) -> u32 {
        self.state.lock().unwrap().consecutive_failures
    }

    /// Record a probe/transport failure; returns true when this crossed
    /// the ejection threshold (healthy → ejected). Pooled connections to
    /// a failing replica are dropped — they are suspect.
    fn note_failure(&self, eject_after: u32) -> bool {
        let mut st = self.state.lock().unwrap();
        st.consecutive_failures = st.consecutive_failures.saturating_add(1);
        st.pool.clear();
        if st.healthy && st.consecutive_failures >= eject_after {
            st.healthy = false;
            return true;
        }
        false
    }

    /// Record a successful round-trip; returns true when this re-admitted
    /// an ejected replica.
    fn note_success(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        st.consecutive_failures = 0;
        if !st.healthy {
            st.healthy = true;
            return true;
        }
        false
    }

    /// One upstream round-trip over a pooled (or fresh) connection. On
    /// success the connection returns to the free list; on error it is
    /// dropped — a half-read line would desynchronize the stream.
    fn call(&self, line: &str, cfg: &ShardCfg) -> Result<String> {
        let pooled = self.state.lock().unwrap().pool.pop();
        let mut cli = match pooled {
            Some(c) => c,
            None => {
                let c = Client::connect_timeout(self.addr, cfg.connect_timeout)?;
                c.set_read_timeout(Some(cfg.read_timeout))?;
                c
            }
        };
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        let out = {
            let _span = telemetry::Span::start(Stage::ProxyUpstream);
            cli.call_line(line)
        };
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
        let resp = out?;
        let mut st = self.state.lock().unwrap();
        if st.pool.len() < CONN_POOL_CAP {
            st.pool.push(cli);
        }
        Ok(resp)
    }

    fn json(&self) -> Json {
        let st = self.state.lock().unwrap();
        obj(vec![
            ("addr", Json::Str(self.label.clone())),
            ("healthy", Json::Bool(st.healthy)),
            ("consecutive_failures", Json::Num(st.consecutive_failures as f64)),
            ("in_flight", Json::Num(self.in_flight.load(Ordering::Relaxed) as f64)),
            ("served", Json::Num(self.served.load(Ordering::Relaxed) as f64)),
            ("errors", Json::Num(self.errors.load(Ordering::Relaxed) as f64)),
        ])
    }
}

/// Consistent-routing key of a sample request: FNV-1a over
/// `(dataset, encoder, draft_size)`. Deterministic across processes and
/// runs (no `RandomState`), so tests — and operators reading logs — can
/// predict a request's home replica.
pub fn route_key(dataset: &str, encoder: &str, draft_size: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for part in [dataset, "/", encoder, "/", draft_size] {
        for b in part.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// The home replica index of a routing key among `n` backends.
pub fn home_index(key: u64, n: usize) -> usize {
    (key % n.max(1) as u64) as usize
}

/// The routing/health/failover core of the proxy tier: N backend replicas
/// plus the policy that picks one per request. See the module docs for
/// the four behaviours; [`ProxyServer`] is the TCP front-end over this.
pub struct Shard {
    backends: Vec<Arc<BackendSlot>>,
    cfg: ShardCfg,
    stats: Arc<ShardStats>,
}

impl Shard {
    /// Build a shard over `host:port` backend addresses and start the
    /// background health prober (unless `cfg.health_interval` is zero).
    pub fn new(addrs: &[String], cfg: ShardCfg) -> Result<Shard> {
        anyhow::ensure!(!addrs.is_empty(), "a shard needs at least one backend address");
        let mut backends = Vec::with_capacity(addrs.len());
        for a in addrs {
            backends.push(Arc::new(BackendSlot::new(a)?));
        }
        let stats = Arc::new(ShardStats::default());
        if cfg.health_interval > Duration::ZERO {
            let backends = backends.clone();
            let stats = stats.clone();
            let cfg = cfg.clone();
            std::thread::spawn(move || health_loop(&backends, &stats, &cfg));
        }
        Ok(Shard { backends, cfg, stats })
    }

    /// The proxy-tier counters.
    pub fn stats(&self) -> &ShardStats {
        &self.stats
    }

    /// The backend replicas, in configuration order.
    pub fn backends(&self) -> &[Arc<BackendSlot>] {
        &self.backends
    }

    /// Replicas currently in the routing set.
    pub fn healthy_count(&self) -> usize {
        self.backends.iter().filter(|b| b.healthy()).count()
    }

    /// The [`ShardStats`] + per-backend health as one JSON object (the
    /// `"shard"` section of aggregated responses).
    pub fn stats_json(&self) -> Json {
        let load = |a: &AtomicUsize| Json::Num(a.load(Ordering::Relaxed) as f64);
        obj(vec![
            ("routed", load(&self.stats.routed)),
            ("spilled", load(&self.stats.spilled)),
            ("failovers", load(&self.stats.failovers)),
            ("ejections", load(&self.stats.ejections)),
            ("readmissions", load(&self.stats.readmissions)),
            ("upstream_errors", load(&self.stats.upstream_errors)),
            ("fanouts", load(&self.stats.fanouts)),
            ("healthy", Json::Num(self.healthy_count() as f64)),
            (
                "backends",
                Json::Arr(self.backends.iter().map(|b| b.json()).collect()),
            ),
        ])
    }

    /// Serve one parsed request: answer `ping` locally, fan `stats`/
    /// `metrics` out to every replica, and route/failover sample
    /// requests. Always returns a response line (errors are structured,
    /// never panics across the wire).
    pub fn dispatch(&self, req: &Request) -> String {
        match req {
            Request::Ping => obj(vec![
                ("ok", Json::Bool(true)),
                ("pong", Json::Bool(true)),
                ("proxy", Json::Bool(true)),
                ("backends", Json::Num(self.backends.len() as f64)),
                ("healthy", Json::Num(self.healthy_count() as f64)),
            ])
            .to_string(),
            Request::Stats | Request::Metrics { .. } => self.fan_out(&req.to_line()),
            Request::Sample(s) | Request::SampleFleet(s) => self.proxy_sample(s, &req.to_line()),
        }
    }

    /// The eligible replica for the next attempt: the home replica when
    /// it is healthy and untried, else the least-loaded healthy untried
    /// one (ties break on configuration order). `None` when the routing
    /// set is exhausted.
    fn pick(&self, home: usize, tried: &[usize]) -> Option<usize> {
        let eligible = |i: usize| !tried.contains(&i) && self.backends[i].healthy();
        if eligible(home) {
            return Some(home);
        }
        self.backends
            .iter()
            .enumerate()
            .filter(|(i, _)| eligible(*i))
            .min_by_key(|(i, b)| (b.in_flight(), *i))
            .map(|(i, _)| i)
    }

    /// Route one idempotent sample request: home replica first, then
    /// spill (on `overloaded`) or failover (on replica failure) per the
    /// module-level policy, all under the [`RetryPolicy`] budget.
    fn proxy_sample(&self, s: &SampleRequest, line: &str) -> String {
        self.stats.routed.fetch_add(1, Ordering::Relaxed);
        let (dataset, encoder, draft_size) = s.route_fields();
        let home = home_index(route_key(dataset, encoder, draft_size), self.backends.len());
        let deadline = Instant::now() + self.cfg.retry.deadline;
        let mut backoff = self.cfg.retry.backoff;
        let mut tried: Vec<usize> = Vec::new();
        let mut last_err = String::from("no replica attempted");
        for _attempt in 0..self.cfg.retry.max_attempts.max(1) {
            let Some(idx) = self.pick(home, &tried) else { break };
            let slot = &self.backends[idx];
            match slot.call(line, &self.cfg) {
                Ok(resp) => match response_err_code(&resp) {
                    None => {
                        if slot.note_success() {
                            self.stats.readmissions.fetch_add(1, Ordering::Relaxed);
                        }
                        slot.served.fetch_add(1, Ordering::Relaxed);
                        return resp;
                    }
                    // The replica's own admission control shed the
                    // request: spill to the least-loaded other replica
                    // (immediately — the cluster is not in trouble, one
                    // queue is). No other replica left ⇒ the overload
                    // verdict stands.
                    Some(ErrCode::Overloaded) => {
                        tried.push(idx);
                        if self.pick(home, &tried).is_none() {
                            return resp;
                        }
                        self.stats.spilled.fetch_add(1, Ordering::Relaxed);
                        last_err = format!("{} overloaded", slot.label);
                        continue;
                    }
                    // Deterministic verdicts: every replica would answer
                    // these identically, so retrying only burns budget.
                    Some(ErrCode::Expired) | Some(ErrCode::BadRequest) => return resp,
                    // Replica-local failure (err=failed/unavailable/…):
                    // the request is idempotent — fail over. The replica
                    // itself is still answering, so this does not count
                    // toward ejection (the prober owns that verdict).
                    Some(_) => {
                        slot.errors.fetch_add(1, Ordering::Relaxed);
                        self.stats.upstream_errors.fetch_add(1, Ordering::Relaxed);
                        tried.push(idx);
                        last_err = format!("{}: {}", slot.label, response_detail(&resp));
                    }
                },
                // Transport failure: fail over AND count toward ejection.
                Err(e) => {
                    slot.errors.fetch_add(1, Ordering::Relaxed);
                    self.stats.upstream_errors.fetch_add(1, Ordering::Relaxed);
                    if slot.note_failure(self.cfg.eject_after) {
                        self.stats.ejections.fetch_add(1, Ordering::Relaxed);
                    }
                    tried.push(idx);
                    last_err = format!("{}: {e:#}", slot.label);
                }
            }
            if Instant::now() >= deadline || self.pick(home, &tried).is_none() {
                break;
            }
            self.stats.failovers.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(backoff.min(MAX_FAILOVER_BACKOFF));
            backoff = backoff.saturating_mul(2).min(MAX_FAILOVER_BACKOFF);
        }
        if tried.is_empty() {
            return error_response(
                ErrCode::Unavailable,
                &format!(
                    "no healthy backend for {dataset}/{encoder}/{draft_size} ({} replicas, 0 in the routing set)",
                    self.backends.len()
                ),
            );
        }
        error_response(
            ErrCode::UpstreamExhausted,
            &format!(
                "sample failed on every available replica ({} tried, last: {last_err})",
                tried.len()
            ),
        )
    }

    /// Fan one `stats`/`metrics` line out to every replica and aggregate:
    /// per-backend sections, merged scheduler counters, shard counters.
    fn fan_out(&self, line: &str) -> String {
        self.stats.fanouts.fetch_add(1, Ordering::Relaxed);
        let mut sections = Vec::new();
        let mut merged: BTreeMap<String, f64> = BTreeMap::new();
        let mut merged_pairs = 0usize;
        let mut any_ok = false;
        for slot in &self.backends {
            let section = match slot.call(line, &self.cfg) {
                Ok(resp) => match Json::parse(resp.trim()) {
                    Ok(j) => {
                        let ok = j.get("ok") == Some(&Json::Bool(true));
                        any_ok |= ok;
                        if ok {
                            merge_scheduler_counters(&j, &mut merged, &mut merged_pairs);
                        }
                        obj(vec![
                            ("addr", Json::Str(slot.label.clone())),
                            ("healthy", Json::Bool(slot.healthy())),
                            ("ok", Json::Bool(ok)),
                            ("response", j),
                        ])
                    }
                    Err(e) => backend_error_section(slot, &format!("unparseable response: {e}")),
                },
                Err(e) => backend_error_section(slot, &format!("{e:#}")),
            };
            sections.push(section);
        }
        if !any_ok {
            return error_response(ErrCode::Unavailable, "no backend answered the fan-out");
        }
        let mut merged_fields: Vec<(&str, Json)> = merged
            .iter()
            .map(|(k, v)| (k.as_str(), Json::Num(*v)))
            .collect();
        merged_fields.push(("pairs", Json::Num(merged_pairs as f64)));
        obj(vec![
            ("ok", Json::Bool(true)),
            ("backends", Json::Arr(sections)),
            ("schedulers_merged", obj(merged_fields)),
            ("shard", self.stats_json()),
        ])
        .to_string()
    }
}

/// The per-backend section of a fan-out when the replica could not be
/// queried (section-level failure, not response-level).
fn backend_error_section(slot: &BackendSlot, detail: &str) -> Json {
    obj(vec![
        ("addr", Json::Str(slot.label.clone())),
        ("healthy", Json::Bool(slot.healthy())),
        ("ok", Json::Bool(false)),
        ("detail", Json::Str(detail.to_string())),
    ])
}

/// Sum one backend's per-pair scheduler counters into `merged`.
/// Configured limits and high-water marks (`max_live`, `queue_depth`,
/// `max_live_seen`) take the max — summing a cap across replicas would
/// fabricate capacity the cluster does not have.
fn merge_scheduler_counters(
    resp: &Json,
    merged: &mut BTreeMap<String, f64>,
    pairs: &mut usize,
) {
    let Some(entries) = resp.get("schedulers").and_then(Json::as_arr) else {
        return;
    };
    for entry in entries {
        let Some(stats) = entry.get("stats").and_then(Json::as_obj) else {
            continue;
        };
        *pairs += 1;
        for (k, v) in stats {
            let Some(x) = v.as_f64() else { continue };
            let slot = merged.entry(k.clone()).or_insert(0.0);
            if matches!(k.as_str(), "max_live" | "queue_depth" | "max_live_seen") {
                *slot = slot.max(x);
            } else {
                *slot += x;
            }
        }
    }
}

/// The background prober: `ping` every replica each interval; failures
/// count toward ejection, one success re-admits. Runs for the process
/// lifetime (like the server's accept loop).
fn health_loop(backends: &[Arc<BackendSlot>], stats: &ShardStats, cfg: &ShardCfg) {
    let ping = Request::Ping.to_line();
    loop {
        for b in backends {
            if probe(b, cfg, &ping) {
                if b.note_success() {
                    stats.readmissions.fetch_add(1, Ordering::Relaxed);
                }
            } else if b.note_failure(cfg.eject_after) {
                stats.ejections.fetch_add(1, Ordering::Relaxed);
            }
        }
        std::thread::sleep(cfg.health_interval);
    }
}

/// One health probe: fresh connection (a wedged pooled connection must
/// not mask a live replica, or vice versa), short timeout, `ping`.
fn probe(b: &BackendSlot, cfg: &ShardCfg, ping: &str) -> bool {
    let Ok(mut c) = Client::connect_timeout(b.addr, cfg.connect_timeout) else {
        return false;
    };
    if c.set_read_timeout(Some(cfg.connect_timeout)).is_err() {
        return false;
    }
    matches!(c.call_line(ping), Ok(r) if r.contains("\"ok\":true"))
}

/// The TCP front-end of the shard tier: accept loop + per-connection
/// threads, every line answered by [`Shard::dispatch`]. Bound by
/// `tppsd proxy`; embed it the same way as
/// [`Server`](super::server::Server) (see `rust/tests/shard.rs`).
pub struct ProxyServer {
    /// the bound address (useful with port 0)
    pub addr: SocketAddr,
    listener: TcpListener,
    shard: Arc<Shard>,
}

impl ProxyServer {
    /// Bind the proxy (port 0 for an ephemeral port) over `host:port`
    /// backend replica addresses.
    pub fn bind(host_port: &str, backends: &[String], cfg: ShardCfg) -> Result<ProxyServer> {
        let shard = Arc::new(Shard::new(backends, cfg)?);
        let listener = TcpListener::bind(host_port)?;
        let addr = listener.local_addr()?;
        Ok(ProxyServer { addr, listener, shard })
    }

    /// Shared handle to the routing core (stats, tests).
    pub fn shard(&self) -> Arc<Shard> {
        self.shard.clone()
    }

    /// Accept loop; blocks forever. Call from a dedicated thread when
    /// embedding.
    pub fn serve(&self) -> Result<()> {
        for stream in self.listener.incoming() {
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let shard = self.shard.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, &shard);
            });
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, shard: &Shard) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // connection closed
        }
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Request::parse(&line) {
            Ok(req) => shard.dispatch(&req),
            Err(e) => error_response(ErrCode::BadRequest, &format!("{e:#}")),
        };
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A shard with no prober and unreachable (but resolvable) backends —
    /// routing policy is testable without sockets.
    fn offline_shard(n: usize) -> Shard {
        let addrs: Vec<String> =
            (0..n).map(|i| format!("127.0.0.1:{}", 1 + i)).collect();
        let cfg = ShardCfg::builder().health_interval(Duration::ZERO).build();
        Shard::new(&addrs, cfg).unwrap()
    }

    #[test]
    fn route_key_is_deterministic_and_spreads() {
        let k1 = route_key("hawkes", "attnhp", "draft");
        assert_eq!(k1, route_key("hawkes", "attnhp", "draft"));
        // the separator matters: ("ab","c") must not collide with ("a","bc")
        assert_ne!(route_key("ab", "c", "d"), route_key("a", "bc", "d"));
        // distinct pairs land on more than one replica out of 3
        let homes: std::collections::BTreeSet<usize> = [
            ("hawkes", "thp"),
            ("hawkes", "sahp"),
            ("hawkes", "attnhp"),
            ("taxi_sim", "thp"),
            ("taxi_sim", "attnhp"),
            ("self_correcting", "sahp"),
        ]
        .iter()
        .map(|(d, e)| home_index(route_key(d, e, "draft"), 3))
        .collect();
        assert!(homes.len() > 1, "all pairs hashed to one replica: {homes:?}");
        assert!(homes.iter().all(|&h| h < 3));
        // n is clamped so home_index never divides by zero
        assert_eq!(home_index(route_key("a", "b", "c"), 0), 0);
    }

    #[test]
    fn health_transitions_eject_and_readmit() {
        let shard = offline_shard(1);
        let b = &shard.backends()[0];
        assert!(b.healthy());
        assert!(!b.note_failure(3));
        assert!(!b.note_failure(3));
        assert!(b.note_failure(3), "third consecutive failure ejects");
        assert!(!b.healthy());
        assert!(!b.note_failure(3), "already ejected: no double-count");
        assert!(b.note_success(), "one success re-admits");
        assert!(b.healthy());
        assert_eq!(b.consecutive_failures(), 0);
        assert!(!b.note_success(), "healthy stays healthy: no re-admission count");
    }

    #[test]
    fn pick_prefers_home_then_least_loaded_healthy() {
        let shard = offline_shard(3);
        assert_eq!(shard.pick(1, &[]), Some(1), "healthy home wins");
        // home tried: least-loaded other replica wins
        shard.backends()[0].in_flight.store(5, Ordering::Relaxed);
        shard.backends()[2].in_flight.store(2, Ordering::Relaxed);
        assert_eq!(shard.pick(1, &[1]), Some(2));
        // ejected replicas leave the routing set
        shard.backends()[2].note_failure(1);
        assert_eq!(shard.pick(1, &[1]), Some(0));
        shard.backends()[0].note_failure(1);
        assert_eq!(shard.pick(1, &[1]), None, "routing set exhausted");
        assert_eq!(shard.healthy_count(), 1);
    }

    #[test]
    fn stats_json_has_every_counter_and_backend_section() {
        let shard = offline_shard(2);
        shard.stats().routed.store(7, Ordering::Relaxed);
        shard.stats().spilled.store(1, Ordering::Relaxed);
        let j = shard.stats_json();
        for key in [
            "routed",
            "spilled",
            "failovers",
            "ejections",
            "readmissions",
            "upstream_errors",
            "fanouts",
            "healthy",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.f64_at("routed"), Some(7.0));
        assert_eq!(j.f64_at("healthy"), Some(2.0));
        let backends = j.get("backends").and_then(Json::as_arr).unwrap();
        assert_eq!(backends.len(), 2);
        for b in backends {
            for key in
                ["addr", "healthy", "consecutive_failures", "in_flight", "served", "errors"]
            {
                assert!(b.get(key).is_some(), "missing backend key {key}");
            }
        }
    }

    #[test]
    fn merged_counters_sum_counts_and_max_limits() {
        let mk = |completed: f64, max_live: f64| {
            format!(
                r#"{{"ok":true,"schedulers":[{{"chaos":"","pair":"p","stats":{{"completed":{completed},"max_live":{max_live},"shed":1}}}}]}}"#
            )
        };
        let mut merged = BTreeMap::new();
        let mut pairs = 0;
        for line in [mk(3.0, 64.0), mk(4.0, 16.0)] {
            merge_scheduler_counters(&Json::parse(&line).unwrap(), &mut merged, &mut pairs);
        }
        assert_eq!(pairs, 2);
        assert_eq!(merged.get("completed"), Some(&7.0));
        assert_eq!(merged.get("shed"), Some(&2.0));
        assert_eq!(merged.get("max_live"), Some(&64.0), "caps take max, not sum");
    }
}
