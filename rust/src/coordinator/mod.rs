//! Layer-3 serving coordinator (the vLLM-router-shaped part of the repo):
//! per-model batching executors, a lazy model router, and a TCP front-end.
//!
//! Architecture (thread-based — the offline registry has no tokio, and the
//! workload is CPU-bound on a single PJRT device, so a reactor would add
//! nothing; bounded channels give the same backpressure):
//!
//! ```text
//!   client conns ──> session threads ──┐
//!                                      ├─> ExecutorHandle(target) ─┐
//!        (sampler code, generic over   │      batching thread      ├─ Backend
//!         runtime::Forward)            ├─> ExecutorHandle(draft)  ─┘  (native
//!                                      │      batching thread         or xla)
//!   Router: (dataset, encoder) ────────┘
//! ```

pub mod batcher;
pub mod protocol;
pub mod router;
pub mod server;

pub use batcher::{BatcherStats, ExecutorHandle, RetryPolicy};
pub use protocol::{FleetRequest, Request, SampleRequest};
pub use router::{ModelPair, Router};
pub use server::{Client, Server};
