//! Layer-3 serving coordinator (the vLLM-router-shaped part of the repo):
//! per-model batching executors, a lazy model router, a continuous-batching
//! scheduler with admission control, and a TCP front-end.
//!
//! Architecture (thread-based — the offline registry has no tokio, and the
//! workload is CPU-bound on a single PJRT device, so a reactor would add
//! nothing; bounded channels give the same backpressure):
//!
//! ```text
//!   client conns ──> tppsd proxy (optional shard tier, DESIGN.md §17)
//!                      │ consistent routing by (dataset,encoder,draft_size)
//!                      │ health checks · spill on overload · failover
//!                      v
//!   replica conns ──> session threads ──┐ build_sessions + submit
//!                                       v
//!   Scheduler (per routed pair): bounded FIFO admission queue
//!        │   max_live cap, deadline check, shed when full
//!        v
//!   SessionPool: one rolling wave over ALL admitted requests
//!        │ co-batched forwards per ModelRole
//!        ├─> ExecutorHandle(target) ─┐
//!        │      batching thread      ├─ worker pool ─ Backend
//!        └─> ExecutorHandle(draft)  ─┘               (native or xla)
//!
//!   Router: (dataset, encoder, draft_size) -> {executor pair, scheduler}
//! ```
//!
//! Requests flow top to bottom: a connection thread parses one JSON line,
//! builds one resumable session per requested sequence
//! ([`scheduler::build_sessions`]), and blocks on
//! [`scheduler::Scheduler::submit`]. The per-pair scheduler admits whole
//! requests FIFO into its shared [`crate::sampler::SessionPool`], so
//! sequences from *different* requests share the same batched draft and
//! target forwards — and the admission queue is bounded, so overload turns
//! into structured `{"ok":false,"err":"overloaded"}` rejections instead of
//! unbounded queueing (DESIGN.md §16; `docs/OPERATIONS.md` documents every
//! wire op).
//!
//! End-to-end (this is the whole client surface — one JSON line each way):
//!
//! ```
//! use std::time::Duration;
//! use tpp_sd::coordinator::{Client, Request, SampleRequest, Server};
//!
//! let backend = tpp_sd::runtime::discover_backend().unwrap();
//! let server = Server::bind(backend, "127.0.0.1:0", 8, Duration::from_millis(1)).unwrap();
//! let addr = server.addr; // port 0 -> ephemeral, read it back
//! std::thread::spawn(move || server.serve());
//!
//! let mut client = Client::connect(addr).unwrap();
//! let req = Request::Sample(SampleRequest::builder().t_end(5.0).build());
//! let line = client.call(&req).unwrap();
//! assert!(line.contains("\"ok\":true"), "unexpected response: {line}");
//! ```
//!
//! For horizontal scale, any number of such servers become replicas
//! behind `tppsd proxy` (the [`shard`] module): same wire protocol, one
//! address, health-checked failover.

pub mod batcher;
pub mod protocol;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod shard;

pub use batcher::{BatcherStats, ExecutorHandle, RetryPolicy};
pub use protocol::{ErrCode, Request, SampleRequest, SampleRequestBuilder};
pub use router::{ModelPair, Router};
pub use scheduler::{
    build_sessions, SchedReject, SchedStats, Scheduler, SchedulerCfg, SchedulerCfgBuilder,
};
pub use server::{Client, Server};
pub use shard::{ProxyServer, Shard, ShardCfg, ShardCfgBuilder, ShardStats};
