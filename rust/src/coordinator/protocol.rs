//! Wire protocol of the sampling server: one JSON object per line.
//!
//! **v2** (canonical, ADR-008) — one `sample` op covers both the single
//! sequence and the fleet case via `n_seq` (default 1):
//!
//!   {"v":2,"op":"sample","dataset":"hawkes","encoder":"attnhp",
//!    "method":"sd","gamma":10,"t_end":30.0,"seed":1,"draft_size":"draft",
//!    "cached":true,"n_seq":8}
//!   {"op":"ping"} | {"op":"stats"} | {"op":"metrics","delta":false}
//!
//! **v1** (compatibility aliases, parsed forever): requests without a
//! `"v"` field (or with `"v":1`) keep their original meaning, and the old
//! `sample_fleet` op still parses — it is the same merged request with a
//! sequences-shaped response. The version gate is strict: a `"v"` other
//! than 1 or 2 is rejected rather than guessed at.
//!
//! `metrics` returns the full telemetry snapshot (per-stage latency
//! p50/p95/p99 + per-role acceptance, DESIGN.md §15) plus every
//! executor's batcher counters; `"delta":true` reports only the activity
//! since the connection's previous `metrics` call — the windowed readout
//! `serve.rs` prints between phases. `stats` includes the same executor
//! counters next to the session/router tallies.
//!
//! `"cached"` (default `true`) lets the sampler use the backend's
//! incremental-forward streams (DESIGN.md §12) when it has them;
//! `false` forces full-window forwards — the A/B knob behind
//! `bench_cached_forward`. Both paths return bit-identical events for the
//! same seed (`rust/tests/cached_forward.rs`), so the flag only moves
//! wall-clock, never a probability.
//!
//! `"chaos"` (default `""` = off) injects deterministic faults into the
//! request's backend from a [`crate::runtime::chaos::FaultPlan`] spec such
//! as `"seed=7,err=0.2,loss=0.1"` (DESIGN.md §13). Recoverable plans
//! return bit-identical events to the fault-free run — that is the point
//! — while unrecoverable ones surface as a structured error instead of a
//! hang.
//!
//! `"deadline_ms"` (default `0` = none) bounds the time a request may
//! wait in the scheduler's admission queue (DESIGN.md §16): a request
//! whose deadline passes before admission is rejected with
//! `err=expired` instead of admitted to do work nobody is waiting for. A
//! full admission queue sheds the request immediately with
//! `err=overloaded`.
//!
//! Response:
//!   {"ok":true,"events":[[t,k],...],"stats":{...}}
//!   {"ok":true,"sequences":[[[t,k],...],...],"stats":{...},"fleet":{...}}
//!   {"ok":false,"err":<code>,"detail":"...","error":"..."}
//!
//! **Errors are structured everywhere**: every failure carries a stable
//! machine-readable `"err"` code from the closed [`ErrCode`] enum next to
//! the human-readable `"detail"` text, built by the one shared
//! [`error_response`] constructor (server, scheduler rejections, chaos
//! paths and the proxy tier all go through it). `"error"` duplicates
//! `"detail"` for v1 clients that predate the code field.
//!
//! A sequences-shaped response runs `n_seq` sequences in lockstep on the
//! fleet engine (DESIGN.md §11); sequence `i` is seeded `seed + i`, so
//! its events are bit-for-bit what a request with `seed + i` and
//! `n_seq:1` would return. The server rejects `n_seq` beyond its
//! per-request cap (64) with `err=bad_request` rather than truncating.
//! The response's `wall_ms` is the fleet's wall-clock (longest session),
//! not the per-sequence sum.

use anyhow::{bail, Result};

use crate::events::Event;
use crate::sampler::{FleetStats, SampleStats};
use crate::util::json::{obj, Json};

/// The closed set of machine-readable error codes every `{"ok":false}`
/// response carries in its `"err"` field (ADR-008). Codes are stable wire
/// strings — clients branch on them (back off, drop, retry elsewhere)
/// without parsing prose, and the proxy tier's failover policy is keyed
/// entirely off this enum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    /// admission control shed the request (queue full / can never fit);
    /// retrying *elsewhere* is reasonable, retrying *here* immediately is
    /// not
    Overloaded,
    /// the request's deadline passed before admission; retrying cannot
    /// help — the client already stopped waiting
    Expired,
    /// the serving replica failed mid-run (a wave failed beyond every
    /// retry and recovery ladder); the request is idempotent, so another
    /// replica may succeed
    Failed,
    /// the request itself is malformed (unknown op/dataset/method, bad
    /// version, over-cap `n_seq`); every replica will reject it the same
    /// way
    BadRequest,
    /// no backend is available to serve the request (proxy tier: every
    /// replica ejected)
    Unavailable,
    /// the proxy exhausted its failover budget without any replica
    /// returning a result
    UpstreamExhausted,
}

impl ErrCode {
    /// Every code, in wire/report order.
    pub const ALL: [ErrCode; 6] = [
        ErrCode::Overloaded,
        ErrCode::Expired,
        ErrCode::Failed,
        ErrCode::BadRequest,
        ErrCode::Unavailable,
        ErrCode::UpstreamExhausted,
    ];

    /// The stable snake_case wire string of the `"err"` field.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrCode::Overloaded => "overloaded",
            ErrCode::Expired => "expired",
            ErrCode::Failed => "failed",
            ErrCode::BadRequest => "bad_request",
            ErrCode::Unavailable => "unavailable",
            ErrCode::UpstreamExhausted => "upstream_exhausted",
        }
    }

    /// Parse a wire string back into its code.
    pub fn parse(s: &str) -> Option<ErrCode> {
        ErrCode::ALL.into_iter().find(|c| c.as_str() == s)
    }
}

impl std::fmt::Display for ErrCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One client request (one JSON object per line).
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// liveness check
    Ping,
    /// server-side counters
    Stats,
    /// full telemetry snapshot (per-stage latency + acceptance, DESIGN.md
    /// §15); `delta` reports only the activity since this connection's
    /// previous `metrics` call
    Metrics {
        /// window the snapshot against the connection's previous call
        delta: bool,
    },
    /// sample `n_seq` sequences (the merged v2 op). The response is
    /// events-shaped when `n_seq == 1` and sequences-shaped otherwise.
    Sample(SampleRequest),
    /// the v1 `sample_fleet` alias: the same merged request, but the
    /// response is *always* sequences-shaped (even at `n_seq == 1`),
    /// exactly as v1 clients expect
    SampleFleet(SampleRequest),
}

/// Parameters of a `sample` request (v2 merged op: `n_seq` sequences in
/// lockstep, default 1).
///
/// The struct is `#[non_exhaustive]` so new wire knobs (this PR added
/// `n_seq`; the shard tier will add more) never break downstream
/// constructors — build one with [`SampleRequest::builder`]:
///
/// ```
/// use tpp_sd::coordinator::SampleRequest;
/// let req = SampleRequest::builder().t_end(5.0).seed(3).n_seq(2).build();
/// assert_eq!(req.n_seq, 2);
/// assert_eq!(req.dataset, "hawkes"); // wire default
/// ```
#[derive(Debug, Clone, PartialEq)]
#[non_exhaustive]
pub struct SampleRequest {
    /// dataset name from the registry
    pub dataset: String,
    /// encoder name (`thp` | `sahp` | `attnhp`)
    pub encoder: String,
    /// "ar" | "sd" | "sd-adaptive"
    pub method: String,
    /// draft length γ (initial γ for `sd-adaptive`)
    pub gamma: usize,
    /// sampling window end T
    pub t_end: f64,
    /// RNG seed of sequence 0 (sequence `i` is seeded `seed + i`)
    pub seed: u64,
    /// draft model size (`draft` | `draft2` | `draft3`)
    pub draft_size: String,
    /// use the backend's incremental-forward streams when available
    /// (default `true`; `false` forces full-window forwards)
    pub cached: bool,
    /// fault-injection spec (`""` = off), e.g. `"seed=7,err=0.2"` —
    /// parsed by [`crate::runtime::chaos::FaultPlan::parse`]
    pub chaos: String,
    /// most milliseconds the request may wait for admission (`0` = no
    /// deadline); an expired request is rejected with `err=expired`
    pub deadline_ms: u64,
    /// sequences driven in lockstep on the fleet engine (default 1,
    /// clamped ≥ 1; the server caps it per request)
    pub n_seq: usize,
}

impl Default for SampleRequest {
    /// The wire defaults — what `{"op":"sample"}` with no other fields
    /// parses to.
    fn default() -> Self {
        SampleRequest {
            dataset: "hawkes".to_string(),
            encoder: "attnhp".to_string(),
            method: "sd".to_string(),
            gamma: 10,
            t_end: 30.0,
            seed: 0,
            draft_size: "draft".to_string(),
            cached: true,
            chaos: String::new(),
            deadline_ms: 0,
            n_seq: 1,
        }
    }
}

impl SampleRequest {
    /// A builder starting from the wire defaults (the only way to
    /// construct one outside this crate — the struct is
    /// `#[non_exhaustive]`).
    pub fn builder() -> SampleRequestBuilder {
        SampleRequestBuilder::default()
    }

    /// The consistent-routing key fields of this request, in shard-tier
    /// order: requests for the same `(dataset, encoder, draft_size)`
    /// route to the same home replica so its executors stay hot.
    pub fn route_fields(&self) -> (&str, &str, &str) {
        (&self.dataset, &self.encoder, &self.draft_size)
    }
}

/// Builder for [`SampleRequest`] — starts from the wire defaults; every
/// setter is optional and chainable.
#[derive(Debug, Clone)]
pub struct SampleRequestBuilder {
    req: SampleRequest,
}

impl Default for SampleRequestBuilder {
    fn default() -> Self {
        SampleRequestBuilder { req: SampleRequest::default() }
    }
}

impl SampleRequestBuilder {
    /// dataset name from the registry
    pub fn dataset(mut self, v: impl Into<String>) -> Self {
        self.req.dataset = v.into();
        self
    }
    /// encoder name (`thp` | `sahp` | `attnhp`)
    pub fn encoder(mut self, v: impl Into<String>) -> Self {
        self.req.encoder = v.into();
        self
    }
    /// sampling method (`ar` | `sd` | `sd-adaptive`)
    pub fn method(mut self, v: impl Into<String>) -> Self {
        self.req.method = v.into();
        self
    }
    /// draft length γ
    pub fn gamma(mut self, v: usize) -> Self {
        self.req.gamma = v;
        self
    }
    /// sampling window end T
    pub fn t_end(mut self, v: f64) -> Self {
        self.req.t_end = v;
        self
    }
    /// RNG seed of sequence 0
    pub fn seed(mut self, v: u64) -> Self {
        self.req.seed = v;
        self
    }
    /// draft model size (`draft` | `draft2` | `draft3`)
    pub fn draft_size(mut self, v: impl Into<String>) -> Self {
        self.req.draft_size = v.into();
        self
    }
    /// use incremental-forward streams when available
    pub fn cached(mut self, v: bool) -> Self {
        self.req.cached = v;
        self
    }
    /// fault-injection spec (`""` = off)
    pub fn chaos(mut self, v: impl Into<String>) -> Self {
        self.req.chaos = v.into();
        self
    }
    /// most milliseconds the request may wait for admission (0 = none)
    pub fn deadline_ms(mut self, v: u64) -> Self {
        self.req.deadline_ms = v;
        self
    }
    /// sequences driven in lockstep (clamped ≥ 1)
    pub fn n_seq(mut self, v: usize) -> Self {
        self.req.n_seq = v.max(1);
        self
    }
    /// Finish the builder.
    pub fn build(self) -> SampleRequest {
        self.req
    }
}

fn parse_sample_fields(j: &Json) -> SampleRequest {
    SampleRequest {
        dataset: j.str_at("dataset").unwrap_or("hawkes").to_string(),
        encoder: j.str_at("encoder").unwrap_or("attnhp").to_string(),
        method: j.str_at("method").unwrap_or("sd").to_string(),
        gamma: j.usize_at("gamma").unwrap_or(10),
        t_end: j.f64_at("t_end").unwrap_or(30.0),
        seed: j.f64_at("seed").unwrap_or(0.0) as u64,
        draft_size: j.str_at("draft_size").unwrap_or("draft").to_string(),
        cached: j.bool_at("cached").unwrap_or(true),
        chaos: j.str_at("chaos").unwrap_or("").to_string(),
        deadline_ms: j.f64_at("deadline_ms").unwrap_or(0.0) as u64,
        n_seq: j.usize_at("n_seq").unwrap_or(1).max(1),
    }
}

fn sample_fields(op: &str, s: &SampleRequest) -> Vec<(&'static str, Json)> {
    vec![
        ("op", Json::Str(op.to_string())),
        ("dataset", Json::Str(s.dataset.clone())),
        ("encoder", Json::Str(s.encoder.clone())),
        ("method", Json::Str(s.method.clone())),
        ("gamma", Json::Num(s.gamma as f64)),
        ("t_end", Json::Num(s.t_end)),
        ("seed", Json::Num(s.seed as f64)),
        ("draft_size", Json::Str(s.draft_size.clone())),
        ("cached", Json::Bool(s.cached)),
        ("chaos", Json::Str(s.chaos.clone())),
        ("deadline_ms", Json::Num(s.deadline_ms as f64)),
        ("n_seq", Json::Num(s.n_seq as f64)),
    ]
}

impl Request {
    /// Parse one request line. Accepts v1 (no `"v"` field or `"v":1`) and
    /// v2 (`"v":2`) shapes; any other version is rejected — a future v3
    /// must fail loudly here, not be half-parsed.
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line.trim())?;
        let v = j.usize_at("v").unwrap_or(1);
        if v != 1 && v != 2 {
            bail!("unsupported protocol version {v} (this server speaks v1 and v2)");
        }
        match j.str_at("op") {
            Some("ping") => Ok(Request::Ping),
            Some("stats") => Ok(Request::Stats),
            Some("metrics") => {
                Ok(Request::Metrics { delta: j.bool_at("delta").unwrap_or(false) })
            }
            Some("sample") => Ok(Request::Sample(parse_sample_fields(&j))),
            // v1 alias — same merged request, sequences-shaped response
            Some("sample_fleet") => Ok(Request::SampleFleet(parse_sample_fields(&j))),
            other => bail!("unknown op {other:?}"),
        }
    }

    /// Serialize to one request line (without the trailing newline).
    /// `Sample` serializes canonically as v2; the `SampleFleet` alias
    /// keeps its v1 shape so a proxy forwarding it is transparent to v1
    /// backends and packet captures alike.
    pub fn to_line(&self) -> String {
        match self {
            Request::Ping => r#"{"op":"ping"}"#.to_string(),
            Request::Stats => r#"{"op":"stats"}"#.to_string(),
            Request::Metrics { delta } => obj(vec![
                ("op", Json::Str("metrics".to_string())),
                ("delta", Json::Bool(*delta)),
            ])
            .to_string(),
            Request::Sample(s) => {
                let mut fields = sample_fields("sample", s);
                fields.push(("v", Json::Num(2.0)));
                obj(fields).to_string()
            }
            Request::SampleFleet(s) => obj(sample_fields("sample_fleet", s)).to_string(),
        }
    }
}

/// Serialize sampling counters for a response.
pub fn stats_json(s: &SampleStats) -> Json {
    obj(vec![
        ("events", Json::Num(s.events as f64)),
        ("rounds", Json::Num(s.rounds as f64)),
        ("target_forwards", Json::Num(s.target_forwards as f64)),
        ("draft_forwards", Json::Num(s.draft_forwards as f64)),
        ("drafted", Json::Num(s.drafted as f64)),
        ("accepted", Json::Num(s.accepted as f64)),
        ("resampled", Json::Num(s.resampled as f64)),
        ("bonus", Json::Num(s.bonus as f64)),
        ("wall_ms", Json::Num(s.wall.as_secs_f64() * 1e3)),
    ])
}

/// Serialize one executor's [`super::batcher::BatcherStats`] — every
/// counter, not a summary. Shared by the `stats` and `metrics` responses
/// so the two surfaces can never drift apart (the old `stats` handler
/// silently dropped all of these).
pub fn batcher_stats_json(s: &super::batcher::BatcherStats) -> Json {
    use std::sync::atomic::Ordering;
    let load = |a: &std::sync::atomic::AtomicUsize| Json::Num(a.load(Ordering::Relaxed) as f64);
    obj(vec![
        ("requests", load(&s.requests)),
        ("batches", load(&s.batches)),
        ("batched_requests", load(&s.batched_requests)),
        ("max_batch_seen", load(&s.max_batch_seen)),
        ("occupancy", Json::Num(s.occupancy())),
        ("delta_requests", load(&s.delta_requests)),
        ("delta_waves", load(&s.delta_waves)),
        ("batched_deltas", load(&s.batched_deltas)),
        ("max_delta_wave", load(&s.max_delta_wave)),
        ("delta_occupancy", Json::Num(s.delta_occupancy())),
        ("retries", load(&s.retries)),
        ("timeouts", load(&s.timeouts)),
        ("gave_up", load(&s.gave_up)),
        ("pool_dispatches", load(&s.pool_dispatches)),
        ("pool_steals", load(&s.pool_steals)),
        ("buffers_reused", load(&s.buffers_reused)),
        ("buffers_allocated", load(&s.buffers_allocated)),
    ])
}

/// Serialize events as the wire's `[[t,k],...]` array.
fn events_json(events: &[Event]) -> Json {
    Json::Arr(
        events
            .iter()
            .map(|e| Json::Arr(vec![Json::Num(e.t), Json::Num(e.k as f64)]))
            .collect(),
    )
}

/// Parse a JSON `[[t,k],...]` array into events, skipping malformed pairs.
fn events_from_json(j: &Json) -> Vec<Event> {
    j.as_arr()
        .map(|a| {
            a.iter()
                .filter_map(|e| {
                    let p = e.as_arr()?;
                    Some(Event::new(p.first()?.as_f64()?, p.get(1)?.as_f64()? as u32))
                })
                .collect()
        })
        .unwrap_or_default()
}

/// Success response carrying the sampled events + counters.
pub fn ok_response(events: &[Event], stats: &SampleStats) -> String {
    obj(vec![
        ("ok", Json::Bool(true)),
        ("events", events_json(events)),
        ("stats", stats_json(stats)),
    ])
    .to_string()
}

/// Sequences-shaped success response (`sample` with `n_seq > 1`, and
/// every `sample_fleet` alias request): every sequence's events, the
/// aggregated sampling counters, and the engine's batching counters.
///
/// `wall_ms` is the *fleet's* wall-clock (the longest session — sessions
/// run in lockstep, so each session's own wall spans the whole run;
/// summing them would overcount ~n_seq-fold).
pub fn fleet_ok_response(runs: &[(Vec<Event>, SampleStats)], fleet: &FleetStats) -> String {
    let mut agg = SampleStats::default();
    for (_, st) in runs {
        agg.merge(st);
    }
    agg.wall = runs.iter().map(|(_, st)| st.wall).max().unwrap_or_default();
    let sequences =
        Json::Arr(runs.iter().map(|(events, _)| events_json(events)).collect());
    let fleet_json = obj(vec![
        ("steps", Json::Num(fleet.steps as f64)),
        ("draft_batches", Json::Num(fleet.draft_batches as f64)),
        ("target_batches", Json::Num(fleet.target_batches as f64)),
        ("draft_occupancy", Json::Num(fleet.draft_occupancy())),
        ("target_occupancy", Json::Num(fleet.target_occupancy())),
        ("delta_batches", Json::Num(fleet.delta_batches as f64)),
        ("delta_seqs", Json::Num(fleet.delta_seqs as f64)),
        ("stream_recoveries", Json::Num(fleet.stream_recoveries as f64)),
        ("degraded_uncached", Json::Num(fleet.degraded_uncached as f64)),
    ]);
    obj(vec![
        ("ok", Json::Bool(true)),
        ("sequences", sequences),
        ("stats", stats_json(&agg)),
        ("fleet", fleet_json),
    ])
    .to_string()
}

/// Parse a sequences-shaped response into per-sequence event streams.
pub fn parse_fleet_response(line: &str) -> Result<Vec<Vec<Event>>> {
    let j = Json::parse(line.trim())?;
    if j.get("ok") != Some(&Json::Bool(true)) {
        bail!(
            "server error [{}]: {}",
            j.str_at("err").unwrap_or("?"),
            j.str_at("detail").or_else(|| j.str_at("error")).unwrap_or("?")
        );
    }
    let sequences = j
        .get("sequences")
        .and_then(Json::as_arr)
        .map(|seqs| seqs.iter().map(events_from_json).collect())
        .unwrap_or_default();
    Ok(sequences)
}

/// The one error-response constructor (`{"ok":false,...}`) — server,
/// scheduler rejections, chaos paths and the proxy all build their
/// failures here, so the error shape cannot drift between surfaces.
/// `"err"` is the stable machine-readable [`ErrCode`]; `"detail"` is the
/// human-readable text; `"error"` duplicates `"detail"` for v1 clients.
pub fn error_response(code: ErrCode, detail: &str) -> String {
    obj(vec![
        ("ok", Json::Bool(false)),
        ("err", Json::Str(code.as_str().to_string())),
        ("detail", Json::Str(detail.to_string())),
        ("error", Json::Str(detail.to_string())),
    ])
    .to_string()
}

/// Classify a response line: `None` for `{"ok":true,...}`, otherwise the
/// structured error code ([`ErrCode::Failed`] when the line is
/// unparseable or carries no known code — a replica that answers garbage
/// is treated like a replica that failed). The proxy's failover policy
/// branches on exactly this.
pub fn response_err_code(line: &str) -> Option<ErrCode> {
    match Json::parse(line.trim()) {
        Ok(j) if j.get("ok") == Some(&Json::Bool(true)) => None,
        Ok(j) => Some(
            j.str_at("err").and_then(ErrCode::parse).unwrap_or(ErrCode::Failed),
        ),
        Err(_) => Some(ErrCode::Failed),
    }
}

/// The human-readable detail of an error response (empty when absent).
pub fn response_detail(line: &str) -> String {
    Json::parse(line.trim())
        .ok()
        .and_then(|j| {
            j.str_at("detail")
                .or_else(|| j.str_at("error"))
                .map(str::to_string)
        })
        .unwrap_or_default()
}

/// Parse a server response into (events, wall_ms).
pub fn parse_response(line: &str) -> Result<(Vec<Event>, f64)> {
    let j = Json::parse(line.trim())?;
    if j.get("ok") != Some(&Json::Bool(true)) {
        bail!(
            "server error [{}]: {}",
            j.str_at("err").unwrap_or("?"),
            j.str_at("detail").or_else(|| j.str_at("error")).unwrap_or("?")
        );
    }
    let events = j.get("events").map(events_from_json).unwrap_or_default();
    let wall = j.f64_at("stats.wall_ms").unwrap_or(f64::NAN);
    Ok((events, wall))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request::Sample(
            SampleRequest::builder()
                .dataset("taxi_sim")
                .encoder("thp")
                .method("sd")
                .gamma(7)
                .t_end(42.5)
                .seed(3)
                .draft_size("draft")
                .cached(false)
                .chaos("seed=7,err=0.25,loss=0.1")
                .deadline_ms(250)
                .build(),
        );
        let line = r.to_line();
        assert!(line.contains("\"v\":2"), "canonical sample line is v2: {line}");
        assert_eq!(Request::parse(&line).unwrap(), r);
        assert_eq!(Request::parse(r#"{"op":"ping"}"#).unwrap(), Request::Ping);
        assert!(Request::parse(r#"{"op":"bogus"}"#).is_err());
        // `cached` defaults to true, `chaos` to off, `deadline_ms` to 0,
        // `n_seq` to 1 — and the bare request parses to exactly
        // `SampleRequest::default()`
        match Request::parse(r#"{"op":"sample"}"#).unwrap() {
            Request::Sample(s) => {
                assert!(s.cached);
                assert!(s.chaos.is_empty());
                assert_eq!(s.deadline_ms, 0);
                assert_eq!(s.n_seq, 1);
                assert_eq!(s, SampleRequest::default());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn version_gate_is_strict() {
        // absent, 1 and 2 all parse; anything else is rejected
        for line in [
            r#"{"op":"sample"}"#,
            r#"{"op":"sample","v":1}"#,
            r#"{"op":"sample","v":2}"#,
        ] {
            assert!(Request::parse(line).is_ok(), "{line}");
        }
        assert!(Request::parse(r#"{"op":"sample","v":3}"#).is_err());
        assert!(Request::parse(r#"{"op":"ping","v":9}"#).is_err());
    }

    #[test]
    fn metrics_request_roundtrip() {
        for delta in [false, true] {
            let r = Request::Metrics { delta };
            assert_eq!(Request::parse(&r.to_line()).unwrap(), r);
        }
        // `delta` defaults to false when absent
        assert_eq!(
            Request::parse(r#"{"op":"metrics"}"#).unwrap(),
            Request::Metrics { delta: false }
        );
    }

    #[test]
    fn err_codes_roundtrip_and_error_response_is_structured() {
        for code in ErrCode::ALL {
            assert_eq!(ErrCode::parse(code.as_str()), Some(code));
            let line = error_response(code, "boom");
            assert_eq!(response_err_code(&line), Some(code));
            assert_eq!(response_detail(&line), "boom");
            // v1 compatibility: the free-form "error" field still carries
            // the same text
            let j = Json::parse(&line).unwrap();
            assert_eq!(j.str_at("error"), Some("boom"));
            assert_eq!(j.str_at("err"), Some(code.as_str()));
        }
        assert_eq!(ErrCode::parse("nonsense"), None);
        // an ok response classifies as no error; garbage as Failed
        let stats = SampleStats::default();
        assert_eq!(response_err_code(&ok_response(&[], &stats)), None);
        assert_eq!(response_err_code("not json"), Some(ErrCode::Failed));
        assert_eq!(
            response_err_code(r#"{"error":"legacy free-form","ok":false}"#),
            Some(ErrCode::Failed)
        );
    }

    #[test]
    fn batcher_stats_json_carries_every_counter() {
        use std::sync::atomic::Ordering;
        let s = super::super::batcher::BatcherStats::default();
        s.requests.store(5, Ordering::Relaxed);
        s.batches.store(2, Ordering::Relaxed);
        s.batched_requests.store(4, Ordering::Relaxed);
        s.retries.store(3, Ordering::Relaxed);
        let j = batcher_stats_json(&s);
        for key in [
            "requests",
            "batches",
            "batched_requests",
            "max_batch_seen",
            "occupancy",
            "delta_requests",
            "delta_waves",
            "batched_deltas",
            "max_delta_wave",
            "delta_occupancy",
            "retries",
            "timeouts",
            "gave_up",
            "pool_dispatches",
            "pool_steals",
            "buffers_reused",
            "buffers_allocated",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.f64_at("requests"), Some(5.0));
        assert_eq!(j.f64_at("retries"), Some(3.0));
        assert_eq!(j.f64_at("occupancy"), Some(2.0));
    }

    #[test]
    fn response_roundtrip() {
        let evs = vec![Event::new(1.5, 2), Event::new(3.25, 0)];
        let stats = SampleStats { events: 2, ..Default::default() };
        let line = ok_response(&evs, &stats);
        let (parsed, _) = parse_response(&line).unwrap();
        assert_eq!(parsed, evs);
        let err = parse_response(&error_response(ErrCode::Failed, "boom"));
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("failed") && msg.contains("boom"), "{msg}");
    }

    #[test]
    fn fleet_request_roundtrip() {
        let r = Request::SampleFleet(SampleRequest::builder().seed(5).n_seq(8).build());
        let line = r.to_line();
        // the alias keeps its v1 wire shape: op=sample_fleet, no "v"
        assert!(line.contains("\"op\":\"sample_fleet\""), "{line}");
        assert!(!line.contains("\"v\":"), "{line}");
        assert_eq!(Request::parse(&line).unwrap(), r);
        // n_seq defaults to 1 and is clamped to ≥ 1
        match Request::parse(r#"{"op":"sample_fleet"}"#).unwrap() {
            Request::SampleFleet(f) => assert_eq!(f.n_seq, 1),
            other => panic!("{other:?}"),
        }
        match Request::parse(r#"{"n_seq":0,"op":"sample_fleet"}"#).unwrap() {
            Request::SampleFleet(f) => assert_eq!(f.n_seq, 1),
            other => panic!("{other:?}"),
        }
        // v2 spells the same thing as op=sample + n_seq
        match Request::parse(r#"{"n_seq":8,"op":"sample","seed":5,"v":2}"#).unwrap() {
            Request::Sample(s) => {
                assert_eq!(s.n_seq, 8);
                assert_eq!(s.seed, 5);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn fleet_response_roundtrip() {
        let runs = vec![
            (vec![Event::new(0.5, 1)], SampleStats { events: 1, ..Default::default() }),
            (vec![], SampleStats::default()),
            (
                vec![Event::new(1.0, 0), Event::new(2.0, 3)],
                SampleStats { events: 2, ..Default::default() },
            ),
        ];
        let fleet =
            FleetStats { steps: 4, target_batches: 4, target_seqs: 6, ..Default::default() };
        let line = fleet_ok_response(&runs, &fleet);
        let parsed = parse_fleet_response(&line).unwrap();
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed[0], runs[0].0);
        assert_eq!(parsed[1], runs[1].0);
        assert_eq!(parsed[2], runs[2].0);
        assert!(parse_fleet_response(&error_response(ErrCode::Failed, "boom")).is_err());
    }

    #[test]
    fn builder_clamps_and_defaults() {
        let d = SampleRequest::builder().build();
        assert_eq!(d, SampleRequest::default());
        assert_eq!(SampleRequest::builder().n_seq(0).build().n_seq, 1);
        let r = SampleRequest::builder().dataset("taxi_sim").n_seq(4).build();
        assert_eq!(r.route_fields(), ("taxi_sim", "attnhp", "draft"));
    }
}
