//! Wire protocol of the sampling server: one JSON object per line.
//!
//! Request:
//!   {"op":"sample","dataset":"hawkes","encoder":"attnhp","method":"sd",
//!    "gamma":10,"t_end":30.0,"seed":1,"draft_size":"draft"}
//!   {"op":"ping"} | {"op":"stats"}
//!
//! Response:
//!   {"ok":true,"events":[[t,k],...],"stats":{...}}
//!   {"ok":false,"error":"..."}

use anyhow::{bail, Result};

use crate::events::Event;
use crate::sampler::SampleStats;
use crate::util::json::{obj, Json};

/// One client request (one JSON object per line).
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// liveness check
    Ping,
    /// server-side counters
    Stats,
    /// sample one sequence
    Sample(SampleRequest),
}

/// Parameters of a `sample` request.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleRequest {
    /// dataset name from the registry
    pub dataset: String,
    /// encoder name (`thp` | `sahp` | `attnhp`)
    pub encoder: String,
    /// "ar" | "sd" | "sd-adaptive"
    pub method: String,
    /// draft length γ (initial γ for `sd-adaptive`)
    pub gamma: usize,
    /// sampling window end T
    pub t_end: f64,
    /// RNG seed
    pub seed: u64,
    /// draft model size (`draft` | `draft2` | `draft3`)
    pub draft_size: String,
}

impl Request {
    /// Parse one request line.
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line.trim())?;
        match j.str_at("op") {
            Some("ping") => Ok(Request::Ping),
            Some("stats") => Ok(Request::Stats),
            Some("sample") => Ok(Request::Sample(SampleRequest {
                dataset: j.str_at("dataset").unwrap_or("hawkes").to_string(),
                encoder: j.str_at("encoder").unwrap_or("attnhp").to_string(),
                method: j.str_at("method").unwrap_or("sd").to_string(),
                gamma: j.usize_at("gamma").unwrap_or(10),
                t_end: j.f64_at("t_end").unwrap_or(30.0),
                seed: j.f64_at("seed").unwrap_or(0.0) as u64,
                draft_size: j.str_at("draft_size").unwrap_or("draft").to_string(),
            })),
            other => bail!("unknown op {other:?}"),
        }
    }

    /// Serialize to one request line (without the trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Request::Ping => r#"{"op":"ping"}"#.to_string(),
            Request::Stats => r#"{"op":"stats"}"#.to_string(),
            Request::Sample(s) => obj(vec![
                ("op", Json::Str("sample".into())),
                ("dataset", Json::Str(s.dataset.clone())),
                ("encoder", Json::Str(s.encoder.clone())),
                ("method", Json::Str(s.method.clone())),
                ("gamma", Json::Num(s.gamma as f64)),
                ("t_end", Json::Num(s.t_end)),
                ("seed", Json::Num(s.seed as f64)),
                ("draft_size", Json::Str(s.draft_size.clone())),
            ])
            .to_string(),
        }
    }
}

/// Serialize sampling counters for a response.
pub fn stats_json(s: &SampleStats) -> Json {
    obj(vec![
        ("events", Json::Num(s.events as f64)),
        ("rounds", Json::Num(s.rounds as f64)),
        ("target_forwards", Json::Num(s.target_forwards as f64)),
        ("draft_forwards", Json::Num(s.draft_forwards as f64)),
        ("drafted", Json::Num(s.drafted as f64)),
        ("accepted", Json::Num(s.accepted as f64)),
        ("resampled", Json::Num(s.resampled as f64)),
        ("bonus", Json::Num(s.bonus as f64)),
        ("wall_ms", Json::Num(s.wall.as_secs_f64() * 1e3)),
    ])
}

/// Success response carrying the sampled events + counters.
pub fn ok_response(events: &[Event], stats: &SampleStats) -> String {
    let evs = Json::Arr(
        events
            .iter()
            .map(|e| Json::Arr(vec![Json::Num(e.t), Json::Num(e.k as f64)]))
            .collect(),
    );
    obj(vec![
        ("ok", Json::Bool(true)),
        ("events", evs),
        ("stats", stats_json(stats)),
    ])
    .to_string()
}

/// Error response (`{"ok":false,...}`).
pub fn err_response(msg: &str) -> String {
    obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ])
    .to_string()
}

/// Parse a server response into (events, wall_ms).
pub fn parse_response(line: &str) -> Result<(Vec<Event>, f64)> {
    let j = Json::parse(line.trim())?;
    if j.get("ok") != Some(&Json::Bool(true)) {
        bail!("server error: {}", j.str_at("error").unwrap_or("?"));
    }
    let events = j
        .get("events")
        .and_then(Json::as_arr)
        .map(|a| {
            a.iter()
                .filter_map(|e| {
                    let p = e.as_arr()?;
                    Some(Event::new(p[0].as_f64()?, p[1].as_f64()? as u32))
                })
                .collect()
        })
        .unwrap_or_default();
    let wall = j.f64_at("stats.wall_ms").unwrap_or(f64::NAN);
    Ok((events, wall))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request::Sample(SampleRequest {
            dataset: "taxi_sim".into(),
            encoder: "thp".into(),
            method: "sd".into(),
            gamma: 7,
            t_end: 42.5,
            seed: 3,
            draft_size: "draft".into(),
        });
        let line = r.to_line();
        assert_eq!(Request::parse(&line).unwrap(), r);
        assert_eq!(Request::parse(r#"{"op":"ping"}"#).unwrap(), Request::Ping);
        assert!(Request::parse(r#"{"op":"bogus"}"#).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let evs = vec![Event::new(1.5, 2), Event::new(3.25, 0)];
        let stats = SampleStats { events: 2, ..Default::default() };
        let line = ok_response(&evs, &stats);
        let (parsed, _) = parse_response(&line).unwrap();
        assert_eq!(parsed, evs);
        assert!(parse_response(&err_response("boom")).is_err());
    }
}
