//! Wire protocol of the sampling server: one JSON object per line.
//!
//! Request:
//!   {"op":"sample","dataset":"hawkes","encoder":"attnhp","method":"sd",
//!    "gamma":10,"t_end":30.0,"seed":1,"draft_size":"draft","cached":true}
//!   {"op":"sample_fleet", ...same fields..., "n_seq":8}
//!   {"op":"ping"} | {"op":"stats"} | {"op":"metrics","delta":false}
//!
//! `metrics` returns the full telemetry snapshot (per-stage latency
//! p50/p95/p99 + per-role acceptance, DESIGN.md §15) plus every
//! executor's batcher counters; `"delta":true` reports only the activity
//! since the connection's previous `metrics` call — the windowed readout
//! `serve.rs` prints between phases. `stats` includes the same executor
//! counters next to the session/router tallies.
//!
//! `"cached"` (default `true`) lets the sampler use the backend's
//! incremental-forward streams (DESIGN.md §12) when it has them;
//! `false` forces full-window forwards — the A/B knob behind
//! `bench_cached_forward`. Both paths return bit-identical events for the
//! same seed (`rust/tests/cached_forward.rs`), so the flag only moves
//! wall-clock, never a probability.
//!
//! `"chaos"` (default `""` = off) injects deterministic faults into the
//! request's backend from a [`crate::runtime::chaos::FaultPlan`] spec such
//! as `"seed=7,err=0.2,loss=0.1"` (DESIGN.md §13). Recoverable plans
//! return bit-identical events to the fault-free run — that is the point
//! — while unrecoverable ones surface as `{"ok":false,...}` instead of a
//! hang.
//!
//! `"deadline_ms"` (default `0` = none) bounds the time a request may
//! wait in the scheduler's admission queue (DESIGN.md §16): a request
//! whose deadline passes before admission is rejected with
//! `{"ok":false,"err":"expired",...}` instead of admitted to do work
//! nobody is waiting for. A full admission queue sheds the request
//! immediately with `{"ok":false,"err":"overloaded",...}`.
//!
//! Response:
//!   {"ok":true,"events":[[t,k],...],"stats":{...}}
//!   {"ok":true,"sequences":[[[t,k],...],...],"stats":{...},"fleet":{...}}
//!   {"ok":false,"error":"..."}
//!   {"ok":false,"err":"overloaded"|"expired"|"failed","error":"..."}
//!
//! The `"err"` code is machine-readable and stable; plain request errors
//! (bad op, unknown dataset, …) carry only `"error"` text.
//!
//! `sample_fleet` runs `n_seq` sequences in lockstep on the fleet engine
//! (DESIGN.md §11); sequence `i` is seeded `seed + i`, so its events are
//! bit-for-bit what a `sample` request with `seed + i` would return. The
//! server rejects `n_seq` beyond its per-request cap (64) with
//! `{"ok":false,...}` rather than truncating. The response's `wall_ms` is
//! the fleet's wall-clock (longest session), not the per-sequence sum.

use anyhow::{bail, Result};

use crate::events::Event;
use crate::sampler::{FleetStats, SampleStats};
use crate::util::json::{obj, Json};

/// One client request (one JSON object per line).
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// liveness check
    Ping,
    /// server-side counters
    Stats,
    /// full telemetry snapshot (per-stage latency + acceptance, DESIGN.md
    /// §15); `delta` reports only the activity since this connection's
    /// previous `metrics` call
    Metrics {
        /// window the snapshot against the connection's previous call
        delta: bool,
    },
    /// sample one sequence
    Sample(SampleRequest),
    /// sample many sequences in lockstep on the fleet engine
    SampleFleet(FleetRequest),
}

/// Parameters of a `sample` request.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleRequest {
    /// dataset name from the registry
    pub dataset: String,
    /// encoder name (`thp` | `sahp` | `attnhp`)
    pub encoder: String,
    /// "ar" | "sd" | "sd-adaptive"
    pub method: String,
    /// draft length γ (initial γ for `sd-adaptive`)
    pub gamma: usize,
    /// sampling window end T
    pub t_end: f64,
    /// RNG seed
    pub seed: u64,
    /// draft model size (`draft` | `draft2` | `draft3`)
    pub draft_size: String,
    /// use the backend's incremental-forward streams when available
    /// (default `true`; `false` forces full-window forwards)
    pub cached: bool,
    /// fault-injection spec (`""` = off), e.g. `"seed=7,err=0.2"` —
    /// parsed by [`crate::runtime::chaos::FaultPlan::parse`]
    pub chaos: String,
    /// most milliseconds the request may wait for admission (`0` = no
    /// deadline); an expired request is rejected with
    /// `{"ok":false,"err":"expired",...}`
    pub deadline_ms: u64,
}

impl Default for SampleRequest {
    /// The wire defaults — what `{"op":"sample"}` with no other fields
    /// parses to.
    fn default() -> Self {
        SampleRequest {
            dataset: "hawkes".to_string(),
            encoder: "attnhp".to_string(),
            method: "sd".to_string(),
            gamma: 10,
            t_end: 30.0,
            seed: 0,
            draft_size: "draft".to_string(),
            cached: true,
            chaos: String::new(),
            deadline_ms: 0,
        }
    }
}

/// Parameters of a `sample_fleet` request.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetRequest {
    /// shared sampling parameters; `base.seed` seeds sequence 0
    pub base: SampleRequest,
    /// number of sequences driven in lockstep (sequence `i` is seeded
    /// `base.seed + i`)
    pub n_seq: usize,
}

fn parse_sample_fields(j: &Json) -> SampleRequest {
    SampleRequest {
        dataset: j.str_at("dataset").unwrap_or("hawkes").to_string(),
        encoder: j.str_at("encoder").unwrap_or("attnhp").to_string(),
        method: j.str_at("method").unwrap_or("sd").to_string(),
        gamma: j.usize_at("gamma").unwrap_or(10),
        t_end: j.f64_at("t_end").unwrap_or(30.0),
        seed: j.f64_at("seed").unwrap_or(0.0) as u64,
        draft_size: j.str_at("draft_size").unwrap_or("draft").to_string(),
        cached: j.bool_at("cached").unwrap_or(true),
        chaos: j.str_at("chaos").unwrap_or("").to_string(),
        deadline_ms: j.f64_at("deadline_ms").unwrap_or(0.0) as u64,
    }
}

fn sample_fields(op: &str, s: &SampleRequest) -> Vec<(&'static str, Json)> {
    vec![
        ("op", Json::Str(op.to_string())),
        ("dataset", Json::Str(s.dataset.clone())),
        ("encoder", Json::Str(s.encoder.clone())),
        ("method", Json::Str(s.method.clone())),
        ("gamma", Json::Num(s.gamma as f64)),
        ("t_end", Json::Num(s.t_end)),
        ("seed", Json::Num(s.seed as f64)),
        ("draft_size", Json::Str(s.draft_size.clone())),
        ("cached", Json::Bool(s.cached)),
        ("chaos", Json::Str(s.chaos.clone())),
        ("deadline_ms", Json::Num(s.deadline_ms as f64)),
    ]
}

impl Request {
    /// Parse one request line.
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line.trim())?;
        match j.str_at("op") {
            Some("ping") => Ok(Request::Ping),
            Some("stats") => Ok(Request::Stats),
            Some("metrics") => {
                Ok(Request::Metrics { delta: j.bool_at("delta").unwrap_or(false) })
            }
            Some("sample") => Ok(Request::Sample(parse_sample_fields(&j))),
            Some("sample_fleet") => Ok(Request::SampleFleet(FleetRequest {
                base: parse_sample_fields(&j),
                n_seq: j.usize_at("n_seq").unwrap_or(1).max(1),
            })),
            other => bail!("unknown op {other:?}"),
        }
    }

    /// Serialize to one request line (without the trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Request::Ping => r#"{"op":"ping"}"#.to_string(),
            Request::Stats => r#"{"op":"stats"}"#.to_string(),
            Request::Metrics { delta } => obj(vec![
                ("op", Json::Str("metrics".to_string())),
                ("delta", Json::Bool(*delta)),
            ])
            .to_string(),
            Request::Sample(s) => obj(sample_fields("sample", s)).to_string(),
            Request::SampleFleet(f) => {
                let mut fields = sample_fields("sample_fleet", &f.base);
                fields.push(("n_seq", Json::Num(f.n_seq as f64)));
                obj(fields).to_string()
            }
        }
    }
}

/// Serialize sampling counters for a response.
pub fn stats_json(s: &SampleStats) -> Json {
    obj(vec![
        ("events", Json::Num(s.events as f64)),
        ("rounds", Json::Num(s.rounds as f64)),
        ("target_forwards", Json::Num(s.target_forwards as f64)),
        ("draft_forwards", Json::Num(s.draft_forwards as f64)),
        ("drafted", Json::Num(s.drafted as f64)),
        ("accepted", Json::Num(s.accepted as f64)),
        ("resampled", Json::Num(s.resampled as f64)),
        ("bonus", Json::Num(s.bonus as f64)),
        ("wall_ms", Json::Num(s.wall.as_secs_f64() * 1e3)),
    ])
}

/// Serialize one executor's [`super::batcher::BatcherStats`] — every
/// counter, not a summary. Shared by the `stats` and `metrics` responses
/// so the two surfaces can never drift apart (the old `stats` handler
/// silently dropped all of these).
pub fn batcher_stats_json(s: &super::batcher::BatcherStats) -> Json {
    use std::sync::atomic::Ordering;
    let load = |a: &std::sync::atomic::AtomicUsize| Json::Num(a.load(Ordering::Relaxed) as f64);
    obj(vec![
        ("requests", load(&s.requests)),
        ("batches", load(&s.batches)),
        ("batched_requests", load(&s.batched_requests)),
        ("max_batch_seen", load(&s.max_batch_seen)),
        ("occupancy", Json::Num(s.occupancy())),
        ("delta_requests", load(&s.delta_requests)),
        ("delta_waves", load(&s.delta_waves)),
        ("batched_deltas", load(&s.batched_deltas)),
        ("max_delta_wave", load(&s.max_delta_wave)),
        ("delta_occupancy", Json::Num(s.delta_occupancy())),
        ("retries", load(&s.retries)),
        ("timeouts", load(&s.timeouts)),
        ("gave_up", load(&s.gave_up)),
        ("pool_dispatches", load(&s.pool_dispatches)),
        ("pool_steals", load(&s.pool_steals)),
        ("buffers_reused", load(&s.buffers_reused)),
        ("buffers_allocated", load(&s.buffers_allocated)),
    ])
}

/// Serialize events as the wire's `[[t,k],...]` array.
fn events_json(events: &[Event]) -> Json {
    Json::Arr(
        events
            .iter()
            .map(|e| Json::Arr(vec![Json::Num(e.t), Json::Num(e.k as f64)]))
            .collect(),
    )
}

/// Parse a JSON `[[t,k],...]` array into events, skipping malformed pairs.
fn events_from_json(j: &Json) -> Vec<Event> {
    j.as_arr()
        .map(|a| {
            a.iter()
                .filter_map(|e| {
                    let p = e.as_arr()?;
                    Some(Event::new(p.first()?.as_f64()?, p.get(1)?.as_f64()? as u32))
                })
                .collect()
        })
        .unwrap_or_default()
}

/// Success response carrying the sampled events + counters.
pub fn ok_response(events: &[Event], stats: &SampleStats) -> String {
    obj(vec![
        ("ok", Json::Bool(true)),
        ("events", events_json(events)),
        ("stats", stats_json(stats)),
    ])
    .to_string()
}

/// Success response of a `sample_fleet` request: every sequence's events,
/// the aggregated sampling counters, and the engine's batching counters.
///
/// `wall_ms` is the *fleet's* wall-clock (the longest session — sessions
/// run in lockstep, so each session's own wall spans the whole run;
/// summing them would overcount ~n_seq-fold).
pub fn fleet_ok_response(runs: &[(Vec<Event>, SampleStats)], fleet: &FleetStats) -> String {
    let mut agg = SampleStats::default();
    for (_, st) in runs {
        agg.merge(st);
    }
    agg.wall = runs.iter().map(|(_, st)| st.wall).max().unwrap_or_default();
    let sequences =
        Json::Arr(runs.iter().map(|(events, _)| events_json(events)).collect());
    let fleet_json = obj(vec![
        ("steps", Json::Num(fleet.steps as f64)),
        ("draft_batches", Json::Num(fleet.draft_batches as f64)),
        ("target_batches", Json::Num(fleet.target_batches as f64)),
        ("draft_occupancy", Json::Num(fleet.draft_occupancy())),
        ("target_occupancy", Json::Num(fleet.target_occupancy())),
        ("delta_batches", Json::Num(fleet.delta_batches as f64)),
        ("delta_seqs", Json::Num(fleet.delta_seqs as f64)),
        ("stream_recoveries", Json::Num(fleet.stream_recoveries as f64)),
        ("degraded_uncached", Json::Num(fleet.degraded_uncached as f64)),
    ]);
    obj(vec![
        ("ok", Json::Bool(true)),
        ("sequences", sequences),
        ("stats", stats_json(&agg)),
        ("fleet", fleet_json),
    ])
    .to_string()
}

/// Parse a `sample_fleet` response into per-sequence event streams.
pub fn parse_fleet_response(line: &str) -> Result<Vec<Vec<Event>>> {
    let j = Json::parse(line.trim())?;
    if j.get("ok") != Some(&Json::Bool(true)) {
        bail!("server error: {}", j.str_at("error").unwrap_or("?"));
    }
    let sequences = j
        .get("sequences")
        .and_then(Json::as_arr)
        .map(|seqs| seqs.iter().map(events_from_json).collect())
        .unwrap_or_default();
    Ok(sequences)
}

/// Error response (`{"ok":false,...}`).
pub fn err_response(msg: &str) -> String {
    obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ])
    .to_string()
}

/// Admission-control rejection: an error response with a stable
/// machine-readable `"err"` code (`"overloaded"` | `"expired"` |
/// `"failed"`) next to the human-readable `"error"` text, so clients can
/// branch on the code (back off, drop, retry elsewhere) without parsing
/// prose.
pub fn overload_response(code: &str, msg: &str) -> String {
    obj(vec![
        ("ok", Json::Bool(false)),
        ("err", Json::Str(code.to_string())),
        ("error", Json::Str(msg.to_string())),
    ])
    .to_string()
}

/// Parse a server response into (events, wall_ms).
pub fn parse_response(line: &str) -> Result<(Vec<Event>, f64)> {
    let j = Json::parse(line.trim())?;
    if j.get("ok") != Some(&Json::Bool(true)) {
        bail!("server error: {}", j.str_at("error").unwrap_or("?"));
    }
    let events = j.get("events").map(events_from_json).unwrap_or_default();
    let wall = j.f64_at("stats.wall_ms").unwrap_or(f64::NAN);
    Ok((events, wall))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = Request::Sample(SampleRequest {
            dataset: "taxi_sim".into(),
            encoder: "thp".into(),
            method: "sd".into(),
            gamma: 7,
            t_end: 42.5,
            seed: 3,
            draft_size: "draft".into(),
            cached: false,
            chaos: "seed=7,err=0.25,loss=0.1".into(),
            deadline_ms: 250,
        });
        let line = r.to_line();
        assert_eq!(Request::parse(&line).unwrap(), r);
        assert_eq!(Request::parse(r#"{"op":"ping"}"#).unwrap(), Request::Ping);
        assert!(Request::parse(r#"{"op":"bogus"}"#).is_err());
        // `cached` defaults to true, `chaos` to off, `deadline_ms` to 0 —
        // and the bare request parses to exactly `SampleRequest::default()`
        match Request::parse(r#"{"op":"sample"}"#).unwrap() {
            Request::Sample(s) => {
                assert!(s.cached);
                assert!(s.chaos.is_empty());
                assert_eq!(s.deadline_ms, 0);
                assert_eq!(s, SampleRequest::default());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn metrics_request_roundtrip() {
        for delta in [false, true] {
            let r = Request::Metrics { delta };
            assert_eq!(Request::parse(&r.to_line()).unwrap(), r);
        }
        // `delta` defaults to false when absent
        assert_eq!(
            Request::parse(r#"{"op":"metrics"}"#).unwrap(),
            Request::Metrics { delta: false }
        );
    }

    #[test]
    fn batcher_stats_json_carries_every_counter() {
        use std::sync::atomic::Ordering;
        let s = super::super::batcher::BatcherStats::default();
        s.requests.store(5, Ordering::Relaxed);
        s.batches.store(2, Ordering::Relaxed);
        s.batched_requests.store(4, Ordering::Relaxed);
        s.retries.store(3, Ordering::Relaxed);
        let j = batcher_stats_json(&s);
        for key in [
            "requests",
            "batches",
            "batched_requests",
            "max_batch_seen",
            "occupancy",
            "delta_requests",
            "delta_waves",
            "batched_deltas",
            "max_delta_wave",
            "delta_occupancy",
            "retries",
            "timeouts",
            "gave_up",
            "pool_dispatches",
            "pool_steals",
            "buffers_reused",
            "buffers_allocated",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.f64_at("requests"), Some(5.0));
        assert_eq!(j.f64_at("retries"), Some(3.0));
        assert_eq!(j.f64_at("occupancy"), Some(2.0));
    }

    #[test]
    fn response_roundtrip() {
        let evs = vec![Event::new(1.5, 2), Event::new(3.25, 0)];
        let stats = SampleStats { events: 2, ..Default::default() };
        let line = ok_response(&evs, &stats);
        let (parsed, _) = parse_response(&line).unwrap();
        assert_eq!(parsed, evs);
        assert!(parse_response(&err_response("boom")).is_err());
    }

    #[test]
    fn fleet_request_roundtrip() {
        let r = Request::SampleFleet(FleetRequest {
            base: SampleRequest {
                dataset: "hawkes".into(),
                encoder: "attnhp".into(),
                method: "sd".into(),
                gamma: 10,
                t_end: 30.0,
                seed: 5,
                draft_size: "draft".into(),
                cached: true,
                chaos: String::new(),
                deadline_ms: 0,
            },
            n_seq: 8,
        });
        let line = r.to_line();
        assert_eq!(Request::parse(&line).unwrap(), r);
        // n_seq defaults to 1 and is clamped to ≥ 1
        match Request::parse(r#"{"op":"sample_fleet"}"#).unwrap() {
            Request::SampleFleet(f) => assert_eq!(f.n_seq, 1),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn fleet_response_roundtrip() {
        let runs = vec![
            (vec![Event::new(0.5, 1)], SampleStats { events: 1, ..Default::default() }),
            (vec![], SampleStats::default()),
            (
                vec![Event::new(1.0, 0), Event::new(2.0, 3)],
                SampleStats { events: 2, ..Default::default() },
            ),
        ];
        let fleet =
            FleetStats { steps: 4, target_batches: 4, target_seqs: 6, ..Default::default() };
        let line = fleet_ok_response(&runs, &fleet);
        let parsed = parse_fleet_response(&line).unwrap();
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed[0], runs[0].0);
        assert_eq!(parsed[1], runs[1].0);
        assert_eq!(parsed[2], runs[2].0);
        assert!(parse_fleet_response(&err_response("boom")).is_err());
    }
}
