//! Batching executor: the serving-path heart of the coordinator.
//!
//! Model objects need not be `Send` (XLA wrappers hold raw pointers), so
//! each loaded model lives on a dedicated executor thread that owns its
//! [`ModelBackend`] — the thread loads the model through the shared
//! [`Backend`] registry, so the same batcher serves the native CPU models
//! and the PJRT executors. Concurrent sessions submit single-sequence
//! forward requests over a channel; the thread coalesces up to `max_batch`
//! requests that arrive within `batch_window` into ONE batched forward
//! (the B=8 path), then fans the slots back out. This is the same
//! dynamic-batching idea vLLM's router applies to token steps,
//! transplanted to TPP forward passes.
//!
//! The handle is a [`Forward`] (single-sequence path), a [`BatchForward`]
//! (the fleet engine enqueues a whole wave of sequences at once, which
//! the executor thread coalesces into full batches without waiting out
//! the batch window), and — when the executor's model keeps incremental
//! state — a [`CachedForward`]: stream ids are allocated by the model on
//! the executor thread and travel opaquely through the request channel,
//! so `sample_fleet` co-batches delta forwards across connections exactly
//! like full forwards (DESIGN.md §12).
//!
//! Invariants (property-tested in `rust/tests/coordinator.rs` and
//! `rust/tests/fleet.rs`):
//!   * every request gets exactly one reply (no loss, no duplication);
//!   * replies carry the requester's own sequence results regardless of
//!     how requests were grouped into batches;
//!   * numerical results are identical to the direct path (same forward),
//!     and delta replies never leak another stream's state (the
//!     crosstalk regression in `rust/tests/fleet.rs`);
//!   * transient failures are invisible to callers up to the
//!     [`RetryPolicy`] bounds: the handle resubmits with exponential
//!     backoff under a per-request deadline, and a retried forward
//!     returns bit-identical rows (`rust/tests/chaos.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::runtime::{
    pool, Backend, BatchForward, CachedForward, Forward, ModelBackend, PoolStats, SeqDelta,
    SeqInput, SlotOut, StreamId,
};

/// Aggregate counters exposed by an executor thread.
#[derive(Debug, Default)]
pub struct BatcherStats {
    /// total forward requests enqueued (counted at submit time, so it is
    /// exact even while requests are still waiting in the channel)
    pub requests: AtomicUsize,
    /// batched forward calls issued
    pub batches: AtomicUsize,
    /// Σ batch-size over issued batches — occupancy = batched_requests /
    /// batches; trails `requests` by whatever is still queued.
    ///
    /// `batches`/`batched_requests`/`max_batch_seen` describe FULL-forward
    /// coalescing only (one batched model call each); delta forwards on
    /// incremental streams are tracked by the `delta_*` counters, so
    /// [`BatcherStats::occupancy`] never conflates the two.
    pub batched_requests: AtomicUsize,
    /// largest full-forward batch coalesced so far
    pub max_batch_seen: AtomicUsize,
    /// of `requests`, how many were delta forwards on incremental streams
    /// (counted at submit time, like `requests`)
    pub delta_requests: AtomicUsize,
    /// drained waves that contained ≥ 1 delta forward (each served by one
    /// [`CachedForward::forward_delta_batch`] call)
    pub delta_waves: AtomicUsize,
    /// Σ delta count over those waves — delta occupancy =
    /// batched_deltas / delta_waves
    pub batched_deltas: AtomicUsize,
    /// largest delta wave coalesced so far
    pub max_delta_wave: AtomicUsize,
    /// transient-error resubmissions (each retried attempt counts once;
    /// the initial submission of a request is not a retry)
    pub retries: AtomicUsize,
    /// requests aborted by the per-request deadline — waiting for a
    /// reply or mid-backoff (DESIGN.md §13)
    pub timeouts: AtomicUsize,
    /// requests that exhausted [`RetryPolicy::max_attempts`] and returned
    /// the last transient error to the caller
    pub gave_up: AtomicUsize,
    /// worker-pool group dispatches attributed to this executor's forward
    /// calls (DESIGN.md §14). The pool counters are process-wide, so the
    /// attribution is approximate when several executors run concurrently
    /// — within one executor the deltas are still monotone and indicative.
    pub pool_dispatches: AtomicUsize,
    /// worker-pool job steals attributed to this executor's forward calls
    pub pool_steals: AtomicUsize,
    /// recycled output buffers served during this executor's forward calls
    pub buffers_reused: AtomicUsize,
    /// freshly allocated output buffers during this executor's forward
    /// calls
    pub buffers_allocated: AtomicUsize,
}

impl BatcherStats {
    /// Mean requests per batched FULL forward call.
    pub fn occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Mean delta forwards per drained delta wave (the cached-path
    /// analogue of [`BatcherStats::occupancy`]).
    pub fn delta_occupancy(&self) -> f64 {
        let w = self.delta_waves.load(Ordering::Relaxed);
        if w == 0 {
            return 0.0;
        }
        self.batched_deltas.load(Ordering::Relaxed) as f64 / w as f64
    }

    /// Fold a [`PoolStats`] interval delta into the pool/buffer counters
    /// (called by the executor loop around each model call).
    fn add_pool_delta(&self, d: &PoolStats) {
        self.pool_dispatches.fetch_add(d.pool_dispatches, Ordering::Relaxed);
        self.pool_steals.fetch_add(d.pool_steals, Ordering::Relaxed);
        self.buffers_reused.fetch_add(d.buffers_reused, Ordering::Relaxed);
        self.buffers_allocated.fetch_add(d.buffers_allocated, Ordering::Relaxed);
    }
}

/// Bounded-retry policy of an [`ExecutorHandle`] (DESIGN.md §13).
///
/// Only errors marked transient
/// ([`crate::runtime::chaos::is_transient`]) are retried: forwards are
/// pure functions of their inputs and injected faults are fail-stop, so
/// a resubmitted request returns bit-identical rows — retrying can never
/// perturb a sampler's RNG decision streams. Non-transient errors (e.g.
/// "unknown stream" after a stream loss) propagate immediately so the
/// fleet engine's rebase/degradation ladder can handle them.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// total attempts per request (1 ⇒ no retries)
    pub max_attempts: usize,
    /// first retry's backoff; doubles per retry up to 100ms
    pub backoff: Duration,
    /// per-request deadline covering all attempts and backoffs
    pub deadline: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            backoff: Duration::from_micros(500),
            deadline: Duration::from_secs(30),
        }
    }
}

/// Backoff growth cap (exponential backoff stops doubling here).
const MAX_BACKOFF: Duration = Duration::from_millis(100);

/// One queued unit of executor work. Forward-type requests (`Full`,
/// `Delta`) coalesce into batches; stream-control requests are cheap and
/// are served in arrival order within the drained wave. Per-stream
/// ordering is guaranteed by construction: a stream has one owner, and
/// the owner blocks on each reply before sending the next request.
enum Request {
    /// full-window forward of one sequence
    Full {
        /// the sequence to run
        seq: SeqInput,
        /// where the slot view goes
        reply: SyncSender<Result<SlotOut>>,
    },
    /// delta forward against an open incremental stream
    Delta {
        /// stream id (allocated by the executor's model)
        stream: StreamId,
        /// the events past the stream's checkpoint
        delta: SeqDelta,
        /// where the slot view goes
        reply: SyncSender<Result<SlotOut>>,
    },
    /// open a stream on the executor's model
    Open {
        /// where the new stream id goes
        reply: SyncSender<Result<StreamId>>,
    },
    /// rewind a stream to `len` committed events
    Rewind {
        /// stream id
        stream: StreamId,
        /// committed length to rewind to
        len: usize,
        /// completion signal
        reply: SyncSender<Result<()>>,
    },
    /// release a stream (fire-and-forget, idempotent)
    Close {
        /// stream id
        stream: StreamId,
    },
}

/// Cloneable, `Send` handle to a model executor thread. Implements
/// [`Forward`] and [`BatchForward`], so both the blocking samplers and the
/// fleet engine run unchanged on the serving path.
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: SyncSender<Request>,
    max_bucket: usize,
    /// batch capacity the executor thread coalesces to
    max_batch: usize,
    /// whether the executor's model supports incremental streams (probed
    /// at load time; gates the handle's [`Forward::cached`])
    supports_streams: bool,
    /// bounded-retry / deadline policy applied to every forward request
    policy: RetryPolicy,
    /// shared batching counters
    pub stats: Arc<BatcherStats>,
    /// `dataset/encoder/size` tag for logs
    pub name: String,
}

impl ExecutorHandle {
    /// Spawn an executor thread for `(dataset, encoder, size)`, loading the
    /// model through `backend` **on the new thread** (model objects need
    /// not be `Send`).
    ///
    /// `batch_window`: how long the thread waits for co-batchable requests
    /// after the first arrives (0 ⇒ opportunistic draining only).
    pub fn spawn(
        backend: Arc<dyn Backend>,
        dataset: &str,
        encoder: &str,
        size: &str,
        max_batch: usize,
        batch_window: Duration,
    ) -> Result<ExecutorHandle> {
        Self::spawn_with_policy(
            backend,
            dataset,
            encoder,
            size,
            max_batch,
            batch_window,
            RetryPolicy::default(),
        )
    }

    /// [`ExecutorHandle::spawn`] with an explicit [`RetryPolicy`] (tests
    /// use tight deadlines; the default is serving-friendly).
    pub fn spawn_with_policy(
        backend: Arc<dyn Backend>,
        dataset: &str,
        encoder: &str,
        size: &str,
        max_batch: usize,
        batch_window: Duration,
        policy: RetryPolicy,
    ) -> Result<ExecutorHandle> {
        let (tx, rx) = sync_channel::<Request>(1024);
        let stats = Arc::new(BatcherStats::default());
        let stats2 = stats.clone();
        let (ready_tx, ready_rx) = sync_channel::<Result<(usize, usize, bool)>>(1);
        let (ds, enc, sz) = (dataset.to_string(), encoder.to_string(), size.to_string());
        let name = format!("{ds}/{enc}/{sz}");
        std::thread::Builder::new()
            .name(format!("exec-{name}"))
            .spawn(move || {
                // The model is created on this thread and never leaves it.
                let exec = match backend.load_model(&ds, &enc, &sz) {
                    Ok(e) => {
                        let cap = e.max_batch().min(max_batch).max(1);
                        let streams = e.as_ref().cached().is_some();
                        let _ = ready_tx.send(Ok((e.max_bucket(), cap, streams)));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                run_loop(exec, rx, stats2, max_batch, batch_window);
            })
            .expect("spawn executor thread");
        let (max_bucket, max_batch, supports_streams) = ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during load"))??;
        Ok(ExecutorHandle { tx, max_bucket, max_batch, supports_streams, policy, stats, name })
    }

    /// Enqueue one full forward, counting it, and hand back the reply
    /// channel.
    fn submit(&self, seq: SeqInput) -> Result<Receiver<Result<SlotOut>>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Full { seq, reply })
            .map_err(|_| anyhow!("executor '{}' stopped", self.name))?;
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        Ok(rx)
    }

    /// Enqueue one delta forward, counting it, and hand back the reply
    /// channel.
    fn submit_delta(
        &self,
        stream: StreamId,
        delta: SeqDelta,
    ) -> Result<Receiver<Result<SlotOut>>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Delta { stream, delta, reply })
            .map_err(|_| anyhow!("executor '{}' stopped", self.name))?;
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.stats.delta_requests.fetch_add(1, Ordering::Relaxed);
        Ok(rx)
    }

    /// Wait out one reply under the request deadline, separating the
    /// three infrastructure outcomes the satellite tests pin down:
    /// deadline exceeded (`Timeout` — counted in
    /// [`BatcherStats::timeouts`], never retried), executor death
    /// (`Disconnected` — never retried), and an op-level `Err` carried in
    /// the reply (retried below iff transient).
    fn recv_reply<T>(&self, rx: &Receiver<Result<T>>, deadline: Instant) -> Result<T> {
        let wait = deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(wait) {
            Ok(res) => res,
            Err(RecvTimeoutError::Timeout) => {
                self.stats.timeouts.fetch_add(1, Ordering::Relaxed);
                Err(anyhow!(
                    "executor '{}': request deadline ({:?}) exceeded",
                    self.name,
                    self.policy.deadline
                ))
            }
            Err(RecvTimeoutError::Disconnected) => Err(anyhow!(
                "executor '{}' died: reply channel disconnected",
                self.name
            )),
        }
    }

    /// Bounded-retry driver shared by every forward path: submit, wait,
    /// and resubmit transient failures with exponential backoff until
    /// success, a non-transient error, [`RetryPolicy::max_attempts`], or
    /// the per-request deadline. `first_err` lets the batch paths hand
    /// over a request that already failed its wave attempt (that wave
    /// attempt counts as attempt 1).
    ///
    /// Retrying is sound because a forward is a pure function of its
    /// request and injected faults are fail-stop: the retried attempt
    /// returns bit-identical rows, and no sampler RNG is consumed
    /// between attempts (DESIGN.md §13).
    fn with_retry<T>(
        &self,
        submit: impl Fn() -> Result<Receiver<Result<T>>>,
        first_err: Option<anyhow::Error>,
    ) -> Result<T> {
        let deadline = Instant::now() + self.policy.deadline;
        let mut backoff = self.policy.backoff;
        let mut attempt = 1usize;
        let mut last_err = first_err;
        loop {
            if let Some(e) = last_err.take() {
                // The previous attempt failed transiently: give up,
                // time out, or back off and resubmit.
                if attempt >= self.policy.max_attempts {
                    self.stats.gave_up.fetch_add(1, Ordering::Relaxed);
                    return Err(anyhow!(
                        "executor '{}': gave up after {attempt} attempts: {e:#}",
                        self.name
                    ));
                }
                if Instant::now() + backoff >= deadline {
                    self.stats.timeouts.fetch_add(1, Ordering::Relaxed);
                    return Err(anyhow!(
                        "executor '{}': request deadline ({:?}) exceeded during retry backoff: {e:#}",
                        self.name,
                        self.policy.deadline
                    ));
                }
                {
                    let _backoff_span =
                        crate::telemetry::Span::start(crate::telemetry::Stage::RetryBackoff);
                    std::thread::sleep(backoff);
                }
                backoff = (backoff * 2).min(MAX_BACKOFF);
                attempt += 1;
                self.stats.retries.fetch_add(1, Ordering::Relaxed);
            }
            match self.recv_reply(&submit()?, deadline) {
                Ok(v) => return Ok(v),
                Err(e) if crate::runtime::chaos::is_transient(&e) => last_err = Some(e),
                Err(e) => return Err(e),
            }
        }
    }
}

fn run_loop(
    exec: Box<dyn ModelBackend>,
    rx: Receiver<Request>,
    stats: Arc<BatcherStats>,
    max_batch: usize,
    batch_window: Duration,
) {
    let cap = exec.max_batch().min(max_batch).max(1);
    while let Ok(first) = rx.recv() {
        // Control ops are served the moment they arrive — they never
        // coalesce with anything, and their callers block on the reply,
        // so parking them behind the batch window would add pure dead
        // time (notably ~2·N Open round trips while the fleet engine
        // opens its per-session streams).
        let first = match serve_control(exec.as_ref(), first) {
            Some(fwd) => fwd,
            None => continue,
        };
        let mut pending = vec![first];
        let mut disconnected = false;
        let deadline = Instant::now() + batch_window;
        let wait_span = crate::telemetry::Span::start(crate::telemetry::Stage::BatchWait);
        while pending.len() < cap {
            let wait = deadline.saturating_duration_since(Instant::now());
            let next = if wait.is_zero() {
                rx.try_recv().map_err(|e| match e {
                    TryRecvError::Empty => RecvTimeoutError::Timeout,
                    TryRecvError::Disconnected => RecvTimeoutError::Disconnected,
                })
            } else {
                rx.recv_timeout(wait)
            };
            match next {
                Ok(r) => {
                    if let Some(fwd) = serve_control(exec.as_ref(), r) {
                        pending.push(fwd);
                    }
                }
                Err(RecvTimeoutError::Timeout) => break,
                // All senders gone: serve what we already hold, then stop —
                // conflating this with Timeout would silently drain the
                // loop one empty iteration later.
                Err(RecvTimeoutError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        drop(wait_span);
        // Partition the drained wave (control ops were already served on
        // receipt). Full forwards batch into ONE model call; deltas batch
        // into ONE forward_delta_batch call (the backend decides whether
        // the wave is worth fanning across cores). Relative order within
        // one stream is safe by construction — a stream's owner blocks on
        // each reply.
        let mut seqs: Vec<SeqInput> = Vec::new();
        let mut replies: Vec<SyncSender<Result<SlotOut>>> = Vec::new();
        let mut deltas: Vec<(StreamId, SeqDelta, SyncSender<Result<SlotOut>>)> = Vec::new();
        for r in pending {
            match r {
                Request::Full { seq, reply } => {
                    seqs.push(seq);
                    replies.push(reply);
                }
                Request::Delta { stream, delta, reply } => deltas.push((stream, delta, reply)),
                Request::Open { .. } | Request::Rewind { .. } | Request::Close { .. } => {
                    unreachable!("control ops are served on receipt")
                }
            }
        }
        if !seqs.is_empty() {
            stats.batches.fetch_add(1, Ordering::Relaxed);
            stats.batched_requests.fetch_add(seqs.len(), Ordering::Relaxed);
            stats.max_batch_seen.fetch_max(seqs.len(), Ordering::Relaxed);
            let pool_before = pool::stats();
            let served = exec.forward(&seqs);
            stats.add_pool_delta(&pool::stats().since(&pool_before));
            match served {
                Ok(out) => {
                    let out = out.into_shared();
                    for (b, reply) in replies.into_iter().enumerate() {
                        let _ = reply.send(Ok(SlotOut::new(out.clone(), b)));
                    }
                }
                Err(e) => {
                    // replicate the error per requester
                    let msg = format!("{e:#}");
                    for reply in replies {
                        let _ = reply.send(Err(anyhow!("{msg}")));
                    }
                }
            }
        }
        if !deltas.is_empty() {
            stats.delta_waves.fetch_add(1, Ordering::Relaxed);
            stats.batched_deltas.fetch_add(deltas.len(), Ordering::Relaxed);
            stats.max_delta_wave.fetch_max(deltas.len(), Ordering::Relaxed);
            // One forward_delta_batch call serves the whole wave, so the
            // backend can fan heavy waves (e.g. post-slide rebases) across
            // cores; like full batches, a wave-level error replicates to
            // every requester in the wave.
            let (wave, dreplies): (Vec<(StreamId, SeqDelta)>, Vec<SyncSender<Result<SlotOut>>>) =
                deltas.into_iter().map(|(s, d, r)| ((s, d), r)).unzip();
            let pool_before = pool::stats();
            let served = match exec.as_ref().cached() {
                Some(c) => c.forward_delta_batch(wave),
                None => Err(no_streams(exec.as_ref())),
            };
            stats.add_pool_delta(&pool::stats().since(&pool_before));
            match served {
                Ok(outs) if outs.len() == dreplies.len() => {
                    for (out, reply) in outs.into_iter().zip(dreplies) {
                        let _ = reply.send(Ok(out));
                    }
                }
                Ok(outs) => {
                    let msg = format!(
                        "forward_delta_batch returned {} slots for {} deltas",
                        outs.len(),
                        dreplies.len()
                    );
                    for reply in dreplies {
                        let _ = reply.send(Err(anyhow!("{msg}")));
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for reply in dreplies {
                        let _ = reply.send(Err(anyhow!("{msg}")));
                    }
                }
            }
        }
        if disconnected {
            break;
        }
    }
}

/// Error for stream ops reaching a model without [`CachedForward`]
/// support (only possible by calling the handle's stream methods
/// directly, bypassing [`Forward::cached`] discovery).
fn no_streams(exec: &dyn ModelBackend) -> anyhow::Error {
    anyhow!("backend '{}' has no incremental streams", exec.descriptor())
}

/// Serve a stream-control op immediately; forward-type requests pass
/// through (`Some`) to be coalesced into the wave. Safe to run ahead of
/// anything queued behind it: a stream has one owner who blocks on every
/// reply, so a control op can never overtake that stream's own pending
/// forward.
fn serve_control(exec: &dyn ModelBackend, r: Request) -> Option<Request> {
    match r {
        Request::Open { reply } => {
            let _ = reply.send(match exec.cached() {
                Some(c) => c.open_stream(),
                None => Err(no_streams(exec)),
            });
            None
        }
        Request::Rewind { stream, len, reply } => {
            let _ = reply.send(match exec.cached() {
                Some(c) => c.rewind(stream, len),
                None => Err(no_streams(exec)),
            });
            None
        }
        Request::Close { stream } => {
            if let Some(c) = exec.cached() {
                c.close_stream(stream);
            }
            None
        }
        fwd => Some(fwd),
    }
}

impl Forward for ExecutorHandle {
    fn forward1(&self, seq: SeqInput) -> Result<SlotOut> {
        self.with_retry(|| self.submit(seq.clone()), None)
    }

    fn max_bucket(&self) -> usize {
        self.max_bucket
    }

    fn cached(&self) -> Option<&dyn CachedForward> {
        if self.supports_streams {
            Some(self)
        } else {
            None
        }
    }
}

impl CachedForward for ExecutorHandle {
    fn open_stream(&self) -> Result<StreamId> {
        self.with_retry(
            || {
                let (reply, rx) = sync_channel(1);
                self.tx
                    .send(Request::Open { reply })
                    .map_err(|_| anyhow!("executor '{}' stopped", self.name))?;
                Ok(rx)
            },
            None,
        )
    }

    fn forward_delta(&self, stream: StreamId, delta: &SeqDelta) -> Result<SlotOut> {
        self.with_retry(|| self.submit_delta(stream, delta.clone()), None)
    }

    fn rewind(&self, stream: StreamId, len: usize) -> Result<()> {
        // No retry: a rewind that reached the model already moved stream
        // state, so blind resubmission is not provably idempotent under
        // every failure. Deadline/disconnect classification still applies.
        let deadline = Instant::now() + self.policy.deadline;
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request::Rewind { stream, len, reply })
            .map_err(|_| anyhow!("executor '{}' stopped", self.name))?;
        self.recv_reply(&rx, deadline)
    }

    fn close_stream(&self, stream: StreamId) {
        // fire-and-forget: a stopped executor has no state left to free
        let _ = self.tx.send(Request::Close { stream });
    }

    /// Wave-enqueue, like [`BatchForward::forward_batch`]: all deltas land
    /// in the executor thread's channel together and coalesce into one
    /// drained wave instead of paying the batch window per request.
    /// Per-delta transient failures are retried individually (the wave
    /// attempt counts as attempt 1), so one injected fault never fails
    /// its wave-mates.
    fn forward_delta_batch(&self, reqs: Vec<(StreamId, SeqDelta)>) -> Result<Vec<SlotOut>> {
        let deadline = Instant::now() + self.policy.deadline;
        let rxs: Vec<_> = reqs
            .iter()
            .map(|(s, d)| self.submit_delta(*s, d.clone()))
            .collect::<Result<_>>()?;
        rxs.into_iter()
            .zip(reqs)
            .map(|(rx, (s, d))| match self.recv_reply(&rx, deadline) {
                Ok(out) => Ok(out),
                Err(e) if crate::runtime::chaos::is_transient(&e) => {
                    self.with_retry(|| self.submit_delta(s, d.clone()), Some(e))
                }
                Err(e) => Err(e),
            })
            .collect()
    }
}

impl BatchForward for ExecutorHandle {
    /// Enqueue the whole wave before reading any reply: the requests land
    /// in the executor thread's channel together, so it coalesces them
    /// into full batches without waiting out the batch window. Per-request
    /// transient failures are retried individually (the wave attempt
    /// counts as attempt 1), so one injected fault never fails its
    /// wave-mates.
    fn forward_batch(&self, seqs: Vec<SeqInput>) -> Result<Vec<SlotOut>> {
        let deadline = Instant::now() + self.policy.deadline;
        let rxs: Vec<_> = seqs
            .iter()
            .map(|seq| self.submit(seq.clone()))
            .collect::<Result<_>>()?;
        rxs.into_iter()
            .zip(seqs)
            .map(|(rx, seq)| match self.recv_reply(&rx, deadline) {
                Ok(out) => Ok(out),
                Err(e) if crate::runtime::chaos::is_transient(&e) => {
                    self.with_retry(|| self.submit(seq.clone()), Some(e))
                }
                Err(e) => Err(e),
            })
            .collect()
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }
}
