//! Batching executor: the serving-path heart of the coordinator.
//!
//! Model objects need not be `Send` (XLA wrappers hold raw pointers), so
//! each loaded model lives on a dedicated executor thread that owns its
//! [`ModelBackend`] — the thread loads the model through the shared
//! [`Backend`] registry, so the same batcher serves the native CPU models
//! and the PJRT executors. Concurrent sessions submit single-sequence
//! forward requests over a channel; the thread coalesces up to `max_batch`
//! requests that arrive within `batch_window` into ONE batched forward
//! (the B=8 path), then fans the slots back out. This is the same
//! dynamic-batching idea vLLM's router applies to token steps,
//! transplanted to TPP forward passes.
//!
//! The handle is both a [`Forward`] (single-sequence path) and a
//! [`BatchForward`]: the fleet engine enqueues a whole wave of sequences
//! at once, which the executor thread coalesces into full batches without
//! waiting out the batch window.
//!
//! Invariants (property-tested in `rust/tests/coordinator.rs`):
//!   * every request gets exactly one reply (no loss, no duplication);
//!   * replies carry the requester's own sequence results regardless of
//!     how requests were grouped into batches;
//!   * numerical results are identical to the direct path (same forward).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::runtime::{Backend, BatchForward, Forward, ModelBackend, SeqInput, SlotOut};

/// Aggregate counters exposed by an executor thread.
#[derive(Debug, Default)]
pub struct BatcherStats {
    /// total forward requests enqueued (counted at submit time, so it is
    /// exact even while requests are still waiting in the channel)
    pub requests: AtomicUsize,
    /// batched forward calls issued
    pub batches: AtomicUsize,
    /// Σ batch-size over issued batches — occupancy = batched_requests /
    /// batches; trails `requests` by whatever is still queued
    pub batched_requests: AtomicUsize,
    /// largest batch coalesced so far
    pub max_batch_seen: AtomicUsize,
}

impl BatcherStats {
    /// Mean requests per batched forward call.
    pub fn occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }
}

struct Request {
    seq: SeqInput,
    reply: SyncSender<Result<SlotOut>>,
}

/// Cloneable, `Send` handle to a model executor thread. Implements
/// [`Forward`] and [`BatchForward`], so both the blocking samplers and the
/// fleet engine run unchanged on the serving path.
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: SyncSender<Request>,
    max_bucket: usize,
    /// batch capacity the executor thread coalesces to
    max_batch: usize,
    /// shared batching counters
    pub stats: Arc<BatcherStats>,
    /// `dataset/encoder/size` tag for logs
    pub name: String,
}

impl ExecutorHandle {
    /// Spawn an executor thread for `(dataset, encoder, size)`, loading the
    /// model through `backend` **on the new thread** (model objects need
    /// not be `Send`).
    ///
    /// `batch_window`: how long the thread waits for co-batchable requests
    /// after the first arrives (0 ⇒ opportunistic draining only).
    pub fn spawn(
        backend: Arc<dyn Backend>,
        dataset: &str,
        encoder: &str,
        size: &str,
        max_batch: usize,
        batch_window: Duration,
    ) -> Result<ExecutorHandle> {
        let (tx, rx) = sync_channel::<Request>(1024);
        let stats = Arc::new(BatcherStats::default());
        let stats2 = stats.clone();
        let (ready_tx, ready_rx) = sync_channel::<Result<(usize, usize)>>(1);
        let (ds, enc, sz) = (dataset.to_string(), encoder.to_string(), size.to_string());
        let name = format!("{ds}/{enc}/{sz}");
        std::thread::Builder::new()
            .name(format!("exec-{name}"))
            .spawn(move || {
                // The model is created on this thread and never leaves it.
                let exec = match backend.load_model(&ds, &enc, &sz) {
                    Ok(e) => {
                        let cap = e.max_batch().min(max_batch).max(1);
                        let _ = ready_tx.send(Ok((e.max_bucket(), cap)));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                run_loop(exec, rx, stats2, max_batch, batch_window);
            })
            .expect("spawn executor thread");
        let (max_bucket, max_batch) = ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during load"))??;
        Ok(ExecutorHandle { tx, max_bucket, max_batch, stats, name })
    }

    /// Enqueue one request, counting it, and hand back the reply channel.
    fn submit(&self, seq: SeqInput) -> Result<Receiver<Result<SlotOut>>> {
        let (reply, rx) = sync_channel(1);
        self.tx
            .send(Request { seq, reply })
            .map_err(|_| anyhow!("executor '{}' stopped", self.name))?;
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        Ok(rx)
    }
}

fn run_loop(
    exec: Box<dyn ModelBackend>,
    rx: Receiver<Request>,
    stats: Arc<BatcherStats>,
    max_batch: usize,
    batch_window: Duration,
) {
    let cap = exec.max_batch().min(max_batch).max(1);
    while let Ok(first) = rx.recv() {
        let mut pending = vec![first];
        let mut disconnected = false;
        let deadline = Instant::now() + batch_window;
        while pending.len() < cap {
            let wait = deadline.saturating_duration_since(Instant::now());
            let next = if wait.is_zero() {
                rx.try_recv().map_err(|e| match e {
                    TryRecvError::Empty => RecvTimeoutError::Timeout,
                    TryRecvError::Disconnected => RecvTimeoutError::Disconnected,
                })
            } else {
                rx.recv_timeout(wait)
            };
            match next {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                // All senders gone: serve what we already hold, then stop —
                // conflating this with Timeout would silently drain the
                // loop one empty iteration later.
                Err(RecvTimeoutError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.batched_requests.fetch_add(pending.len(), Ordering::Relaxed);
        stats.max_batch_seen.fetch_max(pending.len(), Ordering::Relaxed);

        // Move the inputs out of the requests — no per-batch clones.
        let (seqs, replies): (Vec<SeqInput>, Vec<SyncSender<Result<SlotOut>>>) =
            pending.into_iter().map(|r| (r.seq, r.reply)).unzip();
        match exec.forward(&seqs) {
            Ok(out) => {
                let out = Arc::new(out);
                for (b, reply) in replies.into_iter().enumerate() {
                    let _ = reply.send(Ok(SlotOut::new(out.clone(), b)));
                }
            }
            Err(e) => {
                // replicate the error per requester
                let msg = format!("{e:#}");
                for reply in replies {
                    let _ = reply.send(Err(anyhow!("{msg}")));
                }
            }
        }
        if disconnected {
            break;
        }
    }
}

impl Forward for ExecutorHandle {
    fn forward1(&self, seq: SeqInput) -> Result<SlotOut> {
        self.submit(seq)?
            .recv()
            .map_err(|_| anyhow!("executor '{}' dropped request", self.name))?
    }

    fn max_bucket(&self) -> usize {
        self.max_bucket
    }
}

impl BatchForward for ExecutorHandle {
    /// Enqueue the whole wave before reading any reply: the requests land
    /// in the executor thread's channel together, so it coalesces them
    /// into full batches without waiting out the batch window.
    fn forward_batch(&self, seqs: Vec<SeqInput>) -> Result<Vec<SlotOut>> {
        let rxs: Vec<_> = seqs
            .into_iter()
            .map(|seq| self.submit(seq))
            .collect::<Result<_>>()?;
        rxs.into_iter()
            .map(|rx| {
                rx.recv()
                    .map_err(|_| anyhow!("executor '{}' dropped request", self.name))?
            })
            .collect()
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }
}
