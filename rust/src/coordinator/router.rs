//! Model router: maps `(dataset, encoder)` to a target/draft executor pair,
//! spawning executor threads lazily and reusing them across sessions. The
//! router is backend-agnostic — it only talks to the
//! [`crate::runtime::Backend`] registry.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::batcher::ExecutorHandle;
use crate::runtime::Backend;

/// A routed model pair ready for sampling.
#[derive(Clone)]
pub struct ModelPair {
    /// the big verified model
    pub target: ExecutorHandle,
    /// the small drafting model
    pub draft: ExecutorHandle,
    /// number of real event types of the dataset
    pub num_types: usize,
}

/// Lazily spawning, reusing registry of executor pairs.
pub struct Router {
    backend: Arc<dyn Backend>,
    pairs: Mutex<BTreeMap<(String, String, String), ModelPair>>,
    /// largest batch an executor thread may coalesce
    pub max_batch: usize,
    /// how long an executor thread waits for co-batchable requests
    pub batch_window: Duration,
}

impl Router {
    /// Build a router over a model registry.
    pub fn new(
        backend: Arc<dyn Backend>,
        max_batch: usize,
        batch_window: Duration,
    ) -> Result<Router> {
        Ok(Router {
            backend,
            pairs: Mutex::new(BTreeMap::new()),
            max_batch,
            batch_window,
        })
    }

    /// Number of real event types for a dataset.
    pub fn num_types(&self, dataset: &str) -> Result<usize> {
        self.backend.num_types(dataset)
    }

    /// Datasets known to the backend registry.
    pub fn datasets(&self) -> Vec<String> {
        self.backend.datasets()
    }

    /// Get (spawning if needed) the executor pair for a model.
    pub fn route(&self, dataset: &str, encoder: &str, draft_size: &str) -> Result<ModelPair> {
        let key = (dataset.to_string(), encoder.to_string(), draft_size.to_string());
        if let Some(p) = self.pairs.lock().unwrap().get(&key) {
            return Ok(p.clone());
        }
        let num_types = self.num_types(dataset)?;
        let target = ExecutorHandle::spawn(
            self.backend.clone(),
            dataset,
            encoder,
            "target",
            self.max_batch,
            self.batch_window,
        )?;
        let draft = ExecutorHandle::spawn(
            self.backend.clone(),
            dataset,
            encoder,
            draft_size,
            self.max_batch,
            self.batch_window,
        )?;
        let pair = ModelPair { target, draft, num_types };
        self.pairs.lock().unwrap().insert(key, pair.clone());
        Ok(pair)
    }

    /// Every routed `(dataset, encoder, draft_size)` key with its executor
    /// pair — the `stats`/`metrics` responses walk this to report each
    /// executor's batcher counters.
    pub fn pairs(&self) -> Vec<((String, String, String), ModelPair)> {
        self.pairs
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }
}
