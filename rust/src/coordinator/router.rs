//! Model router: maps `(dataset, encoder)` to a target/draft executor pair,
//! spawning executor threads lazily and reusing them across sessions. The
//! router is backend-agnostic — it only talks to the
//! [`crate::runtime::Backend`] registry. It also owns one lazily spawned
//! continuous-batching [`Scheduler`] per routed pair, so every request for
//! a pair shares one rolling session pool (DESIGN.md §16).
//!
//! The in-process routing key `(dataset, encoder, draft_size)` is the
//! same key the shard tier hashes for its consistent cross-replica
//! routing ([`super::shard::route_key`]) — the proxy keeps sending a pair
//! to the same replica precisely so this router's lazily-spawned
//! executors and scheduler stay hot there.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::batcher::ExecutorHandle;
use super::scheduler::{Scheduler, SchedulerCfg};
use crate::runtime::Backend;

/// A routed model pair ready for sampling.
#[derive(Clone)]
pub struct ModelPair {
    /// the big verified model
    pub target: ExecutorHandle,
    /// the small drafting model
    pub draft: ExecutorHandle,
    /// number of real event types of the dataset
    pub num_types: usize,
}

/// Lazily spawning, reusing registry of executor pairs (and of the
/// per-pair schedulers feeding them).
pub struct Router {
    backend: Arc<dyn Backend>,
    pairs: Mutex<BTreeMap<(String, String, String), ModelPair>>,
    scheds: Mutex<BTreeMap<(String, String, String), Arc<Scheduler>>>,
    /// largest batch an executor thread may coalesce
    pub max_batch: usize,
    /// how long an executor thread waits for co-batchable requests
    pub batch_window: Duration,
    /// admission limits handed to every per-pair [`Scheduler`]
    pub sched_cfg: SchedulerCfg,
}

impl Router {
    /// Build a router over a model registry with default admission limits.
    pub fn new(
        backend: Arc<dyn Backend>,
        max_batch: usize,
        batch_window: Duration,
    ) -> Result<Router> {
        Router::with_scheduler(backend, max_batch, batch_window, SchedulerCfg::default())
    }

    /// Build a router with explicit scheduler admission limits
    /// (`tppsd serve --max-live N --queue-depth Q`).
    pub fn with_scheduler(
        backend: Arc<dyn Backend>,
        max_batch: usize,
        batch_window: Duration,
        sched_cfg: SchedulerCfg,
    ) -> Result<Router> {
        Ok(Router {
            backend,
            pairs: Mutex::new(BTreeMap::new()),
            scheds: Mutex::new(BTreeMap::new()),
            max_batch,
            batch_window,
            sched_cfg,
        })
    }

    /// Number of real event types for a dataset.
    pub fn num_types(&self, dataset: &str) -> Result<usize> {
        self.backend.num_types(dataset)
    }

    /// Datasets known to the backend registry.
    pub fn datasets(&self) -> Vec<String> {
        self.backend.datasets()
    }

    /// Get (spawning if needed) the executor pair for a model.
    pub fn route(&self, dataset: &str, encoder: &str, draft_size: &str) -> Result<ModelPair> {
        let key = (dataset.to_string(), encoder.to_string(), draft_size.to_string());
        if let Some(p) = self.pairs.lock().unwrap().get(&key) {
            return Ok(p.clone());
        }
        let num_types = self.num_types(dataset)?;
        let target = ExecutorHandle::spawn(
            self.backend.clone(),
            dataset,
            encoder,
            "target",
            self.max_batch,
            self.batch_window,
        )?;
        let draft = ExecutorHandle::spawn(
            self.backend.clone(),
            dataset,
            encoder,
            draft_size,
            self.max_batch,
            self.batch_window,
        )?;
        let pair = ModelPair { target, draft, num_types };
        self.pairs.lock().unwrap().insert(key, pair.clone());
        Ok(pair)
    }

    /// Get (spawning if needed) the continuous-batching scheduler for a
    /// model pair. All requests naming the same `(dataset, encoder,
    /// draft_size)` share one scheduler — that sharing is what lets their
    /// forwards co-batch across requests.
    pub fn scheduler(
        &self,
        dataset: &str,
        encoder: &str,
        draft_size: &str,
    ) -> Result<Arc<Scheduler>> {
        let key = (dataset.to_string(), encoder.to_string(), draft_size.to_string());
        if let Some(s) = self.scheds.lock().unwrap().get(&key) {
            return Ok(s.clone());
        }
        let pair = self.route(dataset, encoder, draft_size)?;
        let mut map = self.scheds.lock().unwrap();
        let sched = map
            .entry(key)
            .or_insert_with(|| Scheduler::spawn(pair, self.sched_cfg))
            .clone();
        Ok(sched)
    }

    /// Every routed `(dataset, encoder, draft_size)` key with its executor
    /// pair — the `stats`/`metrics` responses walk this to report each
    /// executor's batcher counters.
    pub fn pairs(&self) -> Vec<((String, String, String), ModelPair)> {
        self.pairs
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    /// Every spawned scheduler with its pair key — the `stats`/`metrics`
    /// responses walk this to report admission counters and gauges.
    pub fn schedulers(&self) -> Vec<((String, String, String), Arc<Scheduler>)> {
        self.scheds
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }
}
