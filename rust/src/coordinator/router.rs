//! Model router: maps `(dataset, encoder)` to a target/draft executor pair,
//! spawning executor threads lazily and reusing them across sessions.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{Context as _, Result};

use super::batcher::ExecutorHandle;
use crate::runtime::ArtifactDir;
use crate::util::json::Json;

/// A routed model pair ready for sampling.
#[derive(Clone)]
pub struct ModelPair {
    pub target: ExecutorHandle,
    pub draft: ExecutorHandle,
    pub num_types: usize,
}

pub struct Router {
    art: ArtifactDir,
    datasets: Json,
    pairs: Mutex<BTreeMap<(String, String, String), ModelPair>>,
    pub max_batch: usize,
    pub batch_window: Duration,
}

impl Router {
    pub fn new(art: ArtifactDir, max_batch: usize, batch_window: Duration) -> Result<Router> {
        let datasets = art.datasets_json()?;
        Ok(Router {
            art,
            datasets,
            pairs: Mutex::new(BTreeMap::new()),
            max_batch,
            batch_window,
        })
    }

    /// Number of real event types for a dataset.
    pub fn num_types(&self, dataset: &str) -> Result<usize> {
        self.datasets
            .usize_at(&format!("datasets.{dataset}.num_types"))
            .with_context(|| format!("unknown dataset '{dataset}'"))
    }

    /// Datasets known to the artifact registry.
    pub fn datasets(&self) -> Vec<String> {
        self.datasets
            .get("datasets")
            .and_then(Json::as_obj)
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// Get (spawning if needed) the executor pair for a model.
    pub fn route(&self, dataset: &str, encoder: &str, draft_size: &str) -> Result<ModelPair> {
        let key = (dataset.to_string(), encoder.to_string(), draft_size.to_string());
        if let Some(p) = self.pairs.lock().unwrap().get(&key) {
            return Ok(p.clone());
        }
        let num_types = self.num_types(dataset)?;
        let target = ExecutorHandle::spawn(
            self.art.clone(),
            dataset,
            encoder,
            "target",
            self.max_batch,
            self.batch_window,
        )?;
        let draft = ExecutorHandle::spawn(
            self.art.clone(),
            dataset,
            encoder,
            draft_size,
            self.max_batch,
            self.batch_window,
        )?;
        let pair = ModelPair { target, draft, num_types };
        self.pairs.lock().unwrap().insert(key, pair.clone());
        Ok(pair)
    }
}
