//! Artifact manifests: the JSON sidecar emitted by `python/compile/aot.py`
//! describing one AOT-compiled forward graph (shapes, parameter order).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Shape metadata of one exported HLO graph.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// encoder name (`thp` | `sahp` | `attnhp`)
    pub encoder: String,
    /// model-size name (`target`, `draft`, ...)
    pub size_name: String,
    /// Transformer depth
    pub n_layers: usize,
    /// attention heads
    pub n_heads: usize,
    /// model width
    pub d_model: usize,
    /// mixture components of the output head
    pub n_mix: usize,
    /// sequence-length bucket (incl. BOS)
    pub bucket: usize,
    /// batch capacity of the graph
    pub batch: usize,
    /// padded event-type dimension
    pub k_max: usize,
    /// BOS token id
    pub bos_id: usize,
    /// kernel implementation tag (`pallas` | `ref`)
    pub impl_name: String,
    /// parameter (name, shape) in positional order
    pub params: Vec<(String, Vec<usize>)>,
}

impl Manifest {
    /// Parse one `*.manifest.json` sidecar.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let need = |k: &str| -> Result<usize> {
            j.usize_at(k).with_context(|| format!("manifest missing {k}"))
        };
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .context("manifest missing params")?
            .iter()
            .map(|p| {
                let name = p.str_at("name").unwrap_or("").to_string();
                let shape = p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default();
                (name, shape)
            })
            .collect();
        Ok(Manifest {
            encoder: j.str_at("encoder").context("encoder")?.to_string(),
            size_name: j.str_at("size.name").context("size.name")?.to_string(),
            n_layers: need("size.n_layers")?,
            n_heads: need("size.n_heads")?,
            d_model: need("size.d_model")?,
            n_mix: need("size.n_mix")?,
            bucket: need("bucket")?,
            batch: need("batch")?,
            k_max: need("k_max")?,
            bos_id: need("bos_id")?,
            impl_name: j.str_at("impl").unwrap_or("pallas").to_string(),
            params,
        })
    }

    /// `fwd_{enc}_{size}_L{bucket}_B{batch}`
    pub fn stem(&self) -> String {
        format!(
            "fwd_{}_{}_L{}_B{}",
            self.encoder, self.size_name, self.bucket, self.batch
        )
    }
}

/// The artifact directory layout produced by `make artifacts`.
#[derive(Debug, Clone)]
pub struct ArtifactDir {
    /// directory containing `hlo/`, `weights/` and `datasets.json`
    pub root: PathBuf,
}

impl ArtifactDir {
    /// Wrap a built artifact directory (errors when `hlo/` is absent).
    pub fn new<P: Into<PathBuf>>(root: P) -> Result<ArtifactDir> {
        let root = root.into();
        if !root.join("hlo").is_dir() {
            bail!(
                "artifact dir {} not built (run `make artifacts`)",
                root.display()
            );
        }
        Ok(ArtifactDir { root })
    }

    /// Default location: `$TPP_SD_ARTIFACTS` or `./artifacts`.
    pub fn discover() -> Result<ArtifactDir> {
        let root = std::env::var("TPP_SD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        ArtifactDir::new(root)
    }

    /// Path of an HLO text dump.
    pub fn hlo_path(&self, stem: &str) -> PathBuf {
        self.root.join("hlo").join(format!("{stem}.hlo.txt"))
    }

    /// Path of a manifest sidecar.
    pub fn manifest_path(&self, stem: &str) -> PathBuf {
        self.root.join("hlo").join(format!("{stem}.manifest.json"))
    }

    /// Path of a trained-weights `.npz`.
    pub fn weights_path(&self, dataset: &str, encoder: &str, size: &str) -> PathBuf {
        self.root
            .join("weights")
            .join(format!("{dataset}_{encoder}_{size}.npz"))
    }

    /// Parse the exported dataset registry (`datasets.json`).
    pub fn datasets_json(&self) -> Result<Json> {
        let p = self.root.join("datasets.json");
        let text = std::fs::read_to_string(&p)
            .with_context(|| format!("reading {}", p.display()))?;
        Ok(Json::parse(&text)?)
    }

    /// All manifests for an (encoder, size) pair, sorted by (bucket, batch).
    pub fn manifests_for(&self, encoder: &str, size: &str) -> Result<Vec<Manifest>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(self.root.join("hlo"))? {
            let p = entry?.path();
            let name = p.file_name().unwrap().to_string_lossy().to_string();
            if name.starts_with(&format!("fwd_{encoder}_{size}_L"))
                && name.ends_with(".manifest.json")
            {
                out.push(Manifest::load(&p)?);
            }
        }
        if out.is_empty() {
            bail!("no artifacts for encoder={encoder} size={size} under {}", self.root.display());
        }
        out.sort_by_key(|m| (m.bucket, m.batch));
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_json() {
        let tmp = std::env::temp_dir().join("tppsd_manifest_test.json");
        std::fs::write(
            &tmp,
            r#"{"encoder":"thp","size":{"name":"draft","n_layers":1,"n_heads":1,
                "d_model":16,"n_mix":8,"d_ff":32},"bucket":64,"batch":1,
                "k_max":24,"bos_id":24,"impl":"pallas",
                "params":[{"name":"emb_type","shape":[25,16],"dtype":"float32"}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&tmp).unwrap();
        assert_eq!(m.encoder, "thp");
        assert_eq!(m.bucket, 64);
        assert_eq!(m.params[0].0, "emb_type");
        assert_eq!(m.params[0].1, vec![25, 16]);
        assert_eq!(m.stem(), "fwd_thp_draft_L64_B1");
        std::fs::remove_file(tmp).ok();
    }
}
