//! Persistent worker pool + buffer recycling for the native hot path
//! (DESIGN.md §14).
//!
//! Every batched full forward and every delta wave used to pay two
//! mechanical costs per call: a `std::thread::scope` spawn/join for the
//! fan-out, and four fresh `vec![0f32; batch*bucket*dim]` output buffers.
//! Under steady-state fleet traffic (thousands of forwards per run) both
//! are pure overhead. This module removes them without changing a single
//! output bit:
//!
//! * [`run_wave`] executes a wave of independent jobs over parked worker
//!   threads. The wave is partitioned into the **same contiguous groups**
//!   the old scoped fan-out used (`per = ceil(n/workers)` jobs per group),
//!   and each job writes only its own disjoint output slice, so scheduling
//!   order is invisible in the results — pooled, scoped, and serial
//!   execution are bit-identical by construction.
//! * [`checkout`]/[`recycle`] keep a free list of `Vec<f32>` output
//!   buffers. A checkout is `clear()` + `resize(len, 0.0)`, which is
//!   observationally identical to `vec![0f32; len]` — and the native
//!   kernels overwrite every row they hand out anyway.
//!
//! Benches A/B the old behaviour through [`set_scoped_baseline`] and
//! [`set_recycling`]; [`stats`] exposes the counters that
//! `BatcherStats`/`FleetStats` surface per executor / per fleet run.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use super::backend::ForwardOut;

/// Below this many total rows a wave runs on the calling thread: even a
/// pool dispatch (~a few µs) exceeds the transcendental work being
/// parallelized. Shared by batched full forwards and delta waves so both
/// paths always carry the same parallelism policy.
pub const MIN_PARALLEL_ROWS: usize = 256;

/// Most free `Vec<f32>` buffers the recycler holds; beyond this, returned
/// buffers are simply freed (bounds worst-case idle memory).
const MAX_POOLED_BUFFERS: usize = 64;

/// Most pooled [`ForwardOut`] shells (`Arc` allocations) kept for reuse.
const MAX_POOLED_SHELLS: usize = 16;

/// Worker count for batched fills, queried once — `available_parallelism`
/// is a syscall and the fleet engine issues thousands of forwards per run.
pub fn fill_workers() -> usize {
    static WORKERS: OnceLock<usize> = OnceLock::new();
    *WORKERS.get_or_init(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// The shared worker-count policy for a wave of `jobs` independent fills
/// covering `total_rows` output rows: 1 (serial, no dispatch) below
/// [`MIN_PARALLEL_ROWS`], else one worker per job up to [`fill_workers`].
pub fn wave_workers(total_rows: usize, jobs: usize) -> usize {
    if jobs <= 1 || total_rows < MIN_PARALLEL_ROWS {
        1
    } else {
        fill_workers().min(jobs)
    }
}

// ---------------------------------------------------------------------------
// mode toggles (benches/tests A/B the pre-pool behaviour)
// ---------------------------------------------------------------------------

static SCOPED_BASELINE: AtomicBool = AtomicBool::new(false);
static RECYCLING: AtomicBool = AtomicBool::new(true);

/// Route [`run_wave`] through the old per-wave `std::thread::scope`
/// spawn/join instead of the persistent pool. For benches that measure the
/// pool's win and tests that prove output equivalence; process-global.
pub fn set_scoped_baseline(on: bool) {
    SCOPED_BASELINE.store(on, Ordering::Relaxed);
}

/// Enable/disable buffer and shell recycling (disabled = every checkout is
/// a fresh allocation, the pre-pool behaviour). Process-global.
pub fn set_recycling(on: bool) {
    RECYCLING.store(on, Ordering::Relaxed);
}

fn scoped_baseline() -> bool {
    SCOPED_BASELINE.load(Ordering::Relaxed)
}

fn recycling() -> bool {
    RECYCLING.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// counters
// ---------------------------------------------------------------------------

static POOL_DISPATCHES: AtomicUsize = AtomicUsize::new(0);
static POOL_STEALS: AtomicUsize = AtomicUsize::new(0);
static BUFFERS_REUSED: AtomicUsize = AtomicUsize::new(0);
static BUFFERS_ALLOCATED: AtomicUsize = AtomicUsize::new(0);

/// Snapshot of the process-wide pool/recycler counters. Attribution to one
/// executor or fleet run is approximate when several run concurrently —
/// the counters are monotone, so deltas over an interval still bound the
/// interval's activity.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Wave groups handed to pool workers (the caller always works group 0
    /// itself, so a W-group wave dispatches W−1).
    pub pool_dispatches: usize,
    /// Jobs a thread claimed from another group's cursor after draining
    /// its own (work-stealing kept a straggler group from idling cores).
    pub pool_steals: usize,
    /// Output buffers served from the free list instead of the allocator.
    pub buffers_reused: usize,
    /// Output buffers that had to be freshly allocated.
    pub buffers_allocated: usize,
}

impl PoolStats {
    /// Counter deltas since an `earlier` snapshot (saturating, so a stale
    /// snapshot cannot underflow).
    pub fn since(&self, earlier: &PoolStats) -> PoolStats {
        PoolStats {
            pool_dispatches: self.pool_dispatches.saturating_sub(earlier.pool_dispatches),
            pool_steals: self.pool_steals.saturating_sub(earlier.pool_steals),
            buffers_reused: self.buffers_reused.saturating_sub(earlier.buffers_reused),
            buffers_allocated: self.buffers_allocated.saturating_sub(earlier.buffers_allocated),
        }
    }
}

/// Current process-wide pool/recycler counters.
pub fn stats() -> PoolStats {
    PoolStats {
        pool_dispatches: POOL_DISPATCHES.load(Ordering::Relaxed),
        pool_steals: POOL_STEALS.load(Ordering::Relaxed),
        buffers_reused: BUFFERS_REUSED.load(Ordering::Relaxed),
        buffers_allocated: BUFFERS_ALLOCATED.load(Ordering::Relaxed),
    }
}

// ---------------------------------------------------------------------------
// buffer + shell recycling
// ---------------------------------------------------------------------------

static FREE: Mutex<Vec<Vec<f32>>> = Mutex::new(Vec::new());
static SHELLS: Mutex<Vec<Arc<ForwardOut>>> = Mutex::new(Vec::new());

/// Check out a zeroed `len`-element buffer, reusing a recycled one when
/// available. `clear()` + `resize(len, 0.0)` makes the reused buffer
/// element-for-element identical to a fresh `vec![0f32; len]`, so
/// recycling cannot change outputs (DESIGN.md §14) — and the fill paths
/// overwrite every row they expose regardless.
pub fn checkout(len: usize) -> Vec<f32> {
    if recycling() {
        if let Some(mut v) = FREE.lock().unwrap().pop() {
            BUFFERS_REUSED.fetch_add(1, Ordering::Relaxed);
            v.clear();
            v.resize(len, 0.0);
            return v;
        }
    }
    BUFFERS_ALLOCATED.fetch_add(1, Ordering::Relaxed);
    vec![0f32; len]
}

/// Return a buffer to the free list (no-op while recycling is disabled,
/// for zero-capacity husks, or when the list is at capacity).
pub fn recycle(mut v: Vec<f32>) {
    if !recycling() || v.capacity() == 0 {
        return;
    }
    let mut free = FREE.lock().unwrap();
    if free.len() < MAX_POOLED_BUFFERS {
        v.clear();
        free.push(v);
    }
}

/// Take a pooled `Arc<ForwardOut>` shell (uniquely owned, so the caller
/// can `Arc::get_mut` it) to avoid a fresh `Arc` allocation per forward.
pub(crate) fn take_shell() -> Option<Arc<ForwardOut>> {
    if !recycling() {
        return None;
    }
    SHELLS.lock().unwrap().pop()
}

/// Pool a uniquely-owned shell for reuse. The shell keeps its buffers
/// until the next [`ForwardOut::into_shared`] swaps them out (at which
/// point they reach the free list through `ForwardOut`'s `Drop`).
pub(crate) fn put_shell(shell: Arc<ForwardOut>) {
    debug_assert_eq!(Arc::strong_count(&shell), 1);
    if !recycling() {
        return;
    }
    let mut shells = SHELLS.lock().unwrap();
    if shells.len() < MAX_POOLED_SHELLS {
        shells.push(shell);
    }
}

// ---------------------------------------------------------------------------
// the persistent worker pool
// ---------------------------------------------------------------------------

/// Type-erased view of one in-flight wave. `data` points at a stack-held
/// [`Ctx`] in the *calling* frame; `call(data, i)` runs job `i`.
///
/// Soundness: the caller blocks until `done == total`, and `done` only
/// reaches `total` after every claimed job has finished running, so no
/// thread dereferences `data` after the caller's frame moves on. Each job
/// index is claimed exactly once (a `fetch_add` on its group cursor), so
/// no `&mut` job aliasing occurs. Stale queue tickets left by a finished
/// wave only ever read the (exhausted) cursors, never `data`.
struct Wave {
    data: *const (),
    call: fn(*const (), usize),
    /// next unclaimed job index per group
    cursors: Vec<AtomicUsize>,
    /// one-past-the-last job index per group
    ends: Vec<usize>,
    total: usize,
    done: Mutex<usize>,
    cv: Condvar,
    poisoned: AtomicBool,
}

// SAFETY: `data`/`call` erase a `&mut [T]` of `T: Send` jobs and a
// `&F: Sync` closure (bounds enforced by `run_pooled`); the claim protocol
// above guarantees exclusive access per job and a happens-before edge from
// every job run to the caller's wake-up (the `done` mutex).
unsafe impl Send for Wave {}
// SAFETY: see above — all shared mutation goes through atomics/locks.
unsafe impl Sync for Wave {}

/// Typed context a wave's `data` pointer erases.
struct Ctx<T, F> {
    jobs: *mut T,
    f: *const F,
}

/// Run job `i` of the wave behind `data`. Declared safe so that the plain
/// fn-pointer type (`fn(*const (), usize)`) erases `T`/`F`; the interior
/// unsafety is justified by the `Wave` claim protocol.
fn call_one<T, F: Fn(&mut T)>(data: *const (), i: usize) {
    // SAFETY: `data` points at a live `Ctx<T, F>` (the caller of
    // `run_pooled` blocks until all jobs finish), `i` was claimed exactly
    // once so the `&mut` is exclusive, and `F: Sync` makes `&F` shareable.
    unsafe {
        let ctx = &*(data as *const Ctx<T, F>);
        (&*ctx.f)(&mut *ctx.jobs.add(i));
    }
}

struct Ticket {
    wave: Arc<Wave>,
    home: usize,
}

struct Queue {
    q: Mutex<VecDeque<Ticket>>,
    cv: Condvar,
}

fn queue() -> &'static Queue {
    static Q: OnceLock<Queue> = OnceLock::new();
    Q.get_or_init(|| Queue { q: Mutex::new(VecDeque::new()), cv: Condvar::new() })
}

/// Spawn the persistent workers once, lazily (first parallel wave). The
/// threads park on the queue condvar between waves and live for the
/// process lifetime — steady-state waves never spawn.
fn ensure_workers() {
    static SPAWN: std::sync::Once = std::sync::Once::new();
    SPAWN.call_once(|| {
        for i in 0..fill_workers().saturating_sub(1) {
            std::thread::Builder::new()
                .name(format!("tpp-pool-{i}"))
                .spawn(worker_loop)
                .expect("spawn pool worker");
        }
    });
}

fn worker_loop() {
    let q = queue();
    loop {
        let ticket = {
            let mut guard = q.q.lock().unwrap();
            loop {
                match guard.pop_front() {
                    Some(t) => break t,
                    None => guard = q.cv.wait(guard).unwrap(),
                }
            }
        };
        work(&ticket.wave, ticket.home);
    }
}

/// Drain the wave starting from group `home`, then steal from the other
/// groups round-robin. Every claim is a `fetch_add`, so each job runs on
/// exactly one thread; job panics poison the wave instead of deadlocking
/// the caller.
fn work(wave: &Wave, home: usize) {
    let groups = wave.cursors.len();
    for off in 0..groups {
        let g = (home + off) % groups;
        loop {
            let i = wave.cursors[g].fetch_add(1, Ordering::Relaxed);
            if i >= wave.ends[g] {
                break;
            }
            if off > 0 {
                POOL_STEALS.fetch_add(1, Ordering::Relaxed);
            }
            if catch_unwind(AssertUnwindSafe(|| (wave.call)(wave.data, i))).is_err() {
                wave.poisoned.store(true, Ordering::Relaxed);
            }
            let mut done = wave.done.lock().unwrap();
            *done += 1;
            if *done == wave.total {
                wave.cv.notify_all();
            }
        }
    }
}

/// Run `f` over every job of a wave. `workers <= 1` (or a single job)
/// runs serially on the caller; otherwise the wave is partitioned into
/// the same contiguous groups the old scoped fan-out used and executed on
/// the persistent pool (or, under [`set_scoped_baseline`], on per-wave
/// scoped threads). Jobs must be independent — each receives `&mut` to
/// its own element only — which is what makes all three execution modes
/// bit-identical.
pub fn run_wave<T: Send, F: Fn(&mut T) + Sync>(jobs: &mut [T], workers: usize, f: F) {
    if workers <= 1 || jobs.len() <= 1 {
        for j in jobs.iter_mut() {
            f(j);
        }
        return;
    }
    // Only genuinely parallel waves are timed — the serial short-circuit
    // above is the per-event hot path and stays span-free.
    let _span = crate::telemetry::Span::start(crate::telemetry::Stage::PoolDispatch);
    if scoped_baseline() {
        run_scoped(jobs, workers, &f);
    } else {
        ensure_workers();
        run_pooled(jobs, workers, &f);
    }
}

/// The pre-pool behaviour: per-wave scoped spawn/join, same grouping.
fn run_scoped<T: Send, F: Fn(&mut T) + Sync>(jobs: &mut [T], workers: usize, f: &F) {
    let per = jobs.len().div_ceil(workers.min(jobs.len()));
    let mut chunks = jobs.chunks_mut(per);
    let first = chunks.next().expect("non-empty wave");
    std::thread::scope(|sc| {
        for chunk in chunks.by_ref() {
            sc.spawn(move || {
                for j in chunk {
                    f(j);
                }
            });
        }
        // the calling thread works too (group 0)
        for j in first {
            f(j);
        }
    });
}

fn run_pooled<T: Send, F: Fn(&mut T) + Sync>(jobs: &mut [T], workers: usize, f: &F) {
    let n = jobs.len();
    let per = n.div_ceil(workers.min(n));
    let groups = n.div_ceil(per);
    let ctx = Ctx { jobs: jobs.as_mut_ptr(), f: f as *const F };
    let wave = Arc::new(Wave {
        data: &ctx as *const Ctx<T, F> as *const (),
        call: call_one::<T, F>,
        cursors: (0..groups).map(|g| AtomicUsize::new(g * per)).collect(),
        ends: (0..groups).map(|g| ((g + 1) * per).min(n)).collect(),
        total: n,
        done: Mutex::new(0),
        cv: Condvar::new(),
        poisoned: AtomicBool::new(false),
    });
    let q = queue();
    {
        let mut guard = q.q.lock().unwrap();
        for g in 1..groups {
            guard.push_back(Ticket { wave: Arc::clone(&wave), home: g });
        }
    }
    POOL_DISPATCHES.fetch_add(groups - 1, Ordering::Relaxed);
    q.cv.notify_all();
    // The caller is group 0's worker (and steals any stragglers).
    work(&wave, 0);
    let mut done = wave.done.lock().unwrap();
    while *done < wave.total {
        done = wave.cv.wait(done).unwrap();
    }
    drop(done);
    // Hygiene: drop this wave's unclaimed tickets (all cursors are
    // exhausted, so a late pop would be a no-op scan anyway).
    q.q.lock().unwrap().retain(|t| !Arc::ptr_eq(&t.wave, &wave));
    if wave.poisoned.load(Ordering::Relaxed) {
        panic!("worker-pool wave job panicked");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(jobs: &mut [(usize, Vec<f32>)], workers: usize) {
        run_wave(jobs, workers, |(base, out)| {
            for (r, v) in out.iter_mut().enumerate() {
                *v = ((*base * 31 + r) as f32 * 0.1).sin();
            }
        });
    }

    #[test]
    fn pooled_wave_matches_serial() {
        for &(n, rows, workers) in &[(1usize, 4usize, 4usize), (3, 7, 2), (8, 16, 4), (13, 5, 8)] {
            let mk = || (0..n).map(|i| (i, vec![0f32; rows])).collect::<Vec<_>>();
            let mut serial = mk();
            fill(&mut serial, 1);
            let mut pooled = mk();
            fill(&mut pooled, workers);
            assert_eq!(serial, pooled, "n={n} workers={workers}");
        }
    }

    #[test]
    fn checkout_is_zeroed_and_reuse_counted() {
        set_recycling(true);
        let before = stats();
        let v = checkout(32);
        assert!(v.iter().all(|&x| x == 0.0));
        recycle(v);
        let w = checkout(16);
        assert_eq!(w.len(), 16);
        assert!(w.iter().all(|&x| x == 0.0));
        let d = stats().since(&before);
        assert!(d.buffers_reused + d.buffers_allocated >= 2);
    }

    #[test]
    fn wave_workers_policy() {
        assert_eq!(wave_workers(10, 1), 1);
        assert_eq!(wave_workers(MIN_PARALLEL_ROWS - 1, 8), 1);
        assert!(wave_workers(MIN_PARALLEL_ROWS, 8) >= 1);
    }
}
