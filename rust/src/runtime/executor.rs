//! The model executor: one trained TPP model, loaded onto the PJRT CPU
//! client, with length-bucketed AOT executables and cached weights.
//!
//! Forward calls pick the smallest compiled bucket that fits the sequence
//! (quadratic attention cost ⇒ small-context calls are much cheaper), and
//! the B=8 graph when a batch of sequences is supplied (the coordinator's
//! batching path). Executables are compiled lazily on first use and cached.
//!
//! XLA wrapper objects hold raw pointers and are not `Send`; the
//! coordinator therefore owns each executor on a dedicated thread and talks
//! to it over channels (see `coordinator::batcher`).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};
use xla::FromRawBytes;

use super::manifest::{ArtifactDir, Manifest};
use crate::model::mixture::{Mixture, TypeDist};

/// One sequence's model input: absolute event times/types (BOS excluded —
/// the executor prepends it).
#[derive(Debug, Clone, Default)]
pub struct SeqInput {
    /// window-start time carried by the BOS row
    pub t0: f64,
    pub times: Vec<f64>,
    pub types: Vec<u32>,
}

impl SeqInput {
    pub fn len_with_bos(&self) -> usize {
        self.times.len() + 1
    }
}

/// One batch slot of a [`ForwardOut`] — what a single-sequence consumer
/// (sampler, likelihood scorer) sees. Cheap to clone (Arc-backed).
#[derive(Debug, Clone)]
pub struct SlotOut {
    out: std::sync::Arc<ForwardOut>,
    b: usize,
}

impl SlotOut {
    pub fn new(out: std::sync::Arc<ForwardOut>, b: usize) -> SlotOut {
        assert!(b < out.batch);
        SlotOut { out, b }
    }

    pub fn mixture(&self, row: usize) -> Mixture {
        self.out.mixture(self.b, row)
    }

    pub fn type_dist(&self, row: usize, k: usize) -> TypeDist {
        self.out.type_dist(self.b, row, k)
    }

    pub fn bucket(&self) -> usize {
        self.out.bucket
    }
}

/// Anything that can run the model forward pass for one sequence: the
/// in-process [`ModelExecutor`] (direct path) or a
/// [`crate::coordinator::ExecutorHandle`] (batched serving path). Samplers
/// and scorers are generic over this, so the exact same algorithm code runs
/// on both paths.
pub trait Forward {
    fn forward1(&self, seq: SeqInput) -> anyhow::Result<SlotOut>;
    /// Largest sequence length (incl. BOS) a forward can take.
    fn max_bucket(&self) -> usize;
}

impl Forward for ModelExecutor {
    fn forward1(&self, seq: SeqInput) -> anyhow::Result<SlotOut> {
        let out = self.forward(std::slice::from_ref(&seq))?;
        Ok(SlotOut::new(std::sync::Arc::new(out), 0))
    }

    fn max_bucket(&self) -> usize {
        ModelExecutor::max_bucket(self)
    }
}

/// Flattened forward outputs for a batch (row-major `[B, L, ·]`).
#[derive(Debug)]
pub struct ForwardOut {
    pub batch: usize,
    pub bucket: usize,
    pub n_mix: usize,
    pub k_max: usize,
    log_w: Vec<f32>,
    mu: Vec<f32>,
    log_sigma: Vec<f32>,
    logits: Vec<f32>,
}

impl ForwardOut {
    /// Construct from raw flattened buffers (used by mock models in tests
    /// and by any alternative backend).
    #[allow(clippy::too_many_arguments)]
    pub fn from_raw(
        batch: usize,
        bucket: usize,
        n_mix: usize,
        k_max: usize,
        log_w: Vec<f32>,
        mu: Vec<f32>,
        log_sigma: Vec<f32>,
        logits: Vec<f32>,
    ) -> ForwardOut {
        assert_eq!(log_w.len(), batch * bucket * n_mix);
        assert_eq!(mu.len(), batch * bucket * n_mix);
        assert_eq!(log_sigma.len(), batch * bucket * n_mix);
        assert_eq!(logits.len(), batch * bucket * k_max);
        ForwardOut { batch, bucket, n_mix, k_max, log_w, mu, log_sigma, logits }
    }

    /// Mixture parameters of `g(τ_{row+1} | history ≤ row)` for batch row b.
    pub fn mixture(&self, b: usize, row: usize) -> Mixture {
        debug_assert!(b < self.batch && row < self.bucket);
        let m = self.n_mix;
        let off = (b * self.bucket + row) * m;
        Mixture {
            log_w: self.log_w[off..off + m].iter().map(|&x| x as f64).collect(),
            mu: self.mu[off..off + m].iter().map(|&x| x as f64).collect(),
            log_sigma: self.log_sigma[off..off + m]
                .iter()
                .map(|&x| x as f64)
                .collect(),
        }
    }

    /// Event-type distribution at `row`, restricted to `k` real types.
    pub fn type_dist(&self, b: usize, row: usize, k: usize) -> TypeDist {
        debug_assert!(b < self.batch && row < self.bucket);
        let off = (b * self.bucket + row) * self.k_max;
        let logits: Vec<f64> = self.logits[off..off + self.k_max]
            .iter()
            .map(|&x| x as f64)
            .collect();
        TypeDist::from_logits(&logits, k)
    }
}

/// A trained model (weights) + its bucketed executables, lazily compiled.
pub struct ModelExecutor {
    client: Rc<xla::PjRtClient>,
    art: ArtifactDir,
    pub encoder: String,
    pub size_name: String,
    pub n_mix: usize,
    pub k_max: usize,
    pub bos_id: u32,
    manifests: BTreeMap<(usize, usize), Manifest>,
    exes: RefCell<BTreeMap<(usize, usize), xla::PjRtLoadedExecutable>>,
    weights: Vec<xla::Literal>,
    /// weights pre-uploaded to the device — forwards then use `execute_b`
    /// and only transfer the 3 small input tensors per call (§Perf: saves
    /// the per-call host→device copy of every parameter literal). Disabled
    /// via TPP_SD_LITERAL_ARGS=1 for the ablation bench.
    weight_bufs: Option<Vec<xla::PjRtBuffer>>,
    /// forward-call counter (perf accounting)
    calls: RefCell<usize>,
}

impl ModelExecutor {
    /// Load weights + manifests for `(dataset, encoder, size)`.
    pub fn load(
        client: Rc<xla::PjRtClient>,
        art: &ArtifactDir,
        dataset: &str,
        encoder: &str,
        size: &str,
    ) -> Result<ModelExecutor> {
        let mut manifests = BTreeMap::new();
        for m in art.manifests_for(encoder, size)? {
            manifests.insert((m.bucket, m.batch), m);
        }
        let m0 = manifests.values().next().unwrap().clone();
        let weights = load_weights(&art.weights_path(dataset, encoder, size), &m0)?;
        let weight_bufs = if std::env::var_os("TPP_SD_LITERAL_ARGS").is_some() {
            None
        } else {
            let bufs = weights
                .iter()
                .map(|w| client.buffer_from_host_literal(None, w))
                .collect::<std::result::Result<Vec<_>, _>>()?;
            // buffer_from_host_literal copies ASYNCHRONOUSLY on a PJRT
            // worker thread while reading the source literal; block until
            // every copy has materialized before the literals can be freed
            // (a cheap one-time sync read per buffer — dropping an executor
            // right after load would otherwise race the copy: SIGSEGV in
            // AbstractTfrtCpuBuffer::CopyFromLiteral).
            for b in &bufs {
                let _ = b.on_device_shape()?;
                let _ = b.to_literal_sync()?;
            }
            Some(bufs)
        };
        Ok(ModelExecutor {
            client,
            art: art.clone(),
            encoder: encoder.to_string(),
            size_name: size.to_string(),
            n_mix: m0.n_mix,
            k_max: m0.k_max,
            bos_id: m0.bos_id as u32,
            manifests,
            exes: RefCell::new(BTreeMap::new()),
            weights,
            weight_bufs,
            calls: RefCell::new(0),
        })
    }

    /// Number of forward calls so far (perf accounting).
    pub fn call_count(&self) -> usize {
        *self.calls.borrow()
    }

    pub fn reset_call_count(&self) {
        *self.calls.borrow_mut() = 0;
    }

    /// Buckets available, ascending and deduplicated.
    pub fn buckets(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.manifests.keys().map(|(bucket, _)| *bucket).collect();
        b.dedup();
        b
    }

    pub fn max_bucket(&self) -> usize {
        *self.buckets().last().unwrap()
    }

    /// Largest batch capacity compiled for any bucket.
    pub fn max_batch(&self) -> usize {
        self.manifests.keys().map(|(_, n)| *n).max().unwrap()
    }

    /// Smallest compiled bucket with capacity ≥ `len` (incl. BOS).
    pub fn pick_bucket(&self, len: usize) -> Result<usize> {
        self.buckets()
            .into_iter()
            .find(|&b| b >= len)
            .with_context(|| format!("sequence length {len} exceeds max bucket"))
    }

    fn ensure_compiled(&self, bucket: usize, batch: usize) -> Result<()> {
        let key = (bucket, batch);
        if self.exes.borrow().contains_key(&key) {
            return Ok(());
        }
        let manifest = self
            .manifests
            .get(&key)
            .with_context(|| format!("no artifact for bucket={bucket} batch={batch}"))?;
        let path = self.art.hlo_path(&manifest.stem());
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading {}", path.display()))?;
        let exe = self
            .client
            .compile(&xla::XlaComputation::from_proto(&proto))
            .with_context(|| format!("compiling {}", manifest.stem()))?;
        self.exes.borrow_mut().insert(key, exe);
        Ok(())
    }

    /// Pre-compile every (bucket, batch) graph (avoids first-call latency
    /// spikes in benchmarks; the serving path normally compiles lazily).
    pub fn warmup(&self) -> Result<()> {
        let keys: Vec<_> = self.manifests.keys().cloned().collect();
        for (bucket, batch) in keys {
            self.ensure_compiled(bucket, batch)?;
        }
        Ok(())
    }

    /// Pre-compile only the graphs of one batch capacity (the evaluation
    /// harness uses B=1 exclusively; compiling the B=8 graphs too would
    /// waste minutes of XLA compile time).
    pub fn warmup_batch(&self, batch: usize) -> Result<()> {
        let keys: Vec<_> = self
            .manifests
            .keys()
            .filter(|(_, n)| *n == batch)
            .cloned()
            .collect();
        for (bucket, batch) in keys {
            self.ensure_compiled(bucket, batch)?;
        }
        Ok(())
    }

    /// Run the forward pass for 1..=max_batch sequences.
    pub fn forward(&self, seqs: &[SeqInput]) -> Result<ForwardOut> {
        assert!(!seqs.is_empty());
        let max_len = seqs.iter().map(SeqInput::len_with_bos).max().unwrap();
        let bucket = self.pick_bucket(max_len)?;
        let batch = self
            .manifests
            .keys()
            .filter(|(b, _)| *b == bucket)
            .map(|(_, n)| *n)
            .find(|&n| n >= seqs.len())
            .with_context(|| format!("no compiled batch size ≥ {}", seqs.len()))?;
        self.ensure_compiled(bucket, batch)?;

        let mut times = vec![0f32; batch * bucket];
        let mut types = vec![self.bos_id as i32; batch * bucket];
        let mut length = vec![1i32; batch];
        for (b, s) in seqs.iter().enumerate() {
            debug_assert_eq!(s.times.len(), s.types.len());
            let row = b * bucket;
            times[row] = s.t0 as f32;
            for (i, (&t, &k)) in s.times.iter().zip(&s.types).enumerate() {
                times[row + 1 + i] = t as f32;
                types[row + 1 + i] = k as i32;
            }
            length[b] = (s.times.len() + 1) as i32;
        }

        let exes = self.exes.borrow();
        let exe = &exes[&(bucket, batch)];
        *self.calls.borrow_mut() += 1;
        let result = if let Some(wbufs) = &self.weight_bufs {
            // fast path: weights resident on device, upload only inputs
            let t_buf =
                self.client.buffer_from_host_buffer::<f32>(&times, &[batch, bucket], None)?;
            let k_buf =
                self.client.buffer_from_host_buffer::<i32>(&types, &[batch, bucket], None)?;
            let l_buf = self.client.buffer_from_host_buffer::<i32>(&length, &[batch], None)?;
            let mut args: Vec<&xla::PjRtBuffer> = wbufs.iter().collect();
            args.push(&t_buf);
            args.push(&k_buf);
            args.push(&l_buf);
            exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0].to_literal_sync()?
        } else {
            let t_lit = xla::Literal::vec1(&times).reshape(&[batch as i64, bucket as i64])?;
            let k_lit = xla::Literal::vec1(&types).reshape(&[batch as i64, bucket as i64])?;
            let l_lit = xla::Literal::vec1(&length);
            let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
            args.push(&t_lit);
            args.push(&k_lit);
            args.push(&l_lit);
            exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?
        };
        let outs = result.to_tuple()?;
        if outs.len() != 4 {
            bail!("expected 4 outputs, got {}", outs.len());
        }
        Ok(ForwardOut {
            batch,
            bucket,
            n_mix: self.n_mix,
            k_max: self.k_max,
            log_w: outs[0].to_vec::<f32>()?,
            mu: outs[1].to_vec::<f32>()?,
            log_sigma: outs[2].to_vec::<f32>()?,
            logits: outs[3].to_vec::<f32>()?,
        })
    }
}

fn load_weights(path: &Path, manifest: &Manifest) -> Result<Vec<xla::Literal>> {
    let mut entries: Vec<(String, xla::Literal)> = xla::Literal::read_npz(path, &())
        .with_context(|| format!("reading weights {}", path.display()))?;
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    if entries.len() != manifest.params.len() {
        bail!(
            "weights {} has {} arrays, manifest expects {}",
            path.display(),
            entries.len(),
            manifest.params.len()
        );
    }
    let mut out = Vec::with_capacity(entries.len());
    for ((key, lit), (name, shape)) in entries.into_iter().zip(&manifest.params) {
        let got_name = key.split_once('|').map(|(_, n)| n).unwrap_or(&key);
        if got_name != name {
            bail!("weight order mismatch: npz '{got_name}' vs manifest '{name}'");
        }
        let dims: Vec<usize> = lit
            .array_shape()
            .map(|s| s.dims().iter().map(|&d| d as usize).collect())
            .unwrap_or_default();
        if &dims != shape {
            bail!("weight '{name}' shape {dims:?} != manifest {shape:?}");
        }
        out.push(lit);
    }
    Ok(out)
}
