//! The XLA/PJRT model executor (compiled only with `--features xla`): one
//! trained TPP model, loaded onto the PJRT CPU client, with length-bucketed
//! AOT executables and cached weights.
//!
//! Forward calls pick the smallest compiled bucket that fits the sequence
//! (quadratic attention cost ⇒ small-context calls are much cheaper), and
//! the B=8 graph when a batch of sequences is supplied (the coordinator's
//! batching path). Executables are compiled lazily on first use and cached.
//!
//! XLA wrapper objects hold raw pointers and are not `Send`; the
//! coordinator therefore owns each executor on a dedicated thread and talks
//! to it over channels (see `coordinator::batcher`). [`XlaBackend`] is the
//! `Send + Sync` registry handed to those threads — it carries only the
//! artifact directory and creates the client on the loading thread.
//!
//! In the offline workspace the `xla` dependency resolves to the vendored
//! API stub (`vendor/xla-stub`), which type-checks this module but errors
//! at runtime; see `docs/adr/001-backend-abstraction.md`.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};
use xla::FromRawBytes;

use super::backend::{
    Backend, CachedForward, Forward, ForwardOut, ModelBackend, SeqInput, SlotOut,
};
use super::manifest::{ArtifactDir, Manifest};
use crate::util::json::Json;

/// Open a PJRT CPU client.
pub fn cpu_client() -> Result<Rc<xla::PjRtClient>> {
    Ok(Rc::new(xla::PjRtClient::cpu()?))
}

/// Registry over an AOT artifact directory: resolves `(dataset, encoder,
/// size)` to a [`ModelExecutor`] created on the *calling* thread (PJRT
/// objects are not `Send`). The parsed `datasets.json` registry is cached
/// after the first metadata query.
#[derive(Debug, Clone)]
pub struct XlaBackend {
    art: ArtifactDir,
    registry: std::sync::OnceLock<Json>,
}

impl XlaBackend {
    /// Wrap an artifact directory.
    pub fn new(art: ArtifactDir) -> XlaBackend {
        XlaBackend { art, registry: std::sync::OnceLock::new() }
    }

    /// Discover the artifact directory from `$TPP_SD_ARTIFACTS`.
    pub fn discover() -> Result<XlaBackend> {
        Ok(XlaBackend::new(ArtifactDir::discover()?))
    }

    /// The underlying artifact directory.
    pub fn artifacts(&self) -> &ArtifactDir {
        &self.art
    }

    /// The parsed `datasets.json`, read from disk at most once.
    fn registry(&self) -> Result<&Json> {
        if let Some(j) = self.registry.get() {
            return Ok(j);
        }
        let parsed = self.art.datasets_json()?;
        Ok(self.registry.get_or_init(|| parsed))
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn datasets(&self) -> Vec<String> {
        self.registry()
            .ok()
            .and_then(|j| {
                j.get("datasets")
                    .and_then(Json::as_obj)
                    .map(|m| m.keys().cloned().collect())
            })
            .unwrap_or_default()
    }

    fn num_types(&self, dataset: &str) -> Result<usize> {
        self.registry()?
            .usize_at(&format!("datasets.{dataset}.num_types"))
            .with_context(|| format!("unknown dataset '{dataset}'"))
    }

    fn dataset_spec(&self, dataset: &str) -> Result<Json> {
        self.registry()?
            .path(&format!("datasets.{dataset}"))
            .cloned()
            .with_context(|| format!("unknown dataset '{dataset}'"))
    }

    fn load_model(
        &self,
        dataset: &str,
        encoder: &str,
        size: &str,
    ) -> Result<Box<dyn ModelBackend>> {
        let client = cpu_client()?;
        Ok(Box::new(ModelExecutor::load(client, &self.art, dataset, encoder, size)?))
    }
}

/// A trained model (weights) + its bucketed executables, lazily compiled.
pub struct ModelExecutor {
    client: Rc<xla::PjRtClient>,
    art: ArtifactDir,
    /// encoder name the weights were trained with
    pub encoder: String,
    /// model-size name (`target`, `draft`, ...)
    pub size_name: String,
    /// mixture components per output row
    pub n_mix: usize,
    /// padded event-type dimension
    pub k_max: usize,
    /// BOS token id of the type vocabulary
    pub bos_id: u32,
    manifests: BTreeMap<(usize, usize), Manifest>,
    exes: RefCell<BTreeMap<(usize, usize), xla::PjRtLoadedExecutable>>,
    weights: Vec<xla::Literal>,
    /// weights pre-uploaded to the device — forwards then use `execute_b`
    /// and only transfer the 3 small input tensors per call (§Perf: saves
    /// the per-call host→device copy of every parameter literal). Disabled
    /// via TPP_SD_LITERAL_ARGS=1 for the ablation bench.
    weight_bufs: Option<Vec<xla::PjRtBuffer>>,
    /// forward-call counter (perf accounting)
    calls: RefCell<usize>,
}

impl ModelExecutor {
    /// Load weights + manifests for `(dataset, encoder, size)`.
    pub fn load(
        client: Rc<xla::PjRtClient>,
        art: &ArtifactDir,
        dataset: &str,
        encoder: &str,
        size: &str,
    ) -> Result<ModelExecutor> {
        let mut manifests = BTreeMap::new();
        for m in art.manifests_for(encoder, size)? {
            manifests.insert((m.bucket, m.batch), m);
        }
        let m0 = manifests.values().next().unwrap().clone();
        let weights = load_weights(&art.weights_path(dataset, encoder, size), &m0)?;
        let weight_bufs = if std::env::var_os("TPP_SD_LITERAL_ARGS").is_some() {
            None
        } else {
            let bufs = weights
                .iter()
                .map(|w| client.buffer_from_host_literal(None, w))
                .collect::<std::result::Result<Vec<_>, _>>()?;
            // buffer_from_host_literal copies ASYNCHRONOUSLY on a PJRT
            // worker thread while reading the source literal; block until
            // every copy has materialized before the literals can be freed
            // (a cheap one-time sync read per buffer — dropping an executor
            // right after load would otherwise race the copy: SIGSEGV in
            // AbstractTfrtCpuBuffer::CopyFromLiteral).
            for b in &bufs {
                let _ = b.on_device_shape()?;
                let _ = b.to_literal_sync()?;
            }
            Some(bufs)
        };
        Ok(ModelExecutor {
            client,
            art: art.clone(),
            encoder: encoder.to_string(),
            size_name: size.to_string(),
            n_mix: m0.n_mix,
            k_max: m0.k_max,
            bos_id: m0.bos_id as u32,
            manifests,
            exes: RefCell::new(BTreeMap::new()),
            weights,
            weight_bufs,
            calls: RefCell::new(0),
        })
    }

    /// Number of forward calls so far (perf accounting).
    pub fn call_count(&self) -> usize {
        *self.calls.borrow()
    }

    /// Reset the forward-call counter.
    pub fn reset_call_count(&self) {
        *self.calls.borrow_mut() = 0;
    }

    /// Buckets available, ascending and deduplicated.
    pub fn buckets(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.manifests.keys().map(|(bucket, _)| *bucket).collect();
        b.dedup();
        b
    }

    /// Largest compiled bucket.
    pub fn max_bucket(&self) -> usize {
        *self.buckets().last().unwrap()
    }

    /// Largest batch capacity compiled for any bucket.
    pub fn max_batch(&self) -> usize {
        self.manifests.keys().map(|(_, n)| *n).max().unwrap()
    }

    /// Smallest compiled bucket with capacity ≥ `len` (incl. BOS).
    pub fn pick_bucket(&self, len: usize) -> Result<usize> {
        self.buckets()
            .into_iter()
            .find(|&b| b >= len)
            .with_context(|| format!("sequence length {len} exceeds max bucket"))
    }

    fn ensure_compiled(&self, bucket: usize, batch: usize) -> Result<()> {
        let key = (bucket, batch);
        if self.exes.borrow().contains_key(&key) {
            return Ok(());
        }
        let manifest = self
            .manifests
            .get(&key)
            .with_context(|| format!("no artifact for bucket={bucket} batch={batch}"))?;
        let path = self.art.hlo_path(&manifest.stem());
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading {}", path.display()))?;
        let exe = self
            .client
            .compile(&xla::XlaComputation::from_proto(&proto))
            .with_context(|| format!("compiling {}", manifest.stem()))?;
        self.exes.borrow_mut().insert(key, exe);
        Ok(())
    }

    /// Pre-compile every (bucket, batch) graph (avoids first-call latency
    /// spikes in benchmarks; the serving path normally compiles lazily).
    pub fn warmup(&self) -> Result<()> {
        let keys: Vec<_> = self.manifests.keys().cloned().collect();
        for (bucket, batch) in keys {
            self.ensure_compiled(bucket, batch)?;
        }
        Ok(())
    }

    /// Pre-compile only the graphs of one batch capacity (the evaluation
    /// harness uses B=1 exclusively; compiling the B=8 graphs too would
    /// waste minutes of XLA compile time).
    pub fn warmup_batch(&self, batch: usize) -> Result<()> {
        let keys: Vec<_> = self
            .manifests
            .keys()
            .filter(|(_, n)| *n == batch)
            .cloned()
            .collect();
        for (bucket, batch) in keys {
            self.ensure_compiled(bucket, batch)?;
        }
        Ok(())
    }

    /// Run the forward pass for 1..=max_batch sequences.
    pub fn forward(&self, seqs: &[SeqInput]) -> Result<ForwardOut> {
        assert!(!seqs.is_empty());
        let max_len = seqs.iter().map(SeqInput::len_with_bos).max().unwrap();
        let bucket = self.pick_bucket(max_len)?;
        let batch = self
            .manifests
            .keys()
            .filter(|(b, _)| *b == bucket)
            .map(|(_, n)| *n)
            .find(|&n| n >= seqs.len())
            .with_context(|| format!("no compiled batch size ≥ {}", seqs.len()))?;
        self.ensure_compiled(bucket, batch)?;

        let mut times = vec![0f32; batch * bucket];
        let mut types = vec![self.bos_id as i32; batch * bucket];
        let mut length = vec![1i32; batch];
        for (b, s) in seqs.iter().enumerate() {
            debug_assert_eq!(s.times.len(), s.types.len());
            let row = b * bucket;
            times[row] = s.t0 as f32;
            for (i, (&t, &k)) in s.times.iter().zip(&s.types).enumerate() {
                times[row + 1 + i] = t as f32;
                types[row + 1 + i] = k as i32;
            }
            length[b] = (s.times.len() + 1) as i32;
        }

        let exes = self.exes.borrow();
        let exe = &exes[&(bucket, batch)];
        *self.calls.borrow_mut() += 1;
        let result = if let Some(wbufs) = &self.weight_bufs {
            // fast path: weights resident on device, upload only inputs
            let t_buf =
                self.client.buffer_from_host_buffer::<f32>(&times, &[batch, bucket], None)?;
            let k_buf =
                self.client.buffer_from_host_buffer::<i32>(&types, &[batch, bucket], None)?;
            let l_buf = self.client.buffer_from_host_buffer::<i32>(&length, &[batch], None)?;
            let mut args: Vec<&xla::PjRtBuffer> = wbufs.iter().collect();
            args.push(&t_buf);
            args.push(&k_buf);
            args.push(&l_buf);
            exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0].to_literal_sync()?
        } else {
            let t_lit = xla::Literal::vec1(&times).reshape(&[batch as i64, bucket as i64])?;
            let k_lit = xla::Literal::vec1(&types).reshape(&[batch as i64, bucket as i64])?;
            let l_lit = xla::Literal::vec1(&length);
            let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
            args.push(&t_lit);
            args.push(&k_lit);
            args.push(&l_lit);
            exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?
        };
        let outs = result.to_tuple()?;
        if outs.len() != 4 {
            bail!("expected 4 outputs, got {}", outs.len());
        }
        Ok(ForwardOut::from_raw(
            batch,
            bucket,
            self.n_mix,
            self.k_max,
            outs[0].to_vec::<f32>()?,
            outs[1].to_vec::<f32>()?,
            outs[2].to_vec::<f32>()?,
            outs[3].to_vec::<f32>()?,
        ))
    }
}

impl Forward for ModelExecutor {
    fn forward1(&self, seq: SeqInput) -> Result<SlotOut> {
        let out = ModelExecutor::forward(self, std::slice::from_ref(&seq))?;
        Ok(SlotOut::new(std::sync::Arc::new(out), 0))
    }

    fn max_bucket(&self) -> usize {
        ModelExecutor::max_bucket(self)
    }
}

impl ModelBackend for ModelExecutor {
    fn forward(&self, seqs: &[SeqInput]) -> Result<ForwardOut> {
        ModelExecutor::forward(self, seqs)
    }

    fn max_bucket(&self) -> usize {
        ModelExecutor::max_bucket(self)
    }

    fn max_batch(&self) -> usize {
        ModelExecutor::max_batch(self)
    }

    fn pick_bucket(&self, len: usize) -> Result<usize> {
        ModelExecutor::pick_bucket(self, len)
    }

    fn warmup(&self) -> Result<()> {
        ModelExecutor::warmup(self)
    }

    fn warmup_batch(&self, batch: usize) -> Result<()> {
        ModelExecutor::warmup_batch(self, batch)
    }

    fn call_count(&self) -> usize {
        ModelExecutor::call_count(self)
    }

    /// Explicitly uncached: the AOT PJRT graphs are fixed-shape and keep
    /// no state between calls, so there is no incremental-inference seam
    /// to expose — samplers detect the `None` and fall back to full
    /// [`SeqInput`] forwards (DESIGN.md §12). A KV-cache variant would
    /// need per-bucket decode graphs compiled with explicit cache
    /// input/output buffers (future work, ADR-003).
    fn cached(&self) -> Option<&dyn CachedForward> {
        None
    }

    fn descriptor(&self) -> String {
        format!("xla:{}/{}", self.encoder, self.size_name)
    }
}

fn load_weights(path: &Path, manifest: &Manifest) -> Result<Vec<xla::Literal>> {
    let mut entries: Vec<(String, xla::Literal)> = xla::Literal::read_npz(path, &())
        .with_context(|| format!("reading weights {}", path.display()))?;
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    if entries.len() != manifest.params.len() {
        bail!(
            "weights {} has {} arrays, manifest expects {}",
            path.display(),
            entries.len(),
            manifest.params.len()
        );
    }
    let mut out = Vec::with_capacity(entries.len());
    for ((key, lit), (name, shape)) in entries.into_iter().zip(&manifest.params) {
        let got_name = key.split_once('|').map(|(_, n)| n).unwrap_or(&key);
        if got_name != name {
            bail!("weight order mismatch: npz '{got_name}' vs manifest '{name}'");
        }
        let dims: Vec<usize> = lit
            .array_shape()
            .map(|s| s.dims().iter().map(|&d| d as usize).collect())
            .unwrap_or_default();
        if &dims != shape {
            bail!("weight '{name}' shape {dims:?} != manifest {shape:?}");
        }
        out.push(lit);
    }
    Ok(out)
}
