//! Pure-Rust CPU inference backend (DESIGN.md §5): the default build's hot
//! path, requiring **no trained artifacts and no PJRT**.
//!
//! [`NativeBackend`] serves the same dataset registry the Python pipeline
//! exports to `artifacts/datasets.json` (`python/compile/config.py` is the
//! source of truth; the tables here mirror it), and loads
//! [`NativeModel`]s whose mixture-head outputs are *analytic* functions of
//! the visible history — a Hawkes-style exponentially-decaying excitation
//! feature drives the log-normal mixture and the type head, so:
//!
//! * every density is exactly known (no weights, no nondeterminism);
//! * outputs are **prefix-causal**: row `r` depends only on the BOS row and
//!   the first `r` events, which is precisely the property TPP-SD's
//!   parallel verification relies on (draft-time and verify-time parameters
//!   for the same prefix are bit-identical);
//! * the draft/target divergence is a dial: the `draft*` sizes shift the
//!   mixture means and flatten the type head, so acceptance rates are
//!   realistic rather than degenerate.
//!
//! The model honours the same length-bucketing (64/128/256/512) and B∈{1,8}
//! batched-call contract as the AOT artifacts, so the coordinator's batcher
//! and every sampler run unchanged on top of it.

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{bail, Context as _, Result};

use super::backend::{Backend, ForwardOut, ModelBackend, SeqInput};
use crate::util::json::{obj, Json};

/// Sequence-length buckets (incl. BOS), mirroring `config.BUCKETS`.
const BUCKETS: [usize; 4] = [64, 128, 256, 512];
/// Batch capacities, mirroring `config.BATCH_SIZES` (B=1 latency path,
/// B=8 the coordinator's batched executor).
const BATCHES: [usize; 2] = [1, 8];
/// Padded event-type dimension, mirroring `config.K_MAX`.
const K_MAX: usize = 24;
/// Mixture components of the native head.
const N_MIX: usize = 2;

/// Transformer encoders the registry knows (`config.ENCODERS`).
const ENCODERS: [&str; 3] = ["thp", "sahp", "attnhp"];

/// One batch slot's mutable stripes of the flat forward-output buffers:
/// `(slot index, log_w, mu, log_sigma, logits)`.
type SlotStripe<'a> =
    (usize, &'a mut [f32], &'a mut [f32], &'a mut [f32], &'a mut [f32]);

/// Below this many total rows (slots × bucket) a batched fill runs on the
/// calling thread: thread-spawn overhead (~tens of µs) would exceed the
/// transcendental work being parallelized.
const MIN_PARALLEL_ROWS: usize = 256;

/// Worker count for batched fills, queried once — `available_parallelism`
/// is a syscall and the fleet engine issues thousands of forwards per run.
fn fill_workers() -> usize {
    static WORKERS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *WORKERS.get_or_init(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Model-size ladder: `(name, mean shift vs target, type-head amplitude)`.
/// `target` is the reference; the `draft*` sizes are increasingly close to
/// it (mirroring the paper's draft-capacity ablation, Tables 3/4).
const SIZES: [(&str, f64, f64); 4] = [
    ("target", 0.00, 1.5),
    ("draft", 0.25, 0.9),
    ("draft2", 0.15, 1.1),
    ("draft3", 0.08, 1.3),
];

/// One dataset registry row (kind + native-model dynamics).
struct DatasetDef {
    name: &'static str,
    kind: &'static str,
    num_types: usize,
    /// excitation gain of the native model's history feature
    excite: f64,
    /// decay rate of the history feature
    decay: f64,
}

/// The registry, mirroring `python/compile/config.DATASETS`: the three
/// paper synthetics plus the four simulated real-data stand-ins.
static DATASETS: [DatasetDef; 7] = [
    DatasetDef { name: "poisson", kind: "poisson", num_types: 1, excite: 0.15, decay: 1.0 },
    DatasetDef { name: "hawkes", kind: "hawkes", num_types: 1, excite: 0.8, decay: 2.0 },
    DatasetDef { name: "multihawkes", kind: "multihawkes", num_types: 2, excite: 0.5, decay: 2.0 },
    DatasetDef { name: "taobao_sim", kind: "kd_hawkes", num_types: 17, excite: 0.5, decay: 3.0 },
    DatasetDef { name: "amazon_sim", kind: "kd_hawkes", num_types: 16, excite: 0.5, decay: 3.0 },
    DatasetDef { name: "taxi_sim", kind: "kd_hawkes", num_types: 10, excite: 0.5, decay: 3.0 },
    DatasetDef {
        name: "stackoverflow_sim",
        kind: "kd_hawkes",
        num_types: 22,
        excite: 0.5,
        decay: 3.0,
    },
];

fn dataset_def(name: &str) -> Result<&'static DatasetDef> {
    DATASETS
        .iter()
        .find(|d| d.name == name)
        .with_context(|| format!("unknown dataset '{name}' (native registry)"))
}

/// Pure-CPU model registry; see the module docs.
#[derive(Debug, Default)]
pub struct NativeBackend {}

impl NativeBackend {
    /// Create the default registry.
    pub fn new() -> NativeBackend {
        NativeBackend {}
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn datasets(&self) -> Vec<String> {
        DATASETS.iter().map(|d| d.name.to_string()).collect()
    }

    fn num_types(&self, dataset: &str) -> Result<usize> {
        Ok(dataset_def(dataset)?.num_types)
    }

    fn dataset_spec(&self, dataset: &str) -> Result<Json> {
        let def = dataset_def(dataset)?;
        let params = match def.kind {
            "poisson" => obj(vec![
                ("A", Json::Num(5.0)),
                ("b", Json::Num(1.0)),
                ("omega", Json::Num(1.0 / 50.0)),
            ]),
            "hawkes" => obj(vec![
                ("mu", Json::Num(2.5)),
                ("alpha", Json::Num(1.0)),
                ("beta", Json::Num(2.0)),
            ]),
            "multihawkes" => obj(vec![
                ("mu", Json::Arr(vec![Json::Num(0.4), Json::Num(0.4)])),
                (
                    "alpha",
                    Json::Arr(vec![
                        Json::Arr(vec![Json::Num(1.0), Json::Num(0.5)]),
                        Json::Arr(vec![Json::Num(0.1), Json::Num(1.0)]),
                    ]),
                ),
                ("beta", Json::Num(2.0)),
            ]),
            // K-dim Hawkes stand-ins: same construction as config._kd_hawkes
            // (power-law base rates, self + ring excitation, branching 0.4).
            "kd_hawkes" => {
                let k = def.num_types;
                let total_rate = match def.name {
                    "taobao_sim" => 2.5,
                    "stackoverflow_sim" => 1.5,
                    _ => 2.0,
                };
                let masses: Vec<f64> = (0..k).map(|i| (i as f64 + 1.0).powf(-0.8)).collect();
                let mass_sum: f64 = masses.iter().sum();
                let mu: Vec<Json> = masses
                    .iter()
                    .map(|m| Json::Num(0.6 * total_rate * m / mass_sum))
                    .collect();
                let beta = 3.0;
                let mut alpha = vec![vec![0.0; k]; k];
                for i in 0..k {
                    alpha[i][i] = 0.3 * beta;
                    alpha[(i + 1) % k][i] = 0.1 * beta;
                }
                let alpha_json = Json::Arr(
                    alpha
                        .into_iter()
                        .map(|row| Json::Arr(row.into_iter().map(Json::Num).collect()))
                        .collect(),
                );
                obj(vec![
                    ("mu", Json::Arr(mu)),
                    ("alpha", alpha_json),
                    ("beta", Json::Num(beta)),
                ])
            }
            other => bail!("unknown dataset kind '{other}'"),
        };
        // The stand-ins are multihawkes processes for ground-truth purposes.
        let kind = if def.kind == "kd_hawkes" { "multihawkes" } else { def.kind };
        Ok(obj(vec![
            ("name", Json::Str(def.name.to_string())),
            ("kind", Json::Str(kind.to_string())),
            ("num_types", Json::Num(def.num_types as f64)),
            ("t_end", Json::Num(100.0)),
            ("params", params),
        ]))
    }

    fn load_model(
        &self,
        dataset: &str,
        encoder: &str,
        size: &str,
    ) -> Result<Box<dyn ModelBackend>> {
        let def = dataset_def(dataset)?;
        if !ENCODERS.contains(&encoder) {
            bail!("unknown encoder '{encoder}' (thp|sahp|attnhp)");
        }
        let (_, bias, type_amp) = SIZES
            .iter()
            .copied()
            .find(|(n, _, _)| *n == size)
            .with_context(|| format!("unknown model size '{size}' (target|draft|draft2|draft3)"))?;
        // Encoders are distinct deterministic models; a small shared offset
        // keeps target/draft of the same encoder mutually consistent.
        let enc_shift = match encoder {
            "thp" => 0.0,
            "sahp" => 0.03,
            _ => -0.03,
        };
        Ok(Box::new(NativeModel {
            dataset: dataset.to_string(),
            encoder: encoder.to_string(),
            size: size.to_string(),
            num_types: def.num_types,
            bias,
            type_amp,
            enc_shift,
            excite: def.excite,
            decay: def.decay,
            calls: AtomicUsize::new(0),
        }))
    }
}

/// One loaded native model: analytic mixture-head parameters over the
/// visible history. See the module docs for the construction.
#[derive(Debug)]
pub struct NativeModel {
    dataset: String,
    encoder: String,
    size: String,
    num_types: usize,
    /// mean shift vs the target model (0 for `target`)
    bias: f64,
    /// type-head peak amplitude (smaller ⇒ flatter draft head)
    type_amp: f64,
    /// per-encoder parameter offset (shared by target and draft)
    enc_shift: f64,
    /// excitation gain of the history feature
    excite: f64,
    /// decay rate of the history feature
    decay: f64,
    calls: AtomicUsize,
}

impl NativeModel {
    /// Write the parameters of one output row.
    ///
    /// `s` is the excitation feature over the row's visible prefix,
    /// anchored at the prefix's last time `t_anchor`; `last_k` is the most
    /// recent visible event type (`K_MAX` for the BOS row).
    #[allow(clippy::too_many_arguments)]
    fn write_row(
        &self,
        s: f64,
        t_anchor: f64,
        last_k: usize,
        log_w: &mut [f32],
        mu: &mut [f32],
        log_sigma: &mut [f32],
        logits: &mut [f32],
    ) {
        // Saturating excitation feature: bounded, so intensities cannot run
        // away however long the history grows.
        let sat = s / (1.0 + 0.15 * s);
        let load = (1.0 + self.excite * sat).ln();
        // Slow inhomogeneity in absolute time (the Poisson flavour).
        let season = 0.08 * (0.05 * t_anchor).sin();
        let base = self.bias + self.enc_shift + season;

        let w0 = 0.3 + 0.4 * (0.5 + 0.5 * (0.37 * sat + 0.21 * last_k as f64).sin());
        log_w[0] = (w0.ln()) as f32;
        log_w[1] = ((1.0 - w0).ln()) as f32;
        mu[0] = (-1.2 + 0.1 * (0.53 * sat).sin() - 0.45 * load + base) as f32;
        mu[1] = (0.3 + 0.05 * (0.29 * sat).cos() - 0.30 * load + base) as f32;
        log_sigma[0] = -0.7;
        log_sigma[1] = -0.3;

        let pref = if last_k >= self.num_types { 0 } else { (last_k + 1) % self.num_types };
        for (k, l) in logits.iter_mut().enumerate() {
            *l = if k == pref {
                self.type_amp as f32
            } else if k < self.num_types {
                0.3
            } else {
                0.0
            };
        }
    }

    /// Fill one batch slot's rows for `seq` (padding rows past the sequence
    /// repeat the final state, so they stay valid distributions).
    fn fill_slot(
        &self,
        seq: &SeqInput,
        bucket: usize,
        log_w: &mut [f32],
        mu: &mut [f32],
        log_sigma: &mut [f32],
        logits: &mut [f32],
    ) {
        let n = seq.times.len();
        // Hawkes-style recursion: s_r = Σ_{i<r} exp(-decay (t_anchor - t_i)),
        // updated in O(1) as each event becomes visible.
        let mut s = 0.0f64;
        let mut t_anchor = seq.t0;
        let mut last_k = K_MAX;
        let real_rows = bucket.min(n + 1);
        for row in 0..real_rows {
            if row >= 1 {
                let t = seq.times[row - 1];
                let dt = (t - t_anchor).max(0.0);
                s = s * (-self.decay * dt).exp() + 1.0;
                t_anchor = t;
                last_k = seq.types[row - 1] as usize;
            }
            let m0 = row * N_MIX;
            let l0 = row * K_MAX;
            self.write_row(
                s,
                t_anchor,
                last_k,
                &mut log_w[m0..m0 + N_MIX],
                &mut mu[m0..m0 + N_MIX],
                &mut log_sigma[m0..m0 + N_MIX],
                &mut logits[l0..l0 + K_MAX],
            );
        }
        // Padding rows are the final row frozen in place: copy, don't
        // recompute the transcendental math per row.
        let src_m = (real_rows - 1) * N_MIX;
        let src_l = (real_rows - 1) * K_MAX;
        for row in real_rows..bucket {
            let m0 = row * N_MIX;
            let l0 = row * K_MAX;
            log_w.copy_within(src_m..src_m + N_MIX, m0);
            mu.copy_within(src_m..src_m + N_MIX, m0);
            log_sigma.copy_within(src_m..src_m + N_MIX, m0);
            logits.copy_within(src_l..src_l + K_MAX, l0);
        }
    }
}

impl ModelBackend for NativeModel {
    fn forward(&self, seqs: &[SeqInput]) -> Result<ForwardOut> {
        assert!(!seqs.is_empty());
        let max_len = seqs.iter().map(SeqInput::len_with_bos).max().unwrap();
        let bucket = self.pick_bucket(max_len)?;
        let batch = BATCHES
            .iter()
            .copied()
            .find(|&b| b >= seqs.len())
            .with_context(|| format!("no batch capacity ≥ {} (max {})", seqs.len(), 8))?;
        self.calls.fetch_add(1, Ordering::Relaxed);

        let mut log_w = vec![0f32; batch * bucket * N_MIX];
        let mut mu = vec![0f32; batch * bucket * N_MIX];
        let mut log_sigma = vec![0f32; batch * bucket * N_MIX];
        let mut logits = vec![0f32; batch * bucket * K_MAX];
        let empty = SeqInput::default();
        // Real slots, plus ONE padding slot (the empty sequence); the
        // remaining padding slots are copies of it (valid, never read).
        let filled = batch.min(seqs.len() + 1);
        {
            // Per-slot stripes of the flat buffers; disjoint, so batched
            // fills fan out across cores (single-sequence calls stay on the
            // calling thread — the sequential samplers' latency path pays
            // no spawn cost). Every stripe runs the identical per-row math,
            // so batched rows stay bit-identical to single-sequence rows.
            let stripes: Vec<SlotStripe> = log_w
                .chunks_mut(bucket * N_MIX)
                .zip(mu.chunks_mut(bucket * N_MIX))
                .zip(log_sigma.chunks_mut(bucket * N_MIX))
                .zip(logits.chunks_mut(bucket * K_MAX))
                .take(filled)
                .enumerate()
                .map(|(b, (((lw, m), ls), lg))| (b, lw, m, ls, lg))
                .collect();
            let workers = if filled * bucket < MIN_PARALLEL_ROWS {
                1
            } else {
                fill_workers().min(filled)
            };
            if workers <= 1 {
                for (b, lw, m, ls, lg) in stripes {
                    self.fill_slot(seqs.get(b).unwrap_or(&empty), bucket, lw, m, ls, lg);
                }
            } else {
                let per = filled.div_ceil(workers);
                let mut groups: Vec<Vec<SlotStripe>> = Vec::with_capacity(workers);
                let mut it = stripes.into_iter();
                loop {
                    let g: Vec<SlotStripe> = it.by_ref().take(per).collect();
                    if g.is_empty() {
                        break;
                    }
                    groups.push(g);
                }
                std::thread::scope(|sc| {
                    let mut rest = groups.split_off(1);
                    for group in rest.drain(..) {
                        let empty = &empty;
                        sc.spawn(move || {
                            for (b, lw, m, ls, lg) in group {
                                self.fill_slot(seqs.get(b).unwrap_or(empty), bucket, lw, m, ls, lg);
                            }
                        });
                    }
                    // the calling thread works too (group 0)
                    for (b, lw, m, ls, lg) in groups.remove(0) {
                        self.fill_slot(seqs.get(b).unwrap_or(&empty), bucket, lw, m, ls, lg);
                    }
                });
            }
        }
        let pad_m = seqs.len() * bucket * N_MIX..(seqs.len() + 1) * bucket * N_MIX;
        let pad_l = seqs.len() * bucket * K_MAX..(seqs.len() + 1) * bucket * K_MAX;
        for b in filled..batch {
            log_w.copy_within(pad_m.clone(), b * bucket * N_MIX);
            mu.copy_within(pad_m.clone(), b * bucket * N_MIX);
            log_sigma.copy_within(pad_m.clone(), b * bucket * N_MIX);
            logits.copy_within(pad_l.clone(), b * bucket * K_MAX);
        }
        Ok(ForwardOut::from_raw(batch, bucket, N_MIX, K_MAX, log_w, mu, log_sigma, logits))
    }

    fn max_bucket(&self) -> usize {
        *BUCKETS.last().unwrap()
    }

    fn max_batch(&self) -> usize {
        *BATCHES.last().unwrap()
    }

    fn pick_bucket(&self, len: usize) -> Result<usize> {
        BUCKETS
            .iter()
            .copied()
            .find(|&b| b >= len)
            .with_context(|| format!("sequence length {len} exceeds max bucket"))
    }

    fn call_count(&self) -> usize {
        self.calls.load(Ordering::Relaxed)
    }

    fn descriptor(&self) -> String {
        format!("native:{}/{}/{}", self.dataset, self.encoder, self.size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(dataset: &str, size: &str) -> Box<dyn ModelBackend> {
        NativeBackend::new().load_model(dataset, "thp", size).unwrap()
    }

    fn seq(times: &[f64], types: &[u32]) -> SeqInput {
        SeqInput { t0: 0.0, times: times.to_vec(), types: types.to_vec() }
    }

    #[test]
    fn registry_rejects_unknowns() {
        let b = NativeBackend::new();
        assert!(b.load_model("hawkes", "thp", "target").is_ok());
        assert!(b.load_model("nope", "thp", "target").is_err());
        assert!(b.load_model("hawkes", "rnn", "target").is_err());
        assert!(b.load_model("hawkes", "thp", "huge").is_err());
        assert!(b.num_types("nope").is_err());
        assert_eq!(b.num_types("taxi_sim").unwrap(), 10);
        assert_eq!(b.datasets().len(), 7);
    }

    #[test]
    fn dataset_specs_parse_as_ground_truth() {
        let b = NativeBackend::new();
        for ds in b.datasets() {
            let spec = b.dataset_spec(&ds).unwrap();
            let gt = crate::processes::from_dataset_json(&spec)
                .unwrap_or_else(|e| panic!("{ds}: {e:#}"));
            assert_eq!(gt.num_types(), b.num_types(&ds).unwrap(), "{ds}");
        }
    }

    #[test]
    fn bucket_and_batch_selection() {
        let m = model("hawkes", "target");
        assert_eq!(m.pick_bucket(5).unwrap(), 64);
        assert_eq!(m.pick_bucket(64).unwrap(), 64);
        assert_eq!(m.pick_bucket(65).unwrap(), 128);
        assert!(m.pick_bucket(513).is_err());
        assert_eq!(m.max_bucket(), 512);
        assert_eq!(m.max_batch(), 8);
        let s = seq(&[0.5, 1.0], &[0, 0]);
        assert_eq!(m.forward(&[s.clone()]).unwrap().batch, 1);
        assert_eq!(m.forward(&[s.clone(), s.clone(), s]).unwrap().batch, 8);
    }

    #[test]
    fn rows_are_valid_distributions() {
        let m = model("multihawkes", "draft");
        let out = m.forward(&[seq(&[0.5, 1.0, 2.5], &[0, 1, 0])]).unwrap();
        for row in 0..out.bucket {
            let mix = out.mixture(0, row);
            let w_sum: f64 = mix.log_w.iter().map(|w| w.exp()).sum();
            assert!((w_sum - 1.0).abs() < 1e-6, "row {row}: Σw={w_sum}");
            assert!(mix.logpdf(1.0).is_finite());
            let td = out.type_dist(0, row, 2);
            assert!((td.probs.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn outputs_are_prefix_causal() {
        // Row r of a longer sequence equals row r of its length-r prefix:
        // the invariant TPP-SD's parallel verification relies on.
        let m = model("taxi_sim", "target");
        let full = seq(&[0.4, 0.9, 1.7, 2.0, 3.3], &[1, 4, 2, 0, 3]);
        let out_full = m.forward(&[full.clone()]).unwrap();
        for r in 0..=full.times.len() {
            let prefix = seq(&full.times[..r], &full.types[..r]);
            let out_pre = m.forward(&[prefix]).unwrap();
            let a = out_full.mixture(0, r);
            let b = out_pre.mixture(0, r);
            assert_eq!(a, b, "row {r} diverges from its prefix");
            let ta = out_full.type_dist(0, r, 10);
            let tb = out_pre.type_dist(0, r, 10);
            assert_eq!(ta.probs, tb.probs, "type row {r}");
        }
    }

    #[test]
    fn batched_rows_match_single_rows_exactly() {
        let m = model("hawkes", "draft");
        let seqs = vec![
            seq(&[0.2], &[0]),
            seq(&[0.3, 0.8, 1.1], &[0, 0, 0]),
            seq(&[2.0, 2.2], &[0, 0]),
        ];
        let batch = m.forward(&seqs).unwrap();
        for (b, s) in seqs.iter().enumerate() {
            let single = m.forward(std::slice::from_ref(s)).unwrap();
            let row = s.times.len();
            assert_eq!(batch.mixture(b, row), single.mixture(0, row), "slot {b}");
        }
    }

    #[test]
    fn draft_diverges_from_target() {
        let t = model("hawkes", "target");
        let d = model("hawkes", "draft");
        let s = seq(&[0.5, 1.0], &[0, 0]);
        let mt = t.forward(std::slice::from_ref(&s)).unwrap().mixture(0, 2);
        let md = d.forward(std::slice::from_ref(&s)).unwrap().mixture(0, 2);
        assert!((mt.mu[0] - md.mu[0]).abs() > 0.05, "draft must diverge");
        assert_eq!(t.call_count(), 1);
    }
}
