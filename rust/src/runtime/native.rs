//! Pure-Rust CPU inference backend (DESIGN.md §5): the default build's hot
//! path, requiring **no trained artifacts and no PJRT**.
//!
//! [`NativeBackend`] serves the same dataset registry the Python pipeline
//! exports to `artifacts/datasets.json` (`python/compile/config.py` is the
//! source of truth; the tables here mirror it), and loads
//! [`NativeModel`]s whose mixture-head outputs are *analytic* functions of
//! the visible history — a Hawkes-style exponentially-decaying excitation
//! feature drives the log-normal mixture and the type head, so:
//!
//! * every density is exactly known (no weights, no nondeterminism);
//! * outputs are **prefix-causal**: row `r` depends only on the BOS row and
//!   the first `r` events, which is precisely the property TPP-SD's
//!   parallel verification relies on (draft-time and verify-time parameters
//!   for the same prefix are bit-identical);
//! * the draft/target divergence is a dial: the `draft*` sizes shift the
//!   mixture means and flatten the type head, so acceptance rates are
//!   realistic rather than degenerate.
//!
//! The model honours the same length-bucketing (64/128/256/512) and B∈{1,8}
//! batched-call contract as the AOT artifacts, so the coordinator's batcher
//! and every sampler run unchanged on top of it.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{bail, ensure, Context as _, Result};

use super::backend::{
    Backend, CachedForward, ForwardOut, ModelBackend, SeqDelta, SeqInput, SlotOut, StreamId,
};
use super::pool;
use crate::util::json::{obj, Json};

/// Sequence-length buckets (incl. BOS), mirroring `config.BUCKETS`.
const BUCKETS: [usize; 4] = [64, 128, 256, 512];
/// Batch capacities, mirroring `config.BATCH_SIZES` (B=1 latency path,
/// B=8 the coordinator's batched executor).
const BATCHES: [usize; 2] = [1, 8];
/// Padded event-type dimension, mirroring `config.K_MAX`.
const K_MAX: usize = 24;
/// Mixture components of the native head.
const N_MIX: usize = 2;

/// Transformer encoders the registry knows (`config.ENCODERS`).
const ENCODERS: [&str; 3] = ["thp", "sahp", "attnhp"];

/// One batch slot's mutable stripes of the flat forward-output buffers:
/// `(slot index, log_w, mu, log_sigma, logits)`.
type SlotStripe<'a> =
    (usize, &'a mut [f32], &'a mut [f32], &'a mut [f32], &'a mut [f32]);

/// Fixed lane width of the chunked [`NativeModel::fill_slot`] passes: the
/// decay factors of up to `LANES` consecutive rows are computed in one
/// slice pass (each depends only on the input times), then the dependent
/// excitation fold consumes them. Same float ops in the same order as the
/// row-at-a-time loop — output-identical, but the independent pass is
/// autovectorizable.
const LANES: usize = 8;

/// Model-size ladder: `(name, mean shift vs target, type-head amplitude)`.
/// `target` is the reference; the `draft*` sizes are increasingly close to
/// it (mirroring the paper's draft-capacity ablation, Tables 3/4).
const SIZES: [(&str, f64, f64); 4] = [
    ("target", 0.00, 1.5),
    ("draft", 0.25, 0.9),
    ("draft2", 0.15, 1.1),
    ("draft3", 0.08, 1.3),
];

/// One dataset registry row (kind + native-model dynamics).
struct DatasetDef {
    name: &'static str,
    kind: &'static str,
    num_types: usize,
    /// excitation gain of the native model's history feature
    excite: f64,
    /// decay rate of the history feature
    decay: f64,
}

/// The registry, mirroring `python/compile/config.DATASETS`: the three
/// paper synthetics plus the four simulated real-data stand-ins.
static DATASETS: [DatasetDef; 7] = [
    DatasetDef { name: "poisson", kind: "poisson", num_types: 1, excite: 0.15, decay: 1.0 },
    DatasetDef { name: "hawkes", kind: "hawkes", num_types: 1, excite: 0.8, decay: 2.0 },
    DatasetDef { name: "multihawkes", kind: "multihawkes", num_types: 2, excite: 0.5, decay: 2.0 },
    DatasetDef { name: "taobao_sim", kind: "kd_hawkes", num_types: 17, excite: 0.5, decay: 3.0 },
    DatasetDef { name: "amazon_sim", kind: "kd_hawkes", num_types: 16, excite: 0.5, decay: 3.0 },
    DatasetDef { name: "taxi_sim", kind: "kd_hawkes", num_types: 10, excite: 0.5, decay: 3.0 },
    DatasetDef {
        name: "stackoverflow_sim",
        kind: "kd_hawkes",
        num_types: 22,
        excite: 0.5,
        decay: 3.0,
    },
];

fn dataset_def(name: &str) -> Result<&'static DatasetDef> {
    DATASETS
        .iter()
        .find(|d| d.name == name)
        .with_context(|| format!("unknown dataset '{name}' (native registry)"))
}

/// Pure-CPU model registry; see the module docs.
#[derive(Debug, Default)]
pub struct NativeBackend {}

impl NativeBackend {
    /// Create the default registry.
    pub fn new() -> NativeBackend {
        NativeBackend {}
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn datasets(&self) -> Vec<String> {
        DATASETS.iter().map(|d| d.name.to_string()).collect()
    }

    fn num_types(&self, dataset: &str) -> Result<usize> {
        Ok(dataset_def(dataset)?.num_types)
    }

    fn dataset_spec(&self, dataset: &str) -> Result<Json> {
        let def = dataset_def(dataset)?;
        let params = match def.kind {
            "poisson" => obj(vec![
                ("A", Json::Num(5.0)),
                ("b", Json::Num(1.0)),
                ("omega", Json::Num(1.0 / 50.0)),
            ]),
            "hawkes" => obj(vec![
                ("mu", Json::Num(2.5)),
                ("alpha", Json::Num(1.0)),
                ("beta", Json::Num(2.0)),
            ]),
            "multihawkes" => obj(vec![
                ("mu", Json::Arr(vec![Json::Num(0.4), Json::Num(0.4)])),
                (
                    "alpha",
                    Json::Arr(vec![
                        Json::Arr(vec![Json::Num(1.0), Json::Num(0.5)]),
                        Json::Arr(vec![Json::Num(0.1), Json::Num(1.0)]),
                    ]),
                ),
                ("beta", Json::Num(2.0)),
            ]),
            // K-dim Hawkes stand-ins: same construction as config._kd_hawkes
            // (power-law base rates, self + ring excitation, branching 0.4).
            "kd_hawkes" => {
                let k = def.num_types;
                let total_rate = match def.name {
                    "taobao_sim" => 2.5,
                    "stackoverflow_sim" => 1.5,
                    _ => 2.0,
                };
                let masses: Vec<f64> = (0..k).map(|i| (i as f64 + 1.0).powf(-0.8)).collect();
                let mass_sum: f64 = masses.iter().sum();
                let mu: Vec<Json> = masses
                    .iter()
                    .map(|m| Json::Num(0.6 * total_rate * m / mass_sum))
                    .collect();
                let beta = 3.0;
                let mut alpha = vec![vec![0.0; k]; k];
                for i in 0..k {
                    alpha[i][i] = 0.3 * beta;
                    alpha[(i + 1) % k][i] = 0.1 * beta;
                }
                let alpha_json = Json::Arr(
                    alpha
                        .into_iter()
                        .map(|row| Json::Arr(row.into_iter().map(Json::Num).collect()))
                        .collect(),
                );
                obj(vec![
                    ("mu", Json::Arr(mu)),
                    ("alpha", alpha_json),
                    ("beta", Json::Num(beta)),
                ])
            }
            other => bail!("unknown dataset kind '{other}'"),
        };
        // The stand-ins are multihawkes processes for ground-truth purposes.
        let kind = if def.kind == "kd_hawkes" { "multihawkes" } else { def.kind };
        Ok(obj(vec![
            ("name", Json::Str(def.name.to_string())),
            ("kind", Json::Str(kind.to_string())),
            ("num_types", Json::Num(def.num_types as f64)),
            ("t_end", Json::Num(100.0)),
            ("params", params),
        ]))
    }

    fn load_model(
        &self,
        dataset: &str,
        encoder: &str,
        size: &str,
    ) -> Result<Box<dyn ModelBackend>> {
        let def = dataset_def(dataset)?;
        if !ENCODERS.contains(&encoder) {
            bail!("unknown encoder '{encoder}' (thp|sahp|attnhp)");
        }
        let (_, bias, type_amp) = SIZES
            .iter()
            .copied()
            .find(|(n, _, _)| *n == size)
            .with_context(|| format!("unknown model size '{size}' (target|draft|draft2|draft3)"))?;
        // Encoders are distinct deterministic models; a small shared offset
        // keeps target/draft of the same encoder mutually consistent.
        let enc_shift = match encoder {
            "thp" => 0.0,
            "sahp" => 0.03,
            _ => -0.03,
        };
        Ok(Box::new(NativeModel {
            dataset: dataset.to_string(),
            encoder: encoder.to_string(),
            size: size.to_string(),
            num_types: def.num_types,
            bias,
            type_amp,
            enc_shift,
            excite: def.excite,
            decay: def.decay,
            calls: AtomicUsize::new(0),
            streams: Mutex::new(BTreeMap::new()),
            next_stream: AtomicU64::new(1),
        }))
    }
}

/// The full recurrent state of the native model after some event prefix:
/// everything [`NativeModel::write_row`] conditions on. Because the
/// excitation recursion is a pure fold over events, checkpointing this
/// struct per position makes rewind *exact* — restoring a checkpoint
/// reproduces the forward recursion bit-for-bit (DESIGN.md §12).
#[derive(Debug, Clone, Copy)]
struct StreamState {
    /// decayed-excitation feature Σ_i exp(-decay (t_anchor - t_i))
    s: f64,
    /// time of the last visible event (window start for the BOS row)
    t_anchor: f64,
    /// most recent visible event type (`K_MAX` for the BOS row)
    last_k: usize,
}

impl StreamState {
    /// State of an empty window starting at `t0` (the BOS row).
    fn bos(t0: f64) -> StreamState {
        StreamState { s: 0.0, t_anchor: t0, last_k: K_MAX }
    }

    /// Fold one event into the state. This is THE recursion — both the
    /// cold [`NativeModel::fill_slot`] path and the incremental
    /// [`CachedForward::forward_delta`] path call it, so their float-op
    /// sequences (and therefore their outputs) are identical by
    /// construction.
    #[inline]
    fn advance(&mut self, t: f64, k: u32, decay: f64) {
        let dt = (t - self.t_anchor).max(0.0);
        self.s = self.s * (-decay * dt).exp() + 1.0;
        self.t_anchor = t;
        self.last_k = k as usize;
    }
}

/// Per-stream incremental-inference state: the window start plus one
/// [`StreamState`] checkpoint per committed prefix length
/// (`states[i]` = state after `i` events), so `rewind(len)` is a
/// truncation and a draft forward re-derives nothing.
#[derive(Debug)]
struct NativeStream {
    /// window-start time the stream was (re)based on
    t0: f64,
    /// `states[i]` = recurrent state after the first `i` committed events
    states: Vec<StreamState>,
}

impl NativeStream {
    fn new() -> NativeStream {
        NativeStream { t0: 0.0, states: vec![StreamState::bos(0.0)] }
    }

    /// Committed events.
    fn len(&self) -> usize {
        self.states.len() - 1
    }
}

/// One loaded native model: analytic mixture-head parameters over the
/// visible history. See the module docs for the construction.
#[derive(Debug)]
pub struct NativeModel {
    dataset: String,
    encoder: String,
    size: String,
    num_types: usize,
    /// mean shift vs the target model (0 for `target`)
    bias: f64,
    /// type-head peak amplitude (smaller ⇒ flatter draft head)
    type_amp: f64,
    /// per-encoder parameter offset (shared by target and draft)
    enc_shift: f64,
    /// excitation gain of the history feature
    excite: f64,
    /// decay rate of the history feature
    decay: f64,
    calls: AtomicUsize,
    /// open incremental streams ([`CachedForward`])
    streams: Mutex<BTreeMap<StreamId, NativeStream>>,
    /// next stream id to hand out
    next_stream: AtomicU64,
}

impl NativeModel {
    /// Write the parameters of one output row.
    ///
    /// `s` is the excitation feature over the row's visible prefix,
    /// anchored at the prefix's last time `t_anchor`; `last_k` is the most
    /// recent visible event type (`K_MAX` for the BOS row).
    #[allow(clippy::too_many_arguments)]
    fn write_row(
        &self,
        s: f64,
        t_anchor: f64,
        last_k: usize,
        log_w: &mut [f32],
        mu: &mut [f32],
        log_sigma: &mut [f32],
        logits: &mut [f32],
    ) {
        // Saturating excitation feature: bounded, so intensities cannot run
        // away however long the history grows.
        let sat = s / (1.0 + 0.15 * s);
        let load = (1.0 + self.excite * sat).ln();
        // Slow inhomogeneity in absolute time (the Poisson flavour).
        let season = 0.08 * (0.05 * t_anchor).sin();
        let base = self.bias + self.enc_shift + season;

        let w0 = 0.3 + 0.4 * (0.5 + 0.5 * (0.37 * sat + 0.21 * last_k as f64).sin());
        log_w[0] = (w0.ln()) as f32;
        log_w[1] = ((1.0 - w0).ln()) as f32;
        mu[0] = (-1.2 + 0.1 * (0.53 * sat).sin() - 0.45 * load + base) as f32;
        mu[1] = (0.3 + 0.05 * (0.29 * sat).cos() - 0.30 * load + base) as f32;
        log_sigma[0] = -0.7;
        log_sigma[1] = -0.3;

        // Slice fills instead of a per-element branch ladder: 0.3 over the
        // live types, 0.0 over the padding tail, then the single preferred
        // peak — the same values, but `fill` lowers to vectorized stores.
        let pref = if last_k >= self.num_types { 0 } else { (last_k + 1) % self.num_types };
        let live = self.num_types.min(logits.len());
        logits[..live].fill(0.3);
        logits[live..].fill(0.0);
        logits[pref] = self.type_amp as f32;
    }

    /// Fill one batch slot's rows for `seq` (padding rows past the sequence
    /// repeat the final state, so they stay valid distributions).
    fn fill_slot(
        &self,
        seq: &SeqInput,
        bucket: usize,
        log_w: &mut [f32],
        mu: &mut [f32],
        log_sigma: &mut [f32],
        logits: &mut [f32],
    ) {
        let n = seq.times.len();
        // Hawkes-style recursion: s_r = Σ_{i<r} exp(-decay (t_anchor - t_i)),
        // updated in O(1) as each event becomes visible. The fold below is
        // StreamState::advance unrolled into lane chunks: pass 1 computes
        // the decay factors exp(-decay·Δt) of up to LANES consecutive rows
        // (each Δt depends only on the *input* times, so the pass has no
        // loop-carried dependence), pass 2 runs the dependent
        // `s = s·decay + 1` recurrence and writes the rows. Same float ops
        // in the same order ⇒ bit-identical to the incremental
        // CachedForward streams, which run StreamState::advance directly.
        let mut st = StreamState::bos(seq.t0);
        let real_rows = bucket.min(n + 1);
        self.write_row(
            st.s,
            st.t_anchor,
            st.last_k,
            &mut log_w[..N_MIX],
            &mut mu[..N_MIX],
            &mut log_sigma[..N_MIX],
            &mut logits[..K_MAX],
        );
        let mut decays = [0f64; LANES];
        let mut row = 1;
        while row < real_rows {
            let chunk = LANES.min(real_rows - row);
            for (j, d) in decays[..chunk].iter_mut().enumerate() {
                let r = row + j;
                let prev_t = if r == 1 { seq.t0 } else { seq.times[r - 2] };
                let dt = (seq.times[r - 1] - prev_t).max(0.0);
                *d = (-self.decay * dt).exp();
            }
            for (j, &d) in decays[..chunk].iter().enumerate() {
                let r = row + j;
                st.s = st.s * d + 1.0;
                st.t_anchor = seq.times[r - 1];
                st.last_k = seq.types[r - 1] as usize;
                let m0 = r * N_MIX;
                let l0 = r * K_MAX;
                self.write_row(
                    st.s,
                    st.t_anchor,
                    st.last_k,
                    &mut log_w[m0..m0 + N_MIX],
                    &mut mu[m0..m0 + N_MIX],
                    &mut log_sigma[m0..m0 + N_MIX],
                    &mut logits[l0..l0 + K_MAX],
                );
            }
            row += chunk;
        }
        // Padding rows are the final row frozen in place: copy, don't
        // recompute the transcendental math per row.
        let src_m = (real_rows - 1) * N_MIX;
        let src_l = (real_rows - 1) * K_MAX;
        for row in real_rows..bucket {
            let m0 = row * N_MIX;
            let l0 = row * K_MAX;
            log_w.copy_within(src_m..src_m + N_MIX, m0);
            mu.copy_within(src_m..src_m + N_MIX, m0);
            log_sigma.copy_within(src_m..src_m + N_MIX, m0);
            logits.copy_within(src_l..src_l + K_MAX, l0);
        }
    }
}

impl NativeModel {
    /// The whole delta-forward computation against one (already
    /// extracted) stream: validate, rewind/rebase, fold the new events,
    /// emit rows `base_len..=base_len+m`. Shared by the locked
    /// single-delta path and the parallel wave path, so both produce
    /// identical checkpoints and rows.
    fn delta_on(
        &self,
        stream: StreamId,
        st: &mut NativeStream,
        delta: &SeqDelta,
    ) -> Result<SlotOut> {
        // Delta rows must still fit the model's positional capacity
        // (BOS + events), exactly like a full forward of the same length.
        self.pick_bucket(delta.full_len() + 1)?;
        if delta.t0 != st.t0 {
            // Window slide: the committed prefix was computed against a
            // different BOS time, so no checkpoint is reusable — rebase.
            ensure!(
                delta.base_len == 0,
                "stream {stream}: t0 changed ({} -> {}) with base_len {} != 0 \
                 (slides must rebase from an empty prefix)",
                st.t0,
                delta.t0,
                delta.base_len
            );
            st.t0 = delta.t0;
            st.states.clear();
            st.states.push(StreamState::bos(delta.t0));
        }
        ensure!(
            delta.base_len <= st.len(),
            "stream {stream}: rewind to {} past the committed length {}",
            delta.base_len,
            st.len()
        );
        st.states.truncate(delta.base_len + 1);

        let m = delta.times.len();
        let rows = m + 1;
        let mut log_w = pool::checkout(rows * N_MIX);
        let mut mu = pool::checkout(rows * N_MIX);
        let mut log_sigma = pool::checkout(rows * N_MIX);
        let mut logits = pool::checkout(rows * K_MAX);
        let mut cur = *st.states.last().unwrap();
        for row in 0..rows {
            if row >= 1 {
                cur.advance(delta.times[row - 1], delta.types[row - 1], self.decay);
                st.states.push(cur);
            }
            let m0 = row * N_MIX;
            let l0 = row * K_MAX;
            self.write_row(
                cur.s,
                cur.t_anchor,
                cur.last_k,
                &mut log_w[m0..m0 + N_MIX],
                &mut mu[m0..m0 + N_MIX],
                &mut log_sigma[m0..m0 + N_MIX],
                &mut logits[l0..l0 + K_MAX],
            );
        }
        let out = ForwardOut::from_raw(1, rows, N_MIX, K_MAX, log_w, mu, log_sigma, logits);
        Ok(SlotOut::with_row_offset(out.into_shared(), 0, delta.base_len))
    }
}

impl CachedForward for NativeModel {
    fn open_stream(&self) -> Result<StreamId> {
        let id = self.next_stream.fetch_add(1, Ordering::Relaxed);
        self.streams.lock().unwrap().insert(id, NativeStream::new());
        Ok(id)
    }

    /// O(base-rewind + new events) — independent of the committed history
    /// length. Rows `base_len..=base_len+m` come out bit-identical to a
    /// cold full forward of the same prefix because both paths run
    /// [`StreamState::advance`] over the same event sequence.
    fn forward_delta(&self, stream: StreamId, delta: &SeqDelta) -> Result<SlotOut> {
        let mut streams = self.streams.lock().unwrap();
        let st = streams
            .get_mut(&stream)
            .with_context(|| format!("unknown stream {stream} (closed or never opened)"))?;
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.delta_on(stream, st, delta)
    }

    /// Wave of independent deltas with the same fan-out policy as batched
    /// full forwards: tiny waves (the common draft-step case — a handful
    /// of 1-event deltas, far below thread-spawn cost) run serially on
    /// the calling thread; heavy waves (e.g. every stream rebasing after
    /// a window slide, O(W) replay each) fan across cores. Each stream is
    /// temporarily extracted from the registry so the workers touch
    /// disjoint state. `call_count` counts one call per DELTA on both
    /// paths (a delta is one logical single-sequence forward), unlike
    /// batched full forwards, which count one call per batch.
    fn forward_delta_batch(&self, reqs: Vec<(StreamId, SeqDelta)>) -> Result<Vec<SlotOut>> {
        let total_rows: usize = reqs.iter().map(|(_, d)| d.times.len() + 1).sum();
        let mut ids: Vec<StreamId> = reqs.iter().map(|(s, _)| *s).collect();
        ids.sort_unstable();
        let has_dup = ids.windows(2).any(|w| w[0] == w[1]);
        if reqs.len() <= 1 || total_rows < pool::MIN_PARALLEL_ROWS || has_dup {
            return reqs.iter().map(|(s, d)| self.forward_delta(*s, d)).collect();
        }
        // Extract every stream up front (all-or-nothing, so an unknown id
        // cannot leave the registry half-drained).
        let mut taken: Vec<NativeStream> = Vec::with_capacity(reqs.len());
        {
            let mut streams = self.streams.lock().unwrap();
            for (s, _) in &reqs {
                ensure!(
                    streams.contains_key(s),
                    "unknown stream {s} (closed or never opened)"
                );
            }
            for (s, _) in &reqs {
                taken.push(streams.remove(s).expect("presence checked above"));
            }
        }
        self.calls.fetch_add(reqs.len(), Ordering::Relaxed);
        let mut results: Vec<Option<Result<SlotOut>>> =
            reqs.iter().map(|_| None).collect();
        {
            type DeltaJob<'a> =
                (StreamId, &'a SeqDelta, &'a mut NativeStream, &'a mut Option<Result<SlotOut>>);
            let mut jobs: Vec<DeltaJob> = reqs
                .iter()
                .zip(taken.iter_mut())
                .zip(results.iter_mut())
                .map(|(((s, d), st), r)| (*s, d, st, r))
                .collect();
            let workers = pool::wave_workers(total_rows, jobs.len());
            pool::run_wave(&mut jobs, workers, |(s, d, st, r)| {
                **r = Some(self.delta_on(*s, st, d))
            });
        }
        // Re-register every stream, even those whose delta failed — the
        // owner decides whether to retry, rebase or close.
        {
            let mut streams = self.streams.lock().unwrap();
            for ((s, _), st) in reqs.iter().zip(taken) {
                streams.insert(*s, st);
            }
        }
        results
            .into_iter()
            .map(|r| r.expect("every wave job ran"))
            .collect()
    }

    fn rewind(&self, stream: StreamId, len: usize) -> Result<()> {
        let mut streams = self.streams.lock().unwrap();
        let st = streams
            .get_mut(&stream)
            .with_context(|| format!("unknown stream {stream} (closed or never opened)"))?;
        ensure!(
            len <= st.len(),
            "stream {stream}: rewind to {len} past the committed length {}",
            st.len()
        );
        st.states.truncate(len + 1);
        Ok(())
    }

    fn close_stream(&self, stream: StreamId) {
        self.streams.lock().unwrap().remove(&stream);
    }
}

impl ModelBackend for NativeModel {
    fn forward(&self, seqs: &[SeqInput]) -> Result<ForwardOut> {
        assert!(!seqs.is_empty());
        let max_len = seqs.iter().map(SeqInput::len_with_bos).max().unwrap();
        let bucket = self.pick_bucket(max_len)?;
        let batch = BATCHES
            .iter()
            .copied()
            .find(|&b| b >= seqs.len())
            .with_context(|| format!("no batch capacity ≥ {} (max {})", seqs.len(), 8))?;
        self.calls.fetch_add(1, Ordering::Relaxed);

        let mut log_w = pool::checkout(batch * bucket * N_MIX);
        let mut mu = pool::checkout(batch * bucket * N_MIX);
        let mut log_sigma = pool::checkout(batch * bucket * N_MIX);
        let mut logits = pool::checkout(batch * bucket * K_MAX);
        let empty = SeqInput::default();
        // Real slots, plus ONE padding slot (the empty sequence); the
        // remaining padding slots are copies of it (valid, never read).
        let filled = batch.min(seqs.len() + 1);
        {
            // Per-slot stripes of the flat buffers; disjoint, so batched
            // fills fan out across the persistent pool (single-sequence
            // calls stay on the calling thread — the sequential samplers'
            // latency path pays no dispatch cost). Every stripe runs the
            // identical per-row math, so batched rows stay bit-identical
            // to single-sequence rows.
            let mut stripes: Vec<SlotStripe> = log_w
                .chunks_mut(bucket * N_MIX)
                .zip(mu.chunks_mut(bucket * N_MIX))
                .zip(log_sigma.chunks_mut(bucket * N_MIX))
                .zip(logits.chunks_mut(bucket * K_MAX))
                .take(filled)
                .enumerate()
                .map(|(b, (((lw, m), ls), lg))| (b, lw, m, ls, lg))
                .collect();
            let workers = pool::wave_workers(filled * bucket, filled);
            pool::run_wave(&mut stripes, workers, |(b, lw, m, ls, lg)| {
                self.fill_slot(seqs.get(*b).unwrap_or(&empty), bucket, lw, m, ls, lg)
            });
        }
        let pad_m = seqs.len() * bucket * N_MIX..(seqs.len() + 1) * bucket * N_MIX;
        let pad_l = seqs.len() * bucket * K_MAX..(seqs.len() + 1) * bucket * K_MAX;
        for b in filled..batch {
            log_w.copy_within(pad_m.clone(), b * bucket * N_MIX);
            mu.copy_within(pad_m.clone(), b * bucket * N_MIX);
            log_sigma.copy_within(pad_m.clone(), b * bucket * N_MIX);
            logits.copy_within(pad_l.clone(), b * bucket * K_MAX);
        }
        Ok(ForwardOut::from_raw(batch, bucket, N_MIX, K_MAX, log_w, mu, log_sigma, logits))
    }

    fn max_bucket(&self) -> usize {
        *BUCKETS.last().unwrap()
    }

    fn max_batch(&self) -> usize {
        *BATCHES.last().unwrap()
    }

    fn pick_bucket(&self, len: usize) -> Result<usize> {
        BUCKETS
            .iter()
            .copied()
            .find(|&b| b >= len)
            .with_context(|| format!("sequence length {len} exceeds max bucket"))
    }

    fn call_count(&self) -> usize {
        self.calls.load(Ordering::Relaxed)
    }

    fn cached(&self) -> Option<&dyn CachedForward> {
        Some(self)
    }

    fn descriptor(&self) -> String {
        format!("native:{}/{}/{}", self.dataset, self.encoder, self.size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(dataset: &str, size: &str) -> Box<dyn ModelBackend> {
        NativeBackend::new().load_model(dataset, "thp", size).unwrap()
    }

    fn seq(times: &[f64], types: &[u32]) -> SeqInput {
        SeqInput { t0: 0.0, times: times.to_vec(), types: types.to_vec() }
    }

    #[test]
    fn registry_rejects_unknowns() {
        let b = NativeBackend::new();
        assert!(b.load_model("hawkes", "thp", "target").is_ok());
        assert!(b.load_model("nope", "thp", "target").is_err());
        assert!(b.load_model("hawkes", "rnn", "target").is_err());
        assert!(b.load_model("hawkes", "thp", "huge").is_err());
        assert!(b.num_types("nope").is_err());
        assert_eq!(b.num_types("taxi_sim").unwrap(), 10);
        assert_eq!(b.datasets().len(), 7);
    }

    #[test]
    fn dataset_specs_parse_as_ground_truth() {
        let b = NativeBackend::new();
        for ds in b.datasets() {
            let spec = b.dataset_spec(&ds).unwrap();
            let gt = crate::processes::from_dataset_json(&spec)
                .unwrap_or_else(|e| panic!("{ds}: {e:#}"));
            assert_eq!(gt.num_types(), b.num_types(&ds).unwrap(), "{ds}");
        }
    }

    #[test]
    fn bucket_and_batch_selection() {
        let m = model("hawkes", "target");
        assert_eq!(m.pick_bucket(5).unwrap(), 64);
        assert_eq!(m.pick_bucket(64).unwrap(), 64);
        assert_eq!(m.pick_bucket(65).unwrap(), 128);
        assert!(m.pick_bucket(513).is_err());
        assert_eq!(m.max_bucket(), 512);
        assert_eq!(m.max_batch(), 8);
        let s = seq(&[0.5, 1.0], &[0, 0]);
        assert_eq!(m.forward(&[s.clone()]).unwrap().batch, 1);
        assert_eq!(m.forward(&[s.clone(), s.clone(), s]).unwrap().batch, 8);
    }

    #[test]
    fn rows_are_valid_distributions() {
        let m = model("multihawkes", "draft");
        let out = m.forward(&[seq(&[0.5, 1.0, 2.5], &[0, 1, 0])]).unwrap();
        for row in 0..out.bucket {
            let mix = out.mixture(0, row);
            let w_sum: f64 = mix.log_w.iter().map(|w| w.exp()).sum();
            assert!((w_sum - 1.0).abs() < 1e-6, "row {row}: Σw={w_sum}");
            assert!(mix.logpdf(1.0).is_finite());
            let td = out.type_dist(0, row, 2);
            assert!((td.probs.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn outputs_are_prefix_causal() {
        // Row r of a longer sequence equals row r of its length-r prefix:
        // the invariant TPP-SD's parallel verification relies on.
        let m = model("taxi_sim", "target");
        let full = seq(&[0.4, 0.9, 1.7, 2.0, 3.3], &[1, 4, 2, 0, 3]);
        let out_full = m.forward(&[full.clone()]).unwrap();
        for r in 0..=full.times.len() {
            let prefix = seq(&full.times[..r], &full.types[..r]);
            let out_pre = m.forward(&[prefix]).unwrap();
            let a = out_full.mixture(0, r);
            let b = out_pre.mixture(0, r);
            assert_eq!(a, b, "row {r} diverges from its prefix");
            let ta = out_full.type_dist(0, r, 10);
            let tb = out_pre.type_dist(0, r, 10);
            assert_eq!(ta.probs, tb.probs, "type row {r}");
        }
    }

    #[test]
    fn batched_rows_match_single_rows_exactly() {
        let m = model("hawkes", "draft");
        let seqs = vec![
            seq(&[0.2], &[0]),
            seq(&[0.3, 0.8, 1.1], &[0, 0, 0]),
            seq(&[2.0, 2.2], &[0, 0]),
        ];
        let batch = m.forward(&seqs).unwrap();
        for (b, s) in seqs.iter().enumerate() {
            let single = m.forward(std::slice::from_ref(s)).unwrap();
            let row = s.times.len();
            assert_eq!(batch.mixture(b, row), single.mixture(0, row), "slot {b}");
        }
    }

    #[test]
    fn stream_delta_matches_cold_forward() {
        let m = model("multihawkes", "target");
        let c = m.cached().expect("native models support cached forwards");
        let full = seq(&[0.4, 0.9, 1.7, 2.0], &[1, 0, 1, 0]);
        let sid = c.open_stream().unwrap();
        // feed in two chunks: [e0, e1] then [e2, e3]
        let d1 = SeqDelta { base_len: 0, t0: 0.0, times: vec![0.4, 0.9], types: vec![1, 0] };
        let out1 = c.forward_delta(sid, &d1).unwrap();
        let d2 = SeqDelta { base_len: 2, t0: 0.0, times: vec![1.7, 2.0], types: vec![1, 0] };
        let out2 = c.forward_delta(sid, &d2).unwrap();
        let cold = m.forward(std::slice::from_ref(&full)).unwrap();
        for row in 0..=2 {
            assert_eq!(out1.mixture(row), cold.mixture(0, row), "chunk 1 row {row}");
        }
        for row in 2..=4 {
            assert_eq!(out2.mixture(row), cold.mixture(0, row), "chunk 2 row {row}");
            assert_eq!(
                out2.type_dist(row, 2).probs,
                cold.type_dist(0, row, 2).probs,
                "chunk 2 type row {row}"
            );
        }
        c.close_stream(sid);
        assert!(c.forward_delta(sid, &d1).is_err(), "closed stream must reject");
    }

    #[test]
    fn stream_rewind_restores_checkpoints_exactly() {
        let m = model("hawkes", "draft");
        let c = m.cached().unwrap();
        let sid = c.open_stream().unwrap();
        let d = SeqDelta {
            base_len: 0,
            t0: 0.0,
            times: vec![0.3, 0.8, 1.1, 1.9],
            types: vec![0, 0, 0, 0],
        };
        let first = c.forward_delta(sid, &d).unwrap();
        // rewind to 2 events and extend with a DIFFERENT continuation
        let alt = SeqDelta { base_len: 2, t0: 0.0, times: vec![2.5], types: vec![0] };
        let redone = c.forward_delta(sid, &alt).unwrap();
        // row 2 (state after the shared prefix) must be bit-identical
        assert_eq!(first.mixture(2), redone.mixture(2));
        // row 3 now reflects the alternative event, matching a cold run
        let cold = m
            .forward(std::slice::from_ref(&seq(&[0.3, 0.8, 2.5], &[0, 0, 0])))
            .unwrap();
        assert_eq!(redone.mixture(3), cold.mixture(0, 3));
        // explicit rewind past the committed length is an error
        assert!(c.rewind(sid, 10).is_err());
        assert!(c.rewind(sid, 3).is_ok());
        c.close_stream(sid);
        c.close_stream(sid); // idempotent
    }

    #[test]
    fn stream_rebase_on_t0_change() {
        let m = model("hawkes", "target");
        let c = m.cached().unwrap();
        let sid = c.open_stream().unwrap();
        let d = SeqDelta { base_len: 0, t0: 0.0, times: vec![0.5], types: vec![0] };
        c.forward_delta(sid, &d).unwrap();
        // t0 change with a non-zero base is the slide bug this guards
        let bad = SeqDelta { base_len: 1, t0: 2.0, times: vec![2.5], types: vec![0] };
        assert!(c.forward_delta(sid, &bad).is_err(), "slide without rebase must fail");
        // rebase: base_len 0, new t0 — equals a cold forward with that t0
        let rb = SeqDelta { base_len: 0, t0: 2.0, times: vec![2.5, 3.0], types: vec![0, 0] };
        let out = c.forward_delta(sid, &rb).unwrap();
        let cold = m
            .forward(&[SeqInput { t0: 2.0, times: vec![2.5, 3.0], types: vec![0, 0] }])
            .unwrap();
        for row in 0..=2 {
            assert_eq!(out.mixture(row), cold.mixture(0, row), "rebased row {row}");
        }
        c.close_stream(sid);
    }

    #[test]
    fn stream_delta_respects_bucket_capacity() {
        let m = model("hawkes", "target");
        let c = m.cached().unwrap();
        let sid = c.open_stream().unwrap();
        // 512 events + BOS = 513 positions > max bucket 512
        let d = SeqDelta {
            base_len: 0,
            t0: 0.0,
            times: (0..512).map(|i| i as f64 * 0.1).collect(),
            types: vec![0; 512],
        };
        assert!(c.forward_delta(sid, &d).is_err(), "oversized delta must fail like a full forward");
        c.close_stream(sid);
    }

    #[test]
    fn draft_diverges_from_target() {
        let t = model("hawkes", "target");
        let d = model("hawkes", "draft");
        let s = seq(&[0.5, 1.0], &[0, 0]);
        let mt = t.forward(std::slice::from_ref(&s)).unwrap().mixture(0, 2);
        let md = d.forward(std::slice::from_ref(&s)).unwrap().mixture(0, 2);
        assert!((mt.mu[0] - md.mu[0]).abs() > 0.05, "draft must diverge");
        assert_eq!(t.call_count(), 1);
    }
}
