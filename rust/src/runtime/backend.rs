//! The backend seam (DESIGN.md §5): everything the samplers, metrics and
//! coordinator know about "a model" lives here, independent of how the
//! forward pass is computed.
//!
//! Three layers of contract, from narrow to wide:
//!
//! * [`Forward`] — "run the forward pass for ONE sequence". Samplers and
//!   scorers are generic over this, so the same algorithm code runs on a
//!   direct in-process model, on the coordinator's batched serving path
//!   ([`crate::coordinator::ExecutorHandle`]), and on test mocks.
//! * [`ModelBackend`] — one loaded model: batched forwards (up to
//!   [`ModelBackend::max_batch`] sequences per call), length-bucket
//!   selection, warmup and perf accounting. The coordinator's batcher
//!   drives this interface.
//! * [`Backend`] — a model *registry*: resolves `(dataset, encoder, size)`
//!   to a loaded [`ModelBackend`] and answers dataset metadata queries.
//!   Implementations: [`crate::runtime::NativeBackend`] (pure CPU, default)
//!   and `XlaBackend` (PJRT artifacts, behind `--features xla`).
//!
//! Row layout contract (DESIGN.md §5): a forward over a sequence of `n`
//! events returns `bucket ≥ n + 1` rows; row `r` parameterizes the
//! distribution of the *next* event given the BOS row plus the first `r`
//! events. Rows past `n` are padding and must still hold *valid*
//! distributions (normalized weights, finite parameters).

use std::sync::Arc;

use anyhow::Result;

use super::pool;
use crate::model::mixture::{Mixture, TypeDist};
use crate::util::json::Json;

/// One sequence's model input: absolute event times/types (BOS excluded —
/// the backend prepends it).
#[derive(Debug, Clone, Default)]
pub struct SeqInput {
    /// window-start time carried by the BOS row
    pub t0: f64,
    /// absolute event times, strictly increasing
    pub times: Vec<f64>,
    /// event types, parallel to `times`
    pub types: Vec<u32>,
}

impl SeqInput {
    /// Number of model positions this sequence occupies (events + BOS).
    pub fn len_with_bos(&self) -> usize {
        self.times.len() + 1
    }
}

/// One batch slot of a [`ForwardOut`] — what a single-sequence consumer
/// (sampler, likelihood scorer) sees. Cheap to clone (Arc-backed).
///
/// Rows are addressed in *absolute* sequence coordinates: a full forward
/// serves rows `0..bucket` directly, while a delta forward
/// ([`CachedForward::forward_delta`]) serves only rows
/// `base_len..=base_len+m` and records `base_len` as a row offset — so
/// samplers index rows the same way on both paths.
#[derive(Debug, Clone)]
pub struct SlotOut {
    out: Arc<ForwardOut>,
    b: usize,
    /// absolute row index of the underlying output's row 0
    row_off: usize,
}

impl SlotOut {
    /// View batch row `b` of a shared forward output.
    pub fn new(out: Arc<ForwardOut>, b: usize) -> SlotOut {
        assert!(b < out.batch);
        SlotOut { out, b, row_off: 0 }
    }

    /// View batch row `b` of a shared forward output whose row 0 sits at
    /// absolute sequence row `row_off` (delta forwards).
    pub fn with_row_offset(out: Arc<ForwardOut>, b: usize, row_off: usize) -> SlotOut {
        assert!(b < out.batch);
        SlotOut { out, b, row_off }
    }

    /// Mixture parameters of `g(τ_{row+1} | history ≤ row)`.
    pub fn mixture(&self, row: usize) -> Mixture {
        debug_assert!(row >= self.row_off, "row {row} below delta offset {}", self.row_off);
        self.out.mixture(self.b, row - self.row_off)
    }

    /// [`SlotOut::mixture`] into caller-owned storage (the samplers' hot
    /// loops reuse one scratch [`Mixture`] instead of allocating per read).
    pub fn mixture_into(&self, row: usize, out: &mut Mixture) {
        debug_assert!(row >= self.row_off, "row {row} below delta offset {}", self.row_off);
        self.out.mixture_into(self.b, row - self.row_off, out);
    }

    /// Event-type distribution at `row`, restricted to `k` real types.
    pub fn type_dist(&self, row: usize, k: usize) -> TypeDist {
        debug_assert!(row >= self.row_off, "row {row} below delta offset {}", self.row_off);
        self.out.type_dist(self.b, row - self.row_off, k)
    }

    /// [`SlotOut::type_dist`] into caller-owned storage (allocation-free
    /// once the scratch [`TypeDist`] has warmed up).
    pub fn type_dist_into(&self, row: usize, k: usize, out: &mut TypeDist) {
        debug_assert!(row >= self.row_off, "row {row} below delta offset {}", self.row_off);
        self.out.type_dist_into(self.b, row - self.row_off, k, out);
    }

    /// Bucket (row capacity) of the underlying forward output.
    pub fn bucket(&self) -> usize {
        self.out.bucket
    }

    /// Absolute row index this view starts at (0 for full forwards).
    pub fn row_offset(&self) -> usize {
        self.row_off
    }
}

/// The always-alive placeholder a dropped [`SlotOut`] leaves behind so its
/// real `Arc` can be moved out and (when uniquely owned) shell-pooled.
fn empty_shared() -> Arc<ForwardOut> {
    static EMPTY: std::sync::OnceLock<Arc<ForwardOut>> = std::sync::OnceLock::new();
    EMPTY
        .get_or_init(|| {
            Arc::new(ForwardOut::from_raw(
                1,
                0,
                0,
                0,
                Vec::new(),
                Vec::new(),
                Vec::new(),
                Vec::new(),
            ))
        })
        .clone()
}

impl Drop for SlotOut {
    /// Return the underlying `Arc` shell to the pool when this was the
    /// last view of it (shared views — clones, sibling batch slots — pool
    /// only on the final drop; the static placeholder is never pooled
    /// because the `OnceLock` keeps its count above 1).
    fn drop(&mut self) {
        let out = std::mem::replace(&mut self.out, empty_shared());
        if Arc::strong_count(&out) == 1 {
            pool::put_shell(out);
        }
    }
}

/// Identifier of an open incremental-inference stream
/// ([`CachedForward`]). Allocated by the backend; unique per model object
/// for that model's lifetime.
pub type StreamId = u64;

/// The *delta* form of a [`SeqInput`] against an open stream: only the
/// events past the stream's committed prefix (DESIGN.md §12).
///
/// Semantics of `forward_delta(stream, delta)`: the stream is first
/// rewound to its checkpoint after `base_len` events (so a shorter
/// `base_len` than the stream's current length expresses a draft
/// rejection), then the `times`/`types` events are appended and
/// committed. If `t0` differs from the stream's window start the cache is
/// *rebased*: allowed only with `base_len == 0`, the stream restarts from
/// the new `t0` (the sliding-window invalidation rule).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SeqDelta {
    /// committed events of the stream this delta extends (its checkpoint)
    pub base_len: usize,
    /// window-start time carried by the BOS row; must equal the stream's
    /// unless `base_len == 0` (rebase)
    pub t0: f64,
    /// absolute times of the new events past `base_len`
    pub times: Vec<f64>,
    /// event types, parallel to `times`
    pub types: Vec<u32>,
}

impl SeqDelta {
    /// Total sequence length (events, BOS excluded) once applied.
    pub fn full_len(&self) -> usize {
        self.base_len + self.times.len()
    }
}

/// Incremental forward passes over per-sequence streams — the O(1)-per-
/// event alternative to re-encoding the whole history each call
/// (DESIGN.md §12, ADR-003). Backends that can keep per-stream inference
/// state implement this ([`crate::runtime::NativeModel`], and
/// [`crate::coordinator::ExecutorHandle`] when its executor's model
/// does); discovery goes through [`Forward::cached`], so samplers fall
/// back to full [`SeqInput`] forwards on backends without it (the XLA
/// executor's AOT graphs are fixed-shape and stateless).
///
/// Contract: the rows returned by [`CachedForward::forward_delta`] are
/// **bit-identical** to the same rows of a cold full forward over the
/// stream's committed events plus the delta (property-tested in
/// `rust/tests/cached_forward.rs`).
pub trait CachedForward {
    /// Open a new empty stream (window start `t0 = 0`, no events).
    fn open_stream(&self) -> Result<StreamId>;

    /// Rewind the stream to `len` committed events, then append and
    /// commit the delta's events; returns the rows `base_len..=base_len+m`
    /// (absolute row coordinates via [`SlotOut::row_offset`]).
    fn forward_delta(&self, stream: StreamId, delta: &SeqDelta) -> Result<SlotOut>;

    /// Rewind the stream to `len` committed events without running any
    /// forward math (`len` must not exceed the committed length).
    fn rewind(&self, stream: StreamId, len: usize) -> Result<()>;

    /// Release the stream's state. Unknown ids are ignored (idempotent).
    fn close_stream(&self, stream: StreamId);

    /// Run several independent stream deltas "in one call". The default
    /// loops [`CachedForward::forward_delta`]; the serving-path handle
    /// overrides it to enqueue the whole wave so the executor thread
    /// coalesces the deltas like a batch.
    fn forward_delta_batch(&self, reqs: Vec<(StreamId, SeqDelta)>) -> Result<Vec<SlotOut>> {
        reqs.iter().map(|(s, d)| self.forward_delta(*s, d)).collect()
    }
}

/// Anything that can run the model forward pass for one sequence: a loaded
/// [`ModelBackend`] (direct path), a
/// [`crate::coordinator::ExecutorHandle`] (batched serving path), or a test
/// mock. Samplers and scorers are generic over this, so the exact same
/// algorithm code runs on every path.
pub trait Forward {
    /// Run the forward pass for one sequence.
    fn forward1(&self, seq: SeqInput) -> Result<SlotOut>;

    /// Largest sequence length (incl. BOS) a forward can take.
    fn max_bucket(&self) -> usize;

    /// The incremental-stream interface, when this forward supports it
    /// (`None` ⇒ callers use full [`SeqInput`] forwards).
    fn cached(&self) -> Option<&dyn CachedForward> {
        None
    }
}

/// Adapter that hides a model's [`CachedForward`] support: forwards pass
/// through, `cached()` reports `None`. Used to force the uncached path —
/// the A/B arm of `bench_cached_forward`, the `"cached":false` server
/// knob, and the equivalence suites' reference runs.
#[derive(Debug, Clone, Copy)]
pub struct Uncached<'a, F: ?Sized>(pub &'a F);

impl<F: Forward + ?Sized> Forward for Uncached<'_, F> {
    fn forward1(&self, seq: SeqInput) -> Result<SlotOut> {
        self.0.forward1(seq)
    }

    fn max_bucket(&self) -> usize {
        self.0.max_bucket()
    }
}

impl<F: BatchForward + ?Sized> BatchForward for Uncached<'_, F> {
    fn forward_batch(&self, seqs: Vec<SeqInput>) -> Result<Vec<SlotOut>> {
        self.0.forward_batch(seqs)
    }

    fn max_batch(&self) -> usize {
        BatchForward::max_batch(self.0)
    }
}

/// RAII handle to one open stream on a [`CachedForward`] model: closes the
/// stream on drop, so abandoned sampling runs cannot leak backend state.
pub struct StreamGuard<'a> {
    model: &'a dyn CachedForward,
    id: StreamId,
}

impl<'a> StreamGuard<'a> {
    /// Open a stream on `model` if it supports incremental forwards.
    pub fn open<F: Forward + ?Sized>(model: &'a F) -> Result<Option<StreamGuard<'a>>> {
        match model.cached() {
            Some(c) => Ok(Some(StreamGuard { model: c, id: c.open_stream()? })),
            None => Ok(None),
        }
    }

    /// Run one delta forward on the guarded stream.
    pub fn forward_delta(&self, delta: &SeqDelta) -> Result<SlotOut> {
        self.model.forward_delta(self.id, delta)
    }

    /// The guarded stream's id.
    pub fn id(&self) -> StreamId {
        self.id
    }
}

impl Drop for StreamGuard<'_> {
    fn drop(&mut self) {
        self.model.close_stream(self.id);
    }
}

/// One loaded model, whatever computes it: batched forwards with length
/// bucketing. Object-safe so the coordinator can own `Box<dyn ModelBackend>`
/// on its executor threads (implementations need not be `Send`; the
/// coordinator confines each model to the thread that loaded it).
pub trait ModelBackend {
    /// Run the forward pass for `1..=max_batch()` sequences in one call.
    ///
    /// The output's `bucket` is the smallest compiled/supported bucket that
    /// fits the longest input (incl. BOS); its `batch` is the smallest
    /// supported batch capacity ≥ `seqs.len()`, with padding slots holding
    /// valid (but meaningless) distributions.
    fn forward(&self, seqs: &[SeqInput]) -> Result<ForwardOut>;

    /// Largest sequence length (incl. BOS) any forward can take.
    fn max_bucket(&self) -> usize;

    /// Largest number of sequences one forward call accepts.
    fn max_batch(&self) -> usize;

    /// Smallest supported bucket with capacity ≥ `len` (incl. BOS).
    fn pick_bucket(&self, len: usize) -> Result<usize>;

    /// Pre-build every (bucket, batch) forward variant (no-op where
    /// building is free, e.g. the native backend).
    fn warmup(&self) -> Result<()> {
        Ok(())
    }

    /// Pre-build only the variants of one batch capacity.
    fn warmup_batch(&self, _batch: usize) -> Result<()> {
        Ok(())
    }

    /// Number of forward calls so far (perf accounting).
    fn call_count(&self) -> usize {
        0
    }

    /// The incremental-stream interface, when this model supports it
    /// (`None` ⇒ callers use full [`ModelBackend::forward`] passes).
    fn cached(&self) -> Option<&dyn CachedForward> {
        None
    }

    /// Human-readable `backend:dataset/encoder/size` tag for logs.
    fn descriptor(&self) -> String;
}

impl Forward for Box<dyn ModelBackend> {
    fn forward1(&self, seq: SeqInput) -> Result<SlotOut> {
        let out = self.as_ref().forward(std::slice::from_ref(&seq))?;
        Ok(SlotOut::new(out.into_shared(), 0))
    }

    fn max_bucket(&self) -> usize {
        self.as_ref().max_bucket()
    }

    fn cached(&self) -> Option<&dyn CachedForward> {
        self.as_ref().cached()
    }
}

/// A [`Forward`] that can additionally run ONE batched forward pass for
/// several *independent* sequences — the fleet engine's
/// ([`crate::sampler::engine`]) view of a model. Slot `b` of the returned
/// vector carries exactly the rows sequence `b` would have received from
/// [`Forward::forward1`]; the backend contract (DESIGN.md §5) guarantees
/// those rows are bit-identical regardless of batch capacity or bucket, so
/// co-batching never moves a probability.
///
/// Implementations: `Box<dyn ModelBackend>` (one batched backend call) and
/// [`crate::coordinator::ExecutorHandle`] (the requests are enqueued
/// together and coalesce in the executor thread's batch window).
pub trait BatchForward: Forward {
    /// Run the forward pass for `seqs.len() ≤ max_batch()` sequences in one
    /// batched call, returning one slot view per input sequence (in order).
    fn forward_batch(&self, seqs: Vec<SeqInput>) -> Result<Vec<SlotOut>>;

    /// Largest number of sequences one [`BatchForward::forward_batch`]
    /// call accepts.
    fn max_batch(&self) -> usize;
}

impl BatchForward for Box<dyn ModelBackend> {
    fn forward_batch(&self, seqs: Vec<SeqInput>) -> Result<Vec<SlotOut>> {
        if seqs.is_empty() {
            return Ok(Vec::new());
        }
        let out = self.as_ref().forward(&seqs)?.into_shared();
        Ok((0..seqs.len()).map(|b| SlotOut::new(out.clone(), b)).collect())
    }

    fn max_batch(&self) -> usize {
        self.as_ref().max_batch()
    }
}

/// A model registry: resolves `(dataset, encoder, size)` triples to loaded
/// models and answers dataset metadata queries. `Send + Sync` so the
/// coordinator can hand one registry to every executor thread.
pub trait Backend: Send + Sync {
    /// Short backend name (`"native"`, `"xla"`).
    fn name(&self) -> &'static str;

    /// Datasets this backend can serve.
    fn datasets(&self) -> Vec<String>;

    /// Number of real event types of a dataset.
    fn num_types(&self, dataset: &str) -> Result<usize>;

    /// The dataset's registry entry (kind, `num_types`, ground-truth
    /// process params) in the `datasets.json` schema — the input
    /// [`crate::processes::from_dataset_json`] expects.
    fn dataset_spec(&self, dataset: &str) -> Result<Json>;

    /// Load (or build) the model for `(dataset, encoder, size)`.
    fn load_model(&self, dataset: &str, encoder: &str, size: &str)
        -> Result<Box<dyn ModelBackend>>;
}

/// Flattened forward outputs for a batch (row-major `[B, L, ·]`).
#[derive(Debug)]
pub struct ForwardOut {
    /// batch capacity of this output (≥ the number of input sequences)
    pub batch: usize,
    /// row capacity (sequence-length bucket, incl. BOS)
    pub bucket: usize,
    /// mixture components per row
    pub n_mix: usize,
    /// padded event-type dimension of the logits
    pub k_max: usize,
    log_w: Vec<f32>,
    mu: Vec<f32>,
    log_sigma: Vec<f32>,
    logits: Vec<f32>,
}

impl ForwardOut {
    /// Construct from raw flattened buffers (used by every backend and by
    /// mock models in tests).
    #[allow(clippy::too_many_arguments)]
    pub fn from_raw(
        batch: usize,
        bucket: usize,
        n_mix: usize,
        k_max: usize,
        log_w: Vec<f32>,
        mu: Vec<f32>,
        log_sigma: Vec<f32>,
        logits: Vec<f32>,
    ) -> ForwardOut {
        assert_eq!(log_w.len(), batch * bucket * n_mix);
        assert_eq!(mu.len(), batch * bucket * n_mix);
        assert_eq!(log_sigma.len(), batch * bucket * n_mix);
        assert_eq!(logits.len(), batch * bucket * k_max);
        ForwardOut { batch, bucket, n_mix, k_max, log_w, mu, log_sigma, logits }
    }

    /// Mixture parameters of `g(τ_{row+1} | history ≤ row)` for batch row b.
    pub fn mixture(&self, b: usize, row: usize) -> Mixture {
        let mut out = Mixture::default();
        self.mixture_into(b, row, &mut out);
        out
    }

    /// [`ForwardOut::mixture`] into caller-owned storage: clears and
    /// refills `out`'s parameter vectors with exactly the values
    /// [`ForwardOut::mixture`] would collect, allocation-free once `out`'s
    /// capacity has warmed up.
    pub fn mixture_into(&self, b: usize, row: usize, out: &mut Mixture) {
        debug_assert!(b < self.batch && row < self.bucket);
        let m = self.n_mix;
        let off = (b * self.bucket + row) * m;
        out.log_w.clear();
        out.log_w.extend(self.log_w[off..off + m].iter().map(|&x| x as f64));
        out.mu.clear();
        out.mu.extend(self.mu[off..off + m].iter().map(|&x| x as f64));
        out.log_sigma.clear();
        out.log_sigma.extend(self.log_sigma[off..off + m].iter().map(|&x| x as f64));
    }

    /// Event-type distribution at `row`, restricted to `k` real types.
    pub fn type_dist(&self, b: usize, row: usize, k: usize) -> TypeDist {
        let mut out = TypeDist { probs: Vec::new() };
        self.type_dist_into(b, row, k, &mut out);
        out
    }

    /// [`ForwardOut::type_dist`] into caller-owned storage (same values,
    /// no per-read allocations once `out` has warmed up).
    pub fn type_dist_into(&self, b: usize, row: usize, k: usize, out: &mut TypeDist) {
        debug_assert!(b < self.batch && row < self.bucket);
        let off = (b * self.bucket + row) * self.k_max;
        out.assign_from_logits_f32(&self.logits[off..off + self.k_max], k);
    }

    /// Move `self` into an `Arc`, reusing a pooled shell (a previously
    /// dropped forward's `Arc` allocation) when one is available. The
    /// shell's stale buffers travel back through `self`'s `Drop` to the
    /// buffer free list, so nothing leaks either way.
    pub fn into_shared(mut self) -> Arc<ForwardOut> {
        if let Some(mut shell) = pool::take_shell() {
            if let Some(dst) = Arc::get_mut(&mut shell) {
                dst.batch = self.batch;
                dst.bucket = self.bucket;
                dst.n_mix = self.n_mix;
                dst.k_max = self.k_max;
                std::mem::swap(&mut dst.log_w, &mut self.log_w);
                std::mem::swap(&mut dst.mu, &mut self.mu);
                std::mem::swap(&mut dst.log_sigma, &mut self.log_sigma);
                std::mem::swap(&mut dst.logits, &mut self.logits);
                return shell;
            }
        }
        Arc::new(self)
    }

    /// Deterministically overwrite batch slot `b`'s rows at and past
    /// `first_pad` with garbage-but-finite parameters (still *valid*
    /// distributions, per the row-layout contract above).
    ///
    /// Chaos-layer support ([`crate::runtime::chaos`]): padding rows must
    /// never influence sampling, so scrambling them is invisible to a
    /// correct consumer and loudly visible to one that reads padding.
    pub fn scramble_padding(&mut self, b: usize, first_pad: usize, salt: u64) {
        debug_assert!(b < self.batch);
        let mut rng = crate::util::rng::Rng::new(salt);
        for row in first_pad..self.bucket {
            let m_off = (b * self.bucket + row) * self.n_mix;
            for i in 0..self.n_mix {
                self.log_w[m_off + i] = rng.uniform_in(-3.0, 0.0) as f32;
                self.mu[m_off + i] = rng.uniform_in(-5.0, 5.0) as f32;
                self.log_sigma[m_off + i] = rng.uniform_in(-2.0, 1.0) as f32;
            }
            let l_off = (b * self.bucket + row) * self.k_max;
            for i in 0..self.k_max {
                self.logits[l_off + i] = rng.uniform_in(-4.0, 4.0) as f32;
            }
        }
    }
}

impl Drop for ForwardOut {
    /// Recycle the four output buffers (DESIGN.md §14). A value emptied by
    /// [`ForwardOut::into_shared`] contributes only zero-capacity husks,
    /// which the recycler ignores.
    fn drop(&mut self) {
        pool::recycle(std::mem::take(&mut self.log_w));
        pool::recycle(std::mem::take(&mut self.mu));
        pool::recycle(std::mem::take(&mut self.log_sigma));
        pool::recycle(std::mem::take(&mut self.logits));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_out_row_layout() {
        // 2 batch rows × 2 bucket rows × 2 mix / 3 types, distinct values
        let out = ForwardOut::from_raw(
            2,
            2,
            2,
            3,
            (0..8).map(|i| (i as f32) * 0.01 - 1.0).collect(),
            (0..8).map(|i| i as f32).collect(),
            vec![-0.5; 8],
            (0..12).map(|i| i as f32 * 0.1).collect(),
        );
        // batch 1, row 1 → offset (1*2+1)*2 = 6
        let m = out.mixture(1, 1);
        assert_eq!(m.mu, vec![6.0, 7.0]);
        // logits offset (1*2+1)*3 = 9
        let td = out.type_dist(1, 1, 3);
        assert_eq!(td.probs.len(), 3);
        assert!(td.probs[2] > td.probs[0]);
    }

    #[test]
    fn slot_out_views_one_batch_row() {
        let out = ForwardOut::from_raw(
            2,
            1,
            1,
            2,
            vec![0.0, 0.0],
            vec![1.0, 2.0],
            vec![-0.5, -0.5],
            vec![0.0, 0.0, 0.0, 0.0],
        );
        let shared = Arc::new(out);
        let s0 = SlotOut::new(shared.clone(), 0);
        let s1 = SlotOut::new(shared, 1);
        assert_eq!(s0.mixture(0).mu, vec![1.0]);
        assert_eq!(s1.mixture(0).mu, vec![2.0]);
        assert_eq!(s0.bucket(), 1);
        assert_eq!(s0.row_offset(), 0);
    }

    #[test]
    fn slot_out_row_offset_maps_absolute_rows() {
        // 1 batch row × 3 rows of a delta output whose row 0 sits at
        // absolute row 40: reads at rows 40..=42 map to local 0..=2.
        let out = ForwardOut::from_raw(
            1,
            3,
            1,
            2,
            vec![0.0; 3],
            vec![10.0, 11.0, 12.0],
            vec![-0.5; 3],
            vec![0.0; 6],
        );
        let s = SlotOut::with_row_offset(Arc::new(out), 0, 40);
        assert_eq!(s.row_offset(), 40);
        assert_eq!(s.mixture(40).mu, vec![10.0]);
        assert_eq!(s.mixture(42).mu, vec![12.0]);
        assert_eq!(s.type_dist(41, 2).probs.len(), 2);
    }

    #[test]
    fn seq_delta_full_len() {
        let d = SeqDelta { base_len: 3, t0: 0.0, times: vec![1.0, 2.0], types: vec![0, 1] };
        assert_eq!(d.full_len(), 5);
        assert_eq!(SeqDelta::default().full_len(), 0);
    }
}
