//! Chaos-injection layer (DESIGN.md §13, ADR-004): seeded, deterministic
//! fault injection behind the backend seam.
//!
//! Every wrapper here implements the same traits as the object it wraps —
//! [`Forward`], [`BatchForward`], [`CachedForward`], [`ModelBackend`],
//! [`Backend`] — so the samplers, the fleet engine and the coordinator run
//! *unchanged* while a [`FaultPlan`] injects faults underneath them:
//!
//! * **transient errors** (`err=P`) — the op fails with a
//!   [`TRANSIENT_MARKER`]-tagged `Err` *before* touching the inner model
//!   (fail-stop: no partial side effects, so a retry observes the same
//!   pre-state);
//! * **latency spikes** (`delay=P`, `delay-ms=N`) — the op sleeps before
//!   executing (exercises deadlines, never changes results);
//! * **stream loss** (`loss=P`) — a delta forward force-closes its own
//!   stream first and fails; the next use of the id reports "unknown
//!   stream", which consumers recover from by rebasing on a fresh stream;
//! * **corrupted padding rows** (`pad=P`) — a full forward's padding rows
//!   (rows past each sequence's length, batch slots past the real
//!   sequences) are overwritten with garbage-but-finite parameters. Real
//!   rows are untouched, so a correct consumer is bit-identical — this
//!   fault *detects* padding reads instead of tolerating them;
//! * **executor death** (`die=P`) — the op panics (thread-killing fault;
//!   only meaningful under the coordinator, whose handle observes the
//!   executor channel disconnect).
//!
//! All decisions come from one seeded [`Rng`] per wrapped object, so a
//! fault schedule is a pure function of `(plan, op sequence)`: the same
//! single-threaded run replays the same faults every time. Injected
//! faults are tallied in a shared [`ChaosStats`], which the recovery test
//! suite (`rust/tests/chaos.rs`) reconciles against the consumers'
//! retry/recovery counters.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

use super::backend::{
    Backend, BatchForward, CachedForward, Forward, ForwardOut, ModelBackend, SeqDelta, SeqInput,
    SlotOut, StreamId,
};

/// Substring marking an injected error as *transient* (retry-worthy). The
/// batcher's retry loop only resubmits errors carrying this marker —
/// everything else (e.g. "unknown stream" after a loss) propagates
/// immediately so the stream-recovery ladder can handle it instead.
pub const TRANSIENT_MARKER: &str = "transient";

/// True when an error is marked transient (safe and useful to retry).
pub fn is_transient(err: &anyhow::Error) -> bool {
    format!("{err:#}").contains(TRANSIENT_MARKER)
}

/// Per-fault-kind probability schedule of one chaos run. Parsed from the
/// `--chaos` CLI / wire spec: comma-separated `key=value` pairs, e.g.
/// `seed=7,err=0.2,delay=0.1,delay-ms=2,loss=0.05,pad=0.3`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// seed of the fault-decision stream (`seed=N`, default 0)
    pub seed: u64,
    /// probability a forward-type op fails with a transient error
    /// (`err=P`); `err=1` makes every attempt fail — the canonical
    /// *unrecoverable* plan
    pub p_err: f64,
    /// probability an op sleeps [`FaultPlan::delay`] first (`delay=P`)
    pub p_delay: f64,
    /// latency-spike duration (`delay-ms=N`, default 1ms)
    pub delay: Duration,
    /// probability a delta forward loses its stream (`loss=P`)
    pub p_loss: f64,
    /// probability a full forward's padding rows are corrupted (`pad=P`)
    pub p_pad: f64,
    /// probability an op panics, killing its executor thread (`die=P`)
    pub p_die: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            p_err: 0.0,
            p_delay: 0.0,
            delay: Duration::from_millis(1),
            p_loss: 0.0,
            p_pad: 0.0,
            p_die: 0.0,
        }
    }
}

impl FaultPlan {
    /// Parse a `key=value,...` spec. Unknown keys and probabilities
    /// outside [0, 1] are errors (a typo'd chaos spec silently injecting
    /// nothing would defeat the whole point).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("chaos spec '{part}': expected key=value"))?;
            let prob = |what: &str| -> Result<f64> {
                let p: f64 = val
                    .parse()
                    .map_err(|_| anyhow!("chaos spec: bad {what} probability '{val}'"))?;
                if !(0.0..=1.0).contains(&p) {
                    bail!("chaos spec: {what}={p} outside [0,1]");
                }
                Ok(p)
            };
            match key {
                "seed" => {
                    plan.seed = val
                        .parse()
                        .map_err(|_| anyhow!("chaos spec: bad seed '{val}'"))?
                }
                "err" => plan.p_err = prob("err")?,
                "delay" => plan.p_delay = prob("delay")?,
                "delay-ms" | "delay_ms" => {
                    let ms: u64 = val
                        .parse()
                        .map_err(|_| anyhow!("chaos spec: bad delay-ms '{val}'"))?;
                    plan.delay = Duration::from_millis(ms);
                }
                "loss" => plan.p_loss = prob("loss")?,
                "pad" => plan.p_pad = prob("pad")?,
                "die" => plan.p_die = prob("die")?,
                other => {
                    bail!("chaos spec: unknown key '{other}' (seed|err|delay|delay-ms|loss|pad|die)")
                }
            }
        }
        Ok(plan)
    }

    /// True when every injected fault is recoverable by the retry /
    /// rebase / degradation ladder: sub-certain transient errors, any
    /// amount of latency, stream loss and padding corruption. `err=1`
    /// (every attempt fails) and any `die` make a plan unrecoverable.
    pub fn recoverable(&self) -> bool {
        self.p_err < 1.0 && self.p_die == 0.0
    }

    /// True when the plan injects nothing (e.g. parsed from `""`).
    pub fn is_noop(&self) -> bool {
        self.p_err == 0.0
            && self.p_delay == 0.0
            && self.p_loss == 0.0
            && self.p_pad == 0.0
            && self.p_die == 0.0
    }
}

/// Tally of every fault actually injected (shared across the wrappers of
/// one [`ChaosBackend`], so a test can reconcile the totals against the
/// consumers' retry/recovery counters).
#[derive(Debug, Default)]
pub struct ChaosStats {
    /// transient `Err` returns injected
    pub errors: AtomicUsize,
    /// latency spikes injected
    pub delays: AtomicUsize,
    /// streams force-closed under a delta forward
    pub losses: AtomicUsize,
    /// full forwards whose padding rows were scrambled
    pub corruptions: AtomicUsize,
    /// injected panics (executor deaths)
    pub deaths: AtomicUsize,
}

impl ChaosStats {
    /// Total faults injected, over every kind.
    pub fn total(&self) -> usize {
        let l = |c: &AtomicUsize| c.load(Ordering::Relaxed);
        l(&self.errors) + l(&self.delays) + l(&self.losses) + l(&self.corruptions) + l(&self.deaths)
    }

    /// Relaxed load of one counter (test convenience).
    pub fn get(c: &AtomicUsize) -> usize {
        c.load(Ordering::Relaxed)
    }
}

/// The seeded fault-decision core shared by the wrappers: one plan, one
/// RNG (mutexed — determinism is guaranteed for deterministic op
/// sequences, i.e. single-threaded drivers), one stats tally.
#[derive(Debug)]
struct ChaosCore {
    plan: FaultPlan,
    rng: Mutex<Rng>,
    stats: Arc<ChaosStats>,
}

impl ChaosCore {
    fn new(plan: FaultPlan, seed: u64, stats: Arc<ChaosStats>) -> ChaosCore {
        ChaosCore { plan, rng: Mutex::new(Rng::new(seed)), stats }
    }

    fn roll(&self, p: f64) -> bool {
        p > 0.0 && self.rng.lock().unwrap().uniform() < p
    }

    /// Pre-op fault gate, in fixed roll order (die, delay, err) so a
    /// seeded schedule is reproducible. Runs *before* the inner op:
    /// injected errors are fail-stop and a retry sees unchanged state.
    fn before_op(&self, what: &str) -> Result<()> {
        if self.roll(self.plan.p_die) {
            self.stats.deaths.fetch_add(1, Ordering::Relaxed);
            panic!("chaos: injected death during {what}");
        }
        if self.roll(self.plan.p_delay) {
            self.stats.delays.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.plan.delay);
        }
        if self.roll(self.plan.p_err) {
            self.stats.errors.fetch_add(1, Ordering::Relaxed);
            bail!("chaos: injected {TRANSIENT_MARKER} fault during {what}");
        }
        Ok(())
    }

    /// Stream-loss roll for a delta forward.
    fn lose_stream(&self) -> bool {
        let lose = self.roll(self.plan.p_loss);
        if lose {
            self.stats.losses.fetch_add(1, Ordering::Relaxed);
        }
        lose
    }

    /// Padding-corruption roll for a full forward; returns a scramble
    /// salt when the fault fires.
    fn corrupt_salt(&self) -> Option<u64> {
        if self.roll(self.plan.p_pad) {
            self.stats.corruptions.fetch_add(1, Ordering::Relaxed);
            Some(self.rng.lock().unwrap().next_u64())
        } else {
            None
        }
    }
}

/// Fault-injecting wrapper around any [`Forward`] / [`BatchForward`] /
/// [`CachedForward`] — the direct-path chaos harness (tests wrap a model
/// or a [`crate::coordinator::ExecutorHandle`] in one of these and run
/// the samplers unchanged).
pub struct ChaosForward<F> {
    inner: F,
    core: ChaosCore,
}

impl<F> ChaosForward<F> {
    /// Wrap `inner`, drawing fault decisions from `plan.seed`.
    pub fn new(inner: F, plan: FaultPlan) -> ChaosForward<F> {
        let seed = plan.seed;
        ChaosForward { inner, core: ChaosCore::new(plan, seed, Arc::new(ChaosStats::default())) }
    }

    /// The injected-fault tally.
    pub fn stats(&self) -> Arc<ChaosStats> {
        self.core.stats.clone()
    }

    /// The wrapped object.
    pub fn inner(&self) -> &F {
        &self.inner
    }
}

impl<F: Forward> ChaosForward<F> {
    fn inner_cached(&self) -> Result<&dyn CachedForward> {
        self.inner
            .cached()
            .ok_or_else(|| anyhow!("chaos: inner model has no incremental streams"))
    }
}

impl<F: Forward> Forward for ChaosForward<F> {
    fn forward1(&self, seq: SeqInput) -> Result<SlotOut> {
        self.core.before_op("forward1")?;
        self.inner.forward1(seq)
    }

    fn max_bucket(&self) -> usize {
        self.inner.max_bucket()
    }

    fn cached(&self) -> Option<&dyn CachedForward> {
        self.inner.cached().map(|_| self as &dyn CachedForward)
    }
}

impl<F: BatchForward> BatchForward for ChaosForward<F> {
    fn forward_batch(&self, seqs: Vec<SeqInput>) -> Result<Vec<SlotOut>> {
        self.core.before_op("forward_batch")?;
        self.inner.forward_batch(seqs)
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }
}

impl<F: Forward> CachedForward for ChaosForward<F> {
    fn open_stream(&self) -> Result<StreamId> {
        self.core.before_op("open_stream")?;
        self.inner_cached()?.open_stream()
    }

    fn forward_delta(&self, stream: StreamId, delta: &SeqDelta) -> Result<SlotOut> {
        self.core.before_op("forward_delta")?;
        let c = self.inner_cached()?;
        if self.core.lose_stream() {
            c.close_stream(stream);
            bail!("chaos: stream {stream} lost (forced close)");
        }
        c.forward_delta(stream, delta)
    }

    fn rewind(&self, stream: StreamId, len: usize) -> Result<()> {
        self.inner_cached()?.rewind(stream, len)
    }

    fn close_stream(&self, stream: StreamId) {
        if let Some(c) = self.inner.cached() {
            c.close_stream(stream);
        }
    }
}

/// Fault-injecting wrapper around one loaded [`ModelBackend`] (what a
/// [`ChaosBackend`] hands to the coordinator's executor threads).
pub struct ChaosModel {
    inner: Box<dyn ModelBackend>,
    core: ChaosCore,
}

impl ChaosModel {
    /// Wrap a loaded model; `seed` must be stable per model so fault
    /// schedules do not depend on load order.
    pub fn new(
        inner: Box<dyn ModelBackend>,
        plan: FaultPlan,
        seed: u64,
        stats: Arc<ChaosStats>,
    ) -> ChaosModel {
        ChaosModel { inner, core: ChaosCore::new(plan, seed, stats) }
    }

    fn inner_cached(&self) -> Result<&dyn CachedForward> {
        self.inner
            .as_ref()
            .cached()
            .ok_or_else(|| anyhow!("chaos: inner model has no incremental streams"))
    }
}

impl ModelBackend for ChaosModel {
    fn forward(&self, seqs: &[SeqInput]) -> Result<ForwardOut> {
        self.core.before_op("forward")?;
        let mut out = self.inner.forward(seqs)?;
        if let Some(salt) = self.core.corrupt_salt() {
            // Scramble every padding region: rows past each sequence's
            // length, and whole batch slots past the real sequences. Real
            // rows stay untouched — a consumer that never reads padding
            // is bit-identical under this fault.
            for (b, seq) in seqs.iter().enumerate() {
                out.scramble_padding(b, seq.len_with_bos(), salt ^ b as u64);
            }
            for b in seqs.len()..out.batch {
                out.scramble_padding(b, 0, salt ^ b as u64);
            }
        }
        Ok(out)
    }

    fn max_bucket(&self) -> usize {
        self.inner.max_bucket()
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn pick_bucket(&self, len: usize) -> Result<usize> {
        self.inner.pick_bucket(len)
    }

    fn warmup(&self) -> Result<()> {
        self.inner.warmup()
    }

    fn warmup_batch(&self, batch: usize) -> Result<()> {
        self.inner.warmup_batch(batch)
    }

    fn call_count(&self) -> usize {
        self.inner.call_count()
    }

    fn cached(&self) -> Option<&dyn CachedForward> {
        self.inner.as_ref().cached().map(|_| self as &dyn CachedForward)
    }

    fn descriptor(&self) -> String {
        format!("chaos({})", self.inner.descriptor())
    }
}

impl CachedForward for ChaosModel {
    fn open_stream(&self) -> Result<StreamId> {
        self.core.before_op("open_stream")?;
        self.inner_cached()?.open_stream()
    }

    fn forward_delta(&self, stream: StreamId, delta: &SeqDelta) -> Result<SlotOut> {
        self.core.before_op("forward_delta")?;
        let c = self.inner_cached()?;
        if self.core.lose_stream() {
            c.close_stream(stream);
            bail!("chaos: stream {stream} lost (forced close)");
        }
        c.forward_delta(stream, delta)
    }

    fn rewind(&self, stream: StreamId, len: usize) -> Result<()> {
        self.inner_cached()?.rewind(stream, len)
    }

    fn close_stream(&self, stream: StreamId) {
        if let Some(c) = self.inner.as_ref().cached() {
            c.close_stream(stream);
        }
    }
}

/// Fault-injecting model *registry*: wraps every model a [`Backend`]
/// loads in a [`ChaosModel`] sharing one [`ChaosStats`] tally. This is
/// what `tppsd sample --chaos <spec>`, the wire protocol's `"chaos"`
/// field and the recovery test suite plug into the coordinator.
pub struct ChaosBackend {
    inner: Arc<dyn Backend>,
    plan: FaultPlan,
    stats: Arc<ChaosStats>,
}

impl ChaosBackend {
    /// Wrap a registry with a fault plan.
    pub fn new(inner: Arc<dyn Backend>, plan: FaultPlan) -> ChaosBackend {
        ChaosBackend { inner, plan, stats: Arc::new(ChaosStats::default()) }
    }

    /// The injected-fault tally, shared by every model this registry has
    /// loaded (or will load).
    pub fn stats(&self) -> Arc<ChaosStats> {
        self.stats.clone()
    }

    /// The wrapped plan.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }
}

/// Stable 64-bit fold of a model key, so each [`ChaosModel`]'s fault
/// stream depends on *which* model it is, never on load order.
fn key_seed(base: u64, dataset: &str, encoder: &str, size: &str) -> u64 {
    let mut h = base ^ 0x9E37_79B9_7F4A_7C15;
    for b in dataset.bytes().chain(encoder.bytes()).chain(size.bytes()) {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01B3);
    }
    h
}

impl Backend for ChaosBackend {
    fn name(&self) -> &'static str {
        "chaos"
    }

    fn datasets(&self) -> Vec<String> {
        self.inner.datasets()
    }

    fn num_types(&self, dataset: &str) -> Result<usize> {
        self.inner.num_types(dataset)
    }

    fn dataset_spec(&self, dataset: &str) -> Result<Json> {
        self.inner.dataset_spec(dataset)
    }

    fn load_model(
        &self,
        dataset: &str,
        encoder: &str,
        size: &str,
    ) -> Result<Box<dyn ModelBackend>> {
        let inner = self.inner.load_model(dataset, encoder, size)?;
        let seed = key_seed(self.plan.seed, dataset, encoder, size);
        Ok(Box::new(ChaosModel::new(inner, self.plan.clone(), seed, self.stats.clone())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mock::MockModel;

    #[test]
    fn plan_parses_and_validates() {
        let p = FaultPlan::parse("seed=7,err=0.25,delay=0.5,delay-ms=3,loss=0.1,pad=1").unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.p_err, 0.25);
        assert_eq!(p.p_delay, 0.5);
        assert_eq!(p.delay, Duration::from_millis(3));
        assert_eq!(p.p_loss, 0.1);
        assert_eq!(p.p_pad, 1.0);
        assert!(p.recoverable());
        assert!(!p.is_noop());
        assert!(FaultPlan::parse("").unwrap().is_noop());
        assert!(!FaultPlan::parse("err=1").unwrap().recoverable());
        assert!(!FaultPlan::parse("die=0.5").unwrap().recoverable());
        assert!(FaultPlan::parse("err=1.5").is_err());
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("err").is_err());
    }

    #[test]
    fn injected_errors_are_transient_and_counted() {
        let plan = FaultPlan::parse("seed=1,err=1").unwrap();
        let chaos = ChaosForward::new(MockModel::default(), plan);
        let err = chaos.forward1(SeqInput::default()).unwrap_err();
        assert!(is_transient(&err), "{err:#}");
        assert_eq!(ChaosStats::get(&chaos.stats().errors), 1);
    }

    #[test]
    fn noop_plan_is_bit_exact_passthrough() {
        let inner = MockModel::default();
        let chaos = ChaosForward::new(MockModel::default(), FaultPlan::default());
        let seq = SeqInput { t0: 0.0, times: vec![0.5, 1.5], types: vec![0, 1] };
        let a = chaos.forward1(seq.clone()).unwrap();
        let b = inner.forward1(seq).unwrap();
        assert_eq!(a.mixture(2).mu, b.mixture(2).mu);
        assert_eq!(chaos.stats().total(), 0);
    }

    #[test]
    fn fault_schedule_is_deterministic() {
        let run = || {
            let plan = FaultPlan::parse("seed=5,err=0.4").unwrap();
            let chaos = ChaosForward::new(MockModel::default(), plan);
            (0..50)
                .map(|_| chaos.forward1(SeqInput::default()).is_err())
                .collect::<Vec<bool>>()
        };
        assert_eq!(run(), run());
        assert!(run().iter().any(|&e| e) && run().iter().any(|&e| !e));
    }
}
