//! PJRT runtime (Layer 3 ↔ AOT artifacts): manifest registry, weight
//! loading, and the bucketed forward executor.

pub mod executor;
pub mod manifest;

pub use executor::{ForwardOut, ModelExecutor, SeqInput};
pub use manifest::{ArtifactDir, Manifest};

use std::rc::Rc;

use anyhow::Result;

/// Open a PJRT CPU client.
pub fn cpu_client() -> Result<Rc<xla::PjRtClient>> {
    Ok(Rc::new(xla::PjRtClient::cpu()?))
}
