//! The inference runtime (Layer 3 ↔ model forwards): the backend seam, the
//! pure-Rust [`NativeBackend`] (default), the artifact manifest registry,
//! and — behind `--features xla` — the PJRT executor for AOT artifacts.
//!
//! Pick a backend with [`discover_backend`] (honours `$TPP_SD_BACKEND`) or
//! [`backend_named`]; everything downstream only sees the [`Backend`] /
//! [`ModelBackend`] / [`Forward`] traits (DESIGN.md §5).

pub mod backend;
pub mod chaos;
#[cfg(feature = "xla")]
pub mod executor;
pub mod manifest;
pub mod native;
pub mod pool;

pub use backend::{
    Backend, BatchForward, CachedForward, Forward, ForwardOut, ModelBackend, SeqDelta, SeqInput,
    SlotOut, StreamGuard, StreamId, Uncached,
};
pub use chaos::{ChaosBackend, ChaosForward, ChaosModel, ChaosStats, FaultPlan};
pub use manifest::{ArtifactDir, Manifest};
pub use native::{NativeBackend, NativeModel};
pub use pool::PoolStats;

#[cfg(feature = "xla")]
pub use executor::{cpu_client, ModelExecutor, XlaBackend};

use std::sync::Arc;

use anyhow::{bail, Result};

/// Resolve the inference backend from `$TPP_SD_BACKEND` (default `auto`:
/// the XLA artifact backend when compiled in *and* artifacts are present,
/// the native CPU backend otherwise).
pub fn discover_backend() -> Result<Arc<dyn Backend>> {
    let spec = std::env::var("TPP_SD_BACKEND").unwrap_or_else(|_| "auto".to_string());
    backend_named(&spec)
}

/// Resolve a backend from an optional `--backend` argument, falling back
/// to [`discover_backend`] (which honours `$TPP_SD_BACKEND`). Binaries,
/// examples and benches all route through this so the env var works
/// everywhere.
pub fn backend_from_arg(arg: Option<&str>) -> Result<Arc<dyn Backend>> {
    match arg {
        Some(name) => backend_named(name),
        None => discover_backend(),
    }
}

/// Construct a backend by name: `native`, `xla`, or `auto`.
pub fn backend_named(name: &str) -> Result<Arc<dyn Backend>> {
    match name {
        "native" => Ok(Arc::new(NativeBackend::new())),
        "xla" => xla_backend(),
        "auto" | "" => {
            #[cfg(feature = "xla")]
            {
                if ArtifactDir::discover().is_ok() {
                    return xla_backend();
                }
            }
            Ok(Arc::new(NativeBackend::new()))
        }
        other => bail!("unknown backend '{other}' (native|xla|auto)"),
    }
}

#[cfg(feature = "xla")]
fn xla_backend() -> Result<Arc<dyn Backend>> {
    Ok(Arc::new(XlaBackend::discover()?))
}

#[cfg(not(feature = "xla"))]
fn xla_backend() -> Result<Arc<dyn Backend>> {
    bail!("backend 'xla' requires building with `cargo build --features xla`")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_named_resolves() {
        assert_eq!(backend_named("native").unwrap().name(), "native");
        assert!(backend_named("bogus").is_err());
        // `auto` always resolves to *something* usable
        let b = backend_named("auto").unwrap();
        assert!(!b.datasets().is_empty());
    }
}
