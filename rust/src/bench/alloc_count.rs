//! Counting global allocator for allocations-per-event ceilings.
//!
//! The hot-path discipline (DESIGN.md §14) is enforced empirically: a
//! binary installs [`CountingAllocator`] as its `#[global_allocator]`,
//! snapshots [`allocations`] around a warmed sampling run, and asserts the
//! delta per generated event stays under a ceiling. The counter is a
//! single relaxed atomic — cheap enough that timing numbers taken under
//! it remain representative.
//!
//! The counter is process-wide; binaries that measure with it
//! (`benches/bench_hotpath.rs`, `tests/alloc_ceiling.rs`) keep their
//! measured section single-threaded-deterministic by warming the worker
//! pool and buffer pool first.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

/// Heap allocations observed so far (allocation *calls*, not bytes;
/// reallocations count once, frees are not counted).
pub fn allocations() -> usize {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// A [`System`]-delegating allocator that counts allocation calls.
///
/// Install in a binary with:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: tpp_sd::bench::alloc_count::CountingAllocator =
///     tpp_sd::bench::alloc_count::CountingAllocator;
/// ```
pub struct CountingAllocator;

// SAFETY: pure delegation to `System`; the counter has no effect on the
// returned pointers or layouts.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The allocator is not installed in the library's own test binary, so
    // only the counter plumbing is testable here; the end-to-end ceiling
    // lives in `tests/alloc_ceiling.rs` where the allocator IS installed.
    #[test]
    fn counter_starts_readable() {
        let a = allocations();
        let b = allocations();
        assert!(b >= a);
    }
}
