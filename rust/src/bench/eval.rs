//! Table/figure cell runners (DESIGN.md §6 experiment index).
//!
//! `synthetic_cell` reproduces one (dataset × encoder) cell of **Table 1**
//! (and the KS series of Figures 2/4); `real_cell` one cell of **Table 2**
//! (and the type histograms of Figure 5). The γ- and draft-size ablations
//! (Figure 3/6, Table 3/4) reuse the same runners with different knobs.
//!
//! Since the fleet-engine refactor (DESIGN.md §11) each seed's `n_seq`
//! sequences run in lockstep on [`crate::sampler::engine`] — per-sequence
//! seeds are derived from the cell seed, so results stay deterministic —
//! and the reported wall times are the *fleet* wall times, i.e. the
//! batched-throughput comparison a serving host actually sees.

use std::time::Instant;

use anyhow::Result;

use crate::events::Event;
use crate::metrics::{delta_l, emd_labels, ks_vs_exp1, model_loglik, wasserstein_1d};
use crate::processes::GroundTruth;
use crate::runtime::{BatchForward, Forward};
use crate::sampler::{
    fleet_seeds, sample_ar_fleet, sample_sd_fleet, Gamma, SampleCfg, SampleStats, SdCfg,
};
use crate::util::rng::Rng;

/// Knobs shared by the cell runners (paper defaults in brackets).
#[derive(Debug, Clone)]
pub struct EvalCfg {
    /// sampling window end [100]
    pub t_end: f64,
    /// sequences sampled per method per seed [paper: "the dataset"]
    pub n_seq: usize,
    /// random seeds [3 (tables) / 5 (figures)]
    pub seeds: Vec<u64>,
    /// draft length γ [10]
    pub gamma: usize,
    /// adaptive-γ extension instead of fixed
    pub adaptive: bool,
    /// history length M for Table-2 next-event sampling [100]
    pub history_m: usize,
    /// repetitions N for Table-2 next-event sampling [100]
    pub reps_n: usize,
}

impl Default for EvalCfg {
    fn default() -> Self {
        EvalCfg {
            t_end: 100.0,
            n_seq: 3,
            seeds: vec![0, 1, 2],
            gamma: 10,
            adaptive: false,
            history_m: 100,
            reps_n: 100,
        }
    }
}

impl EvalCfg {
    /// The draft-length policy these knobs select.
    pub fn gamma_policy(&self) -> Gamma {
        if self.adaptive {
            Gamma::Adaptive { init: self.gamma, min: 2, max: 4 * self.gamma.max(1) }
        } else {
            Gamma::Fixed(self.gamma)
        }
    }
}

/// One Table-1 cell: likelihood discrepancies vs ground truth, KS
/// statistics of time-rescaled intervals, wall-times and the speedup ratio.
#[derive(Debug, Clone, Default)]
pub struct SyntheticCell {
    /// per-event |ΔL| of AR samples vs ground truth
    pub dl_ar: f64,
    /// per-event |ΔL| of SD samples vs ground truth
    pub dl_sd: f64,
    /// KS of rescaled AR intervals vs Exp(1)
    pub ks_ar: f64,
    /// KS of rescaled SD intervals vs Exp(1)
    pub ks_sd: f64,
    /// KS of rescaled ground-truth (thinning) intervals vs Exp(1)
    pub ks_gt: f64,
    /// mean AR wall time per seed (s)
    pub t_ar: f64,
    /// mean SD wall time per seed (s)
    pub t_sd: f64,
    /// t_ar / t_sd
    pub speedup: f64,
    /// SD acceptance rate α
    pub alpha: f64,
    /// KS-plot series (F(z), F_n(z)) for Figures 2/4: SD samples
    pub ks_points_sd: Vec<(f64, f64)>,
    /// KS-plot series: AR samples
    pub ks_points_ar: Vec<(f64, f64)>,
    /// KS-plot series: ground-truth thinning samples
    pub ks_points_gt: Vec<(f64, f64)>,
    /// sample count behind the KS band
    pub n_rescaled: usize,
}

/// Run one synthetic cell (Table 1 / Fig. 2 / Fig. 4).
///
/// For each seed: sample `n_seq` sequences with AR and with TPP-SD from the
/// target model; compute (a) per-event |L_gt(Eq.1) − L_model(Eq.2)|,
/// (b) the KS statistic of ground-truth-rescaled intervals, (c) wall times.
/// Ground-truth sequences (thinning) provide the reference KS series.
pub fn synthetic_cell<FT, FD>(
    target: &FT,
    draft: &FD,
    process: &dyn GroundTruth,
    num_types: usize,
    cfg: &EvalCfg,
) -> Result<SyntheticCell>
where
    FT: BatchForward + ?Sized,
    FD: BatchForward + ?Sized,
{
    let scfg = SampleCfg { num_types, t_end: cfg.t_end, max_events: 16 * 1024 };
    let mut cell = SyntheticCell::default();
    let mut z_ar = Vec::new();
    let mut z_sd = Vec::new();
    let mut z_gt = Vec::new();
    let mut sd_stats = SampleStats::default();
    let (mut dl_ar, mut dl_sd) = (Vec::new(), Vec::new());
    let (mut t_ar, mut t_sd) = (0.0, 0.0);

    for &seed in &cfg.seeds {
        let base = seed.wrapping_mul(0x9E37_79B9).wrapping_add(1);
        // --- AR: the seed's n_seq sequences in one fleet ---
        let t0 = Instant::now();
        let (ar_runs, _) = sample_ar_fleet(target, &scfg, &fleet_seeds(base, cfg.n_seq))?;
        t_ar += t0.elapsed().as_secs_f64();
        for (ev, _) in &ar_runs {
            if !ev.is_empty() {
                let lgt = process.loglik(ev, cfg.t_end);
                let lm = model_loglik(target, ev, num_types, cfg.t_end)?;
                dl_ar.push(delta_l(lgt, lm, ev.len()));
                z_ar.extend(process.rescale(ev));
            }
        }
        // --- SD: same, on an independent derived seed stream ---
        let sd_cfg = SdCfg {
            sample: scfg.clone(),
            gamma: cfg.gamma_policy(),
            ..Default::default()
        };
        let t0 = Instant::now();
        let (sd_runs, _) =
            sample_sd_fleet(target, draft, &sd_cfg, &fleet_seeds(base ^ 0x5D5D_5D5D, cfg.n_seq))?;
        t_sd += t0.elapsed().as_secs_f64();
        for (ev, st) in &sd_runs {
            sd_stats.merge(st);
            if !ev.is_empty() {
                let lgt = process.loglik(ev, cfg.t_end);
                let lm = model_loglik(target, ev, num_types, cfg.t_end)?;
                dl_sd.push(delta_l(lgt, lm, ev.len()));
                z_sd.extend(process.rescale(ev));
            }
        }
        // --- ground truth (thinning) for the KS reference series ---
        for i in 0..cfg.n_seq {
            let mut gt_rng = Rng::new(seed * 1000 + i as u64 + 7);
            let gt = process.simulate(&mut gt_rng, cfg.t_end);
            z_gt.extend(process.rescale(&gt));
        }
    }

    cell.dl_ar = crate::util::math::mean(&dl_ar);
    cell.dl_sd = crate::util::math::mean(&dl_sd);
    cell.ks_ar = ks_vs_exp1(&z_ar);
    cell.ks_sd = ks_vs_exp1(&z_sd);
    cell.ks_gt = ks_vs_exp1(&z_gt);
    cell.t_ar = t_ar / cfg.seeds.len() as f64;
    cell.t_sd = t_sd / cfg.seeds.len() as f64;
    cell.speedup = cell.t_ar / cell.t_sd;
    cell.alpha = sd_stats.acceptance_rate();
    cell.ks_points_sd = crate::metrics::ks_plot_points(&z_sd);
    cell.ks_points_ar = crate::metrics::ks_plot_points(&z_ar);
    cell.ks_points_gt = crate::metrics::ks_plot_points(&z_gt);
    cell.n_rescaled = z_sd.len().min(z_ar.len());
    Ok(cell)
}

/// One Table-2 cell: AR-vs-SD consistency on a "real" dataset.
#[derive(Debug, Clone, Default)]
pub struct RealCell {
    /// per-event |ΔL| between AR and SD samples under the target model
    pub dl: f64,
    /// self-consistency baseline: two independent AR runs
    pub dl_ar_baseline: f64,
    /// 1-Wasserstein distance of next-event times, AR vs SD
    pub dws_t: f64,
    /// next-event time distance, AR vs AR (stochasticity baseline)
    pub dws_t_baseline: f64,
    /// EMD of next-event types, AR vs SD
    pub dws_k: f64,
    /// next-event type distance, AR vs AR (stochasticity baseline)
    pub dws_k_baseline: f64,
    /// mean AR wall time per seed (s)
    pub t_ar: f64,
    /// mean SD wall time per seed (s)
    pub t_sd: f64,
    /// t_ar / t_sd
    pub speedup: f64,
    /// SD acceptance rate α
    pub alpha: f64,
    /// type histogram of AR samples (Figure 5)
    pub hist_ar: Vec<f64>,
    /// type histogram of SD samples (Figure 5)
    pub hist_sd: Vec<f64>,
}

/// Run one real-data cell (Table 2 / Fig. 5).
///
/// Likelihood discrepancy: per-event |L(Eq.2) of AR samples − of SD
/// samples| under the target model, with an AR-vs-AR run as the paper's
/// stochasticity baseline. Wasserstein: fix the first M events of a history
/// sequence, redraw the (M+1)-th event N times with each sampler, compare
/// the time and type marginals.
pub fn real_cell<FT, FD>(
    target: &FT,
    draft: &FD,
    history_source: &dyn GroundTruth,
    num_types: usize,
    cfg: &EvalCfg,
) -> Result<RealCell>
where
    FT: BatchForward + ?Sized,
    FD: BatchForward + ?Sized,
{
    let scfg = SampleCfg { num_types, t_end: cfg.t_end, max_events: 16 * 1024 };
    let mut cell = RealCell::default();
    let mut sd_stats = SampleStats::default();
    let (mut dl, mut dl_base) = (Vec::new(), Vec::new());
    let (mut t_ar, mut t_sd) = (0.0, 0.0);
    let mut types_ar: Vec<u32> = Vec::new();
    let mut types_sd: Vec<u32> = Vec::new();

    for &seed in &cfg.seeds {
        let base = seed.wrapping_mul(0xA5A5_5A5A).wrapping_add(3);
        // Three fleets per seed on independent derived seed streams: the
        // AR column, the AR-vs-AR stochasticity baseline, and SD.
        let t0 = Instant::now();
        let (ar_runs, _) = sample_ar_fleet(target, &scfg, &fleet_seeds(base, cfg.n_seq))?;
        t_ar += t0.elapsed().as_secs_f64();
        let (ar2_runs, _) =
            sample_ar_fleet(target, &scfg, &fleet_seeds(base ^ 0xA2A2_A2A2, cfg.n_seq))?;
        let sd_cfg = SdCfg {
            sample: scfg.clone(),
            gamma: cfg.gamma_policy(),
            ..Default::default()
        };
        let t0 = Instant::now();
        let (sd_runs, _) =
            sample_sd_fleet(target, draft, &sd_cfg, &fleet_seeds(base ^ 0x5D5D_5D5D, cfg.n_seq))?;
        t_sd += t0.elapsed().as_secs_f64();
        for ((ev_ar, _), ((ev_ar2, _), (ev_sd, st_sd))) in
            ar_runs.iter().zip(ar2_runs.iter().zip(sd_runs.iter()))
        {
            sd_stats.merge(st_sd);
            if !ev_ar.is_empty() && !ev_sd.is_empty() && !ev_ar2.is_empty() {
                let l_ar = model_loglik(target, ev_ar, num_types, cfg.t_end)?;
                let l_ar2 = model_loglik(target, ev_ar2, num_types, cfg.t_end)?;
                let l_sd = model_loglik(target, ev_sd, num_types, cfg.t_end)?;
                let n = ev_ar.len().min(ev_sd.len());
                dl.push(delta_l(
                    l_ar / ev_ar.len() as f64 * n as f64,
                    l_sd / ev_sd.len() as f64 * n as f64,
                    n,
                ));
                dl_base.push(delta_l(
                    l_ar / ev_ar.len() as f64 * n as f64,
                    l_ar2 / ev_ar2.len() as f64 * n as f64,
                    n,
                ));
            }
            types_ar.extend(ev_ar.iter().map(|e| e.k));
            types_sd.extend(ev_sd.iter().map(|e| e.k));
        }
    }

    // --- Wasserstein next-event experiment (M history events, N reps) ---
    let mut hist_rng = Rng::new(0xBEEF);
    let mut history = history_source.simulate(&mut hist_rng, cfg.t_end * 10.0);
    history.truncate(cfg.history_m);
    let (nt_ar, nk_ar, nt_ar2, nk_ar2, nt_sd, nk_sd) =
        next_event_reps(target, draft, &history, num_types, cfg)?;
    cell.dws_t = wasserstein_1d(&nt_ar, &nt_sd);
    cell.dws_t_baseline = wasserstein_1d(&nt_ar, &nt_ar2);
    cell.dws_k = emd_labels(&nk_ar, &nk_sd, num_types);
    cell.dws_k_baseline = emd_labels(&nk_ar, &nk_ar2, num_types);

    cell.dl = crate::util::math::mean(&dl);
    cell.dl_ar_baseline = crate::util::math::mean(&dl_base);
    cell.t_ar = t_ar / cfg.seeds.len() as f64;
    cell.t_sd = t_sd / cfg.seeds.len() as f64;
    cell.speedup = cell.t_ar / cell.t_sd;
    cell.alpha = sd_stats.acceptance_rate();
    cell.hist_ar = crate::metrics::type_histogram(&types_ar, num_types);
    cell.hist_sd = crate::metrics::type_histogram(&types_sd, num_types);
    Ok(cell)
}

/// Redraw the (M+1)-th event N times per sampler given a fixed history.
#[allow(clippy::type_complexity)]
fn next_event_reps<FT, FD>(
    target: &FT,
    draft: &FD,
    history: &[Event],
    num_types: usize,
    cfg: &EvalCfg,
) -> Result<(Vec<f64>, Vec<u32>, Vec<f64>, Vec<u32>, Vec<f64>, Vec<u32>)>
where
    FT: Forward + ?Sized,
    FD: Forward + ?Sized,
{
    let t_last = history.last().map(|e| e.t).unwrap_or(0.0);
    // Next-event redraws share the target forward (same history ⇒ same
    // distribution parameters); the SD column still exercises the draft:
    // draft proposes, target verifies — exactly one SD round restricted to
    // its first event.
    let mut seq = crate::runtime::SeqInput::default();
    // clamp history into the bucket capacity
    let cap = target.max_bucket().min(draft.max_bucket()) - 2;
    let hist = if history.len() > cap { &history[history.len() - cap..] } else { history };
    seq.t0 = if hist.len() < history.len() {
        history[history.len() - cap - 1].t
    } else {
        0.0
    };
    seq.times = hist.iter().map(|e| e.t).collect();
    seq.types = hist.iter().map(|e| e.k).collect();
    let row = hist.len();
    let fwd_t = target.forward1(seq.clone())?;
    let fwd_d = draft.forward1(seq)?;
    let t_mix = fwd_t.mixture(row);
    let t_td = fwd_t.type_dist(row, num_types);
    let d_mix = fwd_d.mixture(row);
    let d_td = fwd_d.type_dist(row, num_types);

    let mut rng = Rng::new(0xFACE);
    let draw_ar = |rng: &mut Rng| {
        let tau = t_mix.sample(rng);
        let k = t_td.sample(rng) as u32;
        (t_last + tau, k)
    };
    let draw_sd = |rng: &mut Rng| {
        // one-candidate SD round: draft proposes, target verifies.
        let tau_hat = d_mix.sample(rng);
        let k_hat = d_td.sample(rng);
        let lr = t_mix.logpdf(tau_hat) - d_mix.logpdf(tau_hat);
        if rng.uniform().ln() >= lr {
            let (tau2, _) = crate::model::mixture::sample_adjusted_interval(
                &t_mix, &d_mix, rng, 64,
            );
            return (t_last + tau2, t_td.sample(rng) as u32);
        }
        if rng.uniform() * d_td.pmf(k_hat) >= t_td.pmf(k_hat) {
            let adj = crate::model::TypeDist::adjusted(&t_td, &d_td);
            return (t_last + tau_hat, adj.sample(rng) as u32);
        }
        (t_last + tau_hat, k_hat as u32)
    };

    let n = cfg.reps_n;
    let mut out = (
        Vec::with_capacity(n),
        Vec::with_capacity(n),
        Vec::with_capacity(n),
        Vec::with_capacity(n),
        Vec::with_capacity(n),
        Vec::with_capacity(n),
    );
    for _ in 0..n {
        let (t, k) = draw_ar(&mut rng);
        out.0.push(t);
        out.1.push(k);
        let (t, k) = draw_ar(&mut rng);
        out.2.push(t);
        out.3.push(k);
        let (t, k) = draw_sd(&mut rng);
        out.4.push(t);
        out.5.push(k);
    }
    Ok(out)
}
