//! Benchmark & evaluation harness: timing utilities and the cell runners
//! that regenerate every table and figure of the paper's evaluation
//! (experiment index in DESIGN.md §6). Examples and `cargo bench` targets
//! are thin CLI wrappers around this module.

pub mod eval;
pub mod timing;

pub use eval::{real_cell, synthetic_cell, EvalCfg, RealCell, SyntheticCell};
pub use timing::{bench_loop, BenchResult};
