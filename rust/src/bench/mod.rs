//! Benchmark & evaluation harness: timing utilities and the cell runners
//! that regenerate every table and figure of the paper's evaluation
//! (experiment index in DESIGN.md §6). Examples and `cargo bench` targets
//! are thin CLI wrappers around this module.

pub mod alloc_count;
pub mod eval;
pub mod timing;

pub use eval::{real_cell, synthetic_cell, EvalCfg, RealCell, SyntheticCell};
pub use timing::{bench_loop, executor_report, shard_report, BenchResult};

use anyhow::Result;

use crate::util::json::Json;

/// Merge one bench's snapshot into the shared `BENCH_sampling.json`: the
/// file is an object keyed by bench name (`{"bench_fleet":{...},
/// "bench_cached_forward":{...}}`), so the benches record their numbers
/// without clobbering each other's. A legacy single-bench file (top-level
/// `"bench"` key) or an unparseable file is replaced outright.
pub fn merge_snapshot(path: &str, bench: &str, value: Json) -> Result<()> {
    let mut root = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .filter(|j| matches!(j, Json::Obj(m) if !m.contains_key("bench")))
        .unwrap_or_else(|| Json::Obj(Default::default()));
    if let Json::Obj(m) = &mut root {
        m.insert(bench.to_string(), value);
    }
    std::fs::write(path, format!("{root}\n"))?;
    Ok(())
}
