//! Micro-benchmark substrate (no criterion in the offline registry):
//! warmup + timed iterations + percentile reporting.

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use crate::coordinator::{BatcherStats, ShardStats};
use crate::util::math::{mean, percentile, std_dev};

/// Timing samples of one benchmarked closure.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// benchmark label
    pub name: String,
    /// measured iterations
    pub iters: usize,
    /// per-iteration wall times in seconds
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Mean wall time in seconds.
    pub fn mean_s(&self) -> f64 {
        mean(&self.samples)
    }
    /// Standard deviation in seconds.
    pub fn std_s(&self) -> f64 {
        std_dev(&self.samples)
    }
    /// Median wall time in seconds.
    pub fn p50_s(&self) -> f64 {
        percentile(&self.samples, 0.5)
    }
    /// 95th-percentile wall time in seconds.
    pub fn p95_s(&self) -> f64 {
        percentile(&self.samples, 0.95)
    }
    /// 99th-percentile wall time in seconds. Like every percentile here,
    /// NaN samples are ignored per [`percentile`]'s contract (an all-NaN
    /// sample set yields NaN rather than a panic).
    pub fn p99_s(&self) -> f64 {
        percentile(&self.samples, 0.99)
    }

    /// One formatted report line.
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10.3}ms ±{:>8.3}ms  p50 {:>8.3}ms  p95 {:>8.3}ms  p99 {:>8.3}ms  (n={})",
            self.name,
            self.mean_s() * 1e3,
            self.std_s() * 1e3,
            self.p50_s() * 1e3,
            self.p95_s() * 1e3,
            self.p99_s() * 1e3,
            self.iters
        )
    }
}

/// Run `f` for `warmup` unmeasured and `iters` measured iterations.
pub fn bench_loop<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), iters, samples }
}

/// Time a single closure invocation.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

/// One formatted executor-counter line — the single report format shared
/// by `examples/serve.rs` and `benches/bench_coordinator.rs`, so the two
/// surfaces can never drift apart.
pub fn executor_report(name: &str, stats: &BatcherStats) -> String {
    let load = |c: &std::sync::atomic::AtomicUsize| c.load(Ordering::Relaxed);
    format!(
        "executor {:<28} batches={:<5} occupancy={:.2} delta_occupancy={:.2} retries={} \
         timeouts={} gave_up={} pool_dispatches={} pool_steals={} buffers_reused={} \
         buffers_allocated={}",
        name,
        load(&stats.batches),
        stats.occupancy(),
        stats.delta_occupancy(),
        load(&stats.retries),
        load(&stats.timeouts),
        load(&stats.gave_up),
        load(&stats.pool_dispatches),
        load(&stats.pool_steals),
        load(&stats.buffers_reused),
        load(&stats.buffers_allocated),
    )
}

/// One formatted shard-tier counter line — the single report format
/// shared by the proxy tests and benches (the sibling of
/// [`executor_report`], same never-drift rationale).
pub fn shard_report(name: &str, stats: &ShardStats) -> String {
    let load = |c: &std::sync::atomic::AtomicUsize| c.load(Ordering::Relaxed);
    format!(
        "shard {:<31} routed={:<6} spilled={} failovers={} ejections={} readmissions={} \
         upstream_errors={} fanouts={}",
        name,
        load(&stats.routed),
        load(&stats.spilled),
        load(&stats.failovers),
        load(&stats.ejections),
        load(&stats.readmissions),
        load(&stats.upstream_errors),
        load(&stats.fanouts),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_loop_counts() {
        let mut n = 0;
        let r = bench_loop("noop", 3, 10, || n += 1);
        assert_eq!(n, 13);
        assert_eq!(r.iters, 10);
        assert!(r.mean_s() >= 0.0);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn shard_report_carries_every_counter() {
        let s = ShardStats::default();
        s.routed.store(9, Ordering::Relaxed);
        s.failovers.store(2, Ordering::Relaxed);
        let line = shard_report("proxy", &s);
        for needle in [
            "routed=9",
            "failovers=2",
            "spilled=0",
            "ejections=0",
            "readmissions=0",
            "upstream_errors=0",
            "fanouts=0",
        ] {
            assert!(line.contains(needle), "missing {needle} in {line}");
        }
    }
}
