//! Univariate exponential Hawkes process (paper App. B.1):
//! λ(t) = μ + Σ_{t_i < t} α·exp(−β(t − t_i)).

use super::GroundTruth;
use crate::events::Event;
use crate::util::rng::Rng;

/// Univariate exponential Hawkes process.
#[derive(Debug, Clone)]
pub struct Hawkes {
    /// base rate μ
    pub mu: f64,
    /// excitation jump α
    pub alpha: f64,
    /// excitation decay β
    pub beta: f64,
}

impl Hawkes {
    /// Subcritical process (requires α < β).
    pub fn new(mu: f64, alpha: f64, beta: f64) -> Hawkes {
        assert!(alpha < beta, "subcritical Hawkes requires α < β");
        Hawkes { mu, alpha, beta }
    }

    /// Decay state S(t) = Σ_{t_i < t} exp(−β(t − t_i)) from scratch.
    fn decay_state(&self, t: f64, history: &[Event]) -> f64 {
        history
            .iter()
            .map(|e| (-self.beta * (t - e.t)).exp())
            .sum()
    }
}

impl GroundTruth for Hawkes {
    fn num_types(&self) -> usize {
        1
    }

    fn total_intensity(&self, t: f64, history: &[Event]) -> f64 {
        self.mu + self.alpha * self.decay_state(t, history)
    }

    fn integrated_total(&self, a: f64, b: f64, history: &[Event]) -> f64 {
        // All history < a: ∫_a^b α S(s) ds = (α/β)·S(a)·(1 − e^{−β(b−a)})
        let s_a = self.decay_state(a, history);
        self.mu * (b - a) + self.alpha / self.beta * s_a * (1.0 - (-self.beta * (b - a)).exp())
    }

    fn loglik(&self, events: &[Event], t_end: f64) -> f64 {
        // O(N) recursion on the decay state.
        let mut s = 0.0;
        let mut prev = 0.0;
        let mut ll = 0.0;
        for e in events {
            s *= (-self.beta * (e.t - prev)).exp();
            ll += (self.mu + self.alpha * s).max(1e-12).ln();
            s += 1.0;
            prev = e.t;
        }
        let mut comp = self.mu * t_end;
        for e in events {
            comp += self.alpha / self.beta * (1.0 - (-self.beta * (t_end - e.t)).exp());
        }
        ll - comp
    }

    fn simulate(&self, rng: &mut Rng, t_end: f64) -> Vec<Event> {
        // Ogata thinning with the O(1) decay-state recursion; between events
        // the intensity is non-increasing, so λ(t⁺) dominates.
        let mut t = 0.0;
        let mut s = 0.0;
        let mut out = Vec::new();
        loop {
            let lam_bar = self.mu + self.alpha * s;
            let t_next = t + rng.exponential(lam_bar);
            if t_next > t_end {
                return out;
            }
            let s_next = s * (-self.beta * (t_next - t)).exp();
            let lam = self.mu + self.alpha * s_next;
            t = t_next;
            s = s_next;
            if rng.uniform() * lam_bar < lam {
                out.push(Event::new(t, 0));
                s += 1.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::checker::close;
    use crate::util::math::{mean, std_dev};

    fn proc() -> Hawkes {
        Hawkes::new(2.5, 1.0, 2.0)
    }

    #[test]
    fn integrated_matches_numeric() {
        let p = proc();
        let hist = vec![Event::new(0.5, 0), Event::new(1.2, 0), Event::new(2.9, 0)];
        let (a, b) = (3.0, 6.0);
        let n = 400_000;
        let dt = (b - a) / n as f64;
        let num: f64 = (0..n)
            .map(|i| p.total_intensity(a + (i as f64 + 0.5) * dt, &hist) * dt)
            .sum();
        close(p.integrated_total(a, b, &hist), num, 1e-6, "Λ").unwrap();
    }

    #[test]
    fn stationary_rate() {
        // E[N(0,T)]/T → μ/(1−α/β) = 2.5/0.5 = 5.
        let p = proc();
        let mut rng = Rng::new(3);
        let t_end = 200.0;
        let runs = 40;
        let mean_rate = (0..runs)
            .map(|_| p.simulate(&mut rng, t_end).len() as f64 / t_end)
            .sum::<f64>()
            / runs as f64;
        assert!((mean_rate - 5.0).abs() < 0.35, "rate={mean_rate}");
    }

    #[test]
    fn rescaled_intervals_are_exp1() {
        let p = proc();
        let mut rng = Rng::new(4);
        let mut zs = Vec::new();
        for _ in 0..6 {
            let ev = p.simulate(&mut rng, 60.0);
            zs.extend(p.rescale(&ev));
        }
        assert!((mean(&zs) - 1.0).abs() < 0.06, "mean={}", mean(&zs));
        assert!((std_dev(&zs) - 1.0).abs() < 0.1, "sd={}", std_dev(&zs));
    }

    #[test]
    fn loglik_matches_rescaling_identity() {
        // Σ log λ(t_i) − Λ(0,T) computed two ways must agree.
        let p = proc();
        let mut rng = Rng::new(6);
        let ev = p.simulate(&mut rng, 20.0);
        let ll = p.loglik(&ev, 20.0);
        // brute force from trait methods
        let mut sum_log = 0.0;
        for (i, e) in ev.iter().enumerate() {
            sum_log += p.total_intensity(e.t, &ev[..i]).max(1e-12).ln();
        }
        let mut comp = 0.0;
        let mut prev = 0.0;
        for (i, e) in ev.iter().enumerate() {
            comp += p.integrated_total(prev, e.t, &ev[..i]);
            prev = e.t;
        }
        comp += p.integrated_total(prev, 20.0, &ev);
        close(ll, sum_log - comp, 1e-9, "loglik").unwrap();
    }

    #[test]
    #[should_panic(expected = "subcritical")]
    fn rejects_supercritical() {
        Hawkes::new(1.0, 3.0, 2.0);
    }
}
