//! Ground-truth point-process substrates (paper App. B.1): inhomogeneous
//! Poisson, univariate Hawkes and multivariate Hawkes — with thinning
//! simulation (Lewis–Shedler / Ogata), analytic integrated intensities for
//! the time-rescaling theorem, and the CIF-form log-likelihood Eq. (1).
//!
//! These are the processes the synthetic experiments (Table 1, Fig. 2/4)
//! measure against, and the substrate the training corpora were simulated
//! from (same definitions, mirrored in `python/compile/data.py`).

pub mod hawkes;
pub mod multi_hawkes;
pub mod poisson;

pub use hawkes::Hawkes;
pub use multi_hawkes::MultiHawkes;
pub use poisson::InhomPoisson;

use crate::events::Event;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// A ground-truth process: everything the evaluation harness needs.
pub trait GroundTruth {
    /// Number of event types the process emits.
    fn num_types(&self) -> usize;

    /// Total conditional intensity λ*(t) = Σ_k λ*(t, k) given the (strictly
    /// earlier) events of `history`.
    fn total_intensity(&self, t: f64, history: &[Event]) -> f64;

    /// ∫_a^b λ*(s) ds given that all events of `history` are < a.
    fn integrated_total(&self, a: f64, b: f64, history: &[Event]) -> f64;

    /// CIF-form log-likelihood Eq. (1) of `events` on the window [0, t_end].
    fn loglik(&self, events: &[Event], t_end: f64) -> f64;

    /// Simulate one realization on [0, t_end] via thinning.
    fn simulate(&self, rng: &mut Rng, t_end: f64) -> Vec<Event>;

    /// Time-rescaling transform (Theorem 2): z_i = ∫_{t_{i-1}}^{t_i} λ*(s) ds.
    /// Under the true model the z_i are i.i.d. Exp(1).
    fn rescale(&self, events: &[Event]) -> Vec<f64> {
        let mut out = Vec::with_capacity(events.len());
        let mut prev = 0.0;
        for (i, e) in events.iter().enumerate() {
            out.push(self.integrated_total(prev, e.t, &events[..i]));
            prev = e.t;
        }
        out
    }
}

/// Construct a ground-truth process from a `datasets.json` entry.
pub fn from_dataset_json(cfg: &Json) -> anyhow::Result<Box<dyn GroundTruth>> {
    let kind = cfg.str_at("kind").unwrap_or("");
    let p = cfg.get("params").ok_or_else(|| anyhow::anyhow!("params"))?;
    match kind {
        "poisson" => Ok(Box::new(InhomPoisson::new(
            p.f64_at("A").unwrap(),
            p.f64_at("b").unwrap(),
            p.f64_at("omega").unwrap(),
        ))),
        "hawkes" => Ok(Box::new(Hawkes::new(
            p.f64_at("mu").unwrap(),
            p.f64_at("alpha").unwrap(),
            p.f64_at("beta").unwrap(),
        ))),
        "multihawkes" => {
            let mu: Vec<f64> = p
                .get("mu")
                .and_then(Json::as_arr)
                .unwrap()
                .iter()
                .filter_map(Json::as_f64)
                .collect();
            let alpha: Vec<Vec<f64>> = p
                .get("alpha")
                .and_then(Json::as_arr)
                .unwrap()
                .iter()
                .map(|row| row.as_arr().unwrap().iter().filter_map(Json::as_f64).collect())
                .collect();
            Ok(Box::new(MultiHawkes::new(mu, alpha, p.f64_at("beta").unwrap())))
        }
        other => anyhow::bail!("unknown process kind {other}"),
    }
}
