//! Inhomogeneous Poisson process λ(t) = A·(b + sin(ω·π·t)) — paper App. B.1.

use super::GroundTruth;
use crate::events::Event;
use crate::util::rng::Rng;

/// Inhomogeneous (sinusoidal-rate) Poisson process.
#[derive(Debug, Clone)]
pub struct InhomPoisson {
    /// amplitude A
    pub a: f64,
    /// baseline b (≥ 1 keeps the intensity positive)
    pub b: f64,
    /// frequency ω
    pub omega: f64,
}

impl InhomPoisson {
    /// λ(t) = A·(b + sin(ωπt)).
    pub fn new(a: f64, b: f64, omega: f64) -> InhomPoisson {
        assert!(b >= 1.0, "intensity must stay positive (b ≥ 1)");
        InhomPoisson { a, b, omega }
    }

    #[inline]
    fn lambda(&self, t: f64) -> f64 {
        self.a * (self.b + (self.omega * std::f64::consts::PI * t).sin())
    }

    /// Λ(t) = A·(b·t + (1 − cos(ωπt))/(ωπ)); Λ(0) = 0.
    #[inline]
    fn big_lambda(&self, t: f64) -> f64 {
        let w = self.omega * std::f64::consts::PI;
        self.a * (self.b * t + (1.0 - (w * t).cos()) / w)
    }
}

impl GroundTruth for InhomPoisson {
    fn num_types(&self) -> usize {
        1
    }

    fn total_intensity(&self, t: f64, _history: &[Event]) -> f64 {
        self.lambda(t)
    }

    fn integrated_total(&self, a: f64, b: f64, _history: &[Event]) -> f64 {
        self.big_lambda(b) - self.big_lambda(a)
    }

    fn loglik(&self, events: &[Event], t_end: f64) -> f64 {
        let sum_log: f64 = events.iter().map(|e| self.lambda(e.t).max(1e-12).ln()).sum();
        sum_log - self.big_lambda(t_end)
    }

    fn simulate(&self, rng: &mut Rng, t_end: f64) -> Vec<Event> {
        let lam_bar = self.a * (self.b + 1.0);
        let mut t = 0.0;
        let mut out = Vec::new();
        loop {
            t += rng.exponential(lam_bar);
            if t > t_end {
                return out;
            }
            if rng.uniform() * lam_bar < self.lambda(t) {
                out.push(Event::new(t, 0));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::checker::close;

    fn proc() -> InhomPoisson {
        InhomPoisson::new(5.0, 1.0, 1.0 / 50.0)
    }

    #[test]
    fn integral_matches_numeric() {
        let p = proc();
        let (a, b) = (3.0, 47.0);
        let n = 200_000;
        let dt = (b - a) / n as f64;
        let num: f64 = (0..n)
            .map(|i| p.lambda(a + (i as f64 + 0.5) * dt) * dt)
            .sum();
        close(p.integrated_total(a, b, &[]), num, 1e-6, "Λ(a,b)").unwrap();
    }

    #[test]
    fn expected_count_matches_big_lambda() {
        let p = proc();
        let mut rng = Rng::new(11);
        let t_end = 100.0;
        let n_seq = 200;
        let mean =
            (0..n_seq).map(|_| p.simulate(&mut rng, t_end).len()).sum::<usize>() as f64
                / n_seq as f64;
        let want = p.big_lambda(t_end);
        assert!(
            (mean - want).abs() < 3.0 * (want / n_seq as f64).sqrt() + 1.0,
            "mean={mean} want={want}"
        );
    }

    #[test]
    fn rescaled_intervals_are_exp1() {
        // Time-rescaling sanity: mean and variance of z ≈ 1.
        let p = proc();
        let mut rng = Rng::new(5);
        let mut zs = Vec::new();
        for _ in 0..20 {
            let ev = p.simulate(&mut rng, 100.0);
            zs.extend(p.rescale(&ev));
        }
        let mean = crate::util::math::mean(&zs);
        let sd = crate::util::math::std_dev(&zs);
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
        assert!((sd - 1.0).abs() < 0.08, "sd={sd}");
    }

    #[test]
    fn loglik_prefers_truth() {
        // The true parameters should beat perturbed ones on average.
        let p = proc();
        let wrong = InhomPoisson::new(6.5, 1.0, 1.0 / 50.0);
        let mut rng = Rng::new(2);
        let mut diff = 0.0;
        for _ in 0..20 {
            let ev = p.simulate(&mut rng, 100.0);
            diff += p.loglik(&ev, 100.0) - wrong.loglik(&ev, 100.0);
        }
        assert!(diff > 0.0, "diff={diff}");
    }
}
