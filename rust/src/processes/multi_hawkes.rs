//! Multivariate exponential Hawkes process (paper App. B.1):
//! λ_j(t) = μ_j + Σ_i α_{ji} S_i(t),  S_i(t) = Σ_{t^i_k < t} e^{−β(t−t^i_k)}.
//!
//! α is indexed `[effect][cause]`; a single shared decay β (as in the
//! paper's Multi-Hawkes dataset and our simulated real-data stand-ins).

use super::GroundTruth;
use crate::events::Event;
use crate::util::rng::Rng;

/// K-dimensional exponential Hawkes process with shared decay.
#[derive(Debug, Clone)]
pub struct MultiHawkes {
    /// per-type base rates μ_j
    pub mu: Vec<f64>,
    /// α[effect][cause]
    pub alpha: Vec<Vec<f64>>,
    /// shared excitation decay β
    pub beta: f64,
}

impl MultiHawkes {
    /// Subcritical process (column sums of α must stay below β).
    pub fn new(mu: Vec<f64>, alpha: Vec<Vec<f64>>, beta: f64) -> MultiHawkes {
        let k = mu.len();
        assert!(alpha.len() == k && alpha.iter().all(|r| r.len() == k));
        // crude subcriticality check: column sums / β < 1
        for c in 0..k {
            let col: f64 = (0..k).map(|e| alpha[e][c]).sum();
            assert!(col / beta < 1.0, "supercritical column {c}");
        }
        MultiHawkes { mu, alpha, beta }
    }

    /// Number of event types K.
    pub fn k(&self) -> usize {
        self.mu.len()
    }

    /// Per-cause decay states at time t (history strictly before t).
    fn decay_states(&self, t: f64, history: &[Event]) -> Vec<f64> {
        let mut s = vec![0.0; self.k()];
        for e in history {
            s[e.k as usize] += (-self.beta * (t - e.t)).exp();
        }
        s
    }

    /// Per-type intensities given decay states.
    fn lambda_vec(&self, s: &[f64]) -> Vec<f64> {
        (0..self.k())
            .map(|j| self.mu[j] + self.alpha[j].iter().zip(s).map(|(a, x)| a * x).sum::<f64>())
            .collect()
    }
}

impl GroundTruth for MultiHawkes {
    fn num_types(&self) -> usize {
        self.k()
    }

    fn total_intensity(&self, t: f64, history: &[Event]) -> f64 {
        self.lambda_vec(&self.decay_states(t, history)).iter().sum()
    }

    fn integrated_total(&self, a: f64, b: f64, history: &[Event]) -> f64 {
        let s_a = self.decay_states(a, history);
        let mu_total: f64 = self.mu.iter().sum();
        // Σ_j Σ_i α_{ji} ∫ S_i = Σ_i colsum_i · (S_i(a)/β)(1 − e^{−βΔ})
        let decay = 1.0 - (-self.beta * (b - a)).exp();
        let mut exc = 0.0;
        for c in 0..self.k() {
            let col: f64 = (0..self.k()).map(|e| self.alpha[e][c]).sum();
            exc += col * s_a[c] / self.beta * decay;
        }
        mu_total * (b - a) + exc
    }

    fn loglik(&self, events: &[Event], t_end: f64) -> f64 {
        let k = self.k();
        let mut s = vec![0.0; k];
        let mut prev = 0.0;
        let mut ll = 0.0;
        for e in events {
            let d = (-self.beta * (e.t - prev)).exp();
            for x in &mut s {
                *x *= d;
            }
            let j = e.k as usize;
            let lam_j =
                self.mu[j] + self.alpha[j].iter().zip(&s).map(|(a, x)| a * x).sum::<f64>();
            ll += lam_j.max(1e-12).ln();
            s[j] += 1.0;
            prev = e.t;
        }
        let mut comp: f64 = self.mu.iter().sum::<f64>() * t_end;
        for e in events {
            let col: f64 = (0..k).map(|eff| self.alpha[eff][e.k as usize]).sum();
            comp += col / self.beta * (1.0 - (-self.beta * (t_end - e.t)).exp());
        }
        ll - comp
    }

    fn simulate(&self, rng: &mut Rng, t_end: f64) -> Vec<Event> {
        let k = self.k();
        let mut s = vec![0.0; k];
        let mut t = 0.0;
        let mut out = Vec::new();
        loop {
            let lam_vec = self.lambda_vec(&s);
            let lam_bar: f64 = lam_vec.iter().sum();
            let t_next = t + rng.exponential(lam_bar);
            if t_next > t_end {
                return out;
            }
            let d = (-self.beta * (t_next - t)).exp();
            for x in &mut s {
                *x *= d;
            }
            let lam_vec = self.lambda_vec(&s);
            let lam: f64 = lam_vec.iter().sum();
            t = t_next;
            if rng.uniform() * lam_bar < lam {
                let j = rng.categorical(&lam_vec);
                out.push(Event::new(t, j as u32));
                s[j] += 1.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::checker::close;
    use crate::util::math::{mean, std_dev};

    /// The paper's Multi-Hawkes dataset parameters.
    fn proc() -> MultiHawkes {
        MultiHawkes::new(
            vec![0.4, 0.4],
            vec![vec![1.0, 0.5], vec![0.1, 1.0]],
            2.0,
        )
    }

    #[test]
    fn integrated_matches_numeric() {
        let p = proc();
        let hist = vec![
            Event::new(0.3, 0),
            Event::new(0.9, 1),
            Event::new(1.4, 0),
            Event::new(2.2, 1),
        ];
        let (a, b) = (2.5, 5.0);
        let n = 400_000;
        let dt = (b - a) / n as f64;
        let num: f64 = (0..n)
            .map(|i| p.total_intensity(a + (i as f64 + 0.5) * dt, &hist) * dt)
            .sum();
        close(p.integrated_total(a, b, &hist), num, 1e-6, "Λ").unwrap();
    }

    #[test]
    fn stationary_rate_matches_branching_theory() {
        // rate = (I − A/β)^{-1} μ for A = α matrix.
        // A/β = [[.5,.25],[.05,.5]]; solve (I−B) r = μ.
        // (I−B) = [[.5,−.25],[−.05,.5]]; det = .25 − .0125 = .2375
        // r = 1/det · [[.5,.25],[.05,.5]] μ = ([.3/.2375], [.22/.2375])
        let want_total = (0.5 * 0.4 + 0.25 * 0.4 + 0.05 * 0.4 + 0.5 * 0.4) / 0.2375;
        let p = proc();
        let mut rng = Rng::new(8);
        let t_end = 300.0;
        let runs = 30;
        let rate = (0..runs)
            .map(|_| p.simulate(&mut rng, t_end).len() as f64 / t_end)
            .sum::<f64>()
            / runs as f64;
        assert!((rate - want_total).abs() < 0.15, "rate={rate} want={want_total}");
    }

    #[test]
    fn rescaled_intervals_are_exp1() {
        let p = proc();
        let mut rng = Rng::new(12);
        let mut zs = Vec::new();
        for _ in 0..10 {
            let ev = p.simulate(&mut rng, 150.0);
            zs.extend(p.rescale(&ev));
        }
        assert!((mean(&zs) - 1.0).abs() < 0.06, "mean={}", mean(&zs));
        assert!((std_dev(&zs) - 1.0).abs() < 0.1, "sd={}", std_dev(&zs));
    }

    #[test]
    fn type_marginals_nontrivial() {
        // dim 0 receives more excitation → more events of type 0.
        let p = proc();
        let mut rng = Rng::new(13);
        let ev = p.simulate(&mut rng, 400.0);
        let n0 = ev.iter().filter(|e| e.k == 0).count();
        let n1 = ev.len() - n0;
        assert!(n0 > n1, "n0={n0} n1={n1}");
    }

    #[test]
    fn from_json_roundtrip() {
        let j = crate::util::json::Json::parse(
            r#"{"kind":"multihawkes","params":{"mu":[0.4,0.4],
               "alpha":[[1.0,0.5],[0.1,1.0]],"beta":2.0}}"#,
        )
        .unwrap();
        let p = crate::processes::from_dataset_json(&j).unwrap();
        assert_eq!(p.num_types(), 2);
        let ll = p.loglik(&[Event::new(1.0, 0), Event::new(2.0, 1)], 10.0);
        assert!(ll.is_finite());
    }
}
