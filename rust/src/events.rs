//! Core event type shared by processes, samplers, metrics and coordinator.

/// One marked event: absolute time `t` and type `k ∈ [0, K)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// absolute event time
    pub t: f64,
    /// event type (mark)
    pub k: u32,
}

impl Event {
    /// Construct an event at time `t` with type `k`.
    pub fn new(t: f64, k: u32) -> Event {
        Event { t, k }
    }
}

/// Inter-event intervals of a sorted event sequence (τ₁ = t₁ − t₀ with
/// t₀ = 0 by convention).
pub fn intervals(events: &[Event]) -> Vec<f64> {
    let mut prev = 0.0;
    events
        .iter()
        .map(|e| {
            let tau = e.t - prev;
            prev = e.t;
            tau
        })
        .collect()
}

/// True if times are strictly increasing and within (0, t_end].
pub fn is_valid_sequence(events: &[Event], t_end: f64) -> bool {
    let mut prev = 0.0;
    for e in events {
        if !(e.t > prev) || e.t > t_end {
            return false;
        }
        prev = e.t;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intervals_basic() {
        let ev = vec![Event::new(1.0, 0), Event::new(2.5, 1), Event::new(4.0, 0)];
        assert_eq!(intervals(&ev), vec![1.0, 1.5, 1.5]);
    }

    #[test]
    fn validity() {
        let ok = vec![Event::new(0.5, 0), Event::new(1.0, 0)];
        assert!(is_valid_sequence(&ok, 2.0));
        assert!(!is_valid_sequence(&ok, 0.9));
        let bad = vec![Event::new(1.0, 0), Event::new(1.0, 0)];
        assert!(!is_valid_sequence(&bad, 2.0));
    }
}
