//! TPP-SD: Accelerating Transformer Point Process Sampling with Speculative
//! Decoding (NeurIPS 2025) — Rust coordinator (Layer 3).
//!
//! See `rust/DESIGN.md` for the full architecture (the L1/L2/L3 layer
//! diagram is in §2): Pallas kernels (L1) and the JAX CDF-Transformer TPP
//! (L2) are AOT-compiled at build time to HLO text; this crate owns
//! everything on the request path — AR sampling, speculative decoding,
//! ground-truth processes, metrics and the serving coordinator.
//!
//! Inference is pluggable behind the [`runtime::Backend`] seam (DESIGN.md
//! §5): the default build runs the pure-Rust [`runtime::NativeBackend`]
//! (no artifacts, no system deps); `--features xla` adds the PJRT executor
//! that loads the AOT artifacts.

#![warn(missing_docs)]

pub mod bench;
pub mod coordinator;
pub mod events;
pub mod metrics;
pub mod model;
pub mod processes;
pub mod runtime;
pub mod sampler;
pub mod telemetry;
pub mod util;

pub use events::Event;

/// Crate version string.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
