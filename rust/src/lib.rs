//! TPP-SD: Accelerating Transformer Point Process Sampling with Speculative
//! Decoding (NeurIPS 2025) — Rust coordinator (Layer 3).
//!
//! See `DESIGN.md` for the full architecture: Pallas kernels (L1) and the
//! JAX CDF-Transformer TPP (L2) are AOT-compiled at build time to HLO text;
//! this crate loads them via PJRT and owns everything on the request path —
//! AR sampling, speculative decoding, ground-truth processes, metrics and
//! the serving coordinator.

pub mod bench;
pub mod coordinator;
pub mod events;
pub mod metrics;
pub mod model;
pub mod processes;
pub mod runtime;
pub mod sampler;
pub mod util;

pub use events::Event;

/// Crate version string.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
