//! Kolmogorov–Smirnov machinery (paper App. A.4): KS statistic of
//! time-rescaled intervals against Exp(1), 95% confidence bands, and the
//! KS-plot point series of Figures 2/4.

/// Exp(1) CDF.
#[inline]
pub fn exp1_cdf(z: f64) -> f64 {
    1.0 - (-z.max(0.0)).exp()
}

/// Two-sided KS statistic of `samples` against a CDF `f`.
/// D = sup_x |F_n(x) − F(x)| computed exactly at the jump points.
pub fn ks_statistic(samples: &[f64], f: impl Fn(f64) -> f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len() as f64;
    let mut d: f64 = 0.0;
    for (i, x) in xs.iter().enumerate() {
        let fx = f(*x);
        d = d.max((((i + 1) as f64) / n - fx).abs());
        d = d.max((fx - (i as f64) / n).abs());
    }
    d
}

/// KS statistic of rescaled intervals vs Exp(1) (Theorem 2).
pub fn ks_vs_exp1(z: &[f64]) -> f64 {
    ks_statistic(z, exp1_cdf)
}

/// 95% confidence band half-width c(α)/√n with c(0.05) = 1.36 (Knuth).
pub fn ks_band(n: usize) -> f64 {
    1.36 / (n as f64).sqrt()
}

/// Reject H₀: F_n = Exp(1) at the 95% level?
pub fn ks_reject(z: &[f64]) -> bool {
    ks_vs_exp1(z) > ks_band(z.len())
}

/// KS-plot series: points (F(z_i), F_n(z_i)) on the unit square (Fig. 2/4);
/// perfect sampling lies on the diagonal.
pub fn ks_plot_points(z: &[f64]) -> Vec<(f64, f64)> {
    let mut xs = z.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len() as f64;
    xs.iter()
        .enumerate()
        .map(|(i, x)| (exp1_cdf(*x), (i + 1) as f64 / n))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ks_zero_for_perfect_grid() {
        // quantile grid has the minimal possible D = 1/(2n)
        let n = 1000;
        let z: Vec<f64> = (0..n)
            .map(|i| -(1.0 - (i as f64 + 0.5) / n as f64).ln())
            .collect();
        assert!(ks_vs_exp1(&z) <= 0.5 / n as f64 + 1e-9);
    }

    #[test]
    fn exp1_samples_pass_wrong_dist_fails() {
        let mut rng = Rng::new(77);
        let z: Vec<f64> = (0..5000).map(|_| rng.exponential(1.0)).collect();
        assert!(!ks_reject(&z), "true Exp(1) rejected: D={}", ks_vs_exp1(&z));
        let z2: Vec<f64> = (0..5000).map(|_| rng.exponential(1.3)).collect();
        assert!(ks_reject(&z2), "Exp(1.3) not rejected");
    }

    #[test]
    fn plot_points_monotone_on_diag() {
        let mut rng = Rng::new(1);
        let z: Vec<f64> = (0..2000).map(|_| rng.exponential(1.0)).collect();
        let pts = ks_plot_points(&z);
        let band = ks_band(z.len());
        let mut prev = (0.0, 0.0);
        for (x, y) in pts {
            assert!(x >= prev.0 && y >= prev.1);
            assert!((y - x).abs() <= band * 1.6, "({x},{y}) off-diagonal");
            prev = (x, y);
        }
    }

    #[test]
    fn band_shrinks_with_n() {
        assert!(ks_band(100) > ks_band(10_000));
        assert!((ks_band(10_000) - 0.0136).abs() < 1e-12);
    }
}
