//! 1-D optimal transport distances (paper §5.1, Table 2): the 1-Wasserstein
//! distance between empirical samples (`D_WS^t`, continuous times) and the
//! earth mover's distance between event-type distributions (`D_WS^k`).
//!
//! In one dimension both are exact CDF formulas — the paper's POT calls
//! (`ot.wasserstein_1d`, `ot.emd2` with |i−j| ground cost) reduce to the
//! same quantities, so no generic OT solver is needed (DESIGN.md §3).

/// W₁ between two empirical distributions: ∫ |F_a⁻¹(q) − F_b⁻¹(q)| dq.
/// Handles unequal sample counts by integrating over merged quantile
/// breakpoints; for equal n it reduces to mean |sorted_a − sorted_b|.
pub fn wasserstein_1d(a: &[f64], b: &[f64]) -> f64 {
    assert!(!a.is_empty() && !b.is_empty());
    let mut xa = a.to_vec();
    let mut xb = b.to_vec();
    xa.sort_by(|x, y| x.partial_cmp(y).unwrap());
    xb.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let (na, nb) = (xa.len(), xb.len());
    let (mut ia, mut ib) = (0usize, 0usize);
    let mut q = 0.0;
    let mut acc = 0.0;
    while ia < na && ib < nb {
        let qa = (ia + 1) as f64 / na as f64;
        let qb = (ib + 1) as f64 / nb as f64;
        let q_next = qa.min(qb);
        acc += (q_next - q) * (xa[ia] - xb[ib]).abs();
        q = q_next;
        if qa <= q_next + 1e-15 {
            ia += 1;
        }
        if qb <= q_next + 1e-15 {
            ib += 1;
        }
    }
    acc
}

/// EMD between two discrete distributions over ordered types 0..K with
/// ground cost |i − j|: Σ_k |CDF_a(k) − CDF_b(k)|.
pub fn emd_types(pa: &[f64], pb: &[f64]) -> f64 {
    assert_eq!(pa.len(), pb.len());
    let mut ca = 0.0;
    let mut cb = 0.0;
    let mut acc = 0.0;
    for (x, y) in pa.iter().zip(pb) {
        ca += x;
        cb += y;
        acc += (ca - cb).abs();
    }
    acc
}

/// Empirical type distribution over `k` types from labels.
pub fn type_histogram(labels: &[u32], k: usize) -> Vec<f64> {
    let mut h = vec![0.0; k];
    for &l in labels {
        h[(l as usize).min(k - 1)] += 1.0;
    }
    let n = labels.len().max(1) as f64;
    for x in &mut h {
        *x /= n;
    }
    h
}

/// EMD between two label samples over `k` types (the paper's `D_WS^k`).
pub fn emd_labels(a: &[u32], b: &[u32], k: usize) -> f64 {
    emd_types(&type_histogram(a, k), &type_histogram(b, k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::checker::{check, close};
    use crate::util::rng::Rng;

    #[test]
    fn identical_samples_zero() {
        let a = [1.0, 3.0, 2.0];
        assert_eq!(wasserstein_1d(&a, &a), 0.0);
    }

    #[test]
    fn translation_equals_shift() {
        let a = [0.0, 1.0, 2.0, 5.0];
        let b: Vec<f64> = a.iter().map(|x| x + 2.5).collect();
        close(wasserstein_1d(&a, &b), 2.5, 1e-12, "shift").unwrap();
    }

    #[test]
    fn equal_n_reduces_to_sorted_mean_abs_diff() {
        let mut rng = Rng::new(3);
        let a: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..50).map(|_| rng.normal() + 0.3).collect();
        let mut sa = a.clone();
        let mut sb = b.clone();
        sa.sort_by(|x, y| x.partial_cmp(y).unwrap());
        sb.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let want: f64 =
            sa.iter().zip(&sb).map(|(x, y)| (x - y).abs()).sum::<f64>() / 50.0;
        close(wasserstein_1d(&a, &b), want, 1e-12, "equal-n").unwrap();
    }

    #[test]
    fn unequal_n_matches_subdivision() {
        // W1({0,1}, {0,0.5,1}) via quantile integral
        let a = [0.0, 1.0];
        let b = [0.0, 0.5, 1.0];
        // breakpoints: q∈(0,1/3]:|0-0|, (1/3,1/2]:|1-0.5|... compute directly
        let got = wasserstein_1d(&a, &b);
        // integral: q in (1/3,1/2): |F_a^{-1}=0? (q<=1/2 → a=0)|
        // a-quantiles: 0 for q≤.5, 1 for q>.5; b: 0 q≤1/3, .5 q≤2/3, 1 else
        // ∫ = (1/3..1/2):|0-.5| * 1/6 + (1/2..2/3):|1-.5| *1/6 + 0 elsewhere
        let want = 0.5 / 6.0 + 0.5 / 6.0;
        close(got, want, 1e-12, "unequal").unwrap();
    }

    #[test]
    fn property_metric_axioms() {
        check(
            "W1 symmetry + triangle-ish",
            30,
            |r| {
                let n = 5 + r.below(20);
                let m = 5 + r.below(20);
                let a: Vec<f64> = (0..n).map(|_| r.normal()).collect();
                let b: Vec<f64> = (0..m).map(|_| r.normal() * 2.0).collect();
                (a, b)
            },
            |(a, b)| {
                let d1 = wasserstein_1d(a, b);
                let d2 = wasserstein_1d(b, a);
                if d1 < 0.0 {
                    return Err("negative".into());
                }
                close(d1, d2, 1e-9, "symmetry")
            },
        );
    }

    #[test]
    fn emd_types_basics() {
        let p = [1.0, 0.0, 0.0];
        let q = [0.0, 0.0, 1.0];
        // all mass moves distance 2
        close(emd_types(&p, &q), 2.0, 1e-12, "corner").unwrap();
        assert_eq!(emd_types(&p, &p), 0.0);
    }

    #[test]
    fn emd_labels_and_histograms() {
        let a = [0u32, 0, 1, 1];
        let b = [0u32, 1, 1, 1];
        let h = type_histogram(&a, 2);
        close(h[0], 0.5, 1e-12, "hist").unwrap();
        close(emd_labels(&a, &b, 2), 0.25, 1e-12, "emd").unwrap();
    }
}
