//! Evaluation metrics of §5.1: KS statistic + bands (synthetic),
//! 1-Wasserstein / EMD (real), and model/ground-truth likelihood
//! discrepancies.

pub mod ks;
pub mod loglik;
pub mod wasserstein;

pub use ks::{ks_band, ks_plot_points, ks_reject, ks_vs_exp1};
pub use loglik::{delta_l, model_loglik};
pub use wasserstein::{emd_labels, emd_types, type_histogram, wasserstein_1d};
