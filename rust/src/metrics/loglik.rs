//! Model log-likelihood Eq. (2) evaluated from AOT forward outputs, plus
//! the likelihood-discrepancy metrics ΔL of §5.1.
//!
//! Sequences longer than the largest compiled bucket are scored in chunks
//! with a fixed-size context prefix — the same sliding-window approximation
//! the samplers use, applied identically to AR- and SD-generated sequences
//! so the discrepancy comparison stays fair.

use anyhow::Result;

use crate::events::Event;
use crate::runtime::{Forward, SeqInput};

/// Events scored per forward chunk / context carried between chunks.
const CHUNK: usize = 256;
const PREFIX: usize = 128;

/// Eq. (2): Σ_i [log g(τ_i|h) + log f(k_i|h)] + log(1 − G(T − t_N | h_N)).
pub fn model_loglik<F: Forward + ?Sized>(
    exec: &F,
    events: &[Event],
    num_types: usize,
    t_end: f64,
) -> Result<f64> {
    let max_cap = exec.max_bucket();
    assert!(PREFIX + CHUNK + 1 <= max_cap, "chunking exceeds bucket");
    let n = events.len();
    let mut ll = 0.0;

    let mut s = 0usize;
    loop {
        let e = (s + CHUNK).min(n);
        let p0 = s.saturating_sub(PREFIX);
        let t0 = if p0 == 0 { 0.0 } else { events[p0 - 1].t };
        let seq: Vec<Event> = events[p0..e].to_vec();
        let prefix_len = s - p0;
        let input = SeqInput {
            t0,
            times: seq.iter().map(|ev| ev.t).collect(),
            types: seq.iter().map(|ev| ev.k).collect(),
        };
        let fwd = exec.forward1(input)?;
        for i in 0..(e - s) {
            let idx = s + i; // global event index
            let row = prefix_len + i;
            let prev_t = if idx == 0 { 0.0 } else { events[idx - 1].t };
            let tau = events[idx].t - prev_t;
            ll += fwd.mixture(row).logpdf(tau);
            ll += fwd
                .type_dist(row, num_types)
                .pmf(events[idx].k as usize)
                .max(1e-300)
                .ln();
        }
        if e == n {
            // survival term from the row after the last event
            let row = prefix_len + (e - s);
            let t_last = if n == 0 { 0.0 } else { events[n - 1].t };
            ll += fwd.mixture(row).log_survival(t_end - t_last);
            break;
        }
        s = e;
    }
    Ok(ll)
}

/// Per-event-normalized likelihood discrepancy |la − lb| / n, the form in
/// which Table 1/2 report ΔL (per-event so sequence length cancels).
pub fn delta_l(la: f64, lb: f64, n_events: usize) -> f64 {
    (la - lb).abs() / n_events.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_normalizes() {
        assert_eq!(delta_l(10.0, 4.0, 3), 2.0);
        assert_eq!(delta_l(4.0, 10.0, 3), 2.0);
        assert_eq!(delta_l(1.0, 0.0, 0), 1.0);
    }
}
