//! `tppsd` — the leader CLI.
//!
//! Subcommands:
//!   serve   — start the sampling coordinator (TCP line protocol)
//!   proxy   — start the shard tier over N serve replicas
//!   sample  — sample sequences from a model (ar | sd | sd-adaptive)
//!   info    — list backends, datasets and model configurations

use std::time::Duration;

use anyhow::{bail, Result};
use tpp_sd::coordinator::{ProxyServer, RetryPolicy, SchedulerCfg, Server, ShardCfg};
use tpp_sd::runtime::{backend_from_arg, Backend, ChaosBackend, FaultPlan, Uncached};
use tpp_sd::sampler::{
    fleet_seeds, sample_ar_fleet, sample_sd_fleet, FleetRuns, Gamma, SampleCfg, SampleStats, SdCfg,
};
use tpp_sd::util::cli::Args;
use tpp_sd::Event;

const USAGE: &str = "\
tppsd — TPP-SD sampling coordinator

usage: tppsd <command> [options]

commands:
  info                              list datasets / models of the backend
  sample  --dataset D --encoder E   sample sequences and print them
          [--method ar|sd|sd-adaptive] [--gamma 10] [--t-end 30]
          [--seed 0] [--draft-size draft] [--csv]
          [--parallel 1]            sequences driven in lockstep on the
                                    fleet engine; sequence i is seeded
                                    seed+i, bit-for-bit what --parallel 1
                                    with that seed would print
          [--gamma-min 2] [--gamma-max 4γ]
                                    clamps of the sd-adaptive draft length
          [--uncached]              force full-window forwards even when
                                    the backend has incremental streams
                                    (A/B knob; events are bit-identical)
          [--chaos spec]            inject deterministic faults, e.g.
                                    'seed=7,err=0.2,loss=0.1' (keys: seed,
                                    err, delay, delay-ms, loss, pad, die);
                                    recoverable plans print the same
                                    events as a fault-free run
          [--metrics]               print the per-stage latency /
                                    acceptance telemetry report to stderr
                                    at the end of the run
  serve   [--listen 127.0.0.1:7077]  start the sampling coordinator
          [--max-batch 8]           largest batch an executor coalesces
          [--batch-window-ms 2]     how long an executor waits to co-batch
          [--max-live 64]           scheduler cap on co-resident sessions;
                                    a request whose sessions can never fit
                                    is shed with err=overloaded
          [--queue-depth 128]       bound on the pending admission queue;
                                    submits past it are shed, not queued
          (wire protocol and every knob: docs/OPERATIONS.md)
  proxy   --backend host:port [--backend host:port ...]
                                    shard tier: same wire protocol as
                                    serve, routed across N replicas
                                    (repeatable; commas also split)
          [--listen 127.0.0.1:7078] proxy listen address
          [--health-interval-ms 250] period of the background ping prober
          [--eject-after 3]         consecutive probe/transport failures
                                    that eject a replica; one successful
                                    probe re-admits it
          [--failover-attempts 4]   replicas tried per sample request
          [--failover-backoff-us 500] first failover backoff (doubles,
                                    capped at 100ms; spills don't back off)
          [--failover-deadline-ms 30000] total budget per sample request
          [--connect-timeout-ms 2000] bound on each upstream TCP dial
          (topology + aggregation semantics: docs/OPERATIONS.md)

options (all commands):
  --backend auto|native|xla         inference backend [auto]

environment:
  TPP_SD_BACKEND     backend when --backend is absent (default auto)
  TPP_SD_ARTIFACTS   artifact directory for the xla backend (./artifacts)
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_default();
    let args = Args::parse(argv.into_iter().skip(1));
    match cmd.as_str() {
        "info" => info(&args),
        "sample" => sample(&args),
        "serve" => serve(&args),
        "proxy" => proxy(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

/// Resolve the backend from `--backend`, falling back to the environment.
fn pick_backend(args: &Args) -> Result<std::sync::Arc<dyn Backend>> {
    backend_from_arg(args.get("backend"))
}

fn info(args: &Args) -> Result<()> {
    let backend = pick_backend(args)?;
    println!("backend: {}", backend.name());
    println!("datasets:");
    for name in backend.datasets() {
        let spec = backend.dataset_spec(&name)?;
        println!(
            "  {:<18} kind={:<12} K={}",
            name,
            spec.str_at("kind").unwrap_or("?"),
            backend.num_types(&name).unwrap_or(0)
        );
    }
    println!("model sizes: target | draft | draft2 | draft3");
    println!("encoders:    thp | sahp | attnhp");
    Ok(())
}

fn sample(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "hawkes").to_string();
    let encoder = args.str_or("encoder", "attnhp").to_string();
    let method = args.str_or("method", "sd").to_string();
    let backend = pick_backend(args)?;
    // --chaos wraps the whole registry before any model loads, so every
    // forward below runs under the fault plan (DESIGN.md §13).
    let mut chaos_stats = None;
    let backend: std::sync::Arc<dyn Backend> = {
        let plan = FaultPlan::parse(args.str_or("chaos", ""))?;
        if plan.is_noop() {
            backend
        } else {
            let wrapped = std::sync::Arc::new(ChaosBackend::new(backend, plan));
            chaos_stats = Some(wrapped.stats());
            wrapped
        }
    };
    let num_types = backend.num_types(&dataset)?;
    let cfg = SampleCfg {
        num_types,
        t_end: args.f64_or("t-end", 30.0),
        max_events: args.usize_or("max-events", 16 * 1024),
    };
    let target = backend.load_model(&dataset, &encoder, "target")?;
    let seed = args.u64_or("seed", 0);
    let parallel = args.usize_or("parallel", 1).max(1);
    let gamma = args.usize_or("gamma", 10);
    let gamma_policy = if method == "sd-adaptive" {
        let min = args.usize_or("gamma-min", 2);
        let max = args.usize_or("gamma-max", 4 * gamma.max(1));
        if min > max {
            bail!("--gamma-min {min} exceeds --gamma-max {max}");
        }
        Gamma::Adaptive { init: gamma.clamp(min, max), min, max }
    } else {
        Gamma::Fixed(gamma)
    };
    // Load everything before the timer: wall/events-per-second must
    // measure sampling, not model loading (XLA loads compile artifacts).
    let draft = match method.as_str() {
        "ar" => None,
        "sd" | "sd-adaptive" => {
            Some(backend.load_model(&dataset, &encoder, args.str_or("draft-size", "draft"))?)
        }
        other => bail!("unknown method '{other}'"),
    };
    // The fleet path covers --parallel 1 too: fleet(N=1) is bit-for-bit
    // the blocking sampler (rust/tests/fleet.rs), so there is one code
    // path whatever N is.
    let seeds = fleet_seeds(seed, parallel);
    let uncached = args.has("uncached");
    let t0 = std::time::Instant::now();
    let (runs, fleet): (FleetRuns, _) = match &draft {
        None if uncached => sample_ar_fleet(&Uncached(&target), &cfg, &seeds)?,
        None => sample_ar_fleet(&target, &cfg, &seeds)?,
        Some(d) => {
            let sd = SdCfg { sample: cfg, gamma: gamma_policy, ..Default::default() };
            if uncached {
                sample_sd_fleet(&Uncached(&target), &Uncached(d), &sd, &seeds)?
            } else {
                sample_sd_fleet(&target, d, &sd, &seeds)?
            }
        }
    };
    let fleet_wall = t0.elapsed();
    if parallel > 1 {
        report_fleet(&runs, fleet.target_occupancy(), fleet_wall);
    }
    let many = runs.len() > 1;
    if args.has("csv") {
        println!("{}", if many { "seq,t,k" } else { "t,k" });
        for (i, (events, _)) in runs.iter().enumerate() {
            for e in events {
                if many {
                    println!("{i},{:.6},{}", e.t, e.k);
                } else {
                    println!("{:.6},{}", e.t, e.k);
                }
            }
        }
    } else {
        for (i, (events, _)) in runs.iter().enumerate() {
            if many {
                println!("# sequence {i} (seed {})", seed.wrapping_add(i as u64));
            }
            for e in events {
                println!("{:10.5}  {}", e.t, e.k);
            }
        }
    }
    let mut stats = SampleStats::default();
    for (_, st) in &runs {
        stats.merge(st);
    }
    // Sessions run in lockstep, so each session's own wall spans the whole
    // run — report the fleet's wall-clock, not the ~N-fold sum.
    eprintln!(
        "# {} events in {:?} ({} target + {} draft forwards, α={:.2})",
        stats.events,
        fleet_wall,
        stats.target_forwards,
        stats.draft_forwards,
        stats.acceptance_rate()
    );
    if let Some(cs) = chaos_stats {
        eprintln!(
            "# chaos: {} faults injected ({} errors, {} delays, {} losses, {} corruptions); {} streams recovered, {} sessions degraded uncached",
            cs.total(),
            cs.errors.load(std::sync::atomic::Ordering::Relaxed),
            cs.delays.load(std::sync::atomic::Ordering::Relaxed),
            cs.losses.load(std::sync::atomic::Ordering::Relaxed),
            cs.corruptions.load(std::sync::atomic::Ordering::Relaxed),
            fleet.stream_recoveries,
            fleet.degraded_uncached,
        );
    }
    if args.has("metrics") {
        eprintln!("{}", tpp_sd::telemetry::report());
    }
    Ok(())
}

/// One stderr line summarizing a fleet run's batching efficiency.
fn report_fleet(runs: &[(Vec<Event>, SampleStats)], occupancy: f64, wall: std::time::Duration) {
    let events: usize = runs.iter().map(|(ev, _)| ev.len()).sum();
    eprintln!(
        "# fleet: {} sequences, {events} events in {wall:?} ({:.0} events/s, target occupancy {occupancy:.2})",
        runs.len(),
        events as f64 / wall.as_secs_f64().max(1e-9),
    );
}

fn serve(args: &Args) -> Result<()> {
    let backend = pick_backend(args)?;
    let name = backend.name();
    let sched_cfg = SchedulerCfg::builder()
        .max_live(args.usize_or("max-live", 64))
        .queue_depth(args.usize_or("queue-depth", 128))
        .build();
    let server = Server::bind_with_scheduler(
        backend,
        args.str_or("listen", "127.0.0.1:7077"),
        args.usize_or("max-batch", 8),
        Duration::from_millis(args.u64_or("batch-window-ms", 2)),
        sched_cfg,
    )?;
    println!(
        "tppsd serving on {} (backend: {name}, max-live {}, queue-depth {})",
        server.addr, sched_cfg.max_live, sched_cfg.queue_depth
    );
    server.serve()
}

/// `tppsd proxy`: the shard tier — same wire protocol as `serve`, routed
/// across N replicas with health checks, spill and failover
/// (DESIGN.md §17, `docs/OPERATIONS.md`).
fn proxy(args: &Args) -> Result<()> {
    let backends = args.all("backend");
    if backends.is_empty() {
        bail!("proxy needs at least one --backend host:port (repeatable)");
    }
    let cfg = ShardCfg::builder()
        .health_interval(Duration::from_millis(args.u64_or("health-interval-ms", 250)))
        .eject_after(args.u64_or("eject-after", 3) as u32)
        .retry(RetryPolicy {
            max_attempts: args.usize_or("failover-attempts", 4),
            backoff: Duration::from_micros(args.u64_or("failover-backoff-us", 500)),
            deadline: Duration::from_millis(args.u64_or("failover-deadline-ms", 30_000)),
        })
        .connect_timeout(Duration::from_millis(args.u64_or("connect-timeout-ms", 2_000)))
        .build();
    let server = ProxyServer::bind(args.str_or("listen", "127.0.0.1:7078"), &backends, cfg)?;
    println!(
        "tppsd proxy on {} over {} backend(s): {}",
        server.addr,
        backends.len(),
        backends.join(", ")
    );
    server.serve()
}
