//! `tppsd` — the leader CLI.
//!
//! Subcommands:
//!   serve   — start the sampling coordinator (TCP line protocol)
//!   sample  — sample sequences from a trained model (ar | sd | sd-adaptive)
//!   info    — list artifacts, datasets and model configurations

use std::time::Duration;

use anyhow::{bail, Result};
use tpp_sd::coordinator::Server;
use tpp_sd::runtime::{ArtifactDir, ModelExecutor};
use tpp_sd::sampler::{sample_ar, sample_sd, Gamma, SampleCfg, SdCfg};
use tpp_sd::util::cli::Args;
use tpp_sd::util::json::Json;
use tpp_sd::util::rng::Rng;

const USAGE: &str = "\
tppsd — TPP-SD sampling coordinator

usage: tppsd <command> [options]

commands:
  info                              list datasets / models in the artifact dir
  sample  --dataset D --encoder E   sample one sequence and print it
          [--method ar|sd|sd-adaptive] [--gamma 10] [--t-end 30]
          [--seed 0] [--draft-size draft] [--csv]
  serve   [--listen 127.0.0.1:7077] [--max-batch 8] [--batch-window-ms 2]

environment:
  TPP_SD_ARTIFACTS   artifact directory (default ./artifacts)
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_default();
    let args = Args::parse(argv.into_iter().skip(1));
    match cmd.as_str() {
        "info" => info(),
        "sample" => sample(&args),
        "serve" => serve(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn info() -> Result<()> {
    let art = ArtifactDir::discover()?;
    let ds = art.datasets_json()?;
    println!("artifact dir: {}", art.root.display());
    println!("k_max={} buckets={:?}", ds.usize_at("k_max").unwrap_or(0),
        ds.get("buckets").map(|b| b.to_string()).unwrap_or_default());
    if let Some(sizes) = ds.get("sizes").and_then(Json::as_obj) {
        println!("model sizes:");
        for (name, s) in sizes {
            println!(
                "  {:<8} layers={} heads={} d_model={} M={}",
                name,
                s.usize_at("n_layers").unwrap_or(0),
                s.usize_at("n_heads").unwrap_or(0),
                s.usize_at("d_model").unwrap_or(0),
                s.usize_at("n_mix").unwrap_or(0)
            );
        }
    }
    if let Some(dss) = ds.get("datasets").and_then(Json::as_obj) {
        println!("datasets:");
        for (name, d) in dss {
            println!(
                "  {:<18} kind={:<12} K={}",
                name,
                d.str_at("kind").unwrap_or("?"),
                d.usize_at("num_types").unwrap_or(0)
            );
        }
    }
    Ok(())
}

fn sample(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "hawkes").to_string();
    let encoder = args.str_or("encoder", "attnhp").to_string();
    let method = args.str_or("method", "sd").to_string();
    let art = ArtifactDir::discover()?;
    let ds = art.datasets_json()?;
    let Some(num_types) = ds.usize_at(&format!("datasets.{dataset}.num_types")) else {
        bail!("unknown dataset '{dataset}' (see `tppsd info`)");
    };
    let cfg = SampleCfg {
        num_types,
        t_end: args.f64_or("t-end", 30.0),
        max_events: args.usize_or("max-events", 16 * 1024),
    };
    let client = tpp_sd::runtime::cpu_client()?;
    let target = ModelExecutor::load(client.clone(), &art, &dataset, &encoder, "target")?;
    let mut rng = Rng::new(args.u64_or("seed", 0));
    let gamma = args.usize_or("gamma", 10);
    let (events, stats) = match method.as_str() {
        "ar" => sample_ar(&target, &cfg, &mut rng)?,
        "sd" | "sd-adaptive" => {
            let draft = ModelExecutor::load(
                client,
                &art,
                &dataset,
                &encoder,
                args.str_or("draft-size", "draft"),
            )?;
            let g = if method == "sd" {
                Gamma::Fixed(gamma)
            } else {
                Gamma::Adaptive { init: gamma, min: 2, max: 4 * gamma.max(1) }
            };
            let sd = SdCfg { sample: cfg, gamma: g, ..Default::default() };
            sample_sd(&target, &draft, &sd, &mut rng)?
        }
        other => bail!("unknown method '{other}'"),
    };
    if args.has("csv") {
        println!("t,k");
        for e in &events {
            println!("{:.6},{}", e.t, e.k);
        }
    } else {
        for e in &events {
            println!("{:10.5}  {}", e.t, e.k);
        }
    }
    eprintln!(
        "# {} events in {:?} ({} target + {} draft forwards, α={:.2})",
        stats.events,
        stats.wall,
        stats.target_forwards,
        stats.draft_forwards,
        stats.acceptance_rate()
    );
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let art = ArtifactDir::discover()?;
    let server = Server::bind(
        art,
        args.str_or("listen", "127.0.0.1:7077"),
        args.usize_or("max-batch", 8),
        Duration::from_millis(args.u64_or("batch-window-ms", 2)),
    )?;
    println!("tppsd serving on {}", server.addr);
    server.serve()
}
