//! `tppsd` — the leader CLI.
//!
//! Subcommands:
//!   serve   — start the sampling coordinator (TCP line protocol)
//!   sample  — sample sequences from a model (ar | sd | sd-adaptive)
//!   info    — list backends, datasets and model configurations

use std::time::Duration;

use anyhow::{bail, Result};
use tpp_sd::coordinator::Server;
use tpp_sd::runtime::{backend_from_arg, Backend};
use tpp_sd::sampler::{sample_ar, sample_sd, Gamma, SampleCfg, SdCfg};
use tpp_sd::util::cli::Args;
use tpp_sd::util::rng::Rng;

const USAGE: &str = "\
tppsd — TPP-SD sampling coordinator

usage: tppsd <command> [options]

commands:
  info                              list datasets / models of the backend
  sample  --dataset D --encoder E   sample one sequence and print it
          [--method ar|sd|sd-adaptive] [--gamma 10] [--t-end 30]
          [--seed 0] [--draft-size draft] [--csv]
  serve   [--listen 127.0.0.1:7077] [--max-batch 8] [--batch-window-ms 2]

options (all commands):
  --backend auto|native|xla         inference backend [auto]

environment:
  TPP_SD_BACKEND     backend when --backend is absent (default auto)
  TPP_SD_ARTIFACTS   artifact directory for the xla backend (./artifacts)
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_default();
    let args = Args::parse(argv.into_iter().skip(1));
    match cmd.as_str() {
        "info" => info(&args),
        "sample" => sample(&args),
        "serve" => serve(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

/// Resolve the backend from `--backend`, falling back to the environment.
fn pick_backend(args: &Args) -> Result<std::sync::Arc<dyn Backend>> {
    backend_from_arg(args.get("backend"))
}

fn info(args: &Args) -> Result<()> {
    let backend = pick_backend(args)?;
    println!("backend: {}", backend.name());
    println!("datasets:");
    for name in backend.datasets() {
        let spec = backend.dataset_spec(&name)?;
        println!(
            "  {:<18} kind={:<12} K={}",
            name,
            spec.str_at("kind").unwrap_or("?"),
            backend.num_types(&name).unwrap_or(0)
        );
    }
    println!("model sizes: target | draft | draft2 | draft3");
    println!("encoders:    thp | sahp | attnhp");
    Ok(())
}

fn sample(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "hawkes").to_string();
    let encoder = args.str_or("encoder", "attnhp").to_string();
    let method = args.str_or("method", "sd").to_string();
    let backend = pick_backend(args)?;
    let num_types = backend.num_types(&dataset)?;
    let cfg = SampleCfg {
        num_types,
        t_end: args.f64_or("t-end", 30.0),
        max_events: args.usize_or("max-events", 16 * 1024),
    };
    let target = backend.load_model(&dataset, &encoder, "target")?;
    let mut rng = Rng::new(args.u64_or("seed", 0));
    let gamma = args.usize_or("gamma", 10);
    let (events, stats) = match method.as_str() {
        "ar" => sample_ar(&target, &cfg, &mut rng)?,
        "sd" | "sd-adaptive" => {
            let draft =
                backend.load_model(&dataset, &encoder, args.str_or("draft-size", "draft"))?;
            let g = if method == "sd" {
                Gamma::Fixed(gamma)
            } else {
                Gamma::Adaptive { init: gamma, min: 2, max: 4 * gamma.max(1) }
            };
            let sd = SdCfg { sample: cfg, gamma: g, ..Default::default() };
            sample_sd(&target, &draft, &sd, &mut rng)?
        }
        other => bail!("unknown method '{other}'"),
    };
    if args.has("csv") {
        println!("t,k");
        for e in &events {
            println!("{:.6},{}", e.t, e.k);
        }
    } else {
        for e in &events {
            println!("{:10.5}  {}", e.t, e.k);
        }
    }
    eprintln!(
        "# {} events in {:?} ({} target + {} draft forwards, α={:.2})",
        stats.events,
        stats.wall,
        stats.target_forwards,
        stats.draft_forwards,
        stats.acceptance_rate()
    );
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let backend = pick_backend(args)?;
    let name = backend.name();
    let server = Server::bind(
        backend,
        args.str_or("listen", "127.0.0.1:7077"),
        args.usize_or("max-batch", 8),
        Duration::from_millis(args.u64_or("batch-window-ms", 2)),
    )?;
    println!("tppsd serving on {} (backend: {name})", server.addr);
    server.serve()
}
