//! Log-normal mixture math over the decoder outputs (paper §4.2 / App. A.1).
//!
//! The AOT forward pass returns, per sequence position, the parameters of
//! `g(τ|h)` (mixture log-weights, means, log-scales) and the raw event-type
//! logits. Everything downstream — sampling, density evaluation, CDFs,
//! rejection tests — is cheap `O(M)`/`O(K)` math done here in Rust.

use crate::util::math::{logsumexp, norm_cdf, norm_logpdf};
use crate::util::rng::Rng;

/// Parameters of one position's inter-event-interval distribution
/// `g(τ|h) = Σ_m w_m LogNormal(τ; μ_m, σ_m)`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Mixture {
    /// normalized component log-weights
    pub log_w: Vec<f64>,
    /// component log-means μ_m
    pub mu: Vec<f64>,
    /// component log-scales ln σ_m
    pub log_sigma: Vec<f64>,
}

impl Mixture {
    /// Number of mixture components M.
    pub fn n_components(&self) -> usize {
        self.log_w.len()
    }

    /// Sample τ (App. A.1): pick component by weight, then exp of a normal.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let z = rng.categorical_logits(&self.log_w);
        let eps = rng.normal();
        (self.mu[z] + eps * self.log_sigma[z].exp()).exp()
    }

    /// log g(τ) — stable log-sum-exp over components. Allocation-free for
    /// mixtures of up to 8 components (a stack buffer; the native head has
    /// 2) — the verify loop calls this per candidate per proposal.
    pub fn logpdf(&self, tau: f64) -> f64 {
        let tau = tau.max(1e-300);
        let log_tau = tau.ln();
        let n = self.n_components();
        let comp = |m: usize| {
            let ls = self.log_sigma[m];
            let z = (log_tau - self.mu[m]) * (-ls).exp();
            self.log_w[m] - log_tau - ls + norm_logpdf(z)
        };
        if n <= 8 {
            let mut comps = [0f64; 8];
            for (m, c) in comps[..n].iter_mut().enumerate() {
                *c = comp(m);
            }
            logsumexp(&comps[..n])
        } else {
            let comps: Vec<f64> = (0..n).map(comp).collect();
            logsumexp(&comps)
        }
    }

    /// g(τ) — density (may underflow to 0 for extreme τ; callers use
    /// `logpdf` for ratios).
    pub fn pdf(&self, tau: f64) -> f64 {
        self.logpdf(tau).exp()
    }

    /// G(τ) = Σ_m w_m Φ((ln τ − μ_m)/σ_m).
    pub fn cdf(&self, tau: f64) -> f64 {
        if tau <= 0.0 {
            return 0.0;
        }
        let log_tau = tau.ln();
        (0..self.n_components())
            .map(|m| {
                let z = (log_tau - self.mu[m]) * (-self.log_sigma[m]).exp();
                self.log_w[m].exp() * norm_cdf(z)
            })
            .sum()
    }

    /// log(1 − G(τ)) — the survival term of Eq. (2), clamped for stability.
    pub fn log_survival(&self, tau: f64) -> f64 {
        (1.0 - self.cdf(tau)).max(1e-12).ln()
    }
}

/// Categorical event-type distribution from raw logits, restricted to the
/// first `k` real types of the `K_MAX`-padded head.
#[derive(Debug, Clone, Default)]
pub struct TypeDist {
    /// normalized probabilities over the first k types
    pub probs: Vec<f64>,
}

impl TypeDist {
    /// Softmax over the first `k` logits of a `K_MAX`-padded head.
    pub fn from_logits(logits: &[f64], k: usize) -> TypeDist {
        assert!(k >= 1 && k <= logits.len(), "k={k} logits={}", logits.len());
        let m = logits[..k].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut probs: Vec<f64> = logits[..k].iter().map(|l| (l - m).exp()).collect();
        let s: f64 = probs.iter().sum();
        for p in &mut probs {
            *p /= s;
        }
        TypeDist { probs }
    }

    /// [`TypeDist::from_logits`] over an `f32` logits row, refilling
    /// `self` in place (the backends' allocation-free read path). Same
    /// math on the same widened `f64` values, so the probabilities are
    /// bit-identical to collecting the row and calling `from_logits`.
    pub fn assign_from_logits_f32(&mut self, logits: &[f32], k: usize) {
        assert!(k >= 1 && k <= logits.len(), "k={k} logits={}", logits.len());
        let m = logits[..k]
            .iter()
            .map(|&l| l as f64)
            .fold(f64::NEG_INFINITY, f64::max);
        self.probs.clear();
        self.probs.extend(logits[..k].iter().map(|&l| (l as f64 - m).exp()));
        let s: f64 = self.probs.iter().sum();
        for p in &mut self.probs {
            *p /= s;
        }
    }

    /// Draw a type index.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        rng.categorical(&self.probs)
    }

    /// Probability of type `k`.
    pub fn pmf(&self, k: usize) -> f64 {
        self.probs[k]
    }

    /// Adjusted distribution `norm(max(0, p_T − p_D))` (paper Eq. 4).
    /// Falls back to the target distribution when the positive part is
    /// numerically empty (p_T ≤ p_D everywhere ⇒ p_T = p_D).
    pub fn adjusted(target: &TypeDist, draft: &TypeDist) -> TypeDist {
        assert_eq!(target.probs.len(), draft.probs.len());
        let mut probs: Vec<f64> = target
            .probs
            .iter()
            .zip(&draft.probs)
            .map(|(t, d)| (t - d).max(0.0))
            .collect();
        let s: f64 = probs.iter().sum();
        if s <= 1e-300 {
            return target.clone();
        }
        for p in &mut probs {
            *p /= s;
        }
        TypeDist { probs }
    }
}

/// Sample from the adjusted interval distribution
/// `g'(τ) = norm(max(0, g_T − g_D))` via Theorem 1's acceptance–rejection:
/// draw τ ~ g_T, accept w.p. `max(0, g_T(τ) − g_D(τ)) / g_T(τ)`.
///
/// The expected number of proposals is `1/(1−β)` where β is the overlap;
/// a draw cap guards the (measure-zero in practice) g_T ≈ g_D case, where
/// falling back to g_T is exact in the limit.
pub fn sample_adjusted_interval(
    target: &Mixture,
    draft: &Mixture,
    rng: &mut Rng,
    max_tries: usize,
) -> (f64, usize) {
    let mut tries = 0;
    loop {
        tries += 1;
        let tau = target.sample(rng);
        let lt = target.logpdf(tau);
        let ld = draft.logpdf(tau);
        // α = max(0, g_T − g_D)/g_T = max(0, 1 − exp(ld − lt))
        let alpha = 1.0 - (ld - lt).exp();
        if alpha > 0.0 && rng.uniform() < alpha {
            return (tau, tries);
        }
        if tries >= max_tries {
            // g_T ≈ g_D: adjusted dist degenerates; g_T itself is correct.
            return (tau, tries);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::checker::{check, close};

    fn mix(log_w: &[f64], mu: &[f64], sig: &[f64]) -> Mixture {
        let z = logsumexp(log_w);
        Mixture {
            log_w: log_w.iter().map(|l| l - z).collect(),
            mu: mu.to_vec(),
            log_sigma: sig.iter().map(|s| s.ln()).collect(),
        }
    }

    #[test]
    fn single_lognormal_pdf_matches_closed_form() {
        let m = mix(&[0.0], &[0.3], &[0.7]);
        for tau in [0.1, 0.5, 1.0, 2.5, 10.0] {
            let z = (f64::ln(tau) - 0.3) / 0.7;
            let want = (-0.5 * z * z).exp()
                / (tau * 0.7 * (2.0 * std::f64::consts::PI).sqrt());
            close(m.pdf(tau), want, 1e-9, "pdf").unwrap();
        }
    }

    #[test]
    fn pdf_integrates_to_one() {
        let m = mix(&[0.0, -0.5], &[0.0, 1.0], &[0.5, 0.8]);
        // trapezoid over a wide range
        let (mut acc, n, hi) = (0.0, 200_000, 60.0);
        let dt = hi / n as f64;
        for i in 0..n {
            let t = (i as f64 + 0.5) * dt;
            acc += m.pdf(t) * dt;
        }
        close(acc, 1.0, 1e-3, "integral").unwrap();
    }

    #[test]
    fn cdf_is_monotone_and_matches_numeric_integral() {
        let m = mix(&[0.2, -1.0], &[-0.5, 0.5], &[0.4, 1.2]);
        let mut acc = 0.0;
        let dt = 1e-3;
        let mut prev_cdf = 0.0;
        for i in 1..8000 {
            let t = i as f64 * dt;
            acc += m.pdf(t - 0.5 * dt) * dt;
            let c = m.cdf(t);
            assert!(c >= prev_cdf - 1e-12);
            prev_cdf = c;
            if i % 1000 == 0 {
                close(c, acc, 2e-3, &format!("cdf({t})")).unwrap();
            }
        }
    }

    #[test]
    fn sampling_matches_cdf() {
        // KS-style check: empirical CDF of samples vs analytic CDF.
        let m = mix(&[0.0, 0.0], &[0.0, 1.5], &[0.5, 0.3]);
        let mut rng = Rng::new(9);
        let n = 20_000;
        let mut xs: Vec<f64> = (0..n).map(|_| m.sample(&mut rng)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut dmax: f64 = 0.0;
        for (i, x) in xs.iter().enumerate() {
            let emp = (i + 1) as f64 / n as f64;
            dmax = dmax.max((emp - m.cdf(*x)).abs());
        }
        assert!(dmax < 1.36 / (n as f64).sqrt() * 1.5, "KS {dmax}");
    }

    #[test]
    fn type_dist_restricts_to_k() {
        let logits = vec![1.0, 2.0, 3.0, 100.0]; // last is padding
        let d = TypeDist::from_logits(&logits, 3);
        assert_eq!(d.probs.len(), 3);
        close(d.probs.iter().sum::<f64>(), 1.0, 1e-12, "norm").unwrap();
        assert!(d.probs[2] > d.probs[1] && d.probs[1] > d.probs[0]);
    }

    #[test]
    fn adjusted_type_dist_matches_formula() {
        let t = TypeDist { probs: vec![0.5, 0.3, 0.2] };
        let d = TypeDist { probs: vec![0.2, 0.5, 0.3] };
        let a = TypeDist::adjusted(&t, &d);
        // positive part: [0.3, 0, 0] → [1, 0, 0]
        close(a.probs[0], 1.0, 1e-12, "p0").unwrap();
        assert_eq!(a.probs[1], 0.0);
    }

    #[test]
    fn adjusted_identical_falls_back_to_target() {
        let t = TypeDist { probs: vec![0.4, 0.6] };
        let a = TypeDist::adjusted(&t, &t);
        close(a.probs[0], 0.4, 1e-12, "fallback").unwrap();
    }

    /// Theorem 1: the acceptance–rejection sampler reproduces
    /// g' = norm(max(0, g_T − g_D)) — verified against a numerically
    /// normalized density on a grid.
    #[test]
    fn adjusted_interval_sampler_distribution() {
        let gt = mix(&[0.0], &[0.8], &[0.5]);
        let gd = mix(&[0.0], &[0.0], &[0.5]);
        let mut rng = Rng::new(17);
        let n = 30_000;
        let samples: Vec<f64> = (0..n)
            .map(|_| sample_adjusted_interval(&gt, &gd, &mut rng, 1000).0)
            .collect();

        // numeric normalizer Z = ∫ max(0, gT − gD)
        let (mut z, grid, hi) = (0.0, 40_000, 40.0);
        let dt = hi / grid as f64;
        let cdf_at = |x: f64| {
            let mut acc = 0.0;
            let steps = (x / dt) as usize;
            for i in 0..steps {
                let t = (i as f64 + 0.5) * dt;
                acc += (gt.pdf(t) - gd.pdf(t)).max(0.0) * dt;
            }
            acc
        };
        for i in 0..grid {
            let t = (i as f64 + 0.5) * dt;
            z += (gt.pdf(t) - gd.pdf(t)).max(0.0) * dt;
        }
        // KS against the numeric CDF at a few quantiles
        let mut xs = samples;
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.1, 0.25, 0.5, 0.75, 0.9] {
            let x = xs[(q * (n as f64 - 1.0)) as usize];
            let want = cdf_at(x) / z;
            close(want, q, 0.03, &format!("quantile {q}")).unwrap();
        }
    }

    #[test]
    fn property_logpdf_consistent_with_pdf() {
        check(
            "mixture pdf = exp(logpdf)",
            50,
            |r| {
                let m = 1 + r.below(4);
                let mx = Mixture {
                    log_w: {
                        let lw: Vec<f64> = (0..m).map(|_| r.normal()).collect();
                        let z = logsumexp(&lw);
                        lw.iter().map(|l| l - z).collect()
                    },
                    mu: (0..m).map(|_| r.normal()).collect(),
                    log_sigma: (0..m).map(|_| r.uniform_in(-1.5, 0.5)).collect(),
                };
                let tau = r.uniform_in(0.01, 10.0);
                (mx, tau)
            },
            |(mx, tau)| {
                close(mx.pdf(*tau).ln(), mx.logpdf(*tau), 1e-9, "log/exp")
            },
        );
    }
}
