//! Mock models implementing [`Forward`] without any XLA artifacts.
//!
//! These make the *algorithmic* layer (AR, TPP-SD, adjusted-distribution
//! resampling, rolling context, likelihood chunking) unit- and
//! property-testable in milliseconds: the mixture parameters are analytic
//! functions of the visible history, so every density is exactly known and
//! the draft/target divergence is a dial.

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::{BatchForward, Forward, ForwardOut, SeqInput, SlotOut};

/// A deterministic "Transformer": at each position the next-interval
/// distribution is a 2-component log-normal mixture whose parameters drift
/// with the number of visible events, shifted by `bias` (use different
/// biases for draft vs target to control their divergence); the type head
/// prefers type `(n + type_shift) mod k`.
#[derive(Debug, Clone)]
pub struct MockModel {
    /// mixture components per row
    pub n_mix: usize,
    /// padded event-type dimension
    pub k_max: usize,
    /// largest sequence length (incl. BOS) a forward accepts
    pub max_bucket: usize,
    /// shifts μ of the mixture — 0.0 for the "target", ≠0 for a "draft"
    pub bias: f64,
    /// rotates the preferred type
    pub type_shift: usize,
}

impl Default for MockModel {
    fn default() -> Self {
        MockModel { n_mix: 2, k_max: 4, max_bucket: 512, bias: 0.0, type_shift: 0 }
    }
}

impl MockModel {
    /// The analytic decoder: position `row` (events visible = row).
    pub fn params_at(&self, row: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let n = row as f64;
        // weights drift slowly with n; always valid log-softmax
        let w0 = 0.3 + 0.4 * ((n * 0.37).sin() * 0.5 + 0.5);
        let log_w = vec![(w0 as f32).ln(), ((1.0 - w0) as f32).ln()];
        let mu = vec![
            (-1.2 + 0.1 * (n * 0.21).sin() + self.bias) as f32,
            (0.3 + 0.05 * (n * 0.13).cos() + self.bias) as f32,
        ];
        let log_sigma = vec![-0.7f32, -0.3f32];
        let mut logits = vec![0f32; self.k_max];
        for (k, l) in logits.iter_mut().enumerate() {
            *l = if (row + self.type_shift) % self.k_max == k { 1.5 } else { 0.0 };
        }
        (log_w, mu, log_sigma, logits)
    }
}

impl Forward for MockModel {
    fn forward1(&self, seq: SeqInput) -> Result<SlotOut> {
        let rows = seq.len_with_bos();
        let bucket = rows.next_power_of_two().max(8).min(self.max_bucket);
        let mut log_w = Vec::with_capacity(bucket * self.n_mix);
        let mut mu = Vec::with_capacity(bucket * self.n_mix);
        let mut log_sigma = Vec::with_capacity(bucket * self.n_mix);
        let mut logits = Vec::with_capacity(bucket * self.k_max);
        for row in 0..bucket {
            let (w, m, s, l) = self.params_at(row.min(rows));
            log_w.extend(w);
            mu.extend(m);
            log_sigma.extend(s);
            logits.extend(l);
        }
        let out = ForwardOut::from_raw(1, bucket, self.n_mix, self.k_max, log_w, mu, log_sigma, logits);
        Ok(SlotOut::new(Arc::new(out), 0))
    }

    fn max_bucket(&self) -> usize {
        self.max_bucket
    }
}

impl BatchForward for MockModel {
    /// Mock "batched" forward: one [`Forward::forward1`] per sequence —
    /// numerically the identity the real backends guarantee, which is all
    /// the fleet-engine tests need.
    fn forward_batch(&self, seqs: Vec<SeqInput>) -> Result<Vec<SlotOut>> {
        seqs.into_iter().map(|s| self.forward1(s)).collect()
    }

    fn max_batch(&self) -> usize {
        8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::ks::ks_statistic;
    use crate::sampler::{sample_ar, sample_sd, Gamma, SampleCfg, SdCfg};
    use crate::util::rng::Rng;

    fn cfg(k: usize) -> SampleCfg {
        SampleCfg { num_types: k, t_end: 40.0, max_events: 4096 }
    }

    /// draft == target ⇒ density ratios are exactly 1 ⇒ every candidate
    /// accepted, γ+1 events per round.
    #[test]
    fn identical_models_accept_everything() {
        let m = MockModel::default();
        let sd = SdCfg { sample: cfg(4), gamma: Gamma::Fixed(8), ..Default::default() };
        let mut rng = Rng::new(1);
        let (ev, st) = sample_sd(&m, &m, &sd, &mut rng).unwrap();
        assert!(!ev.is_empty());
        // No candidate is ever *rejected* (density ratios are exactly 1);
        // the final round may end mid-verification when the window closes,
        // leaving ≤ γ candidates unjudged.
        assert_eq!(st.resampled, 0, "identical models must never reject");
        assert!(st.accepted + 8 >= st.drafted, "{st:?}");
        assert!(st.bonus + 1 >= st.rounds, "every complete round ends with a bonus");
    }

    /// The paper's core claim on exact densities: SD(draft≠target) produces
    /// the SAME distribution as AR(target). Two-sample KS on intervals.
    #[test]
    fn sd_distribution_equals_ar_with_divergent_draft() {
        let target = MockModel::default();
        let draft = MockModel { bias: 0.35, type_shift: 1, ..Default::default() };
        let scfg = cfg(4);
        let (mut taus_ar, mut taus_sd) = (vec![], vec![]);
        let (mut types_ar, mut types_sd) = (vec![0usize; 4], vec![0usize; 4]);
        for s in 0..40 {
            let mut rng = Rng::new(1000 + s);
            let (ev, _) = sample_ar(&target, &scfg, &mut rng).unwrap();
            taus_ar.extend(crate::events::intervals(&ev));
            ev.iter().for_each(|e| types_ar[e.k as usize] += 1);
            let sd = SdCfg { sample: scfg.clone(), gamma: Gamma::Fixed(6), ..Default::default() };
            let mut rng = Rng::new(9000 + s);
            let (ev, st) = sample_sd(&target, &draft, &sd, &mut rng).unwrap();
            assert!(st.acceptance_rate() < 0.999, "draft must actually diverge");
            taus_sd.extend(crate::events::intervals(&ev));
            ev.iter().for_each(|e| types_sd[e.k as usize] += 1);
        }
        // two-sample KS
        let mut sa = taus_ar.clone();
        sa.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let d = ks_statistic(&taus_sd, |x| {
            sa.partition_point(|&v| v <= x) as f64 / sa.len() as f64
        });
        let crit = 1.36
            * ((sa.len() + taus_sd.len()) as f64 / (sa.len() as f64 * taus_sd.len() as f64))
                .sqrt();
        assert!(d < 1.5 * crit, "KS {d:.4} crit {crit:.4}");
        // type marginals
        let na: usize = types_ar.iter().sum();
        let ns: usize = types_sd.iter().sum();
        for k in 0..4 {
            let pa = types_ar[k] as f64 / na as f64;
            let ps = types_sd[k] as f64 / ns as f64;
            assert!((pa - ps).abs() < 0.03, "type {k}: {pa:.3} vs {ps:.3}");
        }
    }

    /// Strongly divergent draft: still correct, just slow (low α).
    #[test]
    fn very_bad_draft_still_correct_mean() {
        let target = MockModel::default();
        let draft = MockModel { bias: 1.5, ..Default::default() };
        let scfg = cfg(4);
        let (mut c_ar, mut c_sd) = (vec![], vec![]);
        for s in 0..30 {
            let mut rng = Rng::new(s);
            c_ar.push(sample_ar(&target, &scfg, &mut rng).unwrap().0.len() as f64);
            let sd = SdCfg { sample: scfg.clone(), gamma: Gamma::Fixed(4), ..Default::default() };
            let mut rng = Rng::new(7777 + s);
            let (ev, st) = sample_sd(&target, &draft, &sd, &mut rng).unwrap();
            assert!(st.acceptance_rate() < 0.6, "α should be poor");
            c_sd.push(ev.len() as f64);
        }
        let ma = crate::util::math::mean(&c_ar);
        let ms = crate::util::math::mean(&c_sd);
        let se = crate::util::math::std_dev(&c_ar) / (c_ar.len() as f64).sqrt();
        assert!((ma - ms).abs() < 4.0 * se + 1.0, "counts {ma:.1} vs {ms:.1}");
    }

    /// SD must use strictly fewer target forwards than events generated.
    #[test]
    fn sd_saves_target_forwards() {
        let target = MockModel::default();
        let draft = MockModel { bias: 0.1, ..Default::default() };
        let sd = SdCfg { sample: cfg(4), gamma: Gamma::Fixed(10), ..Default::default() };
        let mut rng = Rng::new(3);
        let (ev, st) = sample_sd(&target, &draft, &sd, &mut rng).unwrap();
        assert!(st.target_forwards * 2 < ev.len(), "{st:?}");
    }

    /// Long-horizon run exercises the rolling window (truncations > 0) and
    /// must keep producing valid sequences.
    #[test]
    fn rolling_window_long_horizon() {
        let target = MockModel { max_bucket: 64, ..Default::default() };
        let draft = MockModel { max_bucket: 64, bias: 0.2, ..Default::default() };
        let sd = SdCfg {
            sample: SampleCfg { num_types: 4, t_end: 200.0, max_events: 3000 },
            gamma: Gamma::Fixed(5),
            ..Default::default()
        };
        let mut rng = Rng::new(5);
        let (ev, _) = sample_sd(&target, &draft, &sd, &mut rng).unwrap();
        assert!(ev.len() > 150, "expected a long sequence, got {}", ev.len());
        assert!(crate::events::is_valid_sequence(&ev, 200.0));
    }

    /// Adaptive γ: all-accept rounds grow γ, rejections shrink it; output
    /// remains a valid sequence and α stays in (0, 1].
    #[test]
    fn adaptive_gamma_bounds() {
        let target = MockModel::default();
        let draft = MockModel { bias: 0.4, ..Default::default() };
        let sd = SdCfg {
            sample: cfg(4),
            gamma: Gamma::Adaptive { init: 4, min: 2, max: 12 },
            ..Default::default()
        };
        let mut rng = Rng::new(8);
        let (ev, st) = sample_sd(&target, &draft, &sd, &mut rng).unwrap();
        assert!(!ev.is_empty());
        let a = st.acceptance_rate();
        assert!(a > 0.0 && a <= 1.0, "α={a}");
    }

    /// model_loglik chunking: score a long sequence with a small-bucket
    /// mock; must equal the direct per-event computation on the mock's
    /// analytic densities when the chunk prefix covers the (stateless) mock.
    #[test]
    fn loglik_chunking_consistent() {
        let m = MockModel::default();
        let mut rng = Rng::new(9);
        let scfg = cfg(4);
        let (ev, _) = sample_ar(&m, &scfg, &mut rng).unwrap();
        let ll = crate::metrics::model_loglik(&m, &ev, 4, scfg.t_end).unwrap();
        assert!(ll.is_finite());
        // direct computation from analytic params (mock is position-only)
        let mut want = 0.0;
        let mut prev = 0.0;
        for (i, e) in ev.iter().enumerate() {
            let fwd = m.forward1(SeqInput {
                t0: 0.0,
                times: ev[..i].iter().map(|x| x.t).collect(),
                types: ev[..i].iter().map(|x| x.k).collect(),
            })
            .unwrap();
            want += fwd.mixture(i).logpdf(e.t - prev);
            want += fwd.type_dist(i, 4).pmf(e.k as usize).ln();
            prev = e.t;
        }
        let fwd = m
            .forward1(SeqInput {
                t0: 0.0,
                times: ev.iter().map(|x| x.t).collect(),
                types: ev.iter().map(|x| x.k).collect(),
            })
            .unwrap();
        want += fwd.mixture(ev.len()).log_survival(scfg.t_end - prev);
        // NB: chunked scorer uses a 128-event prefix; the mock depends only
        // on absolute row index, which differs across chunks — so compare
        // only when the sequence fits one chunk.
        if ev.len() <= 256 {
            assert!((ll - want).abs() < 1e-6, "{ll} vs {want}");
        }
    }
}
