//! Model-side math over AOT forward outputs: log-normal mixtures, type
//! distributions, and the model log-likelihood (Eq. 2).

pub mod mixture;
pub mod mock;

pub use mixture::{sample_adjusted_interval, Mixture, TypeDist};
pub use mock::MockModel;
