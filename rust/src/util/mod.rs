//! Substrates written in-house because the container registry is offline
//! (no `rand`, `serde`, `clap`, `proptest`): RNG, JSON, CLI, numerics and a
//! property-test helper.

pub mod checker;
pub mod cli;
pub mod json;
pub mod math;
pub mod rng;
