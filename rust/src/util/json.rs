//! Minimal JSON substrate (the offline registry has no `serde` facade).
//!
//! A complete recursive-descent parser and writer for the JSON the pipeline
//! exchanges (artifact manifests, `datasets.json`, result dumps). Supports
//! the full grammar including string escapes and scientific notation; does
//! not aim to be the fastest parser alive — manifests are tiny.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any JSON number (always stored as `f64`)
    Num(f64),
    /// a string
    Str(String),
    /// an array
    Arr(Vec<Json>),
    /// an object (sorted keys — deterministic output)
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset it occurred at.
#[derive(Debug)]
pub struct JsonError {
    /// byte offset of the failure
    pub pos: usize,
    /// what the parser expected
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    /// Object member lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// The value as a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The value as a number, truncated to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// The value as a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The value as a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The value as an array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    /// The value as an object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// `get` chained through a dotted path, e.g. `"size.n_layers"`.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
    /// Number at a dotted path.
    pub fn f64_at(&self, dotted: &str) -> Option<f64> {
        self.path(dotted).and_then(Json::as_f64)
    }
    /// `usize` at a dotted path.
    pub fn usize_at(&self, dotted: &str) -> Option<usize> {
        self.path(dotted).and_then(Json::as_usize)
    }
    /// String at a dotted path.
    pub fn str_at(&self, dotted: &str) -> Option<&str> {
        self.path(dotted).and_then(Json::as_str)
    }
    /// Bool at a dotted path.
    pub fn bool_at(&self, dotted: &str) -> Option<bool> {
        self.path(dotted).and_then(Json::as_bool)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Note: surrogate pairs unsupported (not produced
                            // by our pipeline); map them to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience: build an object from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let s = r#"{"bucket": 64, "size": {"n_layers": 6}, "params":
                    [{"name": "emb", "shape": [25, 32]}], "impl": "pallas"}"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.usize_at("bucket"), Some(64));
        assert_eq!(j.usize_at("size.n_layers"), Some(6));
        assert_eq!(j.str_at("impl"), Some("pallas"));
        let p = &j.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.str_at("name"), Some("emb"));
        assert_eq!(p.get("shape").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn parse_numbers() {
        for (s, v) in [
            ("0", 0.0),
            ("-12.5", -12.5),
            ("1e3", 1000.0),
            ("2.5E-2", 0.025),
            ("-0.0", 0.0),
        ] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }

    #[test]
    fn parse_strings_and_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "tru", "1 2", "\"abc", "{\"a\":}"] {
            assert!(Json::parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn roundtrip() {
        let s = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null},"e":-3}"#;
        let j = Json::parse(s).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes_on_write() {
        let j = Json::Str("a\"\n\\".into());
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed, j);
    }
}
