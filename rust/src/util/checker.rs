//! Property-testing substrate (no `proptest` in the offline registry).
//!
//! `check(cases, gen, prop)` runs `prop` on `cases` random inputs drawn by
//! `gen`; on failure it re-runs the generator deterministically to report
//! the failing seed so the case can be replayed in a unit test.

use super::rng::Rng;

/// Run a property over randomly generated cases.
///
/// Panics with the failing case index + seed on the first violation.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base_seed = 0xC0FFEE_u64;
    for i in 0..cases {
        let mut rng = Rng::new(base_seed.wrapping_add(i as u64));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {i} (seed {}):\n  input: {input:?}\n  {msg}",
                base_seed.wrapping_add(i as u64)
            );
        }
    }
}

/// Assert two floats agree to a tolerance, returning a property error.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        check(
            "abs is non-negative",
            100,
            |r| r.normal(),
            |x| {
                if x.abs() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative abs".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failures() {
        check("always fails", 1, |r| r.uniform(), |_| Err("boom".into()));
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(close(1.0, 1.1, 1e-6, "x").is_err());
    }
}
