//! Numerics substrate: erf/Φ, log-sum-exp, softmax — shared by the mixture
//! math, metrics and likelihood code.

/// Error function, Abramowitz & Stegun 7.1.26 (|ε| ≤ 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let y = 1.0
        - (((((1.061_405_429 * t - 1.453_152_027) * t) + 1.421_413_741) * t
            - 0.284_496_736)
            * t
            + 0.254_829_592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF Φ(x).
#[inline]
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Standard normal log-density.
#[inline]
pub fn norm_logpdf(x: f64) -> f64 {
    -0.5 * x * x - 0.5 * (2.0 * std::f64::consts::PI).ln()
}

/// log(Σ exp(xs)) computed stably.
pub fn logsumexp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

/// In-place softmax over a slice (stable).
pub fn softmax_inplace(xs: &mut [f64]) {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut sum = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (linear interpolation) of an unsorted slice.
///
/// NaN contract: NaN samples are ignored (a latency sample that failed to
/// compute must not poison the whole distribution); an empty or all-NaN
/// input returns NaN. Never panics — the previous
/// `partial_cmp().unwrap()` sort aborted the entire bench run on a single
/// NaN sample.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(f64::total_cmp);
    let rank = p.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        // reference values from tables
        for (x, want) in [
            (0.0, 0.0),
            (0.5, 0.5204999),
            (1.0, 0.8427008),
            (2.0, 0.9953223),
            (-1.0, -0.8427008),
        ] {
            assert!((erf(x) - want).abs() < 2e-6, "erf({x})={} want {want}", erf(x));
        }
    }

    #[test]
    fn norm_cdf_symmetry() {
        // A&S 7.1.26 carries ~1e-9 absolute error at 0, so symmetry holds
        // to the approximation's accuracy, not machine precision.
        for x in [-3.0, -1.2, 0.0, 0.7, 2.5] {
            assert!((norm_cdf(x) + norm_cdf(-x) - 1.0).abs() < 1e-7);
        }
        assert!((norm_cdf(1.96) - 0.975).abs() < 1e-3);
    }

    #[test]
    fn logsumexp_matches_naive_and_is_stable() {
        let xs = [1.0, 2.0, 3.0];
        let naive = xs.iter().map(|x: &f64| x.exp()).sum::<f64>().ln();
        assert!((logsumexp(&xs) - naive).abs() < 1e-12);
        // stability: huge values don't overflow
        let big = [1000.0, 1000.0];
        assert!((logsumexp(&big) - (1000.0 + 2f64.ln())).abs() < 1e-9);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = [0.1, -2.0, 5.0, 3.3];
        softmax_inplace(&mut xs);
        assert!((xs.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn percentile_basics() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_ignores_nan_samples() {
        // regression: the old partial_cmp().unwrap() sort panicked here
        let xs = [f64::NAN, 1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        // NaN position must not matter
        let xs = [1.0, 2.0, f64::NAN, 3.0, 4.0, f64::NAN];
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_or_all_nan_is_nan() {
        assert!(percentile(&[], 0.5).is_nan());
        assert!(percentile(&[f64::NAN, f64::NAN], 0.5).is_nan());
    }

    #[test]
    fn percentile_handles_signed_zero_and_infinities() {
        // total_cmp orders -0.0 < +0.0 and infinities at the ends
        let xs = [0.0, -0.0, f64::INFINITY, f64::NEG_INFINITY];
        assert_eq!(percentile(&xs, 0.0), f64::NEG_INFINITY);
        assert_eq!(percentile(&xs, 1.0), f64::INFINITY);
    }
}
