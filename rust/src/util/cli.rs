//! CLI argument parsing substrate (no `clap` in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, typed
//! accessors with defaults, and auto-generated usage text.

use std::collections::BTreeMap;

/// Parsed command line.
///
/// Every occurrence of a repeated option is kept in order
/// (`--backend a --backend b`): [`Args::get`] returns the last one (the
/// usual "rightmost wins" override rule), [`Args::all`] returns them all
/// (the shard tier's `--backend` list).
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    positional: Vec<String>,
    spec: Vec<(String, String, String)>, // (name, default, help)
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args().skip(1)`.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.entry(k.to_string()).or_default().push(v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.entry(rest.to_string()).or_default().push(v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Register an option for usage text; returns self for chaining.
    pub fn describe(mut self, name: &str, default: &str, help: &str) -> Self {
        self.spec
            .push((name.to_string(), default.to_string(), help.to_string()));
        self
    }

    /// Usage text from the registered option specs.
    pub fn usage(&self, prog: &str) -> String {
        let mut s = format!("usage: {prog} [options]\n");
        for (n, d, h) in &self.spec {
            s.push_str(&format!("  --{n:<24} {h} [default: {d}]\n"));
        }
        s
    }

    /// True when a bare flag (or valued option) was given.
    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag) || self.opts.contains_key(flag)
    }

    /// Raw option value, if present (the last occurrence when repeated).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts
            .get(key)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
    }

    /// Every occurrence of a repeated option, in order, with each value
    /// further split on commas — `--backend a:1 --backend b:2,c:3` yields
    /// `["a:1", "b:2", "c:3"]`. Empty when the option is absent.
    pub fn all(&self, key: &str) -> Vec<String> {
        self.opts
            .get(key)
            .map(|vals| {
                vals.iter()
                    .flat_map(|v| v.split(','))
                    .map(|x| x.trim().to_string())
                    .filter(|x| !x.is_empty())
                    .collect()
            })
            .unwrap_or_default()
    }

    /// String option with a default.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Float option with a default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `usize` option with a default.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `u64` option with a default.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list (`--encoders thp,sahp`).
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Positional (non-`--`) arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn kinds() {
        // NB: a bare `--flag` greedily consumes a following non-dash token
        // as its value, so positionals come first (documented behaviour).
        let a = parse("pos1 --gamma 10 --encoder=thp --verbose --seeds 1,2,3");
        assert_eq!(a.usize_or("gamma", 5), 10);
        assert_eq!(a.str_or("encoder", "x"), "thp");
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
        assert_eq!(a.list_or("seeds", &[]), vec!["1", "2", "3"]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.f64_or("t-end", 100.0), 100.0);
        assert_eq!(a.list_or("encoders", &["thp", "sahp"]), vec!["thp", "sahp"]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--fast --gamma 3");
        assert!(a.has("fast"));
        assert_eq!(a.usize_or("gamma", 0), 3);
    }

    #[test]
    fn repeated_options_collect_in_order() {
        let a = parse("--backend a:1 --backend b:2,c:3 --gamma 5 --gamma 7");
        assert_eq!(a.all("backend"), vec!["a:1", "b:2", "c:3"]);
        // scalar accessors keep the rightmost-wins override rule
        assert_eq!(a.usize_or("gamma", 0), 7);
        assert!(a.all("missing").is_empty());
    }
}
