//! In-house PRNG substrate (the offline registry has no `rand` crate).
//!
//! xoshiro256++ (Blackman & Vigna) seeded through SplitMix64 — the standard
//! construction; passes BigCrush. Deterministic across platforms, which the
//! experiment harness relies on for seeded reproducibility.

/// xoshiro256++ generator with convenience distributions.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second output of Box-Muller
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-session / per-thread RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Derive a decision stream from the current state **without advancing
    /// this generator** (DESIGN.md §9): the speculative sampler routes its
    /// accept/reject uniforms and adjusted-distribution redraws through a
    /// derived stream so its *proposal* draws stay aligned with plain AR
    /// sampling — with `draft == target` the two samplers then reproduce
    /// identical event streams from the same seed. The derived seed is a
    /// distinct avalanche of the state, so the streams are independent for
    /// every statistical purpose of this crate.
    pub fn derive(&self, tag: u64) -> Rng {
        let mut sm = self.s[0]
            ^ self.s[1].rotate_left(13)
            ^ self.s[2].rotate_left(29)
            ^ self.s[3].rotate_left(43)
            ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(splitmix64(&mut sm))
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // Lemire's multiply-shift rejection-free-enough reduction.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Exponential with the given rate (mean 1/rate).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        // uniform() < 1 strictly, so 1-u > 0 and ln is finite.
        -(1.0 - self.uniform()).ln() / rate
    }

    /// Standard normal (Box-Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        let u1 = (1.0 - self.uniform()).max(1e-300);
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical: all-zero weights");
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample an index from log-weights (log-sum-exp normalized).
    ///
    /// Streams the shifted weights `(l − max)·exp` twice instead of
    /// collecting them: the same float values in the same order as the
    /// old collect-then-[`Rng::categorical`] form (one `uniform()` draw at
    /// the same stream position, identical subtract-walk), with zero
    /// allocations — [`crate::model::mixture::Mixture::sample`] calls this
    /// once per proposed event.
    pub fn categorical_logits(&mut self, logits: &[f64]) -> usize {
        let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let total: f64 = logits.iter().map(|l| (l - m).exp()).sum();
        debug_assert!(total > 0.0, "categorical_logits: empty/degenerate logits");
        let mut u = self.uniform() * total;
        for (i, l) in logits.iter().enumerate() {
            u -= (l - m).exp();
            if u < 0.0 {
                return i;
            }
        }
        logits.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(2);
        let n = 40_000;
        let rate = 2.5;
        let mean: f64 = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn categorical_frequencies() {
        let mut r = Rng::new(4);
        let w = [1.0, 2.0, 7.0];
        let mut counts = [0usize; 3];
        let n = 30_000;
        for _ in 0..n {
            counts[r.categorical(&w)] += 1;
        }
        for i in 0..3 {
            let p = w[i] / 10.0;
            let f = counts[i] as f64 / n as f64;
            assert!((f - p).abs() < 0.02, "i={i} f={f} p={p}");
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn derive_does_not_advance_and_differs_per_tag() {
        let mut a = Rng::new(9);
        let before: Vec<u64> = {
            let mut c = a.clone();
            (0..4).map(|_| c.next_u64()).collect()
        };
        let mut d1 = a.derive(1);
        let mut d2 = a.derive(2);
        // deriving consumed nothing from the parent stream
        let after: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        assert_eq!(before, after);
        // distinct tags give distinct streams, both different from parent
        let x1: Vec<u64> = (0..4).map(|_| d1.next_u64()).collect();
        let x2: Vec<u64> = (0..4).map(|_| d2.next_u64()).collect();
        assert_ne!(x1, x2);
        assert_ne!(x1, after);
    }

    #[test]
    fn fork_is_independent() {
        let mut a = Rng::new(6);
        let mut b = a.fork(1);
        let mut c = a.fork(2);
        // streams differ
        let xs: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        let ys: Vec<u64> = (0..4).map(|_| c.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
