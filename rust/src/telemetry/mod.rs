//! Lock-free telemetry: per-stage latency histograms + acceptance tracking.
//!
//! The paper's speedup claim reduces to two quantities — the acceptance
//! rate α and the draft length γ (Leviathan et al.; Chen et al. report
//! per-stage timing to validate the cost model). This module records both,
//! plus wall-clock latency for every hot-path stage, with three hard
//! constraints (DESIGN.md §15):
//!
//! * **lock-free**: recording is a handful of `Relaxed` atomic adds into
//!   fixed-size arrays — no locks, no allocation, safe from any thread.
//! * **RNG-neutral**: recording touches only [`std::time::Instant`] and
//!   atomics, never a sampler [`crate::util::rng::Rng`] — golden fixtures
//!   stay byte-identical with telemetry on or off (pinned by
//!   `tests/telemetry.rs`).
//! * **cheap enough to leave on**: `bench_hotpath` gates telemetry-on
//!   sampling throughput at ≥ 0.97× telemetry-off.
//!
//! Latencies land in 64 log₂-scale nanosecond buckets (bucket *i* ≥ 1
//! covers `[2^i, 2^(i+1))` ns), so quantile readout is exact to the bucket
//! upper edge — within 2× of the true value across 19 orders of magnitude,
//! from a constant 512-byte array per stage and zero stored samples.
//!
//! Use [`Span`] to time a scope, [`record_round`] for SD accept/reject
//! accounting, [`snapshot`] / [`Snapshot::since`] for windowed deltas, and
//! [`report`] for the shared human-readable summary used by the CLI,
//! `serve.rs` and the benches.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Number of log₂ latency buckets per stage histogram. Bucket 0 holds
/// `[0, 2)` ns; bucket `i ≥ 1` holds `[2^i, 2^(i+1))` ns; bucket 63 is
/// open-ended.
pub const NUM_BUCKETS: usize = 64;

/// A hot-path stage with its own latency histogram.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// One draft-model forward acquisition (blocking driver or fleet wave).
    DraftForward,
    /// One target-model (verify) forward acquisition.
    VerifyForward,
    /// One incremental `forward_delta_batch` wave (also recorded under the
    /// issuing role's forward stage).
    DeltaWave,
    /// Time an executor's batch loop spent waiting out the batch window.
    BatchWait,
    /// One parallel wave dispatched onto the persistent worker pool.
    PoolDispatch,
    /// One retry backoff sleep inside the executor retry ladder.
    RetryBackoff,
    /// One stream-recovery ladder pass (close → reopen → rebase).
    StreamRecovery,
    /// Wall-clock gap between consecutive emitted events, per session.
    EventLatency,
    /// Time a request spent in the scheduler's pending queue between
    /// submission and its admission verdict (admitted, shed or expired).
    QueueWait,
    /// One proxy-tier upstream call: connect/forward/reply round-trip to
    /// a backend replica (`coordinator::shard`), failures included.
    ProxyUpstream,
}

impl Stage {
    /// Every stage, in wire/report order.
    pub const ALL: [Stage; 10] = [
        Stage::DraftForward,
        Stage::VerifyForward,
        Stage::DeltaWave,
        Stage::BatchWait,
        Stage::PoolDispatch,
        Stage::RetryBackoff,
        Stage::StreamRecovery,
        Stage::EventLatency,
        Stage::QueueWait,
        Stage::ProxyUpstream,
    ];

    /// Stable snake_case name used in JSON snapshots and reports.
    pub fn name(self) -> &'static str {
        match self {
            Stage::DraftForward => "draft_forward",
            Stage::VerifyForward => "verify_forward",
            Stage::DeltaWave => "delta_wave",
            Stage::BatchWait => "batch_wait",
            Stage::PoolDispatch => "pool_dispatch",
            Stage::RetryBackoff => "retry_backoff",
            Stage::StreamRecovery => "stream_recovery",
            Stage::EventLatency => "event_latency",
            Stage::QueueWait => "queue_wait",
            Stage::ProxyUpstream => "proxy_upstream",
        }
    }
}

/// Number of distinct [`Stage`]s.
pub const NUM_STAGES: usize = Stage::ALL.len();

/// Index of the log₂ bucket covering `ns` nanoseconds.
///
/// `bucket_index(0) == bucket_index(1) == 0`; for `ns ≥ 2` the index is
/// `⌊log₂ ns⌋`, saturating at [`NUM_BUCKETS`]` - 1`.
pub fn bucket_index(ns: u64) -> usize {
    if ns < 2 {
        0
    } else {
        (ns.ilog2() as usize).min(NUM_BUCKETS - 1)
    }
}

/// Inclusive upper edge (ns) of bucket `i` — the value quantile readout
/// reports for samples landing in that bucket.
fn bucket_hi(i: usize) -> u64 {
    if i + 1 >= NUM_BUCKETS {
        u64::MAX
    } else {
        (1u64 << (i + 1)) - 1
    }
}

/// A lock-free fixed-bucket log₂ latency histogram.
///
/// Recording is three `Relaxed` atomic adds; readout ([`Histo::snap`]) is
/// a racy-but-monotone scan, which is exactly what windowed deltas need.
#[derive(Debug)]
pub struct Histo {
    buckets: [AtomicU64; NUM_BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Histo {
    /// A fresh all-zero histogram.
    pub fn new() -> Self {
        Histo {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    /// Record one latency sample of `ns` nanoseconds.
    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// A point-in-time copy of the counters.
    pub fn snap(&self) -> HistoSnap {
        HistoSnap {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
        }
    }
}

impl Default for Histo {
    fn default() -> Self {
        Histo::new()
    }
}

/// A plain-value snapshot of one [`Histo`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistoSnap {
    /// Per-bucket sample counts (see [`bucket_index`]).
    pub buckets: [u64; NUM_BUCKETS],
    /// Total samples recorded.
    pub count: u64,
    /// Sum of all recorded latencies, in nanoseconds.
    pub sum_ns: u64,
}

impl Default for HistoSnap {
    fn default() -> Self {
        HistoSnap { buckets: [0; NUM_BUCKETS], count: 0, sum_ns: 0 }
    }
}

impl HistoSnap {
    /// The samples recorded between `earlier` and `self`, saturating to
    /// zero per field (snapshots race with recorders, so a field read
    /// slightly out of order must not wrap).
    pub fn since(&self, earlier: &HistoSnap) -> HistoSnap {
        HistoSnap {
            buckets: std::array::from_fn(|i| {
                self.buckets[i].saturating_sub(earlier.buckets[i])
            }),
            count: self.count.saturating_sub(earlier.count),
            sum_ns: self.sum_ns.saturating_sub(earlier.sum_ns),
        }
    }

    /// Mean latency in nanoseconds; NaN when no samples were recorded.
    pub fn mean_ns(&self) -> f64 {
        self.sum_ns as f64 / self.count as f64
    }

    /// The `q`-quantile (clamped to `[0, 1]`) as the inclusive upper edge
    /// of the bucket holding the rank-`⌈q·count⌉` sample — exact to the
    /// bucket bound, i.e. within 2× of the true latency. `None` when the
    /// histogram is empty.
    pub fn quantile_ns(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= rank {
                return Some(bucket_hi(i));
            }
        }
        Some(bucket_hi(NUM_BUCKETS - 1))
    }
}

/// The two model roles tracked by the acceptance tracker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Draft-level accounting: α = accepted / proposed draft events.
    Draft,
    /// Target-level accounting: fraction of verify rounds accepting the
    /// whole draft (the bonus-event rate).
    Target,
}

impl Role {
    /// Both roles, in wire/report order.
    pub const ALL: [Role; 2] = [Role::Draft, Role::Target];

    /// Stable snake_case name used in JSON snapshots and reports.
    pub fn name(self) -> &'static str {
        match self {
            Role::Draft => "draft",
            Role::Target => "target",
        }
    }
}

/// Streaming acceptance counters for one role (atomics; see [`RoleSnap`]).
#[derive(Debug, Default)]
struct RoleAccept {
    rounds: AtomicU64,
    proposed: AtomicU64,
    accepted: AtomicU64,
    gamma_sum: AtomicU64,
}

/// A plain-value snapshot of one role's acceptance counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RoleSnap {
    /// Verify rounds observed.
    pub rounds: u64,
    /// Units proposed: draft events for [`Role::Draft`], one whole-draft
    /// trial per round for [`Role::Target`].
    pub proposed: u64,
    /// Units accepted out of `proposed`.
    pub accepted: u64,
    /// Sum of draft lengths γ across rounds (for mean-γ readout).
    pub gamma_sum: u64,
}

impl RoleSnap {
    /// The activity between `earlier` and `self`, saturating per field.
    pub fn since(&self, earlier: &RoleSnap) -> RoleSnap {
        RoleSnap {
            rounds: self.rounds.saturating_sub(earlier.rounds),
            proposed: self.proposed.saturating_sub(earlier.proposed),
            accepted: self.accepted.saturating_sub(earlier.accepted),
            gamma_sum: self.gamma_sum.saturating_sub(earlier.gamma_sum),
        }
    }

    /// Acceptance rate α = accepted / proposed; NaN when nothing proposed.
    pub fn alpha(&self) -> f64 {
        self.accepted as f64 / self.proposed as f64
    }

    /// Mean accepted units per verify round; NaN when no rounds ran.
    pub fn accepted_per_round(&self) -> f64 {
        self.accepted as f64 / self.rounds as f64
    }

    /// Mean draft length γ per round; NaN when no rounds ran.
    pub fn mean_gamma(&self) -> f64 {
        self.gamma_sum as f64 / self.rounds as f64
    }
}

/// A full metrics registry: one [`Histo`] per [`Stage`] plus one
/// acceptance tracker per [`Role`].
///
/// The process-wide instance behind [`snapshot`]/[`record_duration`] is
/// reached through the module-level free functions, which honor
/// [`set_enabled`]; `Registry` methods themselves always record, so tests
/// can exercise isolated instances deterministically.
#[derive(Debug)]
pub struct Registry {
    stages: [Histo; NUM_STAGES],
    roles: [RoleAccept; 2],
}

impl Registry {
    /// A fresh all-zero registry.
    pub fn new() -> Self {
        Registry {
            stages: std::array::from_fn(|_| Histo::new()),
            roles: std::array::from_fn(|_| RoleAccept::default()),
        }
    }

    /// Record one latency sample for `stage`.
    pub fn record_ns(&self, stage: Stage, ns: u64) {
        self.stages[stage as usize].record_ns(ns);
    }

    /// Record one SD verify round: `gamma` events drafted, `accepted` of
    /// them kept, `all_accepted` when the whole draft survived (the bonus
    /// event fired).
    pub fn record_round(&self, gamma: usize, accepted: usize, all_accepted: bool) {
        let d = &self.roles[Role::Draft as usize];
        d.rounds.fetch_add(1, Ordering::Relaxed);
        d.proposed.fetch_add(gamma as u64, Ordering::Relaxed);
        d.accepted.fetch_add(accepted as u64, Ordering::Relaxed);
        d.gamma_sum.fetch_add(gamma as u64, Ordering::Relaxed);
        let t = &self.roles[Role::Target as usize];
        t.rounds.fetch_add(1, Ordering::Relaxed);
        t.proposed.fetch_add(1, Ordering::Relaxed);
        t.accepted.fetch_add(all_accepted as u64, Ordering::Relaxed);
        t.gamma_sum.fetch_add(gamma as u64, Ordering::Relaxed);
    }

    /// A point-in-time copy of every counter in the registry.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            stages: std::array::from_fn(|i| self.stages[i].snap()),
            roles: std::array::from_fn(|i| RoleSnap {
                rounds: self.roles[i].rounds.load(Ordering::Relaxed),
                proposed: self.roles[i].proposed.load(Ordering::Relaxed),
                accepted: self.roles[i].accepted.load(Ordering::Relaxed),
                gamma_sum: self.roles[i].gamma_sum.load(Ordering::Relaxed),
            }),
        }
    }
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

/// A plain-value snapshot of a whole [`Registry`], indexable by
/// [`Stage`]/[`Role`] and subtractable for windowed readout.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// One histogram snapshot per [`Stage::ALL`] entry, same order.
    pub stages: [HistoSnap; NUM_STAGES],
    /// One acceptance snapshot per [`Role::ALL`] entry, same order.
    pub roles: [RoleSnap; 2],
}

impl Default for Snapshot {
    fn default() -> Self {
        Snapshot { stages: [HistoSnap::default(); NUM_STAGES], roles: [RoleSnap::default(); 2] }
    }
}

impl Snapshot {
    /// The histogram snapshot for `stage`.
    pub fn stage(&self, stage: Stage) -> &HistoSnap {
        &self.stages[stage as usize]
    }

    /// The acceptance snapshot for `role`.
    pub fn role(&self, role: Role) -> &RoleSnap {
        &self.roles[role as usize]
    }

    /// The activity between `earlier` and `self` (per-field saturating
    /// subtraction) — the delta-window primitive behind the server's
    /// `{"op":"metrics","delta":true}`.
    pub fn since(&self, earlier: &Snapshot) -> Snapshot {
        Snapshot {
            stages: std::array::from_fn(|i| self.stages[i].since(&earlier.stages[i])),
            roles: std::array::from_fn(|i| self.roles[i].since(&earlier.roles[i])),
        }
    }

    /// Serialize to the wire JSON shape used by `Request::Metrics`:
    /// `{"stages":{name:{count,total_ms,mean_us,p50_us,p95_us,p99_us}},
    ///   "roles":{name:{rounds,proposed,accepted,alpha,accepted_per_round,
    ///   mean_gamma}}}`. Undefined ratios (empty stage/role) serialize as
    /// `null`, never NaN.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        let us = |ns: u64| ns as f64 / 1e3;
        let finite = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let stages = Stage::ALL
            .iter()
            .map(|&s| {
                let h = self.stage(s);
                let q = |p: f64| match h.quantile_ns(p) {
                    Some(ns) => Json::Num(us(ns)),
                    None => Json::Null,
                };
                (
                    s.name(),
                    obj(vec![
                        ("count", Json::Num(h.count as f64)),
                        ("total_ms", Json::Num(h.sum_ns as f64 / 1e6)),
                        ("mean_us", finite(h.mean_ns() / 1e3)),
                        ("p50_us", q(0.50)),
                        ("p95_us", q(0.95)),
                        ("p99_us", q(0.99)),
                    ]),
                )
            })
            .collect::<Vec<_>>();
        let roles = Role::ALL
            .iter()
            .map(|&r| {
                let a = self.role(r);
                (
                    r.name(),
                    obj(vec![
                        ("rounds", Json::Num(a.rounds as f64)),
                        ("proposed", Json::Num(a.proposed as f64)),
                        ("accepted", Json::Num(a.accepted as f64)),
                        ("alpha", finite(a.alpha())),
                        ("accepted_per_round", finite(a.accepted_per_round())),
                        ("mean_gamma", finite(a.mean_gamma())),
                    ]),
                )
            })
            .collect::<Vec<_>>();
        obj(vec![
            ("stages", Json::Obj(stages.into_iter().map(|(k, v)| (k.to_string(), v)).collect())),
            ("roles", Json::Obj(roles.into_iter().map(|(k, v)| (k.to_string(), v)).collect())),
        ])
    }

    /// Human-readable multi-line summary: one line per active stage
    /// (count, mean, p50/p95/p99 in µs) and per active role (rounds, α,
    /// accepted/round, mean γ). Shared by `tppsd sample --metrics`,
    /// `serve.rs` and the benches.
    pub fn report(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let us = |ns: u64| ns as f64 / 1e3;
        for &stage in &Stage::ALL {
            let h = self.stage(stage);
            if h.count == 0 {
                continue;
            }
            let q = |p: f64| us(h.quantile_ns(p).unwrap_or(0));
            writeln!(
                s,
                "  {:<16} n={:<9} mean {:>10.1}us  p50 {:>10.1}us  p95 {:>10.1}us  \
                 p99 {:>10.1}us",
                stage.name(),
                h.count,
                h.mean_ns() / 1e3,
                q(0.50),
                q(0.95),
                q(0.99),
            )
            .expect("write to String");
        }
        for &role in &Role::ALL {
            let a = self.role(role);
            if a.rounds == 0 {
                continue;
            }
            writeln!(
                s,
                "  accept[{:<6}]   rounds={:<7} alpha {:.3}  accepted/round {:.2}  \
                 mean_gamma {:.2}",
                role.name(),
                a.rounds,
                a.alpha(),
                a.accepted_per_round(),
                a.mean_gamma(),
            )
            .expect("write to String");
        }
        if s.is_empty() {
            return "telemetry: no samples recorded".to_string();
        }
        s.pop();
        format!("telemetry (per-stage latency + acceptance):\n{s}")
    }
}

/// Process-wide enable flag. Recording through the free functions and
/// [`Span`] is a no-op when disabled; snapshots still read.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Enable or disable global recording (used by the `bench_hotpath` A/B
/// gate). Snapshots and reports keep working either way.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether global recording is currently enabled.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The process-wide registry.
fn global() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::new)
}

/// Record `ns` nanoseconds for `stage` in the global registry,
/// unconditionally (callers that pre-check [`enabled`] use this).
pub fn record_ns(stage: Stage, ns: u64) {
    global().record_ns(stage, ns);
}

/// Record a duration for `stage` in the global registry, if enabled.
pub fn record_duration(stage: Stage, d: Duration) {
    if enabled() {
        global().record_ns(stage, d.as_nanos() as u64);
    }
}

/// Record one SD verify round in the global registry, if enabled
/// (see [`Registry::record_round`] for the per-role accounting).
pub fn record_round(gamma: usize, accepted: usize, all_accepted: bool) {
    if enabled() {
        global().record_round(gamma, accepted, all_accepted);
    }
}

/// `Some(Instant::now())` when recording is enabled, else `None` — the
/// zero-cost-when-off half of a manual span.
pub fn now_if_enabled() -> Option<Instant> {
    enabled().then(Instant::now)
}

/// Close a manual span opened with [`now_if_enabled`]: record the elapsed
/// time once under each stage in `stages`. No-op when `start` is `None`.
pub fn record_since(start: Option<Instant>, stages: &[Stage]) {
    if let Some(t0) = start {
        let ns = t0.elapsed().as_nanos() as u64;
        for &s in stages {
            record_ns(s, ns);
        }
    }
}

/// A point-in-time copy of the global registry.
pub fn snapshot() -> Snapshot {
    global().snapshot()
}

/// The shared human-readable report over the global registry
/// (see [`Snapshot::report`]).
pub fn report() -> String {
    snapshot().report()
}

/// RAII timing guard: records the elapsed wall-clock time for one stage
/// into the global registry on drop. Constructing one while telemetry is
/// disabled yields a no-op guard (no `Instant` is ever taken).
#[derive(Debug)]
pub struct Span {
    stage: Stage,
    start: Option<Instant>,
}

impl Span {
    /// Start timing `stage` (no-op guard when telemetry is disabled).
    pub fn start(stage: Stage) -> Self {
        Span { stage, start: now_if_enabled() }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            record_ns(self.stage, t0.elapsed().as_nanos() as u64);
        }
    }
}
