//! Chaos-recovery suite (ISSUE 6 acceptance): deterministic fault
//! injection must be *invisible in the outputs* and *visible in the
//! counters*.
//!
//! Properties pinned here (DESIGN.md §13):
//!   * under any recoverable fault plan, AR and SD fleet outputs — events
//!     AND `SampleStats` — are bit-for-bit identical to the fault-free
//!     run, on the direct backend path and through the coordinator's
//!     executors;
//!   * every injected fault is tallied ([`ChaosStats`]) and reconciles
//!     with the consumers' retry/recovery counters;
//!   * an unrecoverable plan yields a structured `{"ok":false,...}`
//!     server error — no hang, no poisoned connection.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use tpp_sd::coordinator::{
    Client, ExecutorHandle, Request, RetryPolicy, SampleRequest, Server,
};
use tpp_sd::runtime::{
    Backend, ChaosBackend, FaultPlan, Forward, NativeBackend, SeqInput, Uncached,
};
use tpp_sd::sampler::{
    fleet_seeds, sample_ar_fleet, sample_sd_fleet, FleetRuns, Gamma, SampleCfg, SdCfg,
};
use tpp_sd::util::rng::Rng;

mod common;
use common::assert_stats_eq;

const T_END: f64 = 6.0;

fn native() -> Arc<dyn Backend> {
    Arc::new(NativeBackend::new())
}

fn sd_cfg(num_types: usize) -> SdCfg {
    SdCfg {
        sample: SampleCfg { num_types, t_end: T_END, max_events: 4096 },
        gamma: Gamma::Fixed(5),
        ..Default::default()
    }
}

fn ar_cfg(num_types: usize) -> SampleCfg {
    SampleCfg { num_types, t_end: T_END, max_events: 4096 }
}

fn load(c: &AtomicUsize) -> usize {
    c.load(Ordering::Relaxed)
}

/// Fault-free SD and AR fleet runs on the plain native backend — the
/// ground truth every chaotic run must reproduce bit-for-bit.
fn baseline(dataset: &str, num_types: usize, seeds: &[u64]) -> (FleetRuns, FleetRuns) {
    let b = NativeBackend::new();
    let target = b.load_model(dataset, "thp", "target").unwrap();
    let draft = b.load_model(dataset, "thp", "draft").unwrap();
    let (sd, _) = sample_sd_fleet(&target, &draft, &sd_cfg(num_types), seeds).unwrap();
    let (ar, _) = sample_ar_fleet(&target, &ar_cfg(num_types), seeds).unwrap();
    (sd, ar)
}

fn assert_runs_eq(got: &FleetRuns, want: &FleetRuns, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: run count");
    for (i, ((ge, gs), (we, ws))) in got.iter().zip(want).enumerate() {
        assert!(!we.is_empty(), "{what} seq {i}: degenerate baseline");
        assert_eq!(ge, we, "{what} seq {i}: events diverge under faults");
        assert_stats_eq(gs, ws, &format!("{what} seq {i}"));
    }
}

fn random_seq(rng: &mut Rng, max_n: usize) -> SeqInput {
    let n = 1 + rng.below(max_n);
    let mut s = SeqInput::default();
    let mut t = 0.0;
    for _ in 0..n {
        t += rng.exponential(3.0);
        s.times.push(t);
        s.types.push(0);
    }
    s
}

#[test]
fn fault_plan_classification() {
    assert!(!FaultPlan::parse("err=1").unwrap().recoverable());
    assert!(!FaultPlan::parse("die=0.5").unwrap().recoverable());
    // losses, corruption, delays and sub-certain errors are all survivable
    assert!(FaultPlan::parse("err=0.99,loss=1,pad=1,delay=1").unwrap().recoverable());
    assert!(FaultPlan::parse("").unwrap().is_noop());
    assert!(FaultPlan::parse("bogus=1").is_err());
    assert!(FaultPlan::parse("err=1.5").is_err());
}

/// Direct (executor-less) path: stream losses force the engine through
/// the recovery ladder — reopen + rebase, degrading to full-window
/// forwards when streams keep dying — and none of it may move an event
/// or a deterministic counter.
#[test]
fn recoverable_plans_are_bit_exact_on_the_direct_path() {
    let seeds = fleet_seeds(42, 3);
    let (want_sd, want_ar) = baseline("hawkes", 1, &seeds);
    for spec in ["seed=4,loss=0.25", "seed=6,loss=0.15,delay=0.05,delay-ms=1"] {
        let plan = FaultPlan::parse(spec).unwrap();
        assert!(plan.recoverable(), "{spec}");
        let chaos = ChaosBackend::new(native(), plan);
        let stats = chaos.stats();
        let target = chaos.load_model("hawkes", "thp", "target").unwrap();
        let draft = chaos.load_model("hawkes", "thp", "draft").unwrap();
        let (sd, fleet_sd) = sample_sd_fleet(&target, &draft, &sd_cfg(1), &seeds).unwrap();
        assert_runs_eq(&sd, &want_sd, &format!("[{spec}] sd"));
        let (ar, fleet_ar) = sample_ar_fleet(&target, &ar_cfg(1), &seeds).unwrap();
        assert_runs_eq(&ar, &want_ar, &format!("[{spec}] ar"));
        assert!(load(&stats.losses) >= 1, "[{spec}] loss plan never fired");
        // every forced stream loss must have been recovered or degraded
        let handled = fleet_sd.stream_recoveries
            + fleet_sd.degraded_uncached
            + fleet_ar.stream_recoveries
            + fleet_ar.degraded_uncached;
        assert!(handled >= 1, "[{spec}] losses injected but never handled");
    }
}

/// Scrambled padding rows (the classic batching bug, injected on purpose)
/// must never leak into real rows: sessions only read their own row, so
/// the outputs are bit-identical even when every padding row is garbage.
#[test]
fn padding_corruption_never_leaks_into_real_rows() {
    let seeds = fleet_seeds(42, 3);
    let (want_sd, _) = baseline("hawkes", 1, &seeds);
    let chaos = ChaosBackend::new(native(), FaultPlan::parse("seed=9,pad=0.5").unwrap());
    let stats = chaos.stats();
    let target = chaos.load_model("hawkes", "thp", "target").unwrap();
    let draft = chaos.load_model("hawkes", "thp", "draft").unwrap();
    // Uncached forces the full-forward path, where padding exists at all.
    let (sd, _) =
        sample_sd_fleet(&Uncached(&target), &Uncached(&draft), &sd_cfg(1), &seeds).unwrap();
    assert_runs_eq(&sd, &want_sd, "pad/sd");
    assert!(load(&stats.corruptions) >= 1, "pad plan never fired");
}

/// Certain stream loss (`loss=1`): every incremental stream dies on its
/// first delta, every session must degrade to full-window forwards — and
/// the outputs still cannot move.
#[test]
fn total_stream_loss_degrades_to_uncached_but_stays_bit_exact() {
    let seeds = fleet_seeds(42, 3);
    let (want_sd, _) = baseline("hawkes", 1, &seeds);
    let chaos = ChaosBackend::new(native(), FaultPlan::parse("seed=8,loss=1").unwrap());
    let target = chaos.load_model("hawkes", "thp", "target").unwrap();
    let draft = chaos.load_model("hawkes", "thp", "draft").unwrap();
    let (sd, fleet) = sample_sd_fleet(&target, &draft, &sd_cfg(1), &seeds).unwrap();
    assert_runs_eq(&sd, &want_sd, "loss=1/sd");
    assert!(
        fleet.degraded_uncached >= seeds.len(),
        "every session's streams die; expected ≥ {} degradations, saw {}",
        seeds.len(),
        fleet.degraded_uncached
    );
    assert_eq!(fleet.stream_recoveries, 0, "no recovery can succeed under loss=1");
}

/// Serving path: transient errors are absorbed by the handle's bounded
/// retry, every injected error reconciles 1:1 with a counted retry, and
/// a retried forward returns bit-identical rows to the fault-free direct
/// path.
#[test]
fn executor_retries_reconcile_with_injected_errors() {
    let chaos = Arc::new(ChaosBackend::new(
        native(),
        FaultPlan::parse("seed=11,err=0.2").unwrap(),
    ));
    let stats = chaos.stats();
    let handle = ExecutorHandle::spawn_with_policy(
        chaos,
        "hawkes",
        "thp",
        "draft",
        8,
        Duration::from_millis(1),
        RetryPolicy {
            max_attempts: 10,
            backoff: Duration::from_micros(50),
            deadline: Duration::from_secs(30),
        },
    )
    .unwrap();
    let direct = NativeBackend::new().load_model("hawkes", "thp", "draft").unwrap();
    let mut rng = Rng::new(3);
    for _ in 0..100 {
        let seq = random_seq(&mut rng, 30);
        let row = seq.times.len();
        let got = handle.forward1(seq.clone()).unwrap();
        let want = direct.forward1(seq).unwrap();
        assert_eq!(
            got.mixture(row).mu,
            want.mixture(row).mu,
            "a retried forward must return bit-identical rows"
        );
    }
    assert!(load(&stats.errors) >= 1, "err plan never fired");
    assert_eq!(
        load(&handle.stats.retries),
        load(&stats.errors),
        "every injected transient error must be retried exactly once"
    );
    assert_eq!(load(&handle.stats.gave_up), 0);
    assert_eq!(load(&handle.stats.timeouts), 0);
}

/// The crown-jewel property: AR and SD fleets driven through batching
/// executors over a backend injecting BOTH transient errors and stream
/// losses reproduce the fault-free direct runs bit-for-bit, with no
/// request ever given up on.
#[test]
fn fleet_over_chaotic_executors_is_bit_exact() {
    let seeds = fleet_seeds(21, 3);
    let (want_sd, want_ar) = baseline("hawkes", 1, &seeds);
    let chaos = Arc::new(ChaosBackend::new(
        native(),
        FaultPlan::parse("seed=13,err=0.15,loss=0.1").unwrap(),
    ));
    let stats = chaos.stats();
    let policy = RetryPolicy {
        max_attempts: 10,
        backoff: Duration::from_micros(50),
        deadline: Duration::from_secs(30),
    };
    let target = ExecutorHandle::spawn_with_policy(
        chaos.clone(),
        "hawkes",
        "thp",
        "target",
        8,
        Duration::from_millis(1),
        policy,
    )
    .unwrap();
    let draft = ExecutorHandle::spawn_with_policy(
        chaos.clone(),
        "hawkes",
        "thp",
        "draft",
        8,
        Duration::from_millis(1),
        policy,
    )
    .unwrap();
    let (sd, fleet_sd) = sample_sd_fleet(&target, &draft, &sd_cfg(1), &seeds).unwrap();
    assert_runs_eq(&sd, &want_sd, "executor-chaos/sd");
    let (ar, fleet_ar) = sample_ar_fleet(&target, &ar_cfg(1), &seeds).unwrap();
    assert_runs_eq(&ar, &want_ar, "executor-chaos/ar");
    assert!(stats.total() > 0, "chaos plan never fired");
    assert!(load(&stats.losses) >= 1, "loss component never fired");
    assert_eq!(
        load(&target.stats.gave_up) + load(&draft.stats.gave_up),
        0,
        "a recoverable plan must never exhaust the retry budget"
    );
    let handled = fleet_sd.stream_recoveries
        + fleet_sd.degraded_uncached
        + fleet_ar.stream_recoveries
        + fleet_ar.degraded_uncached;
    assert!(handled >= 1, "losses injected but never recovered or degraded");
}

/// Server front-end: an unrecoverable chaos spec must come back as a
/// structured `{"ok":false,...}` error — promptly, leaving the connection
/// healthy — while a recoverable spec returns a response whose events are
/// bit-identical to the fault-free one. Fault-free traffic shares nothing
/// with chaos traffic (per-spec routers).
#[test]
fn server_chaos_errors_are_structured_and_recoverable_specs_are_exact() {
    let server = Server::bind(native(), "127.0.0.1:0", 8, Duration::from_millis(1)).unwrap();
    let addr = server.addr;
    std::thread::spawn(move || server.serve());
    let mut cli = Client::connect(addr).unwrap();

    let mk = |chaos: &str, seed: u64| {
        Request::Sample(
            SampleRequest::builder()
                .dataset("hawkes")
                .encoder("thp")
                .method("sd")
                .gamma(5)
                .t_end(2.0)
                .seed(seed)
                .chaos(chaos)
                .build(),
        )
    };

    // err=1: every forward fails; bounded retries exhaust -> structured error
    let resp = cli.call(&mk("seed=1,err=1", 1)).unwrap();
    assert!(resp.contains("\"ok\":false"), "err=1 must fail structurally: {resp}");
    assert!(resp.contains("executor"), "error should name the executor: {resp}");

    // die=1: the executor thread is killed; still a structured error, no hang
    let resp = cli.call(&mk("seed=2,die=1", 2)).unwrap();
    assert!(resp.contains("\"ok\":false"), "die=1 must fail structurally: {resp}");

    // a malformed spec is rejected cleanly too
    let resp = cli.call(&mk("bogus=1", 3)).unwrap();
    assert!(resp.contains("\"ok\":false"), "bad spec must be rejected: {resp}");

    // the connection survived all of the above
    assert!(cli.call(&Request::Ping).unwrap().contains("pong"));

    // recoverable spec: events bit-identical to the fault-free response
    let (clean, _) =
        tpp_sd::coordinator::protocol::parse_response(&cli.call(&mk("", 5)).unwrap()).unwrap();
    let (faulty, _) = tpp_sd::coordinator::protocol::parse_response(
        &cli.call(&mk("seed=3,loss=0.2", 5)).unwrap(),
    )
    .unwrap();
    assert!(!clean.is_empty(), "degenerate fault-free sample");
    assert_eq!(clean, faulty, "recoverable chaos moved an event on the server path");
}
