//! Coordinator tests: batcher invariants (no request lost / duplicated,
//! results independent of batching), router reuse, and the TCP server
//! round-trip — all running on the active backend (native by default, so
//! no artifacts are required).

use std::sync::Arc;
use std::time::Duration;

use tpp_sd::coordinator::{
    Client, ExecutorHandle, Request, RetryPolicy, Router, SampleRequest, Server,
};
use tpp_sd::runtime::{
    Backend, BatchForward, CachedForward, ChaosBackend, FaultPlan, Forward, ModelBackend, SeqDelta,
    SeqInput,
};
use tpp_sd::util::rng::Rng;

fn backend() -> Arc<dyn Backend> {
    tpp_sd::runtime::discover_backend().expect("backend")
}

fn random_seq(rng: &mut Rng, max_n: usize) -> SeqInput {
    let n = 1 + rng.below(max_n);
    let mut s = SeqInput::default();
    let mut t = 0.0;
    for _ in 0..n {
        t += rng.exponential(3.0);
        s.times.push(t);
        s.types.push(0);
    }
    s
}

/// Every concurrent request gets exactly one reply carrying ITS sequence's
/// results (matched against the direct path), regardless of batching.
#[test]
fn batcher_preserves_per_request_results() {
    let b = backend();
    let handle = ExecutorHandle::spawn(
        b.clone(),
        "hawkes",
        "thp",
        "draft",
        8,
        Duration::from_millis(5),
    )
    .unwrap();
    let direct = b.load_model("hawkes", "thp", "draft").unwrap();

    let mut rng = Rng::new(42);
    let seqs: Vec<SeqInput> = (0..24).map(|_| random_seq(&mut rng, 40)).collect();

    // fire all requests concurrently so the batcher actually batches
    let mut joins = Vec::new();
    for seq in seqs.clone() {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let row = seq.times.len();
            let out = h.forward1(seq).unwrap();
            (row, out.mixture(row).mu)
        }));
    }
    let results: Vec<(usize, Vec<f64>)> =
        joins.into_iter().map(|j| j.join().unwrap()).collect();

    assert!(
        handle.stats.batches.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "no batches formed"
    );
    // compare each against the direct path
    for (seq, (row, mu)) in seqs.iter().zip(&results) {
        let want = direct
            .forward(std::slice::from_ref(seq))
            .unwrap()
            .mixture(0, *row)
            .mu;
        for (a, c) in mu.iter().zip(&want) {
            assert!((a - c).abs() < 1e-4, "batched {a} vs direct {c}");
        }
    }
}

#[test]
fn batcher_batches_under_concurrency() {
    let handle = ExecutorHandle::spawn(
        backend(),
        "hawkes",
        "thp",
        "draft",
        8,
        Duration::from_millis(10),
    )
    .unwrap();
    let mut joins = Vec::new();
    for i in 0..16 {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(i);
            let seq = random_seq(&mut rng, 30);
            h.forward1(seq).unwrap();
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let occ = handle.stats.occupancy();
    assert!(occ > 1.0, "expected batching under concurrency, occupancy={occ}");
}

/// Regression (ISSUE 2 satellite): `requests` counts every enqueued
/// request exactly once — at submit time, not per drained batch — so it
/// always equals the number of `forward1`/`forward_batch` submissions,
/// while `batched_requests`/`batches` describe how they coalesced.
#[test]
fn stats_count_requests_at_enqueue() {
    let handle = ExecutorHandle::spawn(
        backend(),
        "hawkes",
        "thp",
        "draft",
        8,
        Duration::from_millis(20),
    )
    .unwrap();
    let mut rng = Rng::new(7);
    // 5 sequential single requests: no concurrency, so 5 batches of 1
    for _ in 0..5 {
        handle.forward1(random_seq(&mut rng, 20)).unwrap();
    }
    let load = |c: &std::sync::atomic::AtomicUsize| c.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(load(&handle.stats.requests), 5);
    assert_eq!(load(&handle.stats.batched_requests), 5);
    assert_eq!(load(&handle.stats.batches), 5);

    // 8-wide waves: 8 more requests each, coalescing into few batches.
    // Retried a few times because a sender preempted for longer than the
    // batch window can defeat coalescing on a loaded CI runner — the
    // enqueue-time counters stay exact throughout, which is what this
    // test pins.
    let mut sent = 5usize;
    for _ in 0..3 {
        let seqs: Vec<SeqInput> = (0..8).map(|_| random_seq(&mut rng, 20)).collect();
        let outs = handle.forward_batch(seqs).unwrap();
        assert_eq!(outs.len(), 8);
        sent += 8;
        assert_eq!(load(&handle.stats.requests), sent, "requests counted at enqueue");
        assert_eq!(load(&handle.stats.batched_requests), sent, "all requests eventually batched");
        if load(&handle.stats.max_batch_seen) >= 2 {
            break;
        }
    }
    assert!(load(&handle.stats.max_batch_seen) >= 2, "no wave coalesced in 3 attempts");
    assert!(load(&handle.stats.batches) < sent, "the waves must coalesce");
    assert!(handle.stats.occupancy() > 1.0);
}

#[test]
fn spawn_surfaces_load_errors() {
    let err = ExecutorHandle::spawn(
        backend(),
        "no_such_dataset",
        "thp",
        "draft",
        8,
        Duration::from_millis(1),
    );
    assert!(err.is_err(), "unknown dataset must fail at spawn");
}

#[test]
fn router_reuses_pairs_and_rejects_unknown() {
    let router = Router::new(backend(), 8, Duration::from_millis(1)).unwrap();
    assert!(router.num_types("hawkes").unwrap() == 1);
    assert!(router.num_types("nope").is_err());
    let a = router.route("hawkes", "thp", "draft").unwrap();
    let b = router.route("hawkes", "thp", "draft").unwrap();
    // reuse: same underlying executor (stats Arc shared)
    assert!(std::sync::Arc::ptr_eq(&a.target.stats, &b.target.stats));
    assert!(router.datasets().contains(&"multihawkes".to_string()));
}

#[test]
fn server_roundtrip_ar_and_sd() {
    let server = Server::bind(backend(), "127.0.0.1:0", 8, Duration::from_millis(1)).unwrap();
    let addr = server.addr;
    std::thread::spawn(move || server.serve());

    let mut cli = Client::connect(addr).unwrap();
    let pong = cli.call(&Request::Ping).unwrap();
    assert!(pong.contains("pong"));

    for method in ["ar", "sd", "sd-adaptive"] {
        let resp = cli
            .call(&Request::Sample(
                SampleRequest::builder()
                    .dataset("hawkes")
                    .encoder("thp")
                    .method(method)
                    .gamma(5)
                    .t_end(2.0)
                    .seed(1)
                    .build(),
            ))
            .unwrap();
        let (events, wall_ms) =
            tpp_sd::coordinator::protocol::parse_response(&resp).unwrap();
        assert!(wall_ms > 0.0, "{method}: {resp}");
        assert!(tpp_sd::events::is_valid_sequence(&events, 2.0), "{method}");
    }

    // unknown dataset → clean error, connection stays usable
    let resp = cli
        .call(&Request::Sample(
            SampleRequest::builder()
                .dataset("bogus")
                .encoder("thp")
                .method("ar")
                .gamma(1)
                .t_end(1.0)
                .build(),
        ))
        .unwrap();
    assert!(resp.contains("\"ok\":false"));
    assert!(cli.call(&Request::Ping).unwrap().contains("pong"));
}

/// The `"cached":false` knob forces full-window forwards through the same
/// executors; the sampled events must be bit-identical to the default
/// cached path (ISSUE 3 — the flag moves wall-clock, never probability).
#[test]
fn server_cached_flag_does_not_change_events() {
    let server = Server::bind(backend(), "127.0.0.1:0", 8, Duration::from_millis(1)).unwrap();
    let addr = server.addr;
    std::thread::spawn(move || server.serve());
    let mut cli = Client::connect(addr).unwrap();
    for method in ["ar", "sd"] {
        let mk = |cached: bool| {
            Request::Sample(
                SampleRequest::builder()
                    .dataset("hawkes")
                    .encoder("thp")
                    .method(method)
                    .gamma(6)
                    .t_end(4.0)
                    .seed(9)
                    .cached(cached)
                    .build(),
            )
        };
        let (on, _) =
            tpp_sd::coordinator::protocol::parse_response(&cli.call(&mk(true)).unwrap()).unwrap();
        let (off, _) =
            tpp_sd::coordinator::protocol::parse_response(&cli.call(&mk(false)).unwrap()).unwrap();
        assert!(!on.is_empty(), "{method}: degenerate sample");
        assert_eq!(on, off, "{method}: cached vs uncached events diverge");
    }
}

/// `sample_fleet` over the wire: sequence `i` must be byte-identical to a
/// plain `sample` request with `seed + i` — the fleet path re-routes the
/// sampler through the engine without moving a single probability.
#[test]
fn server_fleet_matches_single_samples() {
    let server = Server::bind(backend(), "127.0.0.1:0", 8, Duration::from_millis(1)).unwrap();
    let addr = server.addr;
    std::thread::spawn(move || server.serve());
    let mut cli = Client::connect(addr).unwrap();

    let base = SampleRequest::builder()
        .dataset("hawkes")
        .encoder("thp")
        .method("sd")
        .gamma(5)
        .t_end(3.0)
        .seed(10)
        .build();
    let mut fleet = base.clone();
    fleet.n_seq = 3;
    let resp = cli.call(&Request::SampleFleet(fleet)).unwrap();
    let sequences = tpp_sd::coordinator::protocol::parse_fleet_response(&resp).unwrap();
    assert_eq!(sequences.len(), 3);
    for (i, seq) in sequences.iter().enumerate() {
        let mut single = base.clone();
        single.seed = base.seed + i as u64;
        let resp = cli.call(&Request::Sample(single)).unwrap();
        let (events, _) = tpp_sd::coordinator::protocol::parse_response(&resp).unwrap();
        assert_eq!(seq, &events, "fleet sequence {i} vs single sample");
        assert!(tpp_sd::events::is_valid_sequence(seq, 3.0));
    }
}

fn load(c: &std::sync::atomic::AtomicUsize) -> usize {
    c.load(std::sync::atomic::Ordering::Relaxed)
}

/// A dead executor and an exceeded deadline are structurally distinct
/// failures (ISSUE 6): the former reports "died" immediately (no retry
/// can help), the latter reports the deadline and counts a timeout — the
/// two must never conflate, or operators would retry the unretryable.
#[test]
fn dead_executor_vs_deadline_are_distinct_errors() {
    // die=1: the executor thread panics on its first forward; the handle
    // must surface the death without hanging or retrying.
    let chaos = Arc::new(ChaosBackend::new(
        backend(),
        FaultPlan::parse("seed=1,die=1").unwrap(),
    ));
    let handle =
        ExecutorHandle::spawn(chaos, "hawkes", "thp", "draft", 8, Duration::from_millis(1))
            .unwrap();
    let mut rng = Rng::new(1);
    let err = handle.forward1(random_seq(&mut rng, 10)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("died"), "want a death error, got: {msg}");
    assert_eq!(load(&handle.stats.timeouts), 0);

    // delay=1 longer than a tight per-request deadline: the handle gives
    // up with a deadline error and counts a timeout, not a death.
    let chaos = Arc::new(ChaosBackend::new(
        backend(),
        FaultPlan::parse("seed=2,delay=1,delay-ms=200").unwrap(),
    ));
    let handle = ExecutorHandle::spawn_with_policy(
        chaos,
        "hawkes",
        "thp",
        "draft",
        8,
        Duration::from_millis(1),
        RetryPolicy {
            max_attempts: 2,
            backoff: Duration::from_micros(100),
            deadline: Duration::from_millis(40),
        },
    )
    .unwrap();
    let err = handle.forward1(random_seq(&mut rng, 10)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("deadline"), "want a deadline error, got: {msg}");
    assert!(!msg.contains("died"), "deadline must not report a death: {msg}");
    assert!(load(&handle.stats.timeouts) >= 1);
}

/// Stream control ops (open/rewind/close) are served on receipt, not held
/// for the batch window: with a pathologically long window they must
/// still return immediately.
#[test]
fn stream_ops_bypass_the_batch_window() {
    let handle = ExecutorHandle::spawn(
        backend(),
        "hawkes",
        "thp",
        "draft",
        8,
        Duration::from_secs(3),
    )
    .unwrap();
    let t0 = std::time::Instant::now();
    let sid = handle.open_stream().unwrap();
    handle.rewind(sid, 0).unwrap();
    handle.close_stream(sid);
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "control ops waited for the batch window: {:?}",
        t0.elapsed()
    );
}

/// `stats` must carry the FULL `BatcherStats` snapshot for every live
/// executor (ISSUE 8 satellite): the response previously summarized a
/// couple of counters; this pins every field so a dropped counter is a
/// wire-protocol regression, not a silent omission.
#[test]
fn stats_reports_executor_counters() {
    let server = Server::bind(backend(), "127.0.0.1:0", 8, Duration::from_millis(1)).unwrap();
    let addr = server.addr;
    std::thread::spawn(move || server.serve());
    let mut cli = Client::connect(addr).unwrap();

    // one sample so the router holds exactly one pair (2 executors)
    cli.call(&Request::Sample(
        SampleRequest::builder()
            .dataset("hawkes")
            .encoder("thp")
            .method("sd")
            .gamma(4)
            .t_end(2.0)
            .seed(3)
            .build(),
    ))
    .unwrap();

    let resp = cli.call(&Request::Stats).unwrap();
    let j = tpp_sd::util::json::Json::parse(&resp).unwrap();
    assert_eq!(j.bool_at("ok"), Some(true));
    let execs = match j.path("executors") {
        Some(tpp_sd::util::json::Json::Arr(v)) => v,
        other => panic!("executors must be an array, got {other:?}"),
    };
    assert_eq!(execs.len(), 2, "one routed pair = target + draft executors");
    const COUNTERS: [&str; 17] = [
        "requests",
        "batches",
        "batched_requests",
        "max_batch_seen",
        "delta_requests",
        "delta_waves",
        "batched_deltas",
        "max_delta_wave",
        "retries",
        "timeouts",
        "gave_up",
        "pool_dispatches",
        "pool_steals",
        "buffers_reused",
        "buffers_allocated",
        "occupancy",
        "delta_occupancy",
    ];
    let mut saw_traffic = false;
    for e in execs {
        assert!(e.str_at("name").is_some(), "executor entry without a name");
        assert!(e.str_at("pair").is_some(), "executor entry without its pair id");
        for key in COUNTERS {
            let v = e.f64_at(&format!("stats.{key}"));
            assert!(v.is_some(), "stats.{key} missing from {e:?}");
        }
        saw_traffic |= e.f64_at("stats.requests").unwrap() > 0.0;
    }
    assert!(saw_traffic, "the sample above must have moved some counter");
}

/// `{"op":"metrics"}` round-trip: absolute snapshots carry per-stage
/// percentiles and per-role acceptance; `delta:true` calls report only the
/// window since that connection's previous metrics call. The registry is
/// process-wide and shared with the other tests in this binary, so window
/// assertions are lower bounds, never idle-zero checks.
#[test]
fn metrics_roundtrip_and_delta_windows() {
    let server = Server::bind(backend(), "127.0.0.1:0", 8, Duration::from_millis(1)).unwrap();
    let addr = server.addr;
    std::thread::spawn(move || server.serve());
    let mut cli = Client::connect(addr).unwrap();

    let sample = |cli: &mut Client, seed: u64| {
        cli.call(&Request::Sample(
            SampleRequest::builder()
                .dataset("hawkes")
                .encoder("thp")
                .method("sd")
                .gamma(5)
                .t_end(2.0)
                .seed(seed)
                .build(),
        ))
        .unwrap()
    };
    sample(&mut cli, 1);

    let resp = cli.call(&Request::Metrics { delta: false }).unwrap();
    let j = tpp_sd::util::json::Json::parse(&resp).unwrap();
    assert_eq!(j.bool_at("ok"), Some(true));
    assert!(
        j.f64_at("telemetry.stages.verify_forward.count").expect("verify_forward") >= 1.0,
        "{resp}"
    );
    let p50 = j.f64_at("telemetry.stages.verify_forward.p50_us").expect("p50");
    let p99 = j.f64_at("telemetry.stages.verify_forward.p99_us").expect("p99");
    assert!(p50 > 0.0 && p99 >= p50, "p50 {p50} p99 {p99}");
    assert!(j.f64_at("telemetry.roles.draft.rounds").expect("rounds") >= 1.0);
    assert!(j.f64_at("telemetry.roles.draft.alpha").is_some(), "alpha absent: {resp}");
    assert!(j.path("executors").is_some(), "metrics carries executor stats too");

    // windowing: set the baseline, sample again, read the delta — the
    // window must contain (at least) that one request's forwards.
    cli.call(&Request::Metrics { delta: true }).unwrap();
    sample(&mut cli, 2);
    let resp = cli.call(&Request::Metrics { delta: true }).unwrap();
    let w = tpp_sd::util::json::Json::parse(&resp).unwrap();
    assert!(
        w.f64_at("telemetry.stages.verify_forward.count").expect("windowed count") >= 1.0,
        "delta window missed the sample: {resp}"
    );
    assert!(w.f64_at("telemetry.roles.draft.rounds").expect("windowed rounds") >= 1.0);
}

/// Regression (ISSUE 8 satellite): a server hangup used to surface as a
/// bogus "unexpected response" parse of an empty line. A zero-byte read
/// now reports a structured connection-closed error, and a configurable
/// read timeout keeps a silent peer from hanging the client forever.
#[test]
fn client_surfaces_server_hangup() {
    use std::io::BufRead;

    // hangup: the acceptor reads the full request line, then drops the
    // socket without replying — the client sees clean EOF, not EPIPE.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let acceptor = std::thread::spawn(move || {
        let (sock, _) = listener.accept().unwrap();
        let mut line = String::new();
        std::io::BufReader::new(sock).read_line(&mut line).unwrap();
    });
    let mut cli = Client::connect(addr).unwrap();
    let err = cli.call(&Request::Ping).unwrap_err();
    acceptor.join().unwrap();
    let msg = format!("{err:#}");
    assert!(msg.contains("connection closed"), "want a hangup error, got: {msg}");

    // timeout: the acceptor holds the connection open without replying;
    // a short read timeout turns the would-be hang into an error.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (hold_tx, hold_rx) = std::sync::mpsc::channel::<()>();
    let acceptor = std::thread::spawn(move || {
        let (sock, _) = listener.accept().unwrap();
        let _ = hold_rx.recv(); // keep the socket open until the test ends
        drop(sock);
    });
    let mut cli = Client::connect(addr).unwrap();
    cli.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    let t0 = std::time::Instant::now();
    assert!(cli.call(&Request::Ping).is_err(), "silent peer must not hang the client");
    assert!(t0.elapsed() < Duration::from_secs(30), "timeout did not fire");
    drop(hold_tx);
    acceptor.join().unwrap();
}

/// `delta_occupancy()` tracks delta waves separately from full-forward
/// batches: under a mixed load the full-batch counters and the delta
/// counters must each stay consistent on their own, never conflated.
#[test]
fn delta_occupancy_accounts_mixed_waves() {
    let handle = ExecutorHandle::spawn(
        backend(),
        "hawkes",
        "thp",
        "draft",
        8,
        Duration::from_millis(10),
    )
    .unwrap();
    // one 4-delta wave enqueued whole + one lone delta
    let delta = |t: f64| SeqDelta { base_len: 0, t0: 0.0, times: vec![t], types: vec![0] };
    let wave: Vec<_> = (0..4)
        .map(|i| (handle.open_stream().unwrap(), delta(0.5 + i as f64)))
        .collect();
    let sids: Vec<_> = wave.iter().map(|(s, _)| *s).collect();
    let outs = handle.forward_delta_batch(wave).unwrap();
    assert_eq!(outs.len(), 4);
    let lone = handle.open_stream().unwrap();
    handle.forward_delta(lone, &delta(9.0)).unwrap();
    // two sequential full forwards ride the full-batch counters only
    let mut rng = Rng::new(5);
    handle.forward1(random_seq(&mut rng, 10)).unwrap();
    handle.forward1(random_seq(&mut rng, 10)).unwrap();
    for sid in sids.into_iter().chain([lone]) {
        handle.close_stream(sid);
    }

    assert_eq!(load(&handle.stats.requests), 7, "2 full + 5 delta submissions");
    assert_eq!(load(&handle.stats.delta_requests), 5);
    assert_eq!(load(&handle.stats.batched_deltas), 5, "every delta served in some wave");
    let waves = load(&handle.stats.delta_waves);
    assert!((1..=5).contains(&waves), "delta waves: {waves}");
    assert!(handle.stats.delta_occupancy() >= 1.0);
    assert!(load(&handle.stats.max_delta_wave) >= 1);
    // full-forward occupancy is computed from full batches alone
    assert_eq!(load(&handle.stats.batched_requests), 2);
    assert_eq!(load(&handle.stats.batches), 2);
    assert!((handle.stats.occupancy() - 1.0).abs() < 1e-12);
}
