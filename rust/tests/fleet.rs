//! Fleet-engine equivalence suite (ISSUE 2 acceptance): driving N
//! sessions in lockstep through batched forwards must reproduce the
//! blocking samplers **bit-for-bit** from the same per-sequence seeds —
//! events AND `SampleStats` — for every fleet size, for AR and SD, for
//! fixed and adaptive γ, on the direct backend path and through the
//! coordinator's batching executors.

use std::sync::Arc;
use std::time::Duration;

use tpp_sd::coordinator::ExecutorHandle;
use tpp_sd::runtime::{Backend, NativeBackend, Uncached};
use tpp_sd::sampler::{
    fleet_seeds, sample_ar, sample_ar_fleet, sample_sd, sample_sd_fleet, Gamma, SampleCfg,
    SampleStats, SdCfg,
};
use tpp_sd::util::rng::Rng;

mod common;
use common::assert_stats_eq;

fn sd_cfg(num_types: usize, gamma: Gamma) -> SdCfg {
    SdCfg {
        sample: SampleCfg { num_types, t_end: 10.0, max_events: 4096 },
        gamma,
        ..Default::default()
    }
}

#[test]
fn fleet_sd_is_bit_for_bit_sequential() {
    let b = NativeBackend::new();
    for (dataset, num_types) in [("hawkes", 1), ("taxi_sim", 10)] {
        let target = b.load_model(dataset, "thp", "target").unwrap();
        let draft = b.load_model(dataset, "thp", "draft").unwrap();
        let cfg = sd_cfg(num_types, Gamma::Fixed(6));
        for n in [1usize, 2, 8] {
            let seeds = fleet_seeds(42, n);
            let (runs, fleet) = sample_sd_fleet(&target, &draft, &cfg, &seeds).unwrap();
            assert_eq!(runs.len(), n, "{dataset}: one run per seed");
            let mut agg_fleet = SampleStats::default();
            let mut agg_seq = SampleStats::default();
            for (i, (ev, st)) in runs.iter().enumerate() {
                let mut rng = Rng::new(seeds[i]);
                let (ev_seq, st_seq) = sample_sd(&target, &draft, &cfg, &mut rng).unwrap();
                assert!(!ev_seq.is_empty(), "{dataset}: degenerate test sequence");
                assert_eq!(ev, &ev_seq, "{dataset} fleet(N={n}) seq {i}: events diverge");
                assert_stats_eq(st, &st_seq, &format!("{dataset} fleet(N={n}) seq {i}"));
                agg_fleet.merge(st);
                agg_seq.merge(&st_seq);
            }
            // aggregates (rounds, accepted, drafted, bonus, ...) identical
            assert_stats_eq(&agg_fleet, &agg_seq, &format!("{dataset} fleet(N={n}) aggregate"));
            if n > 1 {
                assert!(
                    fleet.target_occupancy() > 1.0,
                    "{dataset} fleet(N={n}): verify passes must co-batch, occupancy={}",
                    fleet.target_occupancy()
                );
            }
        }
    }
}

#[test]
fn fleet_sd_adaptive_gamma_is_bit_for_bit_sequential() {
    let b = NativeBackend::new();
    let target = b.load_model("multihawkes", "attnhp", "target").unwrap();
    let draft = b.load_model("multihawkes", "attnhp", "draft").unwrap();
    let cfg = sd_cfg(2, Gamma::Adaptive { init: 3, min: 2, max: 12 });
    let seeds = fleet_seeds(7, 8);
    let (runs, _) = sample_sd_fleet(&target, &draft, &cfg, &seeds).unwrap();
    for (i, (ev, st)) in runs.iter().enumerate() {
        let mut rng = Rng::new(seeds[i]);
        let (ev_seq, st_seq) = sample_sd(&target, &draft, &cfg, &mut rng).unwrap();
        assert_eq!(ev, &ev_seq, "adaptive fleet seq {i}");
        assert_stats_eq(st, &st_seq, &format!("adaptive fleet seq {i}"));
    }
}

#[test]
fn fleet_ar_is_bit_for_bit_sequential() {
    let b = NativeBackend::new();
    let target = b.load_model("hawkes", "sahp", "target").unwrap();
    let cfg = SampleCfg { num_types: 1, t_end: 10.0, max_events: 4096 };
    for n in [1usize, 2, 8] {
        let seeds = fleet_seeds(5, n);
        let (runs, _) = sample_ar_fleet(&target, &cfg, &seeds).unwrap();
        for (i, (ev, st)) in runs.iter().enumerate() {
            let mut rng = Rng::new(seeds[i]);
            let (ev_seq, st_seq) = sample_ar(&target, &cfg, &mut rng).unwrap();
            assert!(!ev_seq.is_empty());
            assert_eq!(ev, &ev_seq, "AR fleet(N={n}) seq {i}");
            assert_stats_eq(st, &st_seq, &format!("AR fleet(N={n}) seq {i}"));
        }
    }
}

#[test]
fn fleet_chunks_beyond_max_batch() {
    // 13 sessions > B=8: the engine must chunk each wave and still fan the
    // right slots back to the right sessions.
    let b = NativeBackend::new();
    let target = b.load_model("hawkes", "thp", "target").unwrap();
    let draft = b.load_model("hawkes", "thp", "draft").unwrap();
    let cfg = sd_cfg(1, Gamma::Fixed(4));
    let seeds = fleet_seeds(100, 13);
    let (runs, fleet) = sample_sd_fleet(&target, &draft, &cfg, &seeds).unwrap();
    assert_eq!(runs.len(), 13);
    assert!(fleet.target_occupancy() > 1.0);
    for (i, (ev, _)) in runs.iter().enumerate() {
        let mut rng = Rng::new(seeds[i]);
        let (ev_seq, _) = sample_sd(&target, &draft, &cfg, &mut rng).unwrap();
        assert_eq!(ev, &ev_seq, "chunked fleet seq {i}");
    }
}

#[test]
fn fleet_runs_through_batching_executors() {
    // The serving path: ExecutorHandle implements BatchForward, and the
    // batcher must coalesce the engine's waves without changing a single
    // probability vs the direct path.
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend::new());
    let target_h = ExecutorHandle::spawn(
        backend.clone(),
        "hawkes",
        "thp",
        "target",
        8,
        Duration::from_millis(5),
    )
    .unwrap();
    let draft_h = ExecutorHandle::spawn(
        backend.clone(),
        "hawkes",
        "thp",
        "draft",
        8,
        Duration::from_millis(5),
    )
    .unwrap();
    let target = backend.load_model("hawkes", "thp", "target").unwrap();
    let draft = backend.load_model("hawkes", "thp", "draft").unwrap();

    let cfg = sd_cfg(1, Gamma::Fixed(5));
    let seeds = fleet_seeds(21, 8);
    let (via_exec, _) = sample_sd_fleet(&target_h, &draft_h, &cfg, &seeds).unwrap();
    let (direct, _) = sample_sd_fleet(&target, &draft, &cfg, &seeds).unwrap();
    for (i, ((ev_a, st_a), (ev_b, st_b))) in via_exec.iter().zip(&direct).enumerate() {
        assert_eq!(ev_a, ev_b, "executor vs direct, seq {i}");
        assert_stats_eq(st_a, st_b, &format!("executor vs direct, seq {i}"));
    }
    // the engine's waves actually co-batched inside the executor — on the
    // cached path the waves are delta waves, so the delta occupancy is
    // the metric (full-forward occupancy counts only uncached batches)
    assert!(
        target_h.stats.delta_occupancy() > 1.0,
        "executor delta occupancy {}",
        target_h.stats.delta_occupancy()
    );
}

/// ISSUE 3 regression: fleet(N) on the CACHED executor path — per-session
/// incremental streams whose ids travel through the batcher channel —
/// must stay bit-for-bit equal to N sequential UNCACHED runs with the
/// same seeds. A stream-id mix-up in the batcher (crosstalk between
/// sessions' deltas) breaks this immediately, because every session would
/// then draw from another session's excitation state.
#[test]
fn cached_executor_fleet_is_bit_for_bit_sequential_uncached() {
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend::new());
    let target_h = ExecutorHandle::spawn(
        backend.clone(),
        "taxi_sim",
        "thp",
        "target",
        8,
        Duration::from_millis(5),
    )
    .unwrap();
    let draft_h = ExecutorHandle::spawn(
        backend.clone(),
        "taxi_sim",
        "thp",
        "draft",
        8,
        Duration::from_millis(5),
    )
    .unwrap();
    let target = backend.load_model("taxi_sim", "thp", "target").unwrap();
    let draft = backend.load_model("taxi_sim", "thp", "draft").unwrap();

    // SD: executor+streams fleet vs sequential uncached
    let cfg = sd_cfg(10, Gamma::Fixed(5));
    let seeds = fleet_seeds(77, 8);
    let (via_exec, fleet) = sample_sd_fleet(&target_h, &draft_h, &cfg, &seeds).unwrap();
    assert!(
        fleet.delta_batches > 0,
        "the executor path must actually use delta waves, fleet={fleet:?}"
    );
    for (i, (ev, st)) in via_exec.iter().enumerate() {
        let mut rng = Rng::new(seeds[i]);
        let (ev_ref, st_ref) =
            sample_sd(&Uncached(&target), &Uncached(&draft), &cfg, &mut rng).unwrap();
        assert!(!ev_ref.is_empty(), "degenerate sequence {i}");
        assert_eq!(ev, &ev_ref, "cached executor fleet seq {i} vs sequential uncached");
        assert_stats_eq(st, &st_ref, &format!("cached executor fleet seq {i}"));
    }
    // delta traffic went through the batcher channel
    let deltas = target_h
        .stats
        .delta_requests
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(deltas > 0, "no delta requests reached the target executor");

    // AR: same regression on the single-model path
    let scfg = SampleCfg { num_types: 10, t_end: 10.0, max_events: 4096 };
    let (ar_exec, _) = sample_ar_fleet(&target_h, &scfg, &seeds).unwrap();
    for (i, (ev, st)) in ar_exec.iter().enumerate() {
        let mut rng = Rng::new(seeds[i]);
        let (ev_ref, st_ref) = sample_ar(&Uncached(&target), &scfg, &mut rng).unwrap();
        assert_eq!(ev, &ev_ref, "cached executor AR fleet seq {i}");
        assert_stats_eq(st, &st_ref, &format!("cached executor AR fleet seq {i}"));
    }
}

/// The engine's direct path with mixed support: cached target, uncached
/// draft (the XLA-draft scenario) — still bit-for-bit sequential.
#[test]
fn mixed_cached_roles_fleet_is_bit_for_bit_sequential() {
    let b = NativeBackend::new();
    let target = b.load_model("hawkes", "sahp", "target").unwrap();
    let draft = b.load_model("hawkes", "sahp", "draft").unwrap();
    let cfg = sd_cfg(1, Gamma::Fixed(4));
    let seeds = fleet_seeds(9, 5);
    let (runs, fleet) =
        sample_sd_fleet(&target, &Uncached(&draft), &cfg, &seeds).unwrap();
    assert!(fleet.delta_batches > 0, "target role should run deltas");
    for (i, (ev, _)) in runs.iter().enumerate() {
        let mut rng = Rng::new(seeds[i]);
        let (ev_ref, _) = sample_sd(&target, &draft, &cfg, &mut rng).unwrap();
        assert_eq!(ev, &ev_ref, "mixed-role fleet seq {i}");
    }
}
