//! Incremental-forward-cache equivalence suite (ISSUE 3 acceptance): the
//! `CachedForward` streams must change NOTHING but wall-clock.
//!
//! * **Bit-equivalence**: for random event sequences, random chunkings and
//!   random rewind points, every row a `forward_delta` returns is
//!   bit-identical to a cold full `forward` over the same prefix — across
//!   all three encoders, both model roles (target and draft), and the
//!   64→128 and 128→256 bucket crossings.
//! * **Long horizon**: sequences that outgrow the largest bucket slide
//!   their window (`Context::epoch`); the cache must rebase and stay
//!   bit-identical to the uncached path for both AR and SD.

use tpp_sd::runtime::{
    Backend, CachedForward, ForwardOut, ModelBackend, NativeBackend, SeqDelta, SeqInput, SlotOut,
    StreamId, Uncached,
};
use tpp_sd::sampler::{sample_ar, sample_sd, Gamma, SampleCfg, SdCfg};
use tpp_sd::util::rng::Rng;

mod common;
use common::assert_stats_eq;

const ENCODERS: [&str; 3] = ["thp", "sahp", "attnhp"];
/// Both model roles of a TPP-SD pair.
const ROLES: [&str; 2] = ["target", "draft"];

/// Random strictly-increasing event sequence with `n` events over `k`
/// types, starting after `t_start`.
fn random_events(rng: &mut Rng, n: usize, k: usize, t_start: f64) -> (Vec<f64>, Vec<u32>) {
    let mut t = t_start;
    let mut times = Vec::with_capacity(n);
    let mut types = Vec::with_capacity(n);
    for _ in 0..n {
        t += rng.exponential(2.0);
        times.push(t);
        types.push(rng.below(k) as u32);
    }
    (times, types)
}

/// Assert rows `lo..=hi` of a delta output are bit-identical to the same
/// rows of a cold full forward (slot 0 of `cold`).
fn assert_rows_bit_equal(
    slot: &SlotOut,
    cold: &ForwardOut,
    lo: usize,
    hi: usize,
    k: usize,
    what: &str,
) {
    for row in lo..=hi {
        assert_eq!(slot.mixture(row), cold.mixture(0, row), "{what}: mixture row {row}");
        assert_eq!(
            slot.type_dist(row, k).probs,
            cold.type_dist(0, row, k).probs,
            "{what}: type row {row}"
        );
    }
}

fn cold(model: &dyn ModelBackend, t0: f64, times: &[f64], types: &[u32]) -> ForwardOut {
    model
        .forward(&[SeqInput { t0, times: times.to_vec(), types: types.to_vec() }])
        .expect("cold forward")
}

/// Random chunk sizes: every encoder × role must produce delta rows
/// bit-identical to a cold forward of the full prefix.
#[test]
fn delta_rows_bit_equal_cold_forward_every_encoder_and_role() {
    let b = NativeBackend::new();
    let k = b.num_types("taxi_sim").unwrap();
    let mut rng = Rng::new(0xCAFE);
    for encoder in ENCODERS {
        for role in ROLES {
            let model = b.load_model("taxi_sim", encoder, role).unwrap();
            let c = model.cached().expect("native models expose CachedForward");
            let (times, types) = random_events(&mut rng, 48, k, 0.0);
            let full = cold(model.as_ref(), 0.0, &times, &types);
            let sid = c.open_stream().unwrap();
            let mut fed = 0usize;
            while fed < times.len() {
                let m = (1 + rng.below(7)).min(times.len() - fed);
                let d = SeqDelta {
                    base_len: fed,
                    t0: 0.0,
                    times: times[fed..fed + m].to_vec(),
                    types: types[fed..fed + m].to_vec(),
                };
                let out = c.forward_delta(sid, &d).unwrap();
                // prefix-causality of the backend makes the full-sequence
                // cold rows valid references for every prefix row
                assert_rows_bit_equal(&out, &full, fed, fed + m, k, &format!("{encoder}/{role}"));
                // spot-check against the *exact prefix* cold forward too
                let pre = cold(model.as_ref(), 0.0, &times[..fed + m], &types[..fed + m]);
                assert_eq!(
                    out.mixture(fed + m),
                    pre.mixture(0, fed + m),
                    "{encoder}/{role}: prefix cold forward row {}",
                    fed + m
                );
                fed += m;
            }
            c.close_stream(sid);
        }
    }
}

/// One-event deltas across the 64→128 and 128→256 bucket crossings: the
/// cold reference switches buckets at 63→64 and 127→128 events (+BOS),
/// the stream must not notice.
#[test]
fn bucket_boundary_crossings_are_bit_exact() {
    let b = NativeBackend::new();
    let k = b.num_types("multihawkes").unwrap();
    let model = b.load_model("multihawkes", "thp", "target").unwrap();
    let c = model.cached().unwrap();
    let mut rng = Rng::new(7);
    let (times, types) = random_events(&mut rng, 140, k, 0.0);
    let sid = c.open_stream().unwrap();
    for i in 0..times.len() {
        let d = SeqDelta {
            base_len: i,
            t0: 0.0,
            times: vec![times[i]],
            types: vec![types[i]],
        };
        let out = c.forward_delta(sid, &d).unwrap();
        // around the crossings, check against per-prefix cold forwards so
        // the reference really runs in its own (changing) bucket
        let n = i + 1;
        if (62..=65).contains(&n) || (126..=129).contains(&n) || n == times.len() {
            let pre = cold(model.as_ref(), 0.0, &times[..n], &types[..n]);
            let expect_bucket = if n + 1 <= 64 {
                64
            } else if n + 1 <= 128 {
                128
            } else {
                256
            };
            assert_eq!(pre.bucket, expect_bucket, "cold bucket at n={n}");
            assert_rows_bit_equal(&out, &pre, i, i + 1, k, &format!("crossing n={n}"));
        }
    }
    c.close_stream(sid);
}

/// Random rewind points with divergent re-extensions (the draft-rejection
/// pattern): after every rewind+extend, the stream's rows equal a cold
/// forward of the surviving history.
#[test]
fn random_rewinds_are_bit_exact() {
    let b = NativeBackend::new();
    let k = b.num_types("hawkes").unwrap();
    for (seed, role) in [(11u64, "target"), (12, "draft"), (13, "draft2")] {
        let model = b.load_model("hawkes", "thp", role).unwrap();
        let c = model.cached().unwrap();
        let mut rng = Rng::new(seed);
        let sid = c.open_stream().unwrap();
        let mut times: Vec<f64> = Vec::new();
        let mut types: Vec<u32> = Vec::new();
        for step in 0..60 {
            // rewind to a random surviving prefix (often the full length)
            let keep = rng.below(times.len() + 1);
            times.truncate(keep);
            types.truncate(keep);
            // extend with 0..=4 fresh events from the surviving last time
            let m = rng.below(5).min(200 - keep);
            let t_last = times.last().copied().unwrap_or(0.0);
            let (new_t, new_k) = random_events(&mut rng, m, k, t_last);
            times.extend(&new_t);
            types.extend(&new_k);
            let d = SeqDelta { base_len: keep, t0: 0.0, times: new_t, types: new_k };
            let out = c.forward_delta(sid, &d).unwrap();
            let pre = cold(model.as_ref(), 0.0, &times, &types);
            assert_rows_bit_equal(
                &out,
                &pre,
                keep,
                keep + m,
                k,
                &format!("{role} seed {seed} step {step}"),
            );
        }
        c.close_stream(sid);
    }
}

/// Stream ids are isolated: interleaved deltas on two streams of the same
/// model never observe each other's state.
#[test]
fn interleaved_streams_do_not_crosstalk() {
    let b = NativeBackend::new();
    let model = b.load_model("hawkes", "attnhp", "target").unwrap();
    let c = model.cached().unwrap();
    let mut rng = Rng::new(99);
    let (ta, ka) = random_events(&mut rng, 30, 1, 0.0);
    let (tb, kb) = random_events(&mut rng, 30, 1, 5.0);
    let sa: StreamId = c.open_stream().unwrap();
    let sb: StreamId = c.open_stream().unwrap();
    let cold_a = cold(model.as_ref(), 0.0, &ta, &ka);
    let cold_b = cold(model.as_ref(), 0.0, &tb, &kb);
    for i in 0..30 {
        let da = SeqDelta { base_len: i, t0: 0.0, times: vec![ta[i]], types: vec![ka[i]] };
        let db = SeqDelta { base_len: i, t0: 0.0, times: vec![tb[i]], types: vec![kb[i]] };
        let oa = c.forward_delta(sa, &da).unwrap();
        let ob = c.forward_delta(sb, &db).unwrap();
        assert_rows_bit_equal(&oa, &cold_a, i, i + 1, 1, "stream a");
        assert_rows_bit_equal(&ob, &cold_b, i, i + 1, 1, "stream b");
    }
    c.close_stream(sa);
    c.close_stream(sb);
}

/// Blocking samplers: the cached path must be bit-for-bit the uncached
/// path, events AND counters, at ordinary horizons.
#[test]
fn cached_sampling_is_bit_for_bit_uncached() {
    let b = NativeBackend::new();
    let target = b.load_model("multihawkes", "sahp", "target").unwrap();
    let draft = b.load_model("multihawkes", "sahp", "draft").unwrap();
    let cfg = SampleCfg { num_types: 2, t_end: 12.0, max_events: 4096 };
    for seed in [1u64, 2, 3] {
        let mut r1 = Rng::new(seed);
        let (ev_c, st_c) = sample_ar(&target, &cfg, &mut r1).unwrap();
        let mut r2 = Rng::new(seed);
        let (ev_u, st_u) = sample_ar(&Uncached(&target), &cfg, &mut r2).unwrap();
        assert!(!ev_c.is_empty(), "degenerate AR sequence");
        assert_eq!(ev_c, ev_u, "AR seed {seed}");
        assert_stats_eq(&st_c, &st_u, &format!("AR seed {seed}"));

        let sd = SdCfg { sample: cfg.clone(), gamma: Gamma::Fixed(5), ..Default::default() };
        let mut r1 = Rng::new(seed);
        let (ev_c, st_c) = sample_sd(&target, &draft, &sd, &mut r1).unwrap();
        let mut r2 = Rng::new(seed);
        let (ev_u, st_u) =
            sample_sd(&Uncached(&target), &Uncached(&draft), &sd, &mut r2).unwrap();
        assert_eq!(ev_c, ev_u, "SD seed {seed}");
        assert_stats_eq(&st_c, &st_u, &format!("SD seed {seed}"));

        // mixed roles: only one of the two models cached
        let mut r3 = Rng::new(seed);
        let (ev_m, st_m) = sample_sd(&target, &Uncached(&draft), &sd, &mut r3).unwrap();
        assert_eq!(ev_c, ev_m, "SD mixed-role seed {seed}");
        assert_stats_eq(&st_c, &st_m, &format!("SD mixed-role seed {seed}"));
    }
}

/// ISSUE 3 satellite bugfix: horizons long enough to outgrow the largest
/// bucket (512 incl. BOS) slide the window; the cache must rebase on every
/// slide and stay bit-identical to the uncached path — AR and SD.
#[test]
fn long_horizon_window_slide_stays_bit_exact_ar_and_sd() {
    let b = NativeBackend::new();
    let target = b.load_model("hawkes", "thp", "target").unwrap();
    let draft = b.load_model("hawkes", "thp", "draft").unwrap();
    let cfg = SampleCfg { num_types: 1, t_end: 1200.0, max_events: 4096 };

    let mut r1 = Rng::new(41);
    let (ar_c, _) = sample_ar(&target, &cfg, &mut r1).unwrap();
    let mut r2 = Rng::new(41);
    let (ar_u, _) = sample_ar(&Uncached(&target), &cfg, &mut r2).unwrap();
    assert!(
        ar_c.len() > 512,
        "horizon too short to outgrow the largest bucket: {} events",
        ar_c.len()
    );
    assert_eq!(ar_c, ar_u, "AR long-horizon cached vs uncached");

    let sd = SdCfg { sample: cfg, gamma: Gamma::Fixed(6), ..Default::default() };
    let mut r1 = Rng::new(41);
    let (sd_c, st_c) = sample_sd(&target, &draft, &sd, &mut r1).unwrap();
    let mut r2 = Rng::new(41);
    let (sd_u, st_u) = sample_sd(&Uncached(&target), &Uncached(&draft), &sd, &mut r2).unwrap();
    assert!(sd_c.len() > 512, "SD horizon too short: {} events", sd_c.len());
    assert_eq!(sd_c, sd_u, "SD long-horizon cached vs uncached");
    assert_stats_eq(&st_c, &st_u, "SD long-horizon");
}
