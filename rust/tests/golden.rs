//! Golden regression fixtures: exact event streams per encoder × method,
//! pinned as text under `tests/golden/` (see its README for the bless
//! workflow).
//!
//! These catch what the equivalence suites cannot: a change that shifts
//! AR and SD *together* (e.g. a thinning tweak) leaves `fleet.rs` and
//! `sd_correctness.rs` green but moves every sampled time — the fixtures
//! pin the absolute output. Events are rendered with Rust's shortest
//! round-trip float formatting, so a single ULP of drift fails the diff.
//!
//! Fixtures auto-bless: a missing file is written from the current run and
//! the test passes, so a fresh checkout (or an intentional change, after
//! deleting the stale file) regenerates them in one `cargo test` run.

use std::fmt::Write as _;
use std::path::PathBuf;

use tpp_sd::runtime::{Backend, NativeBackend};
use tpp_sd::sampler::{
    sample_ar_fleet, sample_sd_fleet, Gamma, SampleCfg, SampleStats, SdCfg,
};
use tpp_sd::Event;

const SEED: u64 = 17;
const T_END: f64 = 8.0;
const GAMMA: usize = 5;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

/// Deterministic textual snapshot of one run. `wall` is deliberately
/// excluded — it is the one nondeterministic stat.
fn render(dataset: &str, encoder: &str, method: &str, events: &[Event], s: &SampleStats) -> String {
    let mut out = String::new();
    writeln!(out, "# golden {dataset}/{encoder}/{method} seed={SEED} t_end={T_END} gamma={GAMMA}")
        .unwrap();
    writeln!(out, "events {}", events.len()).unwrap();
    for e in events {
        writeln!(out, "{} {}", e.t, e.k).unwrap();
    }
    writeln!(
        out,
        "stats events={} rounds={} target_forwards={} draft_forwards={} drafted={} accepted={} resampled={} bonus={} adjust_proposals={}",
        s.events,
        s.rounds,
        s.target_forwards,
        s.draft_forwards,
        s.drafted,
        s.accepted,
        s.resampled,
        s.bonus,
        s.adjust_proposals,
    )
    .unwrap();
    out
}

fn run_case(dataset: &str, num_types: usize, encoder: &str, method: &str) -> String {
    let b = NativeBackend::new();
    let target = b.load_model(dataset, encoder, "target").unwrap();
    let cfg = SampleCfg { num_types, t_end: T_END, max_events: 4096 };
    let (events, stats) = match method {
        "ar" => sample_ar_fleet(&target, &cfg, &[SEED]).unwrap().0.pop().unwrap(),
        "sd" => {
            let draft = b.load_model(dataset, encoder, "draft").unwrap();
            let sd = SdCfg { sample: cfg, gamma: Gamma::Fixed(GAMMA), ..Default::default() };
            sample_sd_fleet(&target, &draft, &sd, &[SEED]).unwrap().0.pop().unwrap()
        }
        other => panic!("unknown method {other}"),
    };
    assert!(!events.is_empty(), "{dataset}/{encoder}/{method}: degenerate golden run");
    render(dataset, encoder, method, &events, &stats)
}

fn check(dataset: &str, num_types: usize, encoder: &str, method: &str) {
    let got = run_case(dataset, num_types, encoder, method);
    let path = golden_dir().join(format!("{dataset}_{encoder}_{method}.txt"));
    if !path.exists() {
        std::fs::write(&path, &got)
            .unwrap_or_else(|e| panic!("blessing {}: {e}", path.display()));
        eprintln!("golden: blessed new fixture {}", path.display());
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        got,
        want,
        "golden fixture {} diverged — if the change is intentional, delete the file and rerun to re-bless",
        path.display()
    );
}

#[test]
fn golden_fixtures_are_stable() {
    for encoder in ["thp", "sahp", "attnhp"] {
        for method in ["ar", "sd"] {
            check("hawkes", 1, encoder, method);
        }
    }
    // one multi-type dataset to pin the type-sampling path too
    check("taxi_sim", 10, "thp", "sd");
}
