//! Worker-pool and buffer-recycling invariants (ISSUE 7, DESIGN.md §14):
//! the persistent pool is a pure scheduling change, and a recycled buffer
//! can never leak one forward's contents into another.
//!
//! * **Wave equivalence** (property test): for random job counts, worker
//!   counts and row widths, a pooled wave and a scoped-thread wave write
//!   bit-identically to a serial loop.
//! * **Panic propagation**: a panicking job fails the wave's caller
//!   instead of deadlocking or silently succeeding it.
//! * **Recycled-buffer isolation**: concurrent delta waves on disjoint
//!   incremental streams stay bit-identical to cold forwards even while a
//!   chaos-wrapped model (`pad=1`) keeps scrambling the padding of full
//!   forwards and returning those poisoned buffers to the shared pool.

use std::sync::Arc;

use tpp_sd::runtime::{
    pool, Backend, CachedForward as _, ChaosModel, ChaosStats, FaultPlan, ModelBackend,
    NativeBackend, SeqDelta, SeqInput, StreamId,
};
use tpp_sd::util::rng::Rng;

#[test]
fn pooled_and_scoped_waves_match_serial_across_random_shapes() {
    let fill = |jobs: &mut [(usize, Vec<f32>)], workers: usize| {
        pool::run_wave(jobs, workers, |(base, out)| {
            for (r, v) in out.iter_mut().enumerate() {
                *v = ((*base * 131 + r * 7) as f32 * 0.01).sin();
            }
        });
    };
    let mut rng = Rng::new(42);
    for case in 0..30 {
        let n = 1 + rng.below(40);
        let workers = 1 + rng.below(8);
        let rows = 1 + rng.below(64);
        let mk = || (0..n).map(|i| (i, vec![0f32; rows])).collect::<Vec<_>>();

        let mut serial = mk();
        fill(&mut serial, 1);
        pool::set_scoped_baseline(false);
        let mut pooled = mk();
        fill(&mut pooled, workers);
        pool::set_scoped_baseline(true);
        let mut scoped = mk();
        fill(&mut scoped, workers);
        pool::set_scoped_baseline(false);

        let shape = format!("n={n} workers={workers} rows={rows}");
        assert_eq!(serial, pooled, "case {case}: pooled wave diverged ({shape})");
        assert_eq!(serial, scoped, "case {case}: scoped wave diverged ({shape})");
    }
}

#[test]
#[should_panic]
fn wave_propagates_job_panics() {
    let mut jobs: Vec<(usize, Vec<f32>)> = (0..8).map(|i| (i, vec![0f32; 4])).collect();
    pool::run_wave(&mut jobs, 4, |(base, _out)| {
        assert!(*base != 5, "boom");
    });
}

/// Random strictly-increasing event stream for one session.
fn stream_events(seed: u64, n: usize, k: usize) -> (Vec<f64>, Vec<u32>) {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut times = Vec::with_capacity(n);
    let mut types = Vec::with_capacity(n);
    for _ in 0..n {
        t += 0.05 + rng.uniform() * 0.1;
        times.push(t);
        types.push(rng.below(k) as u32);
    }
    (times, types)
}

#[test]
fn concurrent_delta_streams_never_alias_recycled_buffers_under_pad_chaos() {
    const STREAMS: usize = 4;
    const PER_ROUND: usize = 80;
    const ROUNDS: usize = 3;

    let b = NativeBackend::new();
    let k = b.num_types("hawkes").unwrap();
    // streams + cold references run on the plain native model; the chaos
    // wrapper (same weights) scrambles every full forward's padding and
    // drops the poisoned buffers back into the shared pool
    let native = b.load_model("hawkes", "thp", "target").unwrap();
    let chaos = ChaosModel::new(
        b.load_model("hawkes", "thp", "target").unwrap(),
        FaultPlan::parse("seed=1,pad=1").unwrap(),
        7,
        Arc::new(ChaosStats::default()),
    );

    let seqs: Vec<(Vec<f64>, Vec<u32>)> =
        (0..STREAMS).map(|s| stream_events(100 + s as u64, PER_ROUND * ROUNDS, k)).collect();
    let c = native.cached().expect("native backend exposes incremental streams");
    let sids: Vec<StreamId> = (0..STREAMS).map(|_| c.open_stream().unwrap()).collect();

    // short, padding-heavy input: most of its bucket rows get scrambled
    let small = SeqInput {
        t0: 0.0,
        times: (0..10).map(|i| (i + 1) as f64 * 0.3).collect(),
        types: vec![0; 10],
    };

    for round in 0..ROUNDS {
        let base = round * PER_ROUND;
        // poison the free list right before the wave checks buffers out
        drop(chaos.forward(std::slice::from_ref(&small)).unwrap());

        let wave: Vec<(StreamId, SeqDelta)> = (0..STREAMS)
            .map(|s| {
                let (times, types) = &seqs[s];
                let d = SeqDelta {
                    base_len: base,
                    t0: 0.0,
                    times: times[base..base + PER_ROUND].to_vec(),
                    types: types[base..base + PER_ROUND].to_vec(),
                };
                (sids[s], d)
            })
            .collect();
        // 4 × 81 = 324 output rows ≥ MIN_PARALLEL_ROWS: the parallel path
        let outs = c.forward_delta_batch(wave).unwrap();
        drop(chaos.forward(std::slice::from_ref(&small)).unwrap());

        for (s, slot) in outs.iter().enumerate() {
            let (times, types) = &seqs[s];
            let upto = base + PER_ROUND;
            let cold = native
                .forward(&[SeqInput {
                    t0: 0.0,
                    times: times[..upto].to_vec(),
                    types: types[..upto].to_vec(),
                }])
                .unwrap();
            for row in base..=upto {
                assert_eq!(
                    slot.mixture(row),
                    cold.mixture(0, row),
                    "stream {s} round {round} row {row}: mixture"
                );
                assert_eq!(
                    slot.type_dist(row, k).probs,
                    cold.type_dist(0, row, k).probs,
                    "stream {s} round {round} row {row}: type dist"
                );
            }
        }
    }
    for sid in sids {
        c.close_stream(sid);
    }
}
