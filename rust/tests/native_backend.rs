//! NativeBackend contract tests, including the degenerate-acceptance
//! regression required by DESIGN.md §9.3: with `draft == target` every
//! candidate passes the ratio tests exactly, so `sample_sd` must reproduce
//! `sample_ar`'s event stream **bit-for-bit** from the same seed. The
//! samplers are exercised through the `Forward` trait only — no concrete
//! executor type appears below.

use tpp_sd::runtime::{Backend, Forward, ModelBackend, NativeBackend, SeqInput};
use tpp_sd::sampler::{sample_ar, sample_sd, Gamma, SampleCfg, SdCfg};
use tpp_sd::util::rng::Rng;

/// Generic over `Forward`: the degenerate-acceptance identity. `target`
/// plays both roles, so all density ratios are exactly 1.
fn assert_sd_reproduces_ar<F: Forward + ?Sized>(
    target: &F,
    num_types: usize,
    gamma: usize,
    t_end: f64,
    seed: u64,
) {
    let cfg = SampleCfg { num_types, t_end, max_events: 4096 };
    let mut rng_ar = Rng::new(seed);
    let (ev_ar, st_ar) = sample_ar(target, &cfg, &mut rng_ar).unwrap();
    // keep well inside the bucket so no window truncation desynchronizes
    // the two samplers' model inputs
    assert!(ev_ar.len() < 400, "sequence too long for the identity check");

    let sd = SdCfg {
        sample: cfg,
        gamma: Gamma::Fixed(gamma),
        ..Default::default()
    };
    let mut rng_sd = Rng::new(seed);
    let (ev_sd, st_sd) = sample_sd(target, target, &sd, &mut rng_sd).unwrap();

    assert_eq!(st_sd.resampled, 0, "identical models must never reject");
    assert_eq!(
        ev_ar, ev_sd,
        "draft==target must reproduce AR exactly (γ={gamma}, seed={seed}: \
         {} vs {} events)",
        ev_ar.len(),
        ev_sd.len()
    );
    assert_eq!(st_ar.events, st_sd.events);
}

#[test]
fn degenerate_acceptance_reproduces_ar_exactly() {
    let b = NativeBackend::new();
    for (dataset, num_types) in [("hawkes", 1), ("multihawkes", 2), ("taxi_sim", 10)] {
        let target = b.load_model(dataset, "thp", "target").unwrap();
        for gamma in [1, 4, 10] {
            for seed in [0, 7, 123] {
                assert_sd_reproduces_ar(&target, num_types, gamma, 8.0, seed);
            }
        }
    }
}

#[test]
fn degenerate_acceptance_holds_for_adaptive_gamma() {
    // Adaptive γ only grows on all-accept rounds; with draft == target the
    // identity must survive the growing draft window too.
    let b = NativeBackend::new();
    let target = b.load_model("hawkes", "attnhp", "target").unwrap();
    let cfg = SampleCfg { num_types: 1, t_end: 8.0, max_events: 4096 };
    let mut rng_ar = Rng::new(42);
    let (ev_ar, _) = sample_ar(&target, &cfg, &mut rng_ar).unwrap();
    let sd = SdCfg {
        sample: cfg,
        gamma: Gamma::Adaptive { init: 2, min: 2, max: 12 },
        ..Default::default()
    };
    let mut rng_sd = Rng::new(42);
    let (ev_sd, st) = sample_sd(&target, &target, &sd, &mut rng_sd).unwrap();
    assert_eq!(st.resampled, 0);
    assert_eq!(ev_ar, ev_sd);
}

#[test]
fn distinct_sizes_break_the_identity() {
    // Sanity check that the test above is not vacuous: a real draft (bias
    // ≠ 0) rejects sometimes, so the streams must differ.
    let b = NativeBackend::new();
    let target = b.load_model("hawkes", "thp", "target").unwrap();
    let draft = b.load_model("hawkes", "thp", "draft").unwrap();
    let cfg = SampleCfg { num_types: 1, t_end: 10.0, max_events: 4096 };
    let mut rng_ar = Rng::new(5);
    let (ev_ar, _) = sample_ar(&target, &cfg, &mut rng_ar).unwrap();
    let sd = SdCfg { sample: cfg, gamma: Gamma::Fixed(6), ..Default::default() };
    let mut rng_sd = Rng::new(5);
    let (ev_sd, st) = sample_sd(&target, &draft, &sd, &mut rng_sd).unwrap();
    assert!(st.resampled > 0, "divergent draft should reject at least once");
    assert_ne!(ev_ar, ev_sd);
}

#[test]
fn forward_is_deterministic_across_calls() {
    let b = NativeBackend::new();
    let m = b.load_model("taobao_sim", "sahp", "target").unwrap();
    let seq = SeqInput { t0: 0.0, times: vec![0.3, 0.9, 1.4], types: vec![2, 0, 5] };
    let a = m.forward(std::slice::from_ref(&seq)).unwrap();
    let c = m.forward(std::slice::from_ref(&seq)).unwrap();
    for row in 0..4 {
        assert_eq!(a.mixture(0, row), c.mixture(0, row));
    }
    assert_eq!(m.call_count(), 2);
}

#[test]
fn all_registry_models_sample_without_artifacts() {
    // The whole (dataset × encoder) grid must be serviceable by the
    // native backend out of the box.
    let b = NativeBackend::new();
    for ds in b.datasets() {
        let k = b.num_types(&ds).unwrap();
        let target = b.load_model(&ds, "thp", "target").unwrap();
        let draft = b.load_model(&ds, "thp", "draft").unwrap();
        let cfg = SampleCfg { num_types: k, t_end: 3.0, max_events: 512 };
        let sd = SdCfg { sample: cfg, gamma: Gamma::Fixed(4), ..Default::default() };
        let mut rng = Rng::new(1);
        let (ev, _) = sample_sd(&target, &draft, &sd, &mut rng).unwrap();
        assert!(tpp_sd::events::is_valid_sequence(&ev, 3.0), "{ds}");
        assert!(ev.iter().all(|e| (e.k as usize) < k), "{ds}");
    }
}
