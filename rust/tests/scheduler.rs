//! Continuous-batching scheduler tests (DESIGN.md §16).
//!
//! The core oracle: whatever mix of concurrent requests the scheduler
//! co-batches — ar and sd, cached and uncached, with or without
//! recoverable chaos underneath — every request's events must be
//! bit-for-bit what a sequential per-request run with the same seeds
//! produces. Admission control is pinned the other way around: overload
//! must yield structured rejections whose counters reconcile with
//! client-observed outcomes to the unit.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use tpp_sd::coordinator::{
    build_sessions, Client, Request, Router, SampleRequest, SchedReject, Scheduler, SchedulerCfg,
    Server,
};
use tpp_sd::runtime::{Backend, ChaosBackend, FaultPlan};
use tpp_sd::sampler::{
    fleet_seeds, sample_ar_fleet, sample_sd_fleet, FleetRuns, FleetStats, Gamma, SampleCfg, SdCfg,
};
use tpp_sd::util::json::Json;
use tpp_sd::Event;

fn backend() -> Arc<dyn Backend> {
    tpp_sd::runtime::discover_backend().expect("backend")
}

fn cfg(num_types: usize, t_end: f64) -> SampleCfg {
    SampleCfg { num_types, t_end, max_events: 16 * 1024 }
}

/// Spin until `f` holds (the scheduler thread runs asynchronously; its
/// counters are the only ordering handle the tests have).
fn poll(what: &str, mut f: impl FnMut() -> bool) {
    let t0 = std::time::Instant::now();
    while !f() {
        assert!(t0.elapsed() < Duration::from_secs(60), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// The sequential per-request reference: the pre-scheduler serving path
/// (one isolated fleet per request, cached streams).
fn reference(
    router: &Router,
    method: &str,
    gamma: usize,
    cfg: &SampleCfg,
    seeds: &[u64],
) -> FleetRuns {
    let pair = router.route("hawkes", "thp", "draft").unwrap();
    let (runs, _) = match method {
        "ar" => sample_ar_fleet(&pair.target, cfg, seeds).unwrap(),
        "sd" => {
            let sd = SdCfg { sample: cfg.clone(), gamma: Gamma::Fixed(gamma), ..Default::default() };
            sample_sd_fleet(&pair.target, &pair.draft, &sd, seeds).unwrap()
        }
        "sd-adaptive" => {
            let sd = SdCfg {
                sample: cfg.clone(),
                gamma: Gamma::Adaptive { init: gamma, min: 2, max: 4 * gamma.max(1) },
                ..Default::default()
            };
            sample_sd_fleet(&pair.target, &pair.draft, &sd, seeds).unwrap()
        }
        other => panic!("{other}"),
    };
    runs
}

/// Concurrent mixed-method requests through one shared pool are
/// bit-for-bit the sequential per-request runs — pool membership and
/// cross-request wave composition must be output-invisible, for cached
/// and uncached admissions alike.
#[test]
fn scheduler_matches_sequential_mixed_methods() {
    let router = Arc::new(
        Router::with_scheduler(backend(), 8, Duration::from_millis(1), SchedulerCfg::default())
            .unwrap(),
    );
    let pair = router.route("hawkes", "thp", "draft").unwrap();
    let c = cfg(pair.num_types, 3.0);
    let sched = router.scheduler("hawkes", "thp", "draft").unwrap();

    // (method, gamma, cached, base seed, n_seq) — enough mix that sd and
    // ar sessions of several requests share waves.
    let reqs: Vec<(&str, usize, bool, u64, usize)> = vec![
        ("ar", 0, true, 100, 2),
        ("sd", 5, true, 200, 3),
        ("sd-adaptive", 4, true, 300, 2),
        ("sd", 6, false, 400, 2),
        ("ar", 0, false, 500, 1),
    ];

    let mut joins = Vec::new();
    for &(method, gamma, cached, seed, n) in &reqs {
        let pair = pair.clone();
        let sched = sched.clone();
        let c = c.clone();
        joins.push(std::thread::spawn(move || {
            let seeds = fleet_seeds(seed, n);
            let sessions = build_sessions(&pair, method, gamma, c, &seeds).unwrap();
            sched.submit(sessions, cached, None).unwrap()
        }));
    }
    let got: Vec<FleetRuns> =
        joins.into_iter().map(|j| j.join().unwrap().0).collect();

    for ((method, gamma, _cached, seed, n), runs) in reqs.iter().zip(&got) {
        // cached:false must not change events either, so one cached
        // reference serves both admission modes
        let want = reference(&router, method, *gamma, &c, &fleet_seeds(*seed, *n));
        assert_eq!(runs.len(), *n, "{method}/{seed}");
        for (i, ((ev, st), (ev_ref, _))) in runs.iter().zip(&want).enumerate() {
            assert!(!ev.is_empty(), "{method}/{seed}: degenerate sequence {i}");
            assert_eq!(ev, ev_ref, "{method}/{seed}: sequence {i} diverged");
            assert!(tpp_sd::events::is_valid_sequence(ev, c.t_end));
            assert_eq!(st.events, ev.len(), "{method}/{seed}: stats/events mismatch");
        }
    }

    // full reconciliation: every submit completed, nothing shed/expired,
    // the pool drained, and the cap was respected
    let s = sched.stats();
    assert_eq!(s.admitted.load(Ordering::Relaxed), reqs.len());
    assert_eq!(s.completed.load(Ordering::Relaxed), reqs.len());
    assert_eq!(s.shed.load(Ordering::Relaxed), 0);
    assert_eq!(s.expired.load(Ordering::Relaxed), 0);
    assert_eq!(s.failed.load(Ordering::Relaxed), 0);
    assert_eq!(s.queued.load(Ordering::Relaxed), 0);
    assert_eq!(s.live_sessions.load(Ordering::Relaxed), 0);
    let peak = s.max_live_seen.load(Ordering::Relaxed);
    assert!(peak >= 1 && peak <= sched.cfg().max_live, "peak {peak}");
}

/// The same oracle under recoverable injected faults: retries and stream
/// recovery run *inside* the shared pool, and every co-batched request
/// still gets the fault-free sequential events.
#[test]
fn scheduler_matches_sequential_under_recoverable_chaos() {
    let plan = FaultPlan::parse("seed=13,err=0.15,loss=0.1").unwrap();
    let chaotic: Arc<dyn Backend> = Arc::new(ChaosBackend::new(backend(), plan));
    let router = Arc::new(
        Router::with_scheduler(chaotic, 8, Duration::from_millis(1), SchedulerCfg::default())
            .unwrap(),
    );
    // fault-free reference router over the same registry
    let clean = Router::new(backend(), 8, Duration::from_millis(1)).unwrap();

    let pair = router.route("hawkes", "thp", "draft").unwrap();
    let c = cfg(pair.num_types, 2.5);
    let sched = router.scheduler("hawkes", "thp", "draft").unwrap();

    let reqs: Vec<(&str, usize, u64, usize)> =
        vec![("sd", 5, 700, 2), ("ar", 0, 800, 2), ("sd", 4, 900, 1)];
    let mut joins = Vec::new();
    for &(method, gamma, seed, n) in &reqs {
        let pair = pair.clone();
        let sched = sched.clone();
        let c = c.clone();
        joins.push(std::thread::spawn(move || {
            let sessions =
                build_sessions(&pair, method, gamma, c, &fleet_seeds(seed, n)).unwrap();
            sched.submit(sessions, true, None).unwrap()
        }));
    }
    let got: Vec<FleetRuns> = joins.into_iter().map(|j| j.join().unwrap().0).collect();
    for ((method, gamma, seed, n), runs) in reqs.iter().zip(&got) {
        let want = reference(&clean, method, *gamma, &c, &fleet_seeds(*seed, *n));
        for (i, ((ev, _), (ev_ref, _))) in runs.iter().zip(&want).enumerate() {
            assert!(!ev.is_empty(), "{method}/{seed}: degenerate sequence {i}");
            assert_eq!(ev, ev_ref, "{method}/{seed}: chaos changed sequence {i}");
        }
    }
    assert_eq!(sched.stats().completed.load(Ordering::Relaxed), reqs.len());
    assert_eq!(sched.stats().failed.load(Ordering::Relaxed), 0);
}

/// One `submit` of `n` ar sessions, ready to run on any thread.
fn submit_ar(
    sched: &Scheduler,
    pair: &tpp_sd::coordinator::ModelPair,
    c: &SampleCfg,
    n: usize,
    seed: u64,
    deadline: Option<Duration>,
) -> Result<(FleetRuns, FleetStats), SchedReject> {
    let sessions = build_sessions(pair, "ar", 0, c.clone(), &fleet_seeds(seed, n)).unwrap();
    sched.submit(sessions, true, deadline)
}

/// Admission control, driven deterministically: a request that can never
/// fit is shed at submit; a full queue sheds; a zero deadline expires at
/// admission; and the counters reconcile with the observed outcomes
/// exactly — no submit is ever double- or un-counted.
#[test]
fn overload_sheds_and_deadlines_expire() {
    // every forward sleeps 25ms, so one admitted request holds the pool
    // long enough to build a queue behind it
    let plan = FaultPlan::parse("seed=1,delay=1,delay-ms=25").unwrap();
    let chaotic: Arc<dyn Backend> = Arc::new(ChaosBackend::new(backend(), plan));
    let scfg = SchedulerCfg::builder().max_live(1).queue_depth(1).build();
    let router =
        Arc::new(Router::with_scheduler(chaotic, 8, Duration::from_millis(1), scfg).unwrap());
    let pair = router.route("hawkes", "thp", "draft").unwrap();
    let c = cfg(pair.num_types, 1.0);
    let sched = router.scheduler("hawkes", "thp", "draft").unwrap();
    let stats = sched.stats();

    // (1) 2 sessions under max_live=1: can never be admitted → shed now
    match submit_ar(&sched, &pair, &c, 2, 1, None) {
        Err(SchedReject::Overloaded(m)) => assert!(m.contains("max_live"), "{m}"),
        other => panic!("want Overloaded, got {other:?}"),
    }
    assert_eq!(stats.shed.load(Ordering::Relaxed), 1);

    // (2) A occupies the pool...
    let a = {
        let (sched, pair, c) = (sched.clone(), pair.clone(), c.clone());
        std::thread::spawn(move || submit_ar(&sched, &pair, &c, 1, 2, None))
    };
    poll("A admitted", || stats.admitted.load(Ordering::Relaxed) == 1);

    // (3) ...B waits behind it with an already-passed deadline → expired
    // when its turn comes, deterministically (Duration::ZERO)
    let b = {
        let (sched, pair, c) = (sched.clone(), pair.clone(), c.clone());
        std::thread::spawn(move || submit_ar(&sched, &pair, &c, 1, 3, Some(Duration::ZERO)))
    };
    poll("B queued", || stats.queued.load(Ordering::Relaxed) == 1);

    // (4) the queue (depth 1) is now full → C is shed immediately
    match submit_ar(&sched, &pair, &c, 1, 4, None) {
        Err(SchedReject::Overloaded(m)) => assert!(m.contains("queue full"), "{m}"),
        other => panic!("want Overloaded, got {other:?}"),
    }

    let (runs, _) = a.join().unwrap().expect("A completes");
    assert_eq!(runs.len(), 1);
    assert!(!runs[0].0.is_empty());
    match b.join().unwrap() {
        Err(SchedReject::Expired(m)) => assert!(m.contains("deadline"), "{m}"),
        other => panic!("want Expired, got {other:?}"),
    }

    // exact reconciliation: 4 submits = 1 completed + 2 shed + 1 expired
    assert_eq!(stats.admitted.load(Ordering::Relaxed), 1);
    assert_eq!(stats.completed.load(Ordering::Relaxed), 1);
    assert_eq!(stats.shed.load(Ordering::Relaxed), 2);
    assert_eq!(stats.expired.load(Ordering::Relaxed), 1);
    assert_eq!(stats.failed.load(Ordering::Relaxed), 0);
    assert_eq!(stats.queued.load(Ordering::Relaxed), 0);
    assert_eq!(stats.live_sessions.load(Ordering::Relaxed), 0);
    assert_eq!(stats.max_live_seen.load(Ordering::Relaxed), 1);
}

fn slow_fleet(seed: u64, deadline_ms: u64) -> Request {
    Request::SampleFleet(
        SampleRequest::builder()
            .encoder("thp")
            .method("ar")
            .t_end(1.0)
            .seed(seed)
            .chaos("seed=2,delay=1,delay-ms=30")
            .deadline_ms(deadline_ms)
            .n_seq(1)
            .build(),
    )
}

/// Read the chaos scheduler's counter from a `stats` response (`None`
/// until that scheduler exists).
fn sched_counter(resp: &str, chaos: &str, key: &str) -> Option<f64> {
    let j = Json::parse(resp).unwrap();
    let Some(Json::Arr(entries)) = j.path("schedulers") else { return None };
    entries
        .iter()
        .find(|e| e.str_at("chaos") == Some(chaos))
        .and_then(|e| e.f64_at(&format!("stats.{key}")))
}

/// Wire-level overload: queue-full and deadline-expired come back as
/// structured `{"ok":false,"err":...}` responses, and the scheduler
/// counters reported by the `stats` op reconcile exactly with what the
/// clients observed — 2 ok, 1 expired, 1 overloaded.
#[test]
fn server_overload_errors_reconcile_with_stats() {
    let scfg = SchedulerCfg::builder().max_live(1).queue_depth(2).build();
    let server = Server::bind_with_scheduler(
        backend(),
        "127.0.0.1:0",
        8,
        Duration::from_millis(1),
        scfg,
    )
    .unwrap();
    let addr = server.addr;
    std::thread::spawn(move || server.serve());
    let spec = "seed=2,delay=1,delay-ms=30";
    let mut probe = Client::connect(addr).unwrap();
    let mut stat = |key: &str| {
        let resp = probe.call(&Request::Stats).unwrap();
        sched_counter(&resp, spec, key)
    };

    // A1 admitted and slow; A2 queued behind it
    let a1 = std::thread::spawn(move || {
        Client::connect(addr).unwrap().call(&slow_fleet(10, 0)).unwrap()
    });
    poll("A1 admitted", || stat("admitted") == Some(1.0));
    let a2 = std::thread::spawn(move || {
        Client::connect(addr).unwrap().call(&slow_fleet(11, 0)).unwrap()
    });
    poll("A2 queued", || stat("queued") == Some(1.0));

    // B queues behind A2 with a 1ms deadline — it cannot be admitted
    // before A1 (and then A2) finish their multi-wave runs, so it expires
    let b = std::thread::spawn(move || {
        Client::connect(addr).unwrap().call(&slow_fleet(12, 1)).unwrap()
    });
    poll("B queued", || stat("queued") == Some(2.0));

    // the queue (depth 2) is full → C is shed with a structured error
    let c_resp = Client::connect(addr).unwrap().call(&slow_fleet(13, 0)).unwrap();
    assert!(c_resp.contains(r#""ok":false"#), "{c_resp}");
    assert!(c_resp.contains(r#""err":"overloaded""#), "{c_resp}");

    let a1_resp = a1.join().unwrap();
    let a2_resp = a2.join().unwrap();
    let b_resp = b.join().unwrap();
    for (name, resp) in [("A1", &a1_resp), ("A2", &a2_resp)] {
        let seqs = tpp_sd::coordinator::protocol::parse_fleet_response(resp).unwrap();
        assert_eq!(seqs.len(), 1, "{name}: {resp}");
        assert!(!seqs[0].is_empty(), "{name}: degenerate run");
    }
    assert!(b_resp.contains(r#""err":"expired""#), "{b_resp}");

    // client-observed outcomes == scheduler counters, to the unit
    for (key, want) in [
        ("admitted", 2.0),
        ("completed", 2.0),
        ("expired", 1.0),
        ("shed", 1.0),
        ("failed", 0.0),
        ("queued", 0.0),
        ("live_sessions", 0.0),
        ("max_live_seen", 1.0),
    ] {
        assert_eq!(stat(key), Some(want), "counter {key}");
    }
}

/// Concurrent wire clients hitting the shared pool get reproducible
/// events: re-requesting the same seed sequentially afterwards returns
/// byte-identical sequences.
#[test]
fn concurrent_wire_samples_are_reproducible() {
    let server = Server::bind(backend(), "127.0.0.1:0", 8, Duration::from_millis(1)).unwrap();
    let addr = server.addr;
    std::thread::spawn(move || server.serve());

    let sample = |seed: u64, method: &str| {
        Request::Sample(
            SampleRequest::builder()
                .encoder("thp")
                .method(method)
                .gamma(5)
                .t_end(2.0)
                .seed(seed)
                .build(),
        )
    };

    let mix = [(20u64, "sd"), (21, "ar"), (22, "sd-adaptive"), (23, "sd")];
    let joins: Vec<_> = mix
        .iter()
        .map(|&(seed, method)| {
            let req = sample(seed, method);
            std::thread::spawn(move || {
                let resp = Client::connect(addr).unwrap().call(&req).unwrap();
                tpp_sd::coordinator::protocol::parse_response(&resp).unwrap().0
            })
        })
        .collect();
    let concurrent: Vec<Vec<Event>> = joins.into_iter().map(|j| j.join().unwrap()).collect();

    let mut cli = Client::connect(addr).unwrap();
    for (&(seed, method), got) in mix.iter().zip(&concurrent) {
        let resp = cli.call(&sample(seed, method)).unwrap();
        let (want, _) = tpp_sd::coordinator::protocol::parse_response(&resp).unwrap();
        assert!(!want.is_empty(), "{method}/{seed}: degenerate sample");
        assert_eq!(got, &want, "{method}/{seed}: concurrent vs sequential");
    }
}
