//! Shard/replica tier tests (`tppsd proxy`, DESIGN.md §17).
//!
//! The core oracle is determinism through indirection: a seeded sample
//! request must return bit-identical events whether it is served by one
//! replica directly, through a 1-backend proxy, or through a 3-backend
//! proxy — including when the request's *home* replica is a chaos-killed
//! (`die=1`) server and the proxy fails over. The `ShardStats` counters
//! are pinned against client-observed outcomes to the unit, the same
//! reconciliation discipline as the scheduler suite.

use std::sync::Arc;
use std::time::Duration;

use tpp_sd::coordinator::protocol::{parse_fleet_response, parse_response};
use tpp_sd::coordinator::shard::{home_index, route_key};
use tpp_sd::coordinator::{
    Client, ProxyServer, Request, SampleRequest, SchedulerCfg, Server, ShardCfg,
};
use tpp_sd::runtime::{Backend, ChaosBackend, FaultPlan};
use tpp_sd::util::json::Json;

fn backend() -> Arc<dyn Backend> {
    tpp_sd::runtime::discover_backend().expect("backend")
}

/// Start one clean replica on an ephemeral port; returns its address.
fn spawn_replica() -> std::net::SocketAddr {
    let server = Server::bind(backend(), "127.0.0.1:0", 8, Duration::from_millis(1)).unwrap();
    let addr = server.addr;
    std::thread::spawn(move || server.serve());
    addr
}

/// Start a replica whose whole backend is wrapped in a chaos plan at bind
/// time — unlike a request-carried `"chaos"` spec, the faults apply to
/// the replica's fault-free router, so a proxied request (which would
/// carry the spec along on failover) observes a *replica-local* failure.
fn spawn_chaotic_replica(spec: &str) -> std::net::SocketAddr {
    spawn_chaotic_replica_with(spec, SchedulerCfg::default())
}

fn spawn_chaotic_replica_with(spec: &str, scfg: SchedulerCfg) -> std::net::SocketAddr {
    let chaotic: Arc<dyn Backend> =
        Arc::new(ChaosBackend::new(backend(), FaultPlan::parse(spec).unwrap()));
    let server = Server::bind_with_scheduler(
        chaotic,
        "127.0.0.1:0",
        8,
        Duration::from_millis(1),
        scfg,
    )
    .unwrap();
    let addr = server.addr;
    std::thread::spawn(move || server.serve());
    addr
}

/// A proxy with the prober disabled (tests that need deterministic
/// health state) and a tight failover backoff.
fn spawn_proxy(backends: &[std::net::SocketAddr]) -> ProxyServer {
    let addrs: Vec<String> = backends.iter().map(|a| a.to_string()).collect();
    let cfg = ShardCfg::builder()
        .health_interval(Duration::ZERO)
        .connect_timeout(Duration::from_millis(500))
        .build();
    ProxyServer::bind("127.0.0.1:0", &addrs, cfg).unwrap()
}

fn sample_req(method: &str, seed: u64) -> Request {
    Request::Sample(
        SampleRequest::builder()
            .dataset("hawkes")
            .encoder("thp")
            .method(method)
            .gamma(5)
            .t_end(2.0)
            .seed(seed)
            .build(),
    )
}

fn load(c: &std::sync::atomic::AtomicUsize) -> usize {
    c.load(std::sync::atomic::Ordering::Relaxed)
}

/// Spin until `f` holds (prober/scheduler threads run asynchronously).
fn poll(what: &str, mut f: impl FnMut() -> bool) {
    let t0 = std::time::Instant::now();
    while !f() {
        assert!(t0.elapsed() < Duration::from_secs(60), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Seeded requests are byte-identical through a 1-backend proxy, a
/// 3-backend proxy, and a direct replica connection — consistent routing
/// never touches sampler RNG. Fleet requests decompose through the proxy
/// exactly like they do against a single server: sequence `i` equals a
/// single sample seeded `seed + i`.
#[test]
fn proxy_is_bit_identical_one_vs_three_replicas() {
    let replicas = [spawn_replica(), spawn_replica(), spawn_replica()];
    let proxy3 = spawn_proxy(&replicas);
    let proxy1 = spawn_proxy(&replicas[..1]);
    let shard3 = proxy3.shard();
    let p3 = proxy3.addr;
    let p1 = proxy1.addr;
    std::thread::spawn(move || proxy3.serve());
    std::thread::spawn(move || proxy1.serve());

    let mut via3 = Client::connect(p3).unwrap();
    let mut via1 = Client::connect(p1).unwrap();
    let mut direct = Client::connect(replicas[0]).unwrap();

    // the proxy identifies itself on ping but is otherwise transparent
    let pong = via3.call(&Request::Ping).unwrap();
    assert!(pong.contains("\"pong\":true") && pong.contains("\"proxy\":true"), "{pong}");
    assert!(pong.contains("\"backends\":3") && pong.contains("\"healthy\":3"), "{pong}");

    let mut sent = 0usize;
    for method in ["ar", "sd"] {
        for seed in [11u64, 12] {
            let req = sample_req(method, seed);
            let (a, _) = parse_response(&via3.call(&req).unwrap()).unwrap();
            let (b, _) = parse_response(&via1.call(&req).unwrap()).unwrap();
            let (c, _) = parse_response(&direct.call(&req).unwrap()).unwrap();
            assert!(!a.is_empty(), "{method}/{seed}: degenerate sample");
            assert_eq!(a, b, "{method}/{seed}: 3-replica vs 1-replica proxy");
            assert_eq!(a, c, "{method}/{seed}: proxy vs direct");
            sent += 3; // via3 + via1 + per-proxy bookkeeping below
        }
    }

    // v2 merged op through the proxy: n_seq sequences == singles seed+i
    let fleet = Request::Sample(
        SampleRequest::builder()
            .dataset("hawkes")
            .encoder("thp")
            .method("sd")
            .gamma(5)
            .t_end(2.0)
            .seed(40)
            .n_seq(3)
            .build(),
    );
    let sequences = parse_fleet_response(&via3.call(&fleet).unwrap()).unwrap();
    assert_eq!(sequences.len(), 3);
    for (i, seq) in sequences.iter().enumerate() {
        let (single, _) = parse_response(&via3.call(&sample_req("sd", 40 + i as u64)).unwrap())
            .unwrap();
        assert_eq!(seq, &single, "fleet sequence {i} vs proxied single");
    }

    // all replicas healthy: everything routed, nothing spilled/failed over
    let s = shard3.stats();
    // via3 carried: 4 method/seed samples + 1 fleet + 3 singles = 8
    assert_eq!(load(&s.routed), 8, "sent {sent} total across proxies");
    assert_eq!(load(&s.spilled), 0);
    assert_eq!(load(&s.failovers), 0);
    assert_eq!(load(&s.upstream_errors), 0);
    assert_eq!(load(&s.ejections), 0);
    let served: usize = shard3.backends().iter().map(|b| load(&b.served)).sum();
    assert_eq!(served, 8, "every routed request served by exactly one replica");
    // consistent routing: one (dataset,encoder,draft_size) key, one home
    let home = home_index(route_key("hawkes", "thp", "draft"), 3);
    assert_eq!(load(&shard3.backends()[home].served), 8, "all requests share one home");
}

/// Failover oracle: the home replica is a `die=1` chaos server whose
/// executors die on first use, answering every sample with a structured
/// `err=failed`. The proxy must retry each request on a healthy replica
/// and return events bit-identical to a clean run — and the `ShardStats`
/// must reconcile exactly: every request routed once, failed over once,
/// with zero spills or ejections (the home keeps *answering*, so only
/// the prober may eject it — and the prober is off here).
#[test]
fn failover_under_die_chaos_is_exact_and_reconciles() {
    let home = home_index(route_key("hawkes", "thp", "draft"), 3);
    let mut replicas = [spawn_replica(), spawn_replica(), spawn_replica()];
    replicas[home] = spawn_chaotic_replica("seed=1,die=1");

    let proxy = spawn_proxy(&replicas);
    let shard = proxy.shard();
    let addr = proxy.addr;
    std::thread::spawn(move || proxy.serve());

    // clean reference replica, outside the proxy's routing set
    let reference = spawn_replica();
    let mut refcli = Client::connect(reference).unwrap();
    let mut cli = Client::connect(addr).unwrap();

    let seeds = [21u64, 22, 23];
    for &seed in &seeds {
        let req = sample_req("sd", seed);
        let (got, _) = parse_response(&cli.call(&req).unwrap()).unwrap();
        let (want, _) = parse_response(&refcli.call(&req).unwrap()).unwrap();
        assert!(!want.is_empty(), "seed {seed}: degenerate reference");
        assert_eq!(got, want, "seed {seed}: failover changed the events");
    }

    let s = shard.stats();
    assert_eq!(load(&s.routed), seeds.len());
    assert_eq!(load(&s.failovers), seeds.len(), "home fails once per request");
    assert_eq!(load(&s.upstream_errors), seeds.len());
    assert_eq!(load(&s.spilled), 0);
    assert_eq!(load(&s.ejections), 0, "a replica that answers is the prober's call");
    assert!(shard.backends()[home].healthy(), "structured failures must not eject");
    assert_eq!(load(&shard.backends()[home].errors), seeds.len());
    assert_eq!(load(&shard.backends()[home].served), 0);
    let served: usize =
        shard.backends().iter().map(|b| load(&b.served)).sum();
    assert_eq!(served, seeds.len(), "each request served exactly once elsewhere");
}

/// Read one scheduler counter from a replica's `stats` response.
fn sched_counter(resp: &str, key: &str) -> Option<f64> {
    let j = Json::parse(resp).unwrap();
    let entries = j.path("schedulers").and_then(Json::as_arr)?;
    entries.first().and_then(|e| e.f64_at(&format!("stats.{key}")))
}

/// Spill-to-least-loaded: the home replica is saturated (max_live 1,
/// queue depth 1, slow forwards), so its admission control sheds the
/// proxied request with `err=overloaded` — and the proxy re-sends it to
/// the other replica instead of bouncing the overload to the client.
#[test]
fn overloaded_home_spills_to_other_replica() {
    let home = home_index(route_key("hawkes", "thp", "draft"), 2);
    // slow forwards + tiny admission limits: two direct requests saturate
    // the home (one admitted, one queued)
    let saturated = spawn_chaotic_replica_with(
        "seed=3,delay=1,delay-ms=200",
        SchedulerCfg::builder().max_live(1).queue_depth(1).build(),
    );
    let mut replicas = [spawn_replica(), spawn_replica()];
    replicas[home] = saturated;

    let proxy = spawn_proxy(&replicas);
    let shard = proxy.shard();
    let addr = proxy.addr;
    std::thread::spawn(move || proxy.serve());

    // occupy the home directly (not through the proxy): A admitted, B queued
    let occupy = |seed: u64| {
        std::thread::spawn(move || {
            Client::connect(saturated).unwrap().call(&sample_req("ar", seed)).unwrap()
        })
    };
    let a = occupy(31);
    let mut probe = Client::connect(saturated).unwrap();
    poll("A admitted", || {
        sched_counter(&probe.call(&Request::Stats).unwrap(), "admitted") == Some(1.0)
    });
    let b = occupy(32);
    poll("B queued", || {
        sched_counter(&probe.call(&Request::Stats).unwrap(), "queued") == Some(1.0)
    });

    // the proxied request hits the full queue at home, spills, succeeds
    let mut cli = Client::connect(addr).unwrap();
    let req = sample_req("ar", 33);
    let (got, _) = parse_response(&cli.call(&req).unwrap()).unwrap();
    let other = replicas[1 - home];
    let (want, _) =
        parse_response(&Client::connect(other).unwrap().call(&req).unwrap()).unwrap();
    assert!(!want.is_empty(), "degenerate spill sample");
    assert_eq!(got, want, "the spilled request's events moved");

    let s = shard.stats();
    assert_eq!(load(&s.routed), 1);
    assert_eq!(load(&s.spilled), 1, "exactly one spill off the saturated home");
    assert_eq!(load(&s.failovers), 0, "a spill is not a failover");
    assert_eq!(load(&s.upstream_errors), 0, "overload is not a replica failure");
    assert!(shard.backends()[home].healthy());
    assert_eq!(load(&shard.backends()[1 - home].served), 1);

    // the occupancy requests drain normally afterwards
    assert!(a.join().unwrap().contains("\"ok\":true"));
    assert!(b.join().unwrap().contains("\"ok\":true"));
}

/// `stats`/`metrics` fan out: per-backend sections embedding each
/// replica's own response, merged scheduler counters, and the shard's
/// counter block — the aggregation shape operators script against.
#[test]
fn stats_and_metrics_fan_out_and_aggregate() {
    let replicas = [spawn_replica(), spawn_replica()];
    let proxy = spawn_proxy(&replicas);
    let addr = proxy.addr;
    std::thread::spawn(move || proxy.serve());
    let mut cli = Client::connect(addr).unwrap();

    // one sample through the proxy so some replica has a scheduler
    parse_response(&cli.call(&sample_req("sd", 50)).unwrap()).unwrap();

    let resp = cli.call(&Request::Stats).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.bool_at("ok"), Some(true), "{resp}");
    let sections = j.path("backends").and_then(Json::as_arr).expect("backends array");
    assert_eq!(sections.len(), 2, "one section per replica");
    for sec in sections {
        assert!(sec.str_at("addr").is_some());
        assert_eq!(sec.bool_at("healthy"), Some(true));
        assert_eq!(sec.bool_at("ok"), Some(true));
        // the embedded response is the replica's own full stats payload
        assert_eq!(sec.bool_at("response.ok"), Some(true));
        assert!(sec.path("response.executors").is_some(), "{sec:?}");
    }
    // merged scheduler counters: the sample above completed somewhere
    assert_eq!(j.f64_at("schedulers_merged.completed"), Some(1.0), "{resp}");
    assert!(j.f64_at("schedulers_merged.pairs").unwrap_or(0.0) >= 1.0);
    assert!(j.f64_at("schedulers_merged.max_live").unwrap_or(0.0) >= 1.0);
    // the shard's own counters ride along
    assert_eq!(j.f64_at("shard.routed"), Some(1.0));
    assert_eq!(j.f64_at("shard.fanouts"), Some(1.0));
    assert_eq!(j.f64_at("shard.healthy"), Some(2.0));

    // metrics fans out the same way, embedding telemetry per replica
    let resp = cli.call(&Request::Metrics { delta: false }).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.bool_at("ok"), Some(true), "{resp}");
    let sections = j.path("backends").and_then(Json::as_arr).expect("backends array");
    assert_eq!(sections.len(), 2);
    assert!(
        sections.iter().any(|s| s.path("response.telemetry").is_some()),
        "no replica telemetry embedded: {resp}"
    );
    assert_eq!(j.f64_at("shard.fanouts"), Some(2.0));
}

/// Health ejection and re-admission over the wire: a dead backend address
/// is ejected after `eject_after` failed probes (sample traffic keeps
/// flowing via failover), and a replica that comes back on that address
/// is re-admitted by one successful probe.
#[test]
fn prober_ejects_dead_backend_and_readmits_on_recovery() {
    // reserve a port, then free it — the "dead replica" address
    let parked = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let dead = parked.local_addr().unwrap();
    drop(parked);

    let live = spawn_replica();
    let addrs = vec![live.to_string(), dead.to_string()];
    let cfg = ShardCfg::builder()
        .health_interval(Duration::from_millis(25))
        .eject_after(2)
        .connect_timeout(Duration::from_millis(200))
        .build();
    let proxy = ProxyServer::bind("127.0.0.1:0", &addrs, cfg).unwrap();
    let shard = proxy.shard();
    let addr = proxy.addr;
    std::thread::spawn(move || proxy.serve());

    poll("ejection", || load(&shard.stats().ejections) >= 1);
    assert_eq!(shard.healthy_count(), 1);
    assert!(!shard.backends()[1].healthy());

    // sample traffic flows regardless (failover covers the dead home case)
    let mut cli = Client::connect(addr).unwrap();
    let (events, _) = parse_response(&cli.call(&sample_req("sd", 60)).unwrap()).unwrap();
    assert!(!events.is_empty(), "degenerate sample during ejection");

    // the replica comes back on the same address: one good probe re-admits
    let server = Server::bind(backend(), &dead.to_string(), 8, Duration::from_millis(1))
        .expect("rebind the parked port");
    std::thread::spawn(move || server.serve());
    poll("re-admission", || load(&shard.stats().readmissions) >= 1);
    assert_eq!(shard.healthy_count(), 2);
    assert!(shard.backends()[1].healthy());
}

/// Failover budget exhaustion: when every replica answers a structured
/// replica-local failure, the proxy reports `err=upstream_exhausted`
/// (with the last failure's detail), not a raw upstream error.
#[test]
fn exhausted_failover_budget_reports_upstream_exhausted() {
    let replicas = [
        spawn_chaotic_replica("seed=5,die=1"),
        spawn_chaotic_replica("seed=6,die=1"),
    ];
    let proxy = spawn_proxy(&replicas);
    let addr = proxy.addr;
    std::thread::spawn(move || proxy.serve());
    let mut cli = Client::connect(addr).unwrap();
    let resp = cli.call(&sample_req("sd", 70)).unwrap();
    assert!(resp.contains("\"ok\":false"), "{resp}");
    assert!(resp.contains("\"err\":\"upstream_exhausted\""), "{resp}");
    // the connection survives the failure
    assert!(cli.call(&Request::Ping).unwrap().contains("pong"));
}

/// Deterministic verdicts pass through verbatim: `bad_request` (here: an
/// unknown dataset) must not be retried on other replicas — every
/// replica would answer it identically.
#[test]
fn bad_requests_are_not_retried() {
    let replicas = [spawn_replica(), spawn_replica()];
    let proxy = spawn_proxy(&replicas);
    let shard = proxy.shard();
    let addr = proxy.addr;
    std::thread::spawn(move || proxy.serve());
    let mut cli = Client::connect(addr).unwrap();
    let req = Request::Sample(SampleRequest::builder().dataset("bogus").build());
    let resp = cli.call(&req).unwrap();
    assert!(resp.contains("\"err\":\"bad_request\""), "{resp}");
    assert_eq!(load(&shard.stats().failovers), 0, "deterministic verdicts never retry");
    assert_eq!(load(&shard.stats().upstream_errors), 0, "a client mistake is not a replica failure");
    assert!(shard.backends().iter().all(|b| b.healthy()));
}
