//! Wire-protocol freeze (ADR-008): two complementary locks.
//!
//! 1. **Golden fixture** — the canonical v2 serialization of every
//!    request variant and response shape, byte-for-byte, in
//!    `tests/golden/protocol_v2.txt`. First run writes (blesses) the
//!    fixture; later runs fail on any byte difference. A serialization
//!    change is a *protocol* change: re-bless deliberately (delete the
//!    file and rerun) and bump ADR-008.
//!
//! 2. **v1 document lock** — the exact v1 request lines published in
//!    `docs/OPERATIONS.md` must (a) appear there verbatim, (b) parse, and
//!    (c) serve over a live wire server with their v1 response shapes.
//!    This pins the compatibility promise to the documentation itself: a
//!    doc edit that drops an example, or a parser change that breaks one,
//!    fails the same test.

use std::time::Duration;

use tpp_sd::coordinator::protocol::{
    error_response, fleet_ok_response, ok_response, parse_fleet_response, parse_response,
};
use tpp_sd::coordinator::{Client, ErrCode, Request, SampleRequest, Server};
use tpp_sd::events::Event;
use tpp_sd::sampler::{FleetStats, SampleStats};
use tpp_sd::util::json::Json;

/// Golden fixture directory (under the crate, so the files are committed
/// and reviewed like source).
fn golden_path() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("protocol_v2.txt")
}

/// A sample request with every field away from its default, so the
/// fixture exercises the full field set (and any new field changes it).
fn full_request() -> SampleRequest {
    SampleRequest::builder()
        .dataset("taxi_sim")
        .encoder("thp")
        .method("sd-adaptive")
        .gamma(7)
        .t_end(12.5)
        .seed(42)
        .draft_size("draft2")
        .cached(false)
        .chaos("seed=7,err=0.25,loss=0.1")
        .deadline_ms(250)
        .n_seq(4)
        .build()
}

/// Render the whole canonical wire surface into one deterministic text
/// blob. Durations are powers of two in seconds so `wall_ms` is exact in
/// f64 and the fixture is bit-stable across platforms.
fn canonical_surface() -> String {
    let mut out = String::new();
    let mut line = |label: &str, s: String| {
        out.push_str(label);
        out.push_str(": ");
        out.push_str(&s);
        out.push('\n');
    };

    line("request ping", Request::Ping.to_line());
    line("request stats", Request::Stats.to_line());
    line("request metrics", Request::Metrics { delta: false }.to_line());
    line("request metrics_delta", Request::Metrics { delta: true }.to_line());
    line("request sample_v2", Request::Sample(full_request()).to_line());
    line("request sample_v2_defaults", Request::Sample(SampleRequest::default()).to_line());
    line("request sample_fleet_v1", Request::SampleFleet(full_request()).to_line());

    let events = vec![Event::new(0.5, 1), Event::new(1.25, 0), Event::new(2.0, 3)];
    let stats = SampleStats {
        events: 3,
        rounds: 2,
        target_forwards: 2,
        draft_forwards: 8,
        drafted: 8,
        accepted: 2,
        resampled: 1,
        bonus: 0,
        adjust_proposals: 5,
        wall: Duration::from_millis(250),
    };
    line("response ok", ok_response(&events, &stats));

    let runs = vec![
        (vec![Event::new(0.5, 1)], SampleStats { events: 1, wall: Duration::from_millis(250), ..Default::default() }),
        (vec![], SampleStats::default()),
        (
            vec![Event::new(1.0, 0), Event::new(2.0, 3)],
            SampleStats { events: 2, wall: Duration::from_millis(500), ..Default::default() },
        ),
    ];
    let fleet = FleetStats {
        steps: 4,
        draft_batches: 2,
        draft_seqs: 4,
        target_batches: 2,
        target_seqs: 6,
        delta_batches: 1,
        delta_seqs: 2,
        stream_recoveries: 1,
        degraded_uncached: 0,
        ..Default::default()
    };
    line("response fleet_ok", fleet_ok_response(&runs, &fleet));

    for code in ErrCode::ALL {
        line(
            &format!("response error_{code}"),
            error_response(code, "<detail text>"),
        );
    }
    out
}

/// Byte-for-byte freeze of the canonical serializations. Missing fixture
/// ⇒ bless it (and pass); present ⇒ exact match required.
#[test]
fn golden_wire_surface_is_frozen() {
    let got = canonical_surface();
    // the canonical surface must itself round-trip before freezing it
    for line in got.lines() {
        let (label, payload) = line.split_once(": ").unwrap();
        if let Some(rest) = label.strip_prefix("request ") {
            let req = Request::parse(payload).unwrap_or_else(|e| panic!("{label}: {e:#}"));
            if !rest.ends_with("_defaults") {
                assert_eq!(req.to_line(), payload, "{label}: not a fixpoint");
            }
        }
    }
    let path = golden_path();
    match std::fs::read_to_string(&path) {
        Ok(want) => assert_eq!(
            got, want,
            "canonical wire serialization changed; if intentional (a protocol change!), \
             delete {path:?}, rerun to re-bless, and update ADR-008"
        ),
        Err(_) => {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, &got).unwrap();
            eprintln!("blessed new golden fixture {path:?}");
        }
    }
}

/// The v1 request lines published verbatim in `docs/OPERATIONS.md` (its
/// "v1 compatibility" section). Changing either side — the docs or this
/// list — without the other fails `v1_doc_examples_parse_and_serve`.
const V1_DOC_LINES: [&str; 5] = [
    r#"{"op":"ping"}"#,
    r#"{"op":"stats"}"#,
    r#"{"op":"metrics","delta":true}"#,
    r#"{"op":"sample","dataset":"hawkes","encoder":"thp","method":"sd","gamma":5,"t_end":2.0,"seed":1}"#,
    r#"{"op":"sample_fleet","encoder":"thp","method":"sd","gamma":5,"n_seq":2,"seed":7,"t_end":2.0}"#,
];

/// Every published v1 example must (a) be in the operator docs verbatim,
/// (b) parse as v1 (no `"v"` field), and (c) serve over a live server
/// with the response shape a v1 client expects: events-shaped `sample`,
/// always-sequences `sample_fleet`, and `sample_fleet` sequences equal to
/// v2 `sample` singles at `seed + i`.
#[test]
fn v1_doc_examples_parse_and_serve() {
    let docs = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../docs/OPERATIONS.md"
    ))
    .expect("docs/OPERATIONS.md");
    for line in V1_DOC_LINES {
        assert!(docs.contains(line), "docs/OPERATIONS.md lost the v1 example {line}");
        Request::parse(line).unwrap_or_else(|e| panic!("v1 example no longer parses: {line}: {e:#}"));
    }

    let backend = tpp_sd::runtime::discover_backend().expect("backend");
    let server = Server::bind(backend, "127.0.0.1:0", 8, Duration::from_millis(1)).unwrap();
    let addr = server.addr;
    std::thread::spawn(move || server.serve());
    let mut cli = Client::connect(addr).unwrap();

    // ping: pong, and no proxy marker on a plain server
    let resp = cli.call_line(V1_DOC_LINES[0]).unwrap();
    assert!(resp.contains("\"pong\":true"), "{resp}");
    assert!(!resp.contains("proxy"), "{resp}");

    // stats / metrics: ok + their v1 section keys
    let resp = cli.call_line(V1_DOC_LINES[1]).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.bool_at("ok"), Some(true), "{resp}");
    assert!(j.get("executors").is_some() && j.get("sessions").is_some(), "{resp}");
    let resp = cli.call_line(V1_DOC_LINES[2]).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.bool_at("ok"), Some(true), "{resp}");
    assert!(j.get("telemetry").is_some(), "{resp}");

    // v1 sample: events-shaped, and bit-identical to the canonical v2
    // spelling of the same request
    let resp = cli.call_line(V1_DOC_LINES[3]).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert!(j.get("events").is_some() && j.get("sequences").is_none(), "{resp}");
    let (v1_events, _) = parse_response(&resp).unwrap();
    assert!(!v1_events.is_empty(), "degenerate v1 sample");
    let v2 = Request::Sample(
        SampleRequest::builder()
            .dataset("hawkes")
            .encoder("thp")
            .method("sd")
            .gamma(5)
            .t_end(2.0)
            .seed(1)
            .build(),
    );
    let (v2_events, _) = parse_response(&cli.call(&v2).unwrap()).unwrap();
    assert_eq!(v1_events, v2_events, "v1 and v2 spellings of one request diverged");

    // v1 sample_fleet: always sequences-shaped; sequence i == v2 single
    // seeded seed + i
    let resp = cli.call_line(V1_DOC_LINES[4]).unwrap();
    let sequences = parse_fleet_response(&resp).unwrap();
    assert_eq!(sequences.len(), 2);
    for (i, seq) in sequences.iter().enumerate() {
        let single = Request::Sample(
            SampleRequest::builder()
                .dataset("hawkes")
                .encoder("thp")
                .method("sd")
                .gamma(5)
                .t_end(2.0)
                .seed(7 + i as u64)
                .build(),
        );
        let (events, _) = parse_response(&cli.call(&single).unwrap()).unwrap();
        assert_eq!(seq, &events, "fleet sequence {i} vs v2 single");
    }

    // the alias stays sequences-shaped even at n_seq == 1
    let alias = Request::SampleFleet(
        SampleRequest::builder().dataset("hawkes").encoder("thp").t_end(2.0).seed(9).build(),
    );
    let resp = cli.call(&alias).unwrap();
    let j = Json::parse(&resp).unwrap();
    assert!(j.get("sequences").is_some() && j.get("events").is_none(), "{resp}");
    assert_eq!(parse_fleet_response(&resp).unwrap().len(), 1);
}
