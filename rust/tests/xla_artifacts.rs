//! Artifact-backed tests of the PJRT executor — compiled only with
//! `--features xla`, and skipped at runtime with a notice when the
//! artifact directory is absent (fresh checkouts stay green). Requires the
//! real `xla` crate to actually execute (the vendored stub type-checks but
//! errors at load time).

#![cfg(feature = "xla")]

use std::sync::Arc;

use tpp_sd::runtime::{ArtifactDir, Backend, ModelExecutor, SeqInput, XlaBackend};
use tpp_sd::sampler::{sample_ar, sample_sd, Gamma, SampleCfg, SdCfg};
use tpp_sd::util::rng::Rng;

fn artifacts() -> Option<ArtifactDir> {
    match ArtifactDir::discover() {
        Ok(a) => Some(a),
        Err(_) => {
            eprintln!("NOTE: artifacts/ missing — run `make artifacts`; skipping");
            None
        }
    }
}

#[test]
fn load_all_dataset_encoder_pairs() {
    let Some(art) = artifacts() else { return };
    let ds = art.datasets_json().unwrap();
    let client = tpp_sd::runtime::cpu_client().unwrap();
    for dataset in ["poisson", "hawkes", "multihawkes", "taxi_sim"] {
        for enc in ["thp", "sahp", "attnhp"] {
            let ex = ModelExecutor::load(client.clone(), &art, dataset, enc, "draft")
                .unwrap_or_else(|e| panic!("{dataset}/{enc}: {e:#}"));
            assert_eq!(ex.encoder, enc);
            assert!(ex.max_bucket() >= 256);
        }
    }
    assert!(ds.usize_at("k_max").unwrap() >= 22);
}

#[test]
fn forward_outputs_are_valid_distributions() {
    let Some(art) = artifacts() else { return };
    let client = tpp_sd::runtime::cpu_client().unwrap();
    let ex = ModelExecutor::load(client, &art, "multihawkes", "thp", "draft").unwrap();
    let seq = SeqInput {
        t0: 0.0,
        times: vec![0.5, 1.0, 2.5, 4.0],
        types: vec![0, 1, 0, 1],
    };
    let out = ex.forward(&[seq]).unwrap();
    for row in 0..5 {
        let m = out.mixture(0, row);
        let s: f64 = m.log_w.iter().map(|w| w.exp()).sum();
        assert!((s - 1.0).abs() < 1e-4, "row {row}: Σw = {s}");
        assert!(m.logpdf(1.0).is_finite());
        assert!((0.0..=1.0).contains(&m.cdf(2.0)));
        let td = out.type_dist(0, row, 2);
        let s: f64 = td.probs.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }
}

#[test]
fn xla_backend_serves_samplers_through_the_trait() {
    let Some(art) = artifacts() else { return };
    let backend: Arc<dyn Backend> = Arc::new(XlaBackend::new(art));
    let target = backend.load_model("taxi_sim", "thp", "target").unwrap();
    let draft = backend.load_model("taxi_sim", "thp", "draft").unwrap();
    let cfg = SampleCfg { num_types: 10, t_end: 5.0, max_events: 512 };
    let mut rng = Rng::new(11);
    let (ev, st) = sample_ar(&target, &cfg, &mut rng).unwrap();
    assert!(tpp_sd::events::is_valid_sequence(&ev, cfg.t_end));
    assert_eq!(st.target_forwards, ev.len() + 1);

    let sd_cfg = SdCfg { sample: cfg.clone(), gamma: Gamma::Fixed(5), ..Default::default() };
    let (ev, st) = sample_sd(&target, &draft, &sd_cfg, &mut rng).unwrap();
    assert!(tpp_sd::events::is_valid_sequence(&ev, cfg.t_end));
    assert!(st.target_forwards < ev.len().max(2), "SD must use fewer target forwards");
    assert!(st.acceptance_rate() > 0.0 && st.acceptance_rate() <= 1.0);
}

#[test]
fn sd_matches_ar_interval_distribution_on_artifacts() {
    let Some(art) = artifacts() else { return };
    let backend: Arc<dyn Backend> = Arc::new(XlaBackend::new(art));
    let target = backend.load_model("hawkes", "thp", "target").unwrap();
    let draft = backend.load_model("hawkes", "thp", "draft").unwrap();

    let collect = |method: &str, seed0: u64| -> Vec<f64> {
        let cfg = SampleCfg { num_types: 1, t_end: 10.0, max_events: 8192 };
        let mut taus = Vec::new();
        for s in 0..24u64 {
            let mut rng = Rng::new(seed0 + s);
            let ev = match method {
                "ar" => sample_ar(&target, &cfg, &mut rng).unwrap().0,
                _ => {
                    let sd = SdCfg {
                        sample: cfg.clone(),
                        gamma: Gamma::Fixed(10),
                        ..Default::default()
                    };
                    sample_sd(&target, &draft, &sd, &mut rng).unwrap().0
                }
            };
            taus.extend(tpp_sd::events::intervals(&ev));
        }
        taus
    };

    let ar = collect("ar", 100);
    let sd = collect("sd", 900);
    let mut sa = ar.clone();
    sa.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let d = tpp_sd::metrics::ks::ks_statistic(&sd, |x| {
        sa.partition_point(|&v| v <= x) as f64 / sa.len() as f64
    });
    let crit = 1.36
        * ((sa.len() + sd.len()) as f64 / (sa.len() as f64 * sd.len() as f64)).sqrt();
    assert!(d < 1.5 * crit, "KS {d:.4} crit {crit:.4}");
}
