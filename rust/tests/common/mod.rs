//! Helpers shared by the integration-test binaries (not itself a test
//! binary — Cargo only builds files directly under `tests/`).

use tpp_sd::sampler::SampleStats;

/// Field-by-field equality of every deterministic counter — everything
/// except `wall`, which necessarily differs between runs. Kept in ONE
/// place so a new `SampleStats` field only needs adding here for every
/// equivalence suite to start checking it.
pub fn assert_stats_eq(a: &SampleStats, b: &SampleStats, what: &str) {
    assert_eq!(a.events, b.events, "{what}: events");
    assert_eq!(a.rounds, b.rounds, "{what}: rounds");
    assert_eq!(a.target_forwards, b.target_forwards, "{what}: target_forwards");
    assert_eq!(a.draft_forwards, b.draft_forwards, "{what}: draft_forwards");
    assert_eq!(a.drafted, b.drafted, "{what}: drafted");
    assert_eq!(a.accepted, b.accepted, "{what}: accepted");
    assert_eq!(a.resampled, b.resampled, "{what}: resampled");
    assert_eq!(a.bonus, b.bonus, "{what}: bonus");
    assert_eq!(a.adjust_proposals, b.adjust_proposals, "{what}: adjust_proposals");
}
