//! The paper's central claim (App. A.2): TPP-SD's output distribution is
//! IDENTICAL to AR sampling from the target model. These tests verify it
//! statistically on the active backend (native by default): two-sample KS
//! on inter-event intervals, count means, and type marginals, plus
//! γ-invariance.

use std::sync::Arc;

use tpp_sd::events::intervals;
use tpp_sd::metrics::ks::ks_statistic;
use tpp_sd::metrics::wasserstein::{emd_labels, type_histogram, wasserstein_1d};
use tpp_sd::runtime::{Backend, NativeBackend, Uncached};
use tpp_sd::sampler::{sample_ar, sample_sd, Gamma, SampleCfg, SdCfg};
use tpp_sd::util::rng::Rng;

fn backend() -> Arc<dyn Backend> {
    tpp_sd::runtime::discover_backend().expect("backend")
}

fn two_sample_ks(a: &[f64], b: &[f64]) -> (f64, f64) {
    let mut sa = a.to_vec();
    sa.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let d = ks_statistic(b, |x| {
        sa.partition_point(|&v| v <= x) as f64 / sa.len() as f64
    });
    let crit = 1.36
        * ((sa.len() + b.len()) as f64 / (sa.len() as f64 * b.len() as f64)).sqrt();
    (d, crit)
}

struct Samples {
    taus: Vec<f64>,
    counts: Vec<f64>,
    types: Vec<u32>,
    alpha: f64,
}

#[allow(clippy::too_many_arguments)]
fn collect(
    dataset: &str,
    encoder: &str,
    method: &str,
    gamma: usize,
    n_seq: usize,
    t_end: f64,
    num_types: usize,
    seed0: u64,
) -> Samples {
    let b = backend();
    let target = b.load_model(dataset, encoder, "target").unwrap();
    let draft = b.load_model(dataset, encoder, "draft").unwrap();
    let cfg = SampleCfg { num_types, t_end, max_events: 8192 };
    let mut out = Samples { taus: vec![], counts: vec![], types: vec![], alpha: f64::NAN };
    let mut stats = tpp_sd::sampler::SampleStats::default();
    for s in 0..n_seq as u64 {
        let mut rng = Rng::new(seed0 + s);
        let ev = match method {
            "ar" => sample_ar(&target, &cfg, &mut rng).unwrap().0,
            _ => {
                let sd = SdCfg {
                    sample: cfg.clone(),
                    gamma: Gamma::Fixed(gamma),
                    ..Default::default()
                };
                let (ev, st) = sample_sd(&target, &draft, &sd, &mut rng).unwrap();
                stats.merge(&st);
                ev
            }
        };
        out.counts.push(ev.len() as f64);
        out.taus.extend(intervals(&ev));
        out.types.extend(ev.iter().map(|e| e.k));
    }
    out.alpha = stats.acceptance_rate();
    out
}

/// Headline property: intervals from SD and AR come from the same
/// distribution (two-sample KS below the 95% critical value, with margin).
#[test]
fn sd_matches_ar_interval_distribution() {
    let ar = collect("hawkes", "thp", "ar", 0, 24, 10.0, 1, 100);
    let sd = collect("hawkes", "thp", "sd", 10, 24, 10.0, 1, 900);
    // the draft must genuinely diverge, or the test is vacuous
    assert!(sd.alpha < 0.999, "draft identical to target? α={}", sd.alpha);
    let (d, crit) = two_sample_ks(&ar.taus, &sd.taus);
    assert!(
        d < 1.5 * crit,
        "interval distributions differ: KS={d:.4} crit={crit:.4} \
         (n={},{})",
        ar.taus.len(),
        sd.taus.len()
    );
    // count means within noise
    let ma = tpp_sd::util::math::mean(&ar.counts);
    let ms = tpp_sd::util::math::mean(&sd.counts);
    let sa = tpp_sd::util::math::std_dev(&ar.counts) / (ar.counts.len() as f64).sqrt();
    assert!(
        (ma - ms).abs() < 4.0 * sa.max(1.0),
        "count means differ: AR {ma:.1} vs SD {ms:.1} (se {sa:.2})"
    );
}

/// Type marginals must also agree (multi-type dataset).
#[test]
fn sd_matches_ar_type_marginals() {
    let ar = collect("multihawkes", "thp", "ar", 0, 16, 10.0, 2, 300);
    let sd = collect("multihawkes", "thp", "sd", 8, 16, 10.0, 2, 301);
    let ha = type_histogram(&ar.types, 2);
    let hs = type_histogram(&sd.types, 2);
    let n = ar.types.len().min(sd.types.len()) as f64;
    let se = (ha[0] * (1.0 - ha[0]) / n).sqrt();
    assert!(
        (ha[0] - hs[0]).abs() < 5.0 * se.max(0.01),
        "type-0 share differs: AR {:.3} vs SD {:.3} (se {se:.4})",
        ha[0],
        hs[0]
    );
}

/// ISSUE 3 distribution-identity gate: cached-path SD, uncached SD and AR
/// must be statistically indistinguishable on inter-event times — KS
/// below the 95% band (with margin) AND 1-Wasserstein within a
/// self-calibrated noise bound — at N ≥ 2000 pooled events. The cached
/// and uncached SD runs are additionally compared *bit-for-bit* per seed,
/// which is the exact (non-statistical) form of the same claim.
#[test]
fn cached_sd_uncached_sd_and_ar_share_interval_distribution() {
    let b = NativeBackend::new();
    let target = b.load_model("hawkes", "thp", "target").unwrap();
    let draft = b.load_model("hawkes", "thp", "draft").unwrap();
    let cfg = SampleCfg { num_types: 1, t_end: 25.0, max_events: 8192 };
    let sd = SdCfg { sample: cfg.clone(), gamma: Gamma::Fixed(8), ..Default::default() };

    let n_seq = 64u64;
    let (mut taus_ar, mut taus_sd) = (Vec::new(), Vec::new());
    let mut stats = tpp_sd::sampler::SampleStats::default();
    for s in 0..n_seq {
        let mut rng = Rng::new(4000 + s);
        let (ev_ar, _) = sample_ar(&target, &cfg, &mut rng).unwrap();
        taus_ar.extend(intervals(&ev_ar));

        let mut rng = Rng::new(8000 + s);
        let (ev_sd, st) = sample_sd(&target, &draft, &sd, &mut rng).unwrap();
        stats.merge(&st);
        let mut rng = Rng::new(8000 + s);
        let (ev_un, _) =
            sample_sd(&Uncached(&target), &Uncached(&draft), &sd, &mut rng).unwrap();
        assert_eq!(ev_sd, ev_un, "seed {s}: cached SD must be bit-for-bit uncached SD");
        taus_sd.extend(intervals(&ev_sd));
    }
    assert!(stats.acceptance_rate() < 0.999, "draft identical to target? vacuous test");
    assert!(
        taus_ar.len() >= 2000 && taus_sd.len() >= 2000,
        "need ≥2000 events per arm, got AR {} / SD {}",
        taus_ar.len(),
        taus_sd.len()
    );

    // KS gate
    let (d, crit) = two_sample_ks(&taus_ar, &taus_sd);
    assert!(d < 1.5 * crit, "cached SD vs AR intervals: KS={d:.4} crit={crit:.4}");

    // Wasserstein gate, self-calibrated: the AR sample split in half sets
    // the same-distribution noise floor for W1 at this sample size.
    let even: Vec<f64> = taus_ar.iter().copied().step_by(2).collect();
    let odd: Vec<f64> = taus_ar.iter().copied().skip(1).step_by(2).collect();
    let floor = wasserstein_1d(&even, &odd);
    let w1 = wasserstein_1d(&taus_ar, &taus_sd);
    let mean_tau = tpp_sd::util::math::mean(&taus_ar);
    assert!(
        w1 < 3.0 * floor + 0.05 * mean_tau,
        "cached SD vs AR: W1={w1:.4} exceeds noise floor {floor:.4} (mean τ {mean_tau:.3})"
    );
}

/// Same gate for the type marginal (`D_WS^k`) on a multi-type dataset:
/// EMD between cached-SD and AR type distributions within a
/// self-calibrated bound, and cached == uncached bit-for-bit.
#[test]
fn cached_sd_matches_ar_type_marginal_under_emd() {
    let b = NativeBackend::new();
    let target = b.load_model("multihawkes", "attnhp", "target").unwrap();
    let draft = b.load_model("multihawkes", "attnhp", "draft").unwrap();
    let cfg = SampleCfg { num_types: 2, t_end: 15.0, max_events: 8192 };
    let sd = SdCfg { sample: cfg.clone(), gamma: Gamma::Fixed(6), ..Default::default() };

    let (mut types_ar, mut types_sd) = (Vec::new(), Vec::new());
    for s in 0..24u64 {
        let mut rng = Rng::new(600 + s);
        let (ev_ar, _) = sample_ar(&target, &cfg, &mut rng).unwrap();
        types_ar.extend(ev_ar.iter().map(|e| e.k));
        let mut rng = Rng::new(990 + s);
        let (ev_sd, _) = sample_sd(&target, &draft, &sd, &mut rng).unwrap();
        let mut rng = Rng::new(990 + s);
        let (ev_un, _) =
            sample_sd(&Uncached(&target), &Uncached(&draft), &sd, &mut rng).unwrap();
        assert_eq!(ev_sd, ev_un, "seed {s}: cached vs uncached SD");
        types_sd.extend(ev_sd.iter().map(|e| e.k));
    }
    let even: Vec<u32> = types_ar.iter().copied().step_by(2).collect();
    let odd: Vec<u32> = types_ar.iter().copied().skip(1).step_by(2).collect();
    let floor = emd_labels(&even, &odd, 2);
    let d = emd_labels(&types_ar, &types_sd, 2);
    assert!(
        d < 3.0 * floor + 0.03,
        "type marginal EMD {d:.4} exceeds noise floor {floor:.4}"
    );
}

/// γ must not change the distribution, only the speed (paper Fig. 3).
#[test]
fn gamma_invariance() {
    let g2 = collect("hawkes", "sahp", "sd", 2, 16, 8.0, 1, 500);
    let g20 = collect("hawkes", "sahp", "sd", 20, 16, 8.0, 1, 700);
    let (d, crit) = two_sample_ks(&g2.taus, &g20.taus);
    assert!(d < 1.5 * crit, "γ changed the distribution: KS={d:.4} crit={crit:.4}");
}
