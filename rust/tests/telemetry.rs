//! Telemetry registry suite (ISSUE 8, DESIGN.md §15): bucket-boundary
//! exactness, concurrent-recording linearizability, snapshot-delta
//! arithmetic, JSON shape, and the RNG-neutrality property — toggling
//! telemetry must not move a single sampled event.
//!
//! The RNG-neutrality test toggles the PROCESS-WIDE enable flag, which is
//! why it lives in its own integration-test binary: cargo runs each
//! `tests/*.rs` file as a separate process, so the toggle cannot suppress
//! recording that other suites assert on.

use std::sync::Arc;

use tpp_sd::runtime::Backend;
use tpp_sd::sampler::{sample_ar, sample_sd, Gamma, SampleCfg, SdCfg};
use tpp_sd::telemetry::{self, bucket_index, Histo, NUM_BUCKETS, Registry, Role, Snapshot, Stage};
use tpp_sd::util::rng::Rng;

#[test]
fn bucket_boundaries_are_exact_powers_of_two() {
    assert_eq!(bucket_index(0), 0);
    assert_eq!(bucket_index(1), 0);
    for i in 1..(NUM_BUCKETS - 1) {
        let lo = 1u64 << i;
        // the lower edge of bucket i lands in bucket i…
        assert_eq!(bucket_index(lo), i, "2^{i}");
        // …one below it lands in bucket i-1…
        assert_eq!(bucket_index(lo - 1), i - 1, "2^{i} - 1");
        // …and the inclusive upper edge still lands in bucket i.
        assert_eq!(bucket_index(2 * lo - 1), i, "2^{} - 1", i + 1);
    }
    // the last bucket is open-ended
    assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
}

#[test]
fn quantiles_read_exact_bucket_bounds() {
    let h = Histo::new();
    assert_eq!(h.snap().quantile_ns(0.5), None, "empty histogram has no quantiles");
    // 90 samples in bucket 3 ([8,16) ns), 10 in bucket 10 ([1024,2048) ns)
    for _ in 0..90 {
        h.record_ns(9);
    }
    for _ in 0..10 {
        h.record_ns(1 << 10);
    }
    let s = h.snap();
    assert_eq!(s.count, 100);
    // ranks 1..=90 sit in bucket 3, whose inclusive upper edge is 15
    assert_eq!(s.quantile_ns(0.50), Some(15));
    assert_eq!(s.quantile_ns(0.90), Some(15));
    // ranks 91..=100 sit in bucket 10, upper edge 2047
    assert_eq!(s.quantile_ns(0.91), Some(2047));
    assert_eq!(s.quantile_ns(0.99), Some(2047));
    assert_eq!(s.quantile_ns(1.0), Some(2047));
    // exact mean from the tracked sum, not the buckets
    let want_mean = (90.0 * 9.0 + 10.0 * 1024.0) / 100.0;
    assert!((s.mean_ns() - want_mean).abs() < 1e-9);
}

#[test]
fn concurrent_recording_loses_nothing() {
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 10_000;
    let h = Arc::new(Histo::new());
    let mut join = Vec::new();
    for t in 0..THREADS {
        let h = h.clone();
        join.push(std::thread::spawn(move || {
            for i in 0..PER_THREAD {
                h.record_ns(t * 1_000 + (i % 7));
            }
        }));
    }
    for j in join {
        j.join().expect("recorder thread");
    }
    let s = h.snap();
    // linearizability of the counters: nothing lost, nothing doubled
    assert_eq!(s.count, THREADS * PER_THREAD);
    assert_eq!(s.buckets.iter().sum::<u64>(), s.count, "bucket sum == count");
    let want_sum: u64 = (0..THREADS)
        .map(|t| (0..PER_THREAD).map(|i| t * 1_000 + (i % 7)).sum::<u64>())
        .sum();
    assert_eq!(s.sum_ns, want_sum);
}

#[test]
fn snapshot_delta_arithmetic() {
    let r = Registry::new();
    r.record_ns(Stage::DraftForward, 100);
    r.record_round(5, 3, false);
    let a = r.snapshot();
    r.record_ns(Stage::DraftForward, 200);
    r.record_ns(Stage::EventLatency, 50);
    r.record_round(5, 5, true);
    let b = r.snapshot();

    let d = b.since(&a);
    assert_eq!(d.stage(Stage::DraftForward).count, 1);
    assert_eq!(d.stage(Stage::DraftForward).sum_ns, 200);
    assert_eq!(d.stage(Stage::EventLatency).count, 1);
    assert_eq!(d.stage(Stage::VerifyForward).count, 0);
    // roles: the second round proposed 5, accepted 5, all-accept
    assert_eq!(d.role(Role::Draft).rounds, 1);
    assert_eq!(d.role(Role::Draft).proposed, 5);
    assert_eq!(d.role(Role::Draft).accepted, 5);
    assert_eq!(d.role(Role::Target).proposed, 1);
    assert_eq!(d.role(Role::Target).accepted, 1);
    assert!((d.role(Role::Draft).alpha() - 1.0).abs() < 1e-12);

    // subtracting in the wrong order saturates to zero instead of wrapping
    let wrong = a.since(&b);
    assert_eq!(wrong.stage(Stage::DraftForward).count, 0);
    assert_eq!(wrong.role(Role::Draft).proposed, 0);
    // a snapshot minus itself is the zero snapshot
    assert_eq!(b.since(&b), Snapshot::default());
}

#[test]
fn snapshot_json_shape() {
    let r = Registry::new();
    r.record_ns(Stage::EventLatency, 1_000);
    r.record_ns(Stage::EventLatency, 3_000);
    r.record_round(4, 2, false);
    let j = r.snapshot().to_json();

    assert_eq!(j.f64_at("stages.event_latency.count"), Some(2.0));
    assert!(j.f64_at("stages.event_latency.p50_us").expect("p50") > 0.0);
    let p50 = j.f64_at("stages.event_latency.p50_us").unwrap();
    let p99 = j.f64_at("stages.event_latency.p99_us").unwrap();
    assert!(p99 >= p50, "p99 {p99} < p50 {p50}");
    // draft role: α = 2/4
    assert_eq!(j.f64_at("roles.draft.proposed"), Some(4.0));
    assert_eq!(j.f64_at("roles.draft.alpha"), Some(0.5));
    assert_eq!(j.f64_at("roles.target.accepted"), Some(0.0));
    // an idle stage serializes its undefined percentiles as null, not NaN
    assert_eq!(
        j.path("stages.draft_forward.p50_us"),
        Some(&tpp_sd::util::json::Json::Null)
    );
    assert_eq!(j.f64_at("stages.draft_forward.count"), Some(0.0));
    // the wire line must parse back (NaN would break this)
    let line = j.to_string();
    assert!(tpp_sd::util::json::Json::parse(&line).is_ok(), "unparseable: {line}");

    // the shared report mentions active stages and roles
    let report = r.snapshot().report();
    assert!(report.contains("event_latency"), "{report}");
    assert!(report.contains("accept[draft"), "{report}");
    assert!(!report.contains("draft_forward"), "idle stages stay silent: {report}");
}

#[test]
fn recording_consumes_no_sampler_rng() {
    // Golden-fixture property: the event stream must be byte-identical
    // with telemetry enabled and disabled — recording touches only
    // `Instant` and atomics, never a sampler RNG. Safe to toggle the
    // process-wide flag here: this test binary is its own process.
    let backend: Arc<dyn Backend> = tpp_sd::runtime::discover_backend().expect("backend");
    let target = backend.load_model("hawkes", "thp", "target").expect("target");
    let draft = backend.load_model("hawkes", "thp", "draft").expect("draft");
    let cfg = SampleCfg { num_types: 1, t_end: 8.0, max_events: 4096 };
    let sd = SdCfg { sample: cfg.clone(), gamma: Gamma::Fixed(6), ..Default::default() };

    let run = |on: bool| {
        telemetry::set_enabled(on);
        let mut rng = Rng::new(42);
        let sd_out = sample_sd(&target, &draft, &sd, &mut rng).expect("sd");
        let mut rng = Rng::new(42);
        let ar_out = sample_ar(&target, &cfg, &mut rng).expect("ar");
        (sd_out.0, ar_out.0)
    };
    let (sd_on, ar_on) = run(true);
    let (sd_off, ar_off) = run(false);
    telemetry::set_enabled(true);

    assert!(!sd_on.is_empty() && !ar_on.is_empty(), "degenerate run");
    assert_eq!(sd_on, sd_off, "telemetry moved an SD event");
    assert_eq!(ar_on, ar_off, "telemetry moved an AR event");

    // and the enabled run did record something
    let snap = telemetry::snapshot();
    assert!(snap.stage(Stage::VerifyForward).count > 0);
    assert!(snap.role(Role::Draft).rounds > 0);
}
