//! Integration tests over the active backend. The default build runs them
//! on the pure-Rust `NativeBackend` (no artifacts needed); setting
//! `TPP_SD_BACKEND=xla` (with `--features xla` + artifacts) runs the same
//! suite against the PJRT executor.

use std::sync::Arc;

use tpp_sd::metrics::model_loglik;
use tpp_sd::runtime::{Backend, ModelBackend, SeqInput};
use tpp_sd::sampler::{sample_ar, sample_sd, Gamma, SampleCfg, SdCfg};
use tpp_sd::util::rng::Rng;

fn backend() -> Arc<dyn Backend> {
    tpp_sd::runtime::discover_backend().expect("backend")
}

#[test]
fn load_all_dataset_encoder_pairs() {
    let b = backend();
    for dataset in ["poisson", "hawkes", "multihawkes", "taxi_sim"] {
        for enc in ["thp", "sahp", "attnhp"] {
            let m = b
                .load_model(dataset, enc, "draft")
                .unwrap_or_else(|e| panic!("{dataset}/{enc}: {e:#}"));
            assert!(m.max_bucket() >= 256);
            assert!(m.max_batch() >= 8);
        }
    }
    assert_eq!(b.num_types("hawkes").unwrap(), 1);
    assert_eq!(b.num_types("multihawkes").unwrap(), 2);
    assert_eq!(b.num_types("taxi_sim").unwrap(), 10);
    assert!(b.num_types("bogus").is_err());
    assert!(b.datasets().contains(&"multihawkes".to_string()));
}

#[test]
fn forward_outputs_are_valid_distributions() {
    let b = backend();
    let ex = b.load_model("multihawkes", "thp", "draft").unwrap();
    let seq = SeqInput {
        t0: 0.0,
        times: vec![0.5, 1.0, 2.5, 4.0],
        types: vec![0, 1, 0, 1],
    };
    let out = ex.forward(std::slice::from_ref(&seq)).unwrap();
    for row in 0..5 {
        let m = out.mixture(0, row);
        // log-weights normalized
        let s: f64 = m.log_w.iter().map(|w| w.exp()).sum();
        assert!((s - 1.0).abs() < 1e-4, "row {row}: Σw = {s}");
        // density spot values finite, CDF a probability
        assert!(m.logpdf(1.0).is_finite());
        assert!((0.0..=1.0).contains(&m.cdf(2.0)));
        let td = out.type_dist(0, row, 2);
        let s: f64 = td.probs.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }
}

#[test]
fn batch_rows_match_single_rows() {
    // batching must not change numerics: run 3 sequences individually and
    // as one batch, compare mixture params.
    let b = backend();
    let ex = b.load_model("hawkes", "sahp", "draft").unwrap();
    let mut rng = Rng::new(3);
    let seqs: Vec<SeqInput> = (0..3)
        .map(|_| {
            let n = 5 + rng.below(20);
            let mut t = 0.0;
            let mut s = SeqInput::default();
            for _ in 0..n {
                t += rng.exponential(4.0);
                s.times.push(t);
                s.types.push(0);
            }
            s
        })
        .collect();
    let batch = ex.forward(&seqs).unwrap();
    for (slot, seq) in seqs.iter().enumerate() {
        let single = ex.forward(std::slice::from_ref(seq)).unwrap();
        let row = seq.times.len(); // last row
        let m1 = single.mixture(0, row);
        let m2 = batch.mixture(slot, row);
        for (a, c) in m1.mu.iter().zip(&m2.mu) {
            assert!((a - c).abs() < 1e-4, "batch vs single mu: {a} vs {c}");
        }
    }
}

#[test]
fn ar_and_sd_run_and_stay_in_window() {
    let b = backend();
    let target = b.load_model("taxi_sim", "thp", "target").unwrap();
    let draft = b.load_model("taxi_sim", "thp", "draft").unwrap();
    let cfg = SampleCfg { num_types: 10, t_end: 8.0, max_events: 512 };
    let mut rng = Rng::new(11);
    let (ev, st) = sample_ar(&target, &cfg, &mut rng).unwrap();
    assert!(tpp_sd::events::is_valid_sequence(&ev, cfg.t_end));
    assert_eq!(st.target_forwards, ev.len() + 1); // one forward per event + final
    assert!(ev.iter().all(|e| (e.k as usize) < 10));

    let sd_cfg = SdCfg { sample: cfg.clone(), gamma: Gamma::Fixed(5), ..Default::default() };
    let (ev, st) = sample_sd(&target, &draft, &sd_cfg, &mut rng).unwrap();
    assert!(tpp_sd::events::is_valid_sequence(&ev, cfg.t_end));
    assert!(st.target_forwards < ev.len().max(2), "SD must use fewer target forwards");
    assert!(ev.iter().all(|e| (e.k as usize) < 10));
    assert!(st.acceptance_rate() > 0.0 && st.acceptance_rate() <= 1.0);
}

#[test]
fn adaptive_gamma_runs() {
    let b = backend();
    let target = b.load_model("hawkes", "thp", "target").unwrap();
    let draft = b.load_model("hawkes", "thp", "draft").unwrap();
    let sd_cfg = SdCfg {
        sample: SampleCfg { num_types: 1, t_end: 5.0, max_events: 512 },
        gamma: Gamma::Adaptive { init: 4, min: 2, max: 16 },
        ..Default::default()
    };
    let mut rng = Rng::new(2);
    let (ev, st) = sample_sd(&target, &draft, &sd_cfg, &mut rng).unwrap();
    assert!(!ev.is_empty());
    assert!(st.rounds > 0);
}

#[test]
fn model_loglik_is_finite_and_sane() {
    let b = backend();
    let target = b.load_model("hawkes", "thp", "target").unwrap();
    let cfg = SampleCfg { num_types: 1, t_end: 10.0, max_events: 512 };
    let mut rng = Rng::new(1);
    let (ev, _) = sample_ar(&target, &cfg, &mut rng).unwrap();
    assert!(ev.len() >= 3, "need a non-trivial sequence, got {}", ev.len());
    let ll = model_loglik(&target, &ev, 1, cfg.t_end).unwrap();
    assert!(ll.is_finite());
    // the model's own samples must score far better than the same number of
    // events crammed into implausibly tiny intervals
    let bad: Vec<tpp_sd::Event> = (0..ev.len())
        .map(|i| tpp_sd::Event::new(1e-3 * (i as f64 + 1.0), 0))
        .collect();
    let ll_bad = model_loglik(&target, &bad, 1, cfg.t_end).unwrap();
    assert!(
        ll > ll_bad,
        "model should prefer its own samples: {ll} vs degenerate {ll_bad}"
    );
}

#[test]
fn draft_size_ladder_loads() {
    let b = backend();
    for size in ["draft", "draft2", "draft3"] {
        let m = b
            .load_model("multihawkes", "attnhp", size)
            .unwrap_or_else(|e| panic!("{size}: {e:#}"));
        assert!(m.descriptor().contains(size));
    }
}

#[test]
fn dataset_specs_feed_ground_truth_processes() {
    let b = backend();
    for ds in b.datasets() {
        let spec = b.dataset_spec(&ds).unwrap();
        let gt = tpp_sd::processes::from_dataset_json(&spec)
            .unwrap_or_else(|e| panic!("{ds}: {e:#}"));
        assert_eq!(gt.num_types(), b.num_types(&ds).unwrap(), "{ds}");
        // the process must simulate a plausible sequence
        let mut rng = Rng::new(7);
        let ev = gt.simulate(&mut rng, 5.0);
        assert!(tpp_sd::events::is_valid_sequence(&ev, 5.0), "{ds}");
    }
}
