//! Integration tests over the real artifacts (`make artifacts` must have
//! run). Skipped with a notice when the artifact directory is absent so
//! `cargo test` stays green on a fresh checkout.

use tpp_sd::metrics::model_loglik;
use tpp_sd::runtime::{ArtifactDir, ModelExecutor, SeqInput};
use tpp_sd::sampler::{sample_ar, sample_sd, Gamma, SampleCfg, SdCfg};
use tpp_sd::util::rng::Rng;

fn artifacts() -> Option<ArtifactDir> {
    match ArtifactDir::discover() {
        Ok(a) => Some(a),
        Err(_) => {
            eprintln!("NOTE: artifacts/ missing — run `make artifacts`; skipping");
            None
        }
    }
}

#[test]
fn load_all_dataset_encoder_pairs() {
    let Some(art) = artifacts() else { return };
    let ds = art.datasets_json().unwrap();
    let client = tpp_sd::runtime::cpu_client().unwrap();
    for dataset in ["poisson", "hawkes", "multihawkes", "taxi_sim"] {
        for enc in ["thp", "sahp", "attnhp"] {
            let ex = ModelExecutor::load(client.clone(), &art, dataset, enc, "draft")
                .unwrap_or_else(|e| panic!("{dataset}/{enc}: {e:#}"));
            assert_eq!(ex.encoder, enc);
            assert!(ex.max_bucket() >= 256);
        }
    }
    assert!(ds.usize_at("k_max").unwrap() >= 22);
}

#[test]
fn forward_outputs_are_valid_distributions() {
    let Some(art) = artifacts() else { return };
    let client = tpp_sd::runtime::cpu_client().unwrap();
    let ex = ModelExecutor::load(client, &art, "multihawkes", "thp", "draft").unwrap();
    let seq = SeqInput {
        t0: 0.0,
        times: vec![0.5, 1.0, 2.5, 4.0],
        types: vec![0, 1, 0, 1],
    };
    let out = ex.forward(&[seq]).unwrap();
    for row in 0..5 {
        let m = out.mixture(0, row);
        // log-weights normalized
        let s: f64 = m.log_w.iter().map(|w| w.exp()).sum();
        assert!((s - 1.0).abs() < 1e-4, "row {row}: Σw = {s}");
        // density integrates reasonably (spot value finite)
        assert!(m.logpdf(1.0).is_finite());
        assert!((0.0..=1.0).contains(&m.cdf(2.0)));
        let td = out.type_dist(0, row, 2);
        let s: f64 = td.probs.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }
}

#[test]
fn batch_rows_match_single_rows() {
    // batching must not change numerics: run 3 sequences individually and
    // as one batch, compare mixture params.
    let Some(art) = artifacts() else { return };
    let client = tpp_sd::runtime::cpu_client().unwrap();
    let ex = ModelExecutor::load(client, &art, "hawkes", "sahp", "draft").unwrap();
    let mut rng = Rng::new(3);
    let seqs: Vec<SeqInput> = (0..3)
        .map(|_| {
            let n = 5 + rng.below(20);
            let mut t = 0.0;
            let mut s = SeqInput::default();
            for _ in 0..n {
                t += rng.exponential(4.0);
                s.times.push(t);
                s.types.push(0);
            }
            s
        })
        .collect();
    let batch = ex.forward(&seqs).unwrap();
    for (b, seq) in seqs.iter().enumerate() {
        let single = ex.forward(std::slice::from_ref(seq)).unwrap();
        let row = seq.times.len(); // last row
        let m1 = single.mixture(0, row);
        let m2 = batch.mixture(b, row);
        for (a, c) in m1.mu.iter().zip(&m2.mu) {
            assert!((a - c).abs() < 1e-4, "batch vs single mu: {a} vs {c}");
        }
    }
}

#[test]
fn ar_and_sd_run_and_stay_in_window() {
    let Some(art) = artifacts() else { return };
    let client = tpp_sd::runtime::cpu_client().unwrap();
    let target = ModelExecutor::load(client.clone(), &art, "taxi_sim", "thp", "target").unwrap();
    let draft = ModelExecutor::load(client, &art, "taxi_sim", "thp", "draft").unwrap();
    let cfg = SampleCfg { num_types: 10, t_end: 5.0, max_events: 512 };
    let mut rng = Rng::new(11);
    let (ev, st) = sample_ar(&target, &cfg, &mut rng).unwrap();
    assert!(tpp_sd::events::is_valid_sequence(&ev, cfg.t_end));
    assert_eq!(st.target_forwards, ev.len() + 1); // one forward per event + final
    assert!(ev.iter().all(|e| (e.k as usize) < 10));

    let sd_cfg = SdCfg { sample: cfg.clone(), gamma: Gamma::Fixed(5), ..Default::default() };
    let (ev, st) = sample_sd(&target, &draft, &sd_cfg, &mut rng).unwrap();
    assert!(tpp_sd::events::is_valid_sequence(&ev, cfg.t_end));
    assert!(st.target_forwards < ev.len().max(2), "SD must use fewer target forwards");
    assert!(ev.iter().all(|e| (e.k as usize) < 10));
    assert!(st.acceptance_rate() > 0.0 && st.acceptance_rate() <= 1.0);
}

#[test]
fn adaptive_gamma_runs() {
    let Some(art) = artifacts() else { return };
    let client = tpp_sd::runtime::cpu_client().unwrap();
    let target = ModelExecutor::load(client.clone(), &art, "hawkes", "thp", "target").unwrap();
    let draft = ModelExecutor::load(client, &art, "hawkes", "thp", "draft").unwrap();
    let sd_cfg = SdCfg {
        sample: SampleCfg { num_types: 1, t_end: 5.0, max_events: 512 },
        gamma: Gamma::Adaptive { init: 4, min: 2, max: 16 },
        ..Default::default()
    };
    let mut rng = Rng::new(2);
    let (ev, st) = sample_sd(&target, &draft, &sd_cfg, &mut rng).unwrap();
    assert!(!ev.is_empty());
    assert!(st.rounds > 0);
}

#[test]
fn model_loglik_is_finite_and_sane() {
    let Some(art) = artifacts() else { return };
    let client = tpp_sd::runtime::cpu_client().unwrap();
    let target = ModelExecutor::load(client.clone(), &art, "hawkes", "thp", "target").unwrap();
    let cfg = SampleCfg { num_types: 1, t_end: 10.0, max_events: 512 };
    let mut rng = Rng::new(1);
    let (ev, _) = sample_ar(&target, &cfg, &mut rng).unwrap();
    let ll = model_loglik(&target, &ev, 1, cfg.t_end).unwrap();
    assert!(ll.is_finite());
    // model's own samples should score better than a time-scrambled copy
    let mut bad = ev.clone();
    let span = bad.last().unwrap().t;
    let n = bad.len();
    for (i, e) in bad.iter_mut().enumerate() {
        e.t = span * (i as f64 + 0.5) / n as f64; // uniformize
    }
    let ll_bad = model_loglik(&target, &bad, 1, cfg.t_end).unwrap();
    assert!(
        ll > ll_bad,
        "model should prefer its own samples: {ll} vs uniformized {ll_bad}"
    );
}

#[test]
fn draft_size_ladder_loads() {
    let Some(art) = artifacts() else { return };
    let client = tpp_sd::runtime::cpu_client().unwrap();
    for size in ["draft", "draft2", "draft3"] {
        let ex = ModelExecutor::load(client.clone(), &art, "multihawkes", "attnhp", size)
            .unwrap_or_else(|e| panic!("{size}: {e:#}"));
        assert_eq!(ex.size_name, size);
    }
}
