//! Allocations-per-event ceiling (ISSUE 7, DESIGN.md §14): once the
//! buffer pool, forward-shell pool and session scratch are warm, the AR
//! streaming loop must run in (amortized) constant allocations per event.
//!
//! The counting allocator is process-global, so this test lives in its
//! own integration-test binary: nothing else races the counter.

use tpp_sd::bench::alloc_count::{allocations, CountingAllocator};
use tpp_sd::runtime::{pool, Backend, NativeBackend};
use tpp_sd::sampler::{sample_ar, SampleCfg};
use tpp_sd::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

#[test]
fn warmed_ar_loop_stays_under_allocation_ceiling() {
    // The loop's steady state allocates nothing; the ceiling of 2 per
    // event absorbs one-off growth (event Vec doubling, pool misses on
    // rewind-boundary bucket changes) without letting a per-event
    // allocation regression (one `vec![]` in the hot loop ≈ +1.0) hide.
    const CEILING: f64 = 2.0;

    let b = NativeBackend::new();
    let k = b.num_types("hawkes").unwrap();
    let model = b.load_model("hawkes", "thp", "target").unwrap();
    let cfg = SampleCfg { num_types: k, t_end: 100.0, max_events: 16 * 1024 };

    // warm run: grows the event Vec, context window, mixture scratch, and
    // seeds the buffer/shell free lists
    let (warm, _) = sample_ar(&model, &cfg, &mut Rng::new(7)).unwrap();
    assert!(warm.len() > 100, "warm run produced only {} events", warm.len());

    let pool_before = pool::stats();
    let allocs_before = allocations();
    let (ev, _) = sample_ar(&model, &cfg, &mut Rng::new(11)).unwrap();
    let allocs = allocations() - allocs_before;
    let pd = pool::stats().since(&pool_before);

    assert!(ev.len() > 100, "measured run produced only {} events", ev.len());
    let per_event = allocs as f64 / ev.len() as f64;
    assert!(
        per_event <= CEILING,
        "warmed AR loop allocated {allocs} times for {} events ({per_event:.2}/event, \
         ceiling {CEILING})",
        ev.len()
    );
    // and the economy must come from recycling, not from luck
    assert!(
        pd.buffers_reused > 0,
        "no buffers were recycled during the measured run (reused={}, allocated={})",
        pd.buffers_reused,
        pd.buffers_allocated
    );
}
