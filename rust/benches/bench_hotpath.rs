//! Bench: the zero-allocation hot path (ISSUE 7, DESIGN.md §14) — what do
//! the persistent worker pool and buffer recycling actually buy?
//!
//! Two measurements, both on the native backend by default:
//!
//! * **batched-forward throughput** (rows/s): repeated full forwards of
//!   N ∈ {1, 8} sequences at L = 256, persistent pool vs the old
//!   spawn-scoped-threads-per-wave baseline (`pool::set_scoped_baseline`).
//!   One forward is sanity-compared across modes before timing — the pool
//!   must be a pure scheduling change.
//! * **allocations per event**: warmed `sample_ar`/`sample_sd` runs (N=1
//!   blocking, N=8 fleet) under the counting global allocator, recycling
//!   + pool on vs the baseline (scoped threads, recycling off).
//! * **telemetry overhead** (ISSUE 8, DESIGN.md §15): SD-fleet events/s
//!   with the telemetry registry recording vs disabled, after an equality
//!   probe proving the toggle moves no sampled event (RNG neutrality).
//!
//! The process exits non-zero (the CI `bench-smoke` gate) if pooled
//! throughput falls below `--min-ratio` × scoped (default 0.97, noise
//! guard on an "at least as fast" target) at any measured shape, if
//! the N=1 allocations-per-event drop falls below `--min-alloc-drop`
//! (default 10), or if telemetry-on throughput falls below `--min-ratio`
//! × telemetry-off. The numbers are merged into `BENCH_sampling.json`
//! under the `bench_hotpath` key.
//!
//!     cargo bench --bench bench_hotpath [-- --dataset hawkes
//!         --encoder thp --iters 200 --t-end 150 --gamma 10
//!         --min-ratio 0.97 --min-alloc-drop 10 --out BENCH_sampling.json]

use std::time::Instant;

use anyhow::{ensure, Result};
use tpp_sd::bench::alloc_count::{allocations, CountingAllocator};
use tpp_sd::bench::merge_snapshot;
use tpp_sd::runtime::{pool, Backend, ModelBackend, SeqInput};
use tpp_sd::sampler::{
    sample_ar, sample_ar_fleet, sample_sd, sample_sd_fleet, Gamma, SampleCfg, SdCfg,
};
use tpp_sd::telemetry;
use tpp_sd::util::cli::Args;
use tpp_sd::util::json::Json;
use tpp_sd::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Default snapshot path: the workspace root, independent of the cwd
/// cargo runs the bench with.
const DEFAULT_OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sampling.json");

/// Sequence length (events) filling the L=256 bucket (255 events + BOS).
const LEN: usize = 255;

/// A deterministic history (0.1-spaced, round-robin types), offset per
/// batch slot so the slots are not identical.
fn history(len: usize, k: usize, slot: usize) -> SeqInput {
    SeqInput {
        t0: 0.0,
        times: (0..len).map(|i| (i + 1) as f64 * 0.1 + slot as f64 * 1e-3).collect(),
        types: (0..len).map(|i| ((i + slot) % k) as u32).collect(),
    }
}

/// Best-of-`reps` batched-forward throughput in rows/s for the current
/// pool mode (arms are interleaved by the caller, so drift hits both).
fn forward_rows_per_s(
    model: &dyn ModelBackend,
    seqs: &[SeqInput],
    iters: usize,
    reps: usize,
) -> Result<f64> {
    let rows: usize = seqs.iter().map(SeqInput::len_with_bos).sum();
    let mut best = 0f64;
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..iters {
            let out = model.forward(seqs)?;
            std::hint::black_box(out.mixture(0, LEN).mu[0]);
        }
        let rps = (rows * iters) as f64 / t0.elapsed().as_secs_f64().max(1e-12);
        best = best.max(rps);
    }
    Ok(best)
}

/// Run `f`, returning (allocation calls, events generated).
fn count_allocs(f: impl FnOnce() -> Result<usize>) -> Result<(usize, usize)> {
    let before = allocations();
    let events = f()?;
    Ok((allocations() - before, events))
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let dataset = args.str_or("dataset", "hawkes").to_string();
    let encoder = args.str_or("encoder", "thp").to_string();
    let iters = args.usize_or("iters", 200).max(1);
    let reps = args.usize_or("reps", 5).max(1);
    let gamma = args.usize_or("gamma", 10).max(1);
    let t_end = args.f64_or("t-end", 150.0);
    let min_ratio = args.f64_or("min-ratio", 0.97);
    let min_alloc_drop = args.f64_or("min-alloc-drop", 10.0);
    let out_path = args.str_or("out", DEFAULT_OUT).to_string();

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;
    let k = backend.num_types(&dataset)?;
    let target = backend.load_model(&dataset, &encoder, "target")?;
    let draft = backend.load_model(&dataset, &encoder, "draft")?;
    target.warmup()?;
    draft.warmup()?;
    println!(
        "== hot path: pool + recycling vs scoped threads ({dataset}/{encoder}, backend={}, \
         L={}) ==",
        backend.name(),
        LEN + 1
    );

    // --- part 1: batched full-forward throughput, pooled vs scoped ---
    let mut snapshot: Vec<(String, Json)> = vec![
        ("backend".into(), Json::Str(backend.name().into())),
        ("dataset".into(), Json::Str(dataset.clone())),
        ("encoder".into(), Json::Str(encoder.clone())),
        ("len".into(), Json::Num((LEN + 1) as f64)),
        ("iters".into(), Json::Num(iters as f64)),
        ("t_end".into(), Json::Num(t_end)),
    ];
    let mut throughput_ok = true;
    for n in [1usize, 8] {
        let seqs: Vec<SeqInput> = (0..n).map(|s| history(LEN, k, s)).collect();
        // sanity: the pool must not change a single output bit
        pool::set_scoped_baseline(true);
        let scoped_out = target.forward(&seqs)?;
        pool::set_scoped_baseline(false);
        let pooled_out = target.forward(&seqs)?;
        for b in 0..n {
            ensure!(
                scoped_out.mixture(b, LEN) == pooled_out.mixture(b, LEN),
                "pooled forward diverged from scoped forward at N={n} b={b} — \
                 refusing to time a broken pool"
            );
        }
        let (mut scoped, mut pooled) = (0f64, 0f64);
        for _ in 0..reps {
            pool::set_scoped_baseline(true);
            scoped = scoped.max(forward_rows_per_s(target.as_ref(), &seqs, iters, 1)?);
            pool::set_scoped_baseline(false);
            pooled = pooled.max(forward_rows_per_s(target.as_ref(), &seqs, iters, 1)?);
        }
        let ratio = pooled / scoped;
        println!(
            "forward N={n}: pooled {pooled:12.0} rows/s | scoped {scoped:12.0} rows/s | \
             {ratio:.2}x"
        );
        throughput_ok &= ratio >= min_ratio;
        snapshot.push((format!("rows_per_s_pooled_n{n}"), Json::Num(pooled)));
        snapshot.push((format!("rows_per_s_scoped_n{n}"), Json::Num(scoped)));
        snapshot.push((format!("pool_ratio_n{n}"), Json::Num(ratio)));
    }

    // --- part 2: allocations per generated event ---
    let cfg = SampleCfg { num_types: k, t_end, max_events: 16 * 1024 };
    let sd_cfg = SdCfg { sample: cfg.clone(), gamma: Gamma::Fixed(gamma), ..Default::default() };
    let seeds: Vec<u64> = (0..8).map(|i| 1000 + i).collect();

    // N=1 blocking drivers: baseline (scoped + no recycling) vs optimized.
    let mut gates: Vec<(String, f64, f64)> = Vec::new();
    for (mode, scoped, recycle) in [("base", true, false), ("opt", false, true)] {
        pool::set_scoped_baseline(scoped);
        pool::set_recycling(recycle);
        // warm: fills the buffer/shell pools and the session scratch
        sample_ar(&target, &cfg, &mut Rng::new(7))?;
        sample_sd(&target, &draft, &sd_cfg, &mut Rng::new(7))?;

        let (a, ev) = count_allocs(|| {
            let (ev, _) = sample_ar(&target, &cfg, &mut Rng::new(11))?;
            Ok(ev.len())
        })?;
        let ar_ape = a as f64 / (ev.max(1)) as f64;
        let (a, ev) = count_allocs(|| {
            let (ev, _) = sample_sd(&target, &draft, &sd_cfg, &mut Rng::new(11))?;
            Ok(ev.len())
        })?;
        let sd_ape = a as f64 / (ev.max(1)) as f64;

        // N=8 fleets through the engine
        let (a, ev) = count_allocs(|| {
            let (runs, _) = sample_ar_fleet(&target, &cfg, &seeds)?;
            Ok(runs.iter().map(|(ev, _)| ev.len()).sum())
        })?;
        let ar_fleet_ape = a as f64 / (ev.max(1)) as f64;
        let (a, ev) = count_allocs(|| {
            let (runs, _) = sample_sd_fleet(&target, &draft, &sd_cfg, &seeds)?;
            Ok(runs.iter().map(|(ev, _)| ev.len()).sum())
        })?;
        let sd_fleet_ape = a as f64 / (ev.max(1)) as f64;

        println!(
            "allocs/event [{mode:4}]: ar {ar_ape:8.2}  sd {sd_ape:8.2}  \
             ar_fleet(8) {ar_fleet_ape:8.2}  sd_fleet(8) {sd_fleet_ape:8.2}"
        );
        for (name, v) in [
            ("ar", ar_ape),
            ("sd", sd_ape),
            ("ar_fleet8", ar_fleet_ape),
            ("sd_fleet8", sd_fleet_ape),
        ] {
            snapshot.push((format!("allocs_per_event_{name}_{mode}"), Json::Num(v)));
            match gates.iter_mut().find(|(n, _, _)| n == name) {
                Some(g) => g.2 = v,
                None => gates.push((name.to_string(), v, v)),
            }
        }
    }
    // restore process defaults before any gate can early-exit the process
    pool::set_scoped_baseline(false);
    pool::set_recycling(true);

    let mut drops = Vec::new();
    for (name, base, opt) in &gates {
        let ratio = *base / opt.max(1e-9);
        println!("allocs/event drop [{name}]: {ratio:.1}x (base {base:.2} -> opt {opt:.2})");
        snapshot.push((format!("alloc_drop_{name}"), Json::Num(ratio)));
        drops.push((name.clone(), ratio));
    }

    // --- part 3: telemetry overhead A/B (ISSUE 8, DESIGN.md §15) ---
    // Sanity first: toggling telemetry must not move a single event —
    // recording consumes no sampler RNG, so the streams are identical.
    let tel_probe = |on: bool| -> Result<usize> {
        telemetry::set_enabled(on);
        let (runs, _) = sample_sd_fleet(&target, &draft, &sd_cfg, &seeds)?;
        Ok(runs.iter().map(|(ev, _)| ev.len()).sum())
    };
    let ev_on = tel_probe(true)?;
    let ev_off = tel_probe(false)?;
    ensure!(
        ev_on == ev_off && ev_on > 0,
        "telemetry toggled the sampled events ({ev_on} on vs {ev_off} off) — \
         recording must be RNG-neutral"
    );
    // Interleaved best-of-reps SD-fleet events/s, telemetry off vs on.
    let (mut tel_off, mut tel_on) = (0f64, 0f64);
    for _ in 0..reps {
        for (on, best) in [(false, &mut tel_off), (true, &mut tel_on)] {
            telemetry::set_enabled(on);
            let t0 = Instant::now();
            let (runs, _) = sample_sd_fleet(&target, &draft, &sd_cfg, &seeds)?;
            let events: usize = runs.iter().map(|(ev, _)| ev.len()).sum();
            let eps = events as f64 / t0.elapsed().as_secs_f64().max(1e-12);
            *best = best.max(eps);
        }
    }
    telemetry::set_enabled(true);
    let tel_ratio = tel_on / tel_off.max(1e-12);
    println!(
        "sd_fleet(8) events/s: telemetry on {tel_on:10.0} | off {tel_off:10.0} | \
         {tel_ratio:.2}x"
    );
    snapshot.push(("events_per_s_telemetry_on".into(), Json::Num(tel_on)));
    snapshot.push(("events_per_s_telemetry_off".into(), Json::Num(tel_off)));
    snapshot.push(("telemetry_ratio".into(), Json::Num(tel_ratio)));

    merge_snapshot(&out_path, "bench_hotpath", Json::Obj(snapshot.into_iter().collect()))?;
    println!("snapshot merged into {out_path}");

    // --- gates (CI bench-smoke) ---
    ensure!(
        throughput_ok,
        "pooled forward throughput fell below {min_ratio:.2}x the scoped baseline"
    );
    for (name, drop) in &drops {
        let bar = if name.ends_with("_fleet8") { 1.0 } else { min_alloc_drop };
        ensure!(
            *drop >= bar,
            "allocations-per-event drop for {name} is {drop:.1}x, below the {bar:.1}x gate"
        );
    }
    ensure!(
        tel_ratio >= min_ratio,
        "telemetry-on throughput is {tel_ratio:.2}x telemetry-off, below the \
         {min_ratio:.2}x gate — recording must stay effectively free"
    );
    println!("{}", telemetry::report());
    Ok(())
}
