//! Bench: classical thinning vs model sampling (paper §2.2 / App. D.1).
//!
//! Thinning on the *analytic* ground-truth process is nearly free (no
//! Transformer forwards) — the point of the comparison is the acceptance
//! behaviour: thinning's per-candidate acceptance rate λ*/λ̄ vs TPP-SD's
//! draft acceptance rate α, and the forwards-per-event budget that makes
//! CIF-based SD unattractive (App. D.1's argument).
//!
//!     cargo bench --bench bench_thinning_vs_sd [-- --t-end 20]

use anyhow::Result;
use tpp_sd::processes::{GroundTruth, Hawkes, InhomPoisson};
use tpp_sd::runtime::{Backend, ModelBackend};
use tpp_sd::sampler::{sample_ar, sample_sd, Gamma, SampleCfg, SdCfg};
use tpp_sd::util::cli::Args;
use tpp_sd::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let t_end = args.f64_or("t-end", 20.0);

    // (a) thinning on analytic processes: candidates per accepted event
    //     = 1/acceptance — the CIF-based bound the paper discusses.
    let mut rng = Rng::new(4);
    for (name, p) in [
        ("poisson", Box::new(InhomPoisson::new(5.0, 1.0, 0.02)) as Box<dyn GroundTruth>),
        ("hawkes", Box::new(Hawkes::new(2.5, 1.0, 2.0))),
    ] {
        let t0 = std::time::Instant::now();
        let mut events = 0;
        for _ in 0..50 {
            events += p.simulate(&mut rng, t_end).len();
        }
        println!(
            "thinning {name:<8}: {:>8.3}ms for 50 sequences ({} events) — no forwards",
            t0.elapsed().as_secs_f64() * 1e3,
            events
        );
    }

    // (b) model sampling: forwards per event, AR vs SD
    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;
    let target = backend.load_model("hawkes", "thp", "target")?;
    let draft = backend.load_model("hawkes", "thp", "draft")?;
    target.warmup()?;
    draft.warmup()?;
    let cfg = SampleCfg { num_types: 1, t_end, max_events: 16 * 1024 };
    let mut rng = Rng::new(5);
    let (ev, st) = sample_ar(&target, &cfg, &mut rng)?;
    println!(
        "model AR        : {:.2} target-forwards/event ({} events, {:.2?})",
        st.target_forwards as f64 / ev.len().max(1) as f64,
        ev.len(),
        st.wall
    );
    let sd_cfg = SdCfg { sample: cfg, gamma: Gamma::Fixed(10), ..Default::default() };
    let (ev, st) = sample_sd(&target, &draft, &sd_cfg, &mut rng)?;
    println!(
        "model TPP-SD    : {:.2} target + {:.2} draft forwards/event (α={:.2}, {} events, {:.2?})",
        st.target_forwards as f64 / ev.len().max(1) as f64,
        st.draft_forwards as f64 / ev.len().max(1) as f64,
        st.acceptance_rate(),
        ev.len(),
        st.wall
    );
    Ok(())
}
