//! Bench: incremental forward cache vs full-window forwards (ISSUE 3,
//! DESIGN.md §12) — does O(1)-per-event inference state actually buy the
//! integer-factor speedup the O(L) → O(γ) arithmetic promises?
//!
//! Two levels, both on the native backend by default:
//!
//! * **forward-level** (the gated number): with an L-event history
//!   committed, time draft-step forwards (1 new event) and verify-pass
//!   forwards (γ new events) through `forward_delta`, against full
//!   `forward` calls over the same final sequence. This isolates the
//!   cache from sampler overhead.
//! * **sampling-level**: `sample_sd` / `sample_ar` with the streams on
//!   vs forced off (`Uncached`), identical seeds — identical events by
//!   construction, so the comparison is pure wall-clock.
//!
//! The process exits non-zero if cached draft-step throughput falls below
//! `--min-speedup` × uncached (default 1.0) at `--len` (default 256) —
//! the CI `bench-smoke` gate. The measured numbers are merged into
//! `BENCH_sampling.json` under the `bench_cached_forward` key.
//!
//!     cargo bench --bench bench_cached_forward [-- --dataset hawkes
//!         --encoder thp --len 256 --gamma 10 --iters 2000 --seqs 4
//!         --t-end 150 --min-speedup 1.0 --out BENCH_sampling.json]

use std::time::Instant;

use anyhow::{ensure, Result};
use tpp_sd::bench::merge_snapshot;
use tpp_sd::runtime::{Backend, CachedForward, ModelBackend, SeqDelta, SeqInput, Uncached};
use tpp_sd::sampler::{sample_ar, sample_sd, Gamma, SampleCfg, SdCfg};
use tpp_sd::util::cli::Args;
use tpp_sd::util::json::{obj, Json};
use tpp_sd::util::rng::Rng;

/// Default snapshot path: the workspace root, independent of the cwd
/// cargo runs the bench with.
const DEFAULT_OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sampling.json");

/// A deterministic L-event history (0.1-spaced, round-robin types).
fn history(len: usize, k: usize) -> SeqInput {
    SeqInput {
        t0: 0.0,
        times: (0..len).map(|i| (i + 1) as f64 * 0.1).collect(),
        types: (0..len).map(|i| (i % k) as u32).collect(),
    }
}

/// Forward-level comparison at sequence length `len` (model positions,
/// incl. BOS — so the probed sequence has `len - 1` events and the cold
/// reference runs in the `len` bucket): returns (cached fps, uncached
/// fps) for `m`-event extensions.
fn forward_level(
    model: &dyn ModelBackend,
    len: usize,
    m: usize,
    k: usize,
    iters: usize,
) -> Result<(f64, f64)> {
    let base = history(len - 1 - m, k);
    let ext = history(len - 1, k);
    let c = model.cached().expect("cached-forward bench needs a CachedForward backend");
    let sid = c.open_stream()?;
    // commit the shared history once
    let warm = SeqDelta {
        base_len: 0,
        t0: 0.0,
        times: base.times.clone(),
        types: base.types.clone(),
    };
    c.forward_delta(sid, &warm)?;
    let delta = SeqDelta {
        base_len: base.times.len(),
        t0: 0.0,
        times: ext.times[base.times.len()..].to_vec(),
        types: ext.types[base.times.len()..].to_vec(),
    };
    // sanity: the delta rows equal the cold rows before timing anything
    let row = ext.times.len();
    let cold = model.forward(std::slice::from_ref(&ext))?;
    let hot = c.forward_delta(sid, &delta)?;
    ensure!(
        hot.mixture(row) == cold.mixture(0, row),
        "cached row diverged from cold row — refusing to time a broken cache"
    );

    let t0 = Instant::now();
    for _ in 0..iters {
        // same base each iteration: an implicit rewind + m-event extension,
        // exactly the draft/verify access pattern
        let out = c.forward_delta(sid, &delta)?;
        std::hint::black_box(out.mixture(row).mu[0]);
    }
    let cached_fps = iters as f64 / t0.elapsed().as_secs_f64().max(1e-12);

    let t0 = Instant::now();
    for _ in 0..iters {
        let out = model.forward(std::slice::from_ref(&ext))?;
        std::hint::black_box(out.mixture(0, row).mu[0]);
    }
    let uncached_fps = iters as f64 / t0.elapsed().as_secs_f64().max(1e-12);
    c.close_stream(sid);
    Ok((cached_fps, uncached_fps))
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let dataset = args.str_or("dataset", "hawkes").to_string();
    let encoder = args.str_or("encoder", "thp").to_string();
    let len = args.usize_or("len", 256).max(16);
    let gamma = args.usize_or("gamma", 10).clamp(1, len / 2);
    let iters = args.usize_or("iters", 2000).max(1);
    let seqs = args.usize_or("seqs", 4).max(1);
    let t_end = args.f64_or("t-end", 150.0);
    let min_speedup = args.f64_or("min-speedup", 1.0);
    let out_path = args.str_or("out", DEFAULT_OUT).to_string();

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;
    let k = backend.num_types(&dataset)?;
    let target = backend.load_model(&dataset, &encoder, "target")?;
    let draft = backend.load_model(&dataset, &encoder, "draft")?;
    target.warmup()?;
    draft.warmup()?;
    println!(
        "== cached vs uncached forwards ({dataset}/{encoder}, backend={}, L={len}, γ={gamma}) ==",
        backend.name()
    );

    // --- forward level ---
    let (draft_c, draft_u) = forward_level(draft.as_ref(), len, 1, k, iters)?;
    let draft_speedup = draft_c / draft_u;
    println!(
        "draft step (1 event) : cached {draft_c:10.0} fwd/s | uncached {draft_u:10.0} fwd/s | {draft_speedup:.1}x"
    );
    let (verify_c, verify_u) = forward_level(target.as_ref(), len, gamma, k, iters)?;
    let verify_speedup = verify_c / verify_u;
    println!(
        "verify pass (γ={gamma:2})  : cached {verify_c:10.0} fwd/s | uncached {verify_u:10.0} fwd/s | {verify_speedup:.1}x"
    );

    // --- sampling level ---
    let cfg = SampleCfg { num_types: k, t_end, max_events: 16 * 1024 };
    let sd_cfg = SdCfg { sample: cfg.clone(), gamma: Gamma::Fixed(gamma), ..Default::default() };
    let (mut sd_ev, mut ar_ev) = (0usize, 0usize);
    let (mut t_sd_c, mut t_sd_u, mut t_ar_c, mut t_ar_u) = (0f64, 0f64, 0f64, 0f64);
    for s in 0..seqs as u64 {
        let t0 = Instant::now();
        let mut rng = Rng::new(s);
        let (ev_c, _) = sample_sd(&target, &draft, &sd_cfg, &mut rng)?;
        t_sd_c += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let mut rng = Rng::new(s);
        let (ev_u, _) = sample_sd(&Uncached(&target), &Uncached(&draft), &sd_cfg, &mut rng)?;
        t_sd_u += t0.elapsed().as_secs_f64();
        ensure!(ev_c == ev_u, "cached and uncached SD diverged at seed {s}");
        sd_ev += ev_c.len();

        let t0 = Instant::now();
        let mut rng = Rng::new(s);
        let (ev_c, _) = sample_ar(&target, &cfg, &mut rng)?;
        t_ar_c += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let mut rng = Rng::new(s);
        let (ev_u, _) = sample_ar(&Uncached(&target), &cfg, &mut rng)?;
        t_ar_u += t0.elapsed().as_secs_f64();
        ensure!(ev_c == ev_u, "cached and uncached AR diverged at seed {s}");
        ar_ev += ev_c.len();
    }
    let sd_c_eps = sd_ev as f64 / t_sd_c.max(1e-12);
    let sd_u_eps = sd_ev as f64 / t_sd_u.max(1e-12);
    let ar_c_eps = ar_ev as f64 / t_ar_c.max(1e-12);
    let ar_u_eps = ar_ev as f64 / t_ar_u.max(1e-12);
    println!(
        "TPP-SD sampling      : cached {sd_c_eps:10.0} ev/s | uncached {sd_u_eps:10.0} ev/s | {:.1}x ({sd_ev} events)",
        sd_c_eps / sd_u_eps
    );
    println!(
        "AR sampling          : cached {ar_c_eps:10.0} ev/s | uncached {ar_u_eps:10.0} ev/s | {:.1}x ({ar_ev} events)",
        ar_c_eps / ar_u_eps
    );

    // --- snapshot ---
    let snapshot = obj(vec![
        ("backend", Json::Str(backend.name().into())),
        ("dataset", Json::Str(dataset.clone())),
        ("encoder", Json::Str(encoder.clone())),
        ("len", Json::Num(len as f64)),
        ("gamma", Json::Num(gamma as f64)),
        ("iters", Json::Num(iters as f64)),
        ("t_end", Json::Num(t_end)),
        ("cached_draft_fwd_per_s", Json::Num(draft_c)),
        ("uncached_draft_fwd_per_s", Json::Num(draft_u)),
        ("draft_speedup", Json::Num(draft_speedup)),
        ("cached_verify_fwd_per_s", Json::Num(verify_c)),
        ("uncached_verify_fwd_per_s", Json::Num(verify_u)),
        ("verify_speedup", Json::Num(verify_speedup)),
        ("sd_cached_events_per_s", Json::Num(sd_c_eps)),
        ("sd_uncached_events_per_s", Json::Num(sd_u_eps)),
        ("sd_speedup", Json::Num(sd_c_eps / sd_u_eps)),
        ("ar_cached_events_per_s", Json::Num(ar_c_eps)),
        ("ar_uncached_events_per_s", Json::Num(ar_u_eps)),
        ("ar_speedup", Json::Num(ar_c_eps / ar_u_eps)),
    ]);
    merge_snapshot(&out_path, "bench_cached_forward", snapshot)?;
    println!("snapshot merged into {out_path}");

    // --- gate (CI bench-smoke): cached must not be slower than uncached ---
    ensure!(
        draft_speedup >= min_speedup && verify_speedup >= min_speedup,
        "cached path too slow at L={len}: draft {draft_speedup:.2}x, verify {verify_speedup:.2}x \
         (gate {min_speedup:.2}x)"
    );
    if draft_speedup < 2.0 {
        println!("WARNING: draft speedup {draft_speedup:.2}x below the 2x acceptance bar");
    }
    Ok(())
}
