//! Bench: end-to-end sampling wall-time, AR vs TPP-SD — the Table-1/2
//! headline measurement, reduced to one (dataset × encoder) pair per run —
//! plus the fleet engine at the same event budget (`--parallel` sequences
//! in lockstep, DESIGN.md §11).
//!
//!     cargo bench --bench bench_sampling [-- --dataset hawkes --encoder attnhp
//!                                           --gamma 10 --t-end 20 --runs 3
//!                                           --parallel 8]

use anyhow::Result;
use tpp_sd::runtime::{Backend, ModelBackend};
use tpp_sd::sampler::{
    fleet_seeds, sample_ar, sample_sd, sample_sd_fleet, Gamma, SampleCfg, SdCfg,
};
use tpp_sd::util::cli::Args;
use tpp_sd::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let dataset = args.str_or("dataset", "hawkes").to_string();
    let encoder = args.str_or("encoder", "attnhp").to_string();
    let gamma = args.usize_or("gamma", 10);
    let t_end = args.f64_or("t-end", 20.0);
    let runs = args.usize_or("runs", 3);
    let parallel = args.usize_or("parallel", 8).max(1);

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;
    let num_types = backend.num_types(&dataset)?;
    let target = backend.load_model(&dataset, &encoder, "target")?;
    let draft = backend.load_model(&dataset, &encoder, "draft")?;
    target.warmup()?;
    draft.warmup()?;

    let cfg = SampleCfg { num_types, t_end, max_events: 16 * 1024 };
    println!(
        "== sampling wall-time ({dataset}/{encoder}, backend={}, γ={gamma}, T={t_end}) ==",
        backend.name()
    );

    let (mut t_ar, mut t_sd, mut ev_ar, mut ev_sd, mut alpha) = (0.0, 0.0, 0, 0, 0.0);
    for seed in 0..runs as u64 {
        let mut rng = Rng::new(seed);
        let (ev, st) = sample_ar(&target, &cfg, &mut rng)?;
        t_ar += st.wall.as_secs_f64();
        ev_ar += ev.len();
        let sd_cfg =
            SdCfg { sample: cfg.clone(), gamma: Gamma::Fixed(gamma), ..Default::default() };
        let mut rng = Rng::new(seed + 1000);
        let (ev, st) = sample_sd(&target, &draft, &sd_cfg, &mut rng)?;
        t_sd += st.wall.as_secs_f64();
        ev_sd += ev.len();
        alpha += st.acceptance_rate();
    }
    let per_ar = t_ar / ev_ar.max(1) as f64;
    let per_sd = t_sd / ev_sd.max(1) as f64;
    println!(
        "AR     : {:8.2}ms/event ({} events, {:.2}s total)",
        per_ar * 1e3,
        ev_ar,
        t_ar
    );
    println!(
        "TPP-SD : {:8.2}ms/event ({} events, {:.2}s total, α={:.2})",
        per_sd * 1e3,
        ev_sd,
        t_sd,
        alpha / runs as f64
    );
    println!("speedup S_AR/SD = {:.2}x", per_ar / per_sd);

    // --- fleet engine: the same SD workload, `parallel` sequences per call
    let sd_cfg = SdCfg { sample: cfg, gamma: Gamma::Fixed(gamma), ..Default::default() };
    let (mut t_fleet, mut ev_fleet) = (0.0, 0usize);
    for seed in 0..runs as u64 {
        let t0 = std::time::Instant::now();
        let (fleet_runs, _) =
            sample_sd_fleet(&target, &draft, &sd_cfg, &fleet_seeds(seed + 1000, parallel))?;
        t_fleet += t0.elapsed().as_secs_f64();
        ev_fleet += fleet_runs.iter().map(|(ev, _)| ev.len()).sum::<usize>();
    }
    let per_fleet = t_fleet / ev_fleet.max(1) as f64;
    println!(
        "TPP-SD fleet(N={parallel}): {:8.2}ms/event ({} events, {:.2}s total)",
        per_fleet * 1e3,
        ev_fleet,
        t_fleet
    );
    println!("fleet speedup vs sequential SD = {:.2}x", per_sd / per_fleet);
    Ok(())
}
