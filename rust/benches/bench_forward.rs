//! Bench: raw forward-pass latency per (size × bucket × batch) — the L2/L3
//! hot path that every sampler cost model builds on, plus the
//! length-bucketing ablation of DESIGN.md §10 (what a single max-length
//! graph would cost instead).
//!
//!     cargo bench --bench bench_forward [-- --encoder thp --dataset hawkes]

use anyhow::Result;
use tpp_sd::bench::bench_loop;
use tpp_sd::runtime::{Backend, ModelBackend, SeqInput};
use tpp_sd::util::cli::Args;
use tpp_sd::util::rng::Rng;

fn seq_of_len(rng: &mut Rng, n: usize, k: usize) -> SeqInput {
    let mut t = 0.0;
    let mut s = SeqInput::default();
    for _ in 0..n {
        t += rng.exponential(5.0);
        s.times.push(t);
        s.types.push(rng.below(k) as u32);
    }
    s
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let dataset = args.str_or("dataset", "hawkes").to_string();
    let encoder = args.str_or("encoder", "thp").to_string();
    let iters = args.usize_or("iters", 20);

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;
    println!("== forward latency ({dataset}/{encoder}, backend={}) ==", backend.name());
    let mut rng = Rng::new(1);

    for size in ["draft", "target"] {
        let exec = backend.load_model(&dataset, &encoder, size)?;
        exec.warmup()?;
        for &fill in &[40usize, 100, 220, 460] {
            let seq = seq_of_len(&mut rng, fill, 1);
            let r = bench_loop(
                &format!("{size} len={fill} (bucket {})", exec.pick_bucket(fill + 1)?),
                2,
                iters,
                || {
                    exec.forward(std::slice::from_ref(&seq)).unwrap();
                },
            );
            println!("{}", r.report());
        }
        // batched: 8 sequences in one call vs 8 calls (batching ablation)
        let seqs: Vec<SeqInput> = (0..8).map(|_| seq_of_len(&mut rng, 100, 1)).collect();
        let r = bench_loop(&format!("{size} len=100 batch=8 (one call)"), 2, iters, || {
            exec.forward(&seqs).unwrap();
        });
        println!("{}", r.report());
        let r = bench_loop(&format!("{size} len=100 batch=8 (8 calls)"), 2, iters, || {
            for s in &seqs {
                exec.forward(std::slice::from_ref(s)).unwrap();
            }
        });
        println!("{}", r.report());
        // bucketing ablation: same short sequence forced through max bucket
        let short = seq_of_len(&mut rng, 40, 1);
        let mut padded = short.clone();
        // pad with events far in the future; length masks them out — this
        // emulates a single max-length graph (no bucketing)
        while padded.times.len() + 1 < exec.max_bucket() {
            padded.times.push(1e6);
            padded.types.push(0);
        }
        let r = bench_loop(&format!("{size} len=40 WITHOUT bucketing"), 2, iters, || {
            exec.forward(std::slice::from_ref(&padded)).unwrap();
        });
        println!("{}", r.report());
    }
    Ok(())
}
