//! Bench: coordinator overhead + batching ablation (DESIGN.md §10).
//!
//! (a) ExecutorHandle (channel hop, batch window) vs direct model forward
//!     at concurrency 1 — the coordinator's overhead budget (<10% target);
//! (b) N concurrent AR sessions through one batching executor vs N
//!     sequential direct sessions — what dynamic batching buys.
//!
//!     cargo bench --bench bench_coordinator [-- --sessions 4 --t-end 5]

use std::time::{Duration, Instant};

use anyhow::Result;
use tpp_sd::coordinator::ExecutorHandle;
use tpp_sd::runtime::{Backend, ModelBackend};
use tpp_sd::sampler::{sample_ar, SampleCfg};
use tpp_sd::util::cli::Args;
use tpp_sd::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let dataset = args.str_or("dataset", "hawkes").to_string();
    let encoder = args.str_or("encoder", "thp").to_string();
    let sessions = args.usize_or("sessions", 4);
    let t_end = args.f64_or("t-end", 5.0);
    let cfg = SampleCfg { num_types: 1, t_end, max_events: 16 * 1024 };

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;

    // (a) direct vs handle, concurrency 1
    {
        let direct = backend.load_model(&dataset, &encoder, "target")?;
        direct.warmup()?;
        // one throwaway run: XLA's first execution of each graph carries
        // one-time autotuning cost even after compilation
        let mut rng = Rng::new(0);
        sample_ar(&direct, &cfg, &mut rng)?;
        let t = Instant::now();
        let mut rng = Rng::new(1);
        let (ev, _) = sample_ar(&direct, &cfg, &mut rng)?;
        let t_direct = t.elapsed().as_secs_f64();
        println!("direct  AR: {:.3}s ({} events)", t_direct, ev.len());

        let handle = ExecutorHandle::spawn(
            backend.clone(),
            &dataset,
            &encoder,
            "target",
            8,
            Duration::from_millis(0),
        )?;
        // warm the handle's lazy compile cache so both paths time pure
        // sampling (the direct path was warmed above)
        let mut rng = Rng::new(0);
        sample_ar(&handle, &cfg, &mut rng)?;
        let mut rng = Rng::new(1);
        let t = Instant::now();
        let (ev, _) = sample_ar(&handle, &cfg, &mut rng)?;
        let t_handle = t.elapsed().as_secs_f64();
        println!(
            "handle  AR: {:.3}s ({} events) — overhead {:+.1}%",
            t_handle,
            ev.len(),
            (t_handle / t_direct - 1.0) * 100.0
        );
    }

    // (b) N concurrent sessions through one batching executor
    for window_ms in [0u64, 2] {
        let handle = ExecutorHandle::spawn(
            backend.clone(),
            &dataset,
            &encoder,
            "target",
            8,
            Duration::from_millis(window_ms),
        )?;
        // warm the compile caches
        let mut rng = Rng::new(9);
        sample_ar(&handle, &SampleCfg { t_end: 1.0, ..cfg.clone() }, &mut rng)?;

        let t = Instant::now();
        let mut join = Vec::new();
        for s in 0..sessions {
            let h = handle.clone();
            let cfg = cfg.clone();
            join.push(std::thread::spawn(move || -> Result<usize> {
                let mut rng = Rng::new(100 + s as u64);
                let (ev, _) = sample_ar(&h, &cfg, &mut rng)?;
                Ok(ev.len())
            }));
        }
        let mut events = 0;
        for j in join {
            events += j.join().expect("session")?;
        }
        let wall = t.elapsed().as_secs_f64();
        println!(
            "batched {} sessions (window {}ms): {:.3}s  {:.1} events/s",
            sessions,
            window_ms,
            wall,
            events as f64 / wall,
        );
        println!("{}", tpp_sd::bench::executor_report(&handle.name, &handle.stats));
    }
    // One process-wide telemetry summary over everything this bench ran
    // (per-stage latency percentiles + acceptance, DESIGN.md §15).
    println!("{}", tpp_sd::telemetry::report());
    Ok(())
}
