//! Bench: fleet engine vs sequential sampling — does driving N sequences
//! in lockstep through batched forwards (DESIGN.md §11) beat running the
//! same N sequences one after another?
//!
//! Measures events/sec of fleet(N) vs N× sequential for both AR and
//! TPP-SD (identical events by construction — the fleet is bit-for-bit
//! the sequential runs, so the comparison is pure wall-clock), and merges
//! a snapshot into `BENCH_sampling.json` (under the `bench_fleet` key,
//! alongside `bench_cached_forward`'s) so the perf trajectory is recorded
//! across PRs.
//!
//!     cargo bench --bench bench_fleet [-- --dataset hawkes --encoder attnhp
//!                                        --gamma 10 --t-end 20 --n 8
//!                                        --reps 3 --out BENCH_sampling.json]

use anyhow::Result;
use tpp_sd::runtime::{Backend, ModelBackend};
use tpp_sd::sampler::{
    fleet_seeds, sample_ar, sample_ar_fleet, sample_sd, sample_sd_fleet, Gamma, SampleCfg, SdCfg,
};
use tpp_sd::util::cli::Args;
use tpp_sd::util::json::{obj, Json};
use tpp_sd::util::rng::Rng;

/// Default snapshot path: the workspace root, independent of the cwd
/// cargo runs the bench with.
const DEFAULT_OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sampling.json");

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let dataset = args.str_or("dataset", "hawkes").to_string();
    let encoder = args.str_or("encoder", "attnhp").to_string();
    let gamma = args.usize_or("gamma", 10);
    let t_end = args.f64_or("t-end", 20.0);
    let n = args.usize_or("n", 8).max(1);
    let reps = args.usize_or("reps", 3).max(1);
    let out_path = args.str_or("out", DEFAULT_OUT).to_string();

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;
    let num_types = backend.num_types(&dataset)?;
    let target = backend.load_model(&dataset, &encoder, "target")?;
    let draft = backend.load_model(&dataset, &encoder, "draft")?;
    target.warmup()?;
    draft.warmup()?;

    let cfg = SampleCfg { num_types, t_end, max_events: 16 * 1024 };
    let sd_cfg = SdCfg { sample: cfg.clone(), gamma: Gamma::Fixed(gamma), ..Default::default() };
    println!(
        "== fleet(N={n}) vs {n}× sequential ({dataset}/{encoder}, backend={}, γ={gamma}, T={t_end}, {reps} reps) ==",
        backend.name()
    );

    // --- AR ---
    let seeds = fleet_seeds(1, n);
    let (mut t_seq, mut t_fleet, mut events) = (0.0f64, 0.0f64, 0usize);
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        let mut ev_seq = 0usize;
        for &s in &seeds {
            let mut rng = Rng::new(s);
            ev_seq += sample_ar(&target, &cfg, &mut rng)?.0.len();
        }
        t_seq += t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let (runs, _) = sample_ar_fleet(&target, &cfg, &seeds)?;
        t_fleet += t0.elapsed().as_secs_f64();
        let ev_fleet: usize = runs.iter().map(|(ev, _)| ev.len()).sum();
        assert_eq!(ev_seq, ev_fleet, "fleet must be bit-for-bit the sequential runs");
        events += ev_fleet;
    }
    let ar_seq_eps = events as f64 / t_seq.max(1e-12);
    let ar_fleet_eps = events as f64 / t_fleet.max(1e-12);
    println!(
        "AR     : sequential {ar_seq_eps:10.0} ev/s | fleet {ar_fleet_eps:10.0} ev/s | {:.2}x",
        ar_fleet_eps / ar_seq_eps
    );

    // --- TPP-SD ---
    let (mut t_seq, mut t_fleet, mut events) = (0.0f64, 0.0f64, 0usize);
    let mut fleet_stats = tpp_sd::sampler::FleetStats::default();
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        let mut ev_seq = 0usize;
        for &s in &seeds {
            let mut rng = Rng::new(s);
            ev_seq += sample_sd(&target, &draft, &sd_cfg, &mut rng)?.0.len();
        }
        t_seq += t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let (runs, fs) = sample_sd_fleet(&target, &draft, &sd_cfg, &seeds)?;
        t_fleet += t0.elapsed().as_secs_f64();
        let ev_fleet: usize = runs.iter().map(|(ev, _)| ev.len()).sum();
        assert_eq!(ev_seq, ev_fleet, "fleet must be bit-for-bit the sequential runs");
        events += ev_fleet;
        fleet_stats = fs;
    }
    let sd_seq_eps = events as f64 / t_seq.max(1e-12);
    let sd_fleet_eps = events as f64 / t_fleet.max(1e-12);
    println!(
        "TPP-SD : sequential {sd_seq_eps:10.0} ev/s | fleet {sd_fleet_eps:10.0} ev/s | {:.2}x",
        sd_fleet_eps / sd_seq_eps
    );
    println!(
        "fleet occupancy: draft {:.2}, target {:.2} (of {n})",
        fleet_stats.draft_occupancy(),
        fleet_stats.target_occupancy()
    );

    // --- snapshot ---
    let snapshot = obj(vec![
        ("backend", Json::Str(backend.name().into())),
        ("dataset", Json::Str(dataset.clone())),
        ("encoder", Json::Str(encoder.clone())),
        ("gamma", Json::Num(gamma as f64)),
        ("t_end", Json::Num(t_end)),
        ("n", Json::Num(n as f64)),
        ("reps", Json::Num(reps as f64)),
        ("ar_seq_events_per_s", Json::Num(ar_seq_eps)),
        ("ar_fleet_events_per_s", Json::Num(ar_fleet_eps)),
        ("ar_fleet_speedup", Json::Num(ar_fleet_eps / ar_seq_eps)),
        ("sd_seq_events_per_s", Json::Num(sd_seq_eps)),
        ("sd_fleet_events_per_s", Json::Num(sd_fleet_eps)),
        ("sd_fleet_speedup", Json::Num(sd_fleet_eps / sd_seq_eps)),
        ("draft_occupancy", Json::Num(fleet_stats.draft_occupancy())),
        ("target_occupancy", Json::Num(fleet_stats.target_occupancy())),
        ("delta_batches", Json::Num(fleet_stats.delta_batches as f64)),
    ]);
    tpp_sd::bench::merge_snapshot(&out_path, "bench_fleet", snapshot)?;
    println!("snapshot merged into {out_path}");
    Ok(())
}
