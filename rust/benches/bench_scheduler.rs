//! Bench: continuous-batching scheduler vs per-request fleets (DESIGN.md
//! §16).
//!
//! Phase 1 — capacity: a Poisson arrival stream of mixed ar/sd/sd-adaptive
//! requests, served two ways on the SAME executor pair: (a) every request
//! submitted to the shared scheduler pool (requests co-batch their
//! forwards), (b) every request driving its own isolated fleet — the
//! pre-scheduler serving path. Per-request events must be bit-identical
//! between the two; the comparison is pure wall-clock/throughput.
//!
//! Phase 2 — overload: a burst of deadline-carrying requests against tight
//! admission limits (`max_live`/`queue_depth`); reports the shed/expired
//! split, demonstrating load shedding instead of unbounded queueing.
//!
//! Merges a snapshot under the `bench_scheduler` key of
//! `BENCH_sampling.json`.
//!
//!     cargo bench --bench bench_scheduler [-- --dataset hawkes --encoder thp
//!                                            --requests 12 --rate 4 --t-end 6
//!                                            --gamma 8 --burst 16
//!                                            --out BENCH_sampling.json]

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use tpp_sd::coordinator::{build_sessions, ModelPair, Router, Scheduler, SchedulerCfg};
use tpp_sd::sampler::{
    fleet_seeds, sample_ar_fleet, sample_sd_fleet, FleetRuns, Gamma, SampleCfg, SdCfg,
};
use tpp_sd::util::cli::Args;
use tpp_sd::util::json::{obj, Json};
use tpp_sd::util::math::percentile;
use tpp_sd::util::rng::Rng;

/// Default snapshot path: the workspace root, independent of the cwd
/// cargo runs the bench with.
const DEFAULT_OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sampling.json");

const METHODS: [&str; 3] = ["ar", "sd", "sd-adaptive"];

struct Req {
    /// seconds after the stream start this request arrives
    arrival: f64,
    method: &'static str,
    n_seq: usize,
    seed: u64,
}

/// The isolated per-request fleet (the old serving path), for the
/// baseline side and the bit-equality oracle.
fn isolated_fleet(
    pair: &ModelPair,
    method: &str,
    gamma: usize,
    cfg: &SampleCfg,
    seeds: &[u64],
) -> Result<FleetRuns> {
    let runs = match method {
        "ar" => sample_ar_fleet(&pair.target, cfg, seeds)?.0,
        "sd" => {
            let sd =
                SdCfg { sample: cfg.clone(), gamma: Gamma::Fixed(gamma), ..Default::default() };
            sample_sd_fleet(&pair.target, &pair.draft, &sd, seeds)?.0
        }
        "sd-adaptive" => {
            let sd = SdCfg {
                sample: cfg.clone(),
                gamma: Gamma::Adaptive { init: gamma, min: 2, max: 4 * gamma.max(1) },
                ..Default::default()
            };
            sample_sd_fleet(&pair.target, &pair.draft, &sd, seeds)?.0
        }
        other => anyhow::bail!("unknown method '{other}'"),
    };
    Ok(runs)
}

/// Drive the arrival stream, one thread per request; `serve` runs the
/// request once its arrival time comes. Returns per-request runs, the
/// per-request latencies (seconds), and the stream's wall-clock.
fn drive<F>(plan: &[Req], serve: F) -> (Vec<FleetRuns>, Vec<f64>, f64)
where
    F: Fn(&Req) -> FleetRuns + Send + Sync + 'static,
{
    let serve = Arc::new(serve);
    let t0 = Instant::now();
    let joins: Vec<_> = plan
        .iter()
        .map(|r| {
            let serve = serve.clone();
            let req = Req { arrival: r.arrival, method: r.method, n_seq: r.n_seq, seed: r.seed };
            std::thread::spawn(move || {
                let wait = req.arrival - t0.elapsed().as_secs_f64();
                if wait > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(wait));
                }
                let t = Instant::now();
                let runs = serve(&req);
                (runs, t.elapsed().as_secs_f64())
            })
        })
        .collect();
    let mut runs = Vec::new();
    let mut lats = Vec::new();
    for j in joins {
        let (r, l) = j.join().expect("request thread");
        runs.push(r);
        lats.push(l);
    }
    (runs, lats, t0.elapsed().as_secs_f64())
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let dataset = args.str_or("dataset", "hawkes").to_string();
    let encoder = args.str_or("encoder", "thp").to_string();
    let requests = args.usize_or("requests", 12).max(1);
    let rate = args.f64_or("rate", 4.0); // mean arrivals per second
    let t_end = args.f64_or("t-end", 6.0);
    let gamma = args.usize_or("gamma", 8);
    let burst = args.usize_or("burst", 16).max(1);
    let out_path = args.str_or("out", DEFAULT_OUT).to_string();

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;
    let router = Arc::new(Router::with_scheduler(
        backend.clone(),
        8,
        Duration::from_millis(1),
        SchedulerCfg::default(),
    )?);
    let pair = router.route(&dataset, &encoder, "draft")?;
    let cfg = SampleCfg { num_types: pair.num_types, t_end, max_events: 16 * 1024 };
    let sched = router.scheduler(&dataset, &encoder, "draft")?;

    // Poisson arrivals of a deterministic method/size mix.
    let mut rng = Rng::new(7);
    let mut t = 0.0;
    let plan: Vec<Req> = (0..requests)
        .map(|i| {
            t += rng.exponential(rate);
            Req {
                arrival: t,
                method: METHODS[i % METHODS.len()],
                n_seq: 1 + i % 3,
                seed: 1000 * i as u64,
            }
        })
        .collect();

    // Warm executor compile caches so both phases time pure serving.
    {
        let warm = SampleCfg { t_end: 1.0, ..cfg.clone() };
        let s = build_sessions(&pair, "sd", gamma, warm.clone(), &[99])?;
        sched
            .submit(s, true, None)
            .map_err(|r| anyhow::anyhow!("warmup rejected: {}", r.message()))?;
        isolated_fleet(&pair, "ar", gamma, &warm, &[98])?;
    }

    println!(
        "== scheduler vs per-request fleets ({dataset}/{encoder}, backend={}, {requests} reqs, λ={rate}/s, T={t_end}) ==",
        backend.name()
    );

    // (a) shared continuous-batching pool
    let (sched_runs, sched_lat, sched_wall) = {
        let (pair, cfg, sched, gamma) = (pair.clone(), cfg.clone(), sched.clone(), gamma);
        drive(&plan, move |r| {
            let sessions =
                build_sessions(&pair, r.method, gamma, cfg.clone(), &fleet_seeds(r.seed, r.n_seq))
                    .expect("sessions");
            sched.submit(sessions, true, None).expect("submit").0
        })
    };

    // (b) one isolated fleet per request (the pre-scheduler path)
    let (base_runs, base_lat, base_wall) = {
        let (pair, cfg, gamma) = (pair.clone(), cfg.clone(), gamma);
        drive(&plan, move |r| {
            isolated_fleet(&pair, r.method, gamma, &cfg, &fleet_seeds(r.seed, r.n_seq))
                .expect("fleet")
        })
    };

    // The oracle: co-batching across requests must not move a single event.
    let mut events = 0usize;
    for (i, (a, b)) in sched_runs.iter().zip(&base_runs).enumerate() {
        assert_eq!(a.len(), b.len(), "request {i}: run count");
        for (j, ((ev_a, _), (ev_b, _))) in a.iter().zip(b).enumerate() {
            assert_eq!(ev_a, ev_b, "request {i} sequence {j}: scheduler diverged from fleet");
            events += ev_a.len();
        }
    }

    let sched_eps = events as f64 / sched_wall.max(1e-12);
    let base_eps = events as f64 / base_wall.max(1e-12);
    println!(
        "scheduler : {sched_eps:10.0} ev/s  wall {sched_wall:6.2}s  p50 {:6.3}s p95 {:6.3}s",
        percentile(&sched_lat, 0.5),
        percentile(&sched_lat, 0.95)
    );
    println!(
        "per-req   : {base_eps:10.0} ev/s  wall {base_wall:6.2}s  p50 {:6.3}s p95 {:6.3}s",
        percentile(&base_lat, 0.5),
        percentile(&base_lat, 0.95)
    );
    println!("throughput ratio: {:.2}x (identical events: {events})", sched_eps / base_eps);

    // --- Phase 2: overload under tight limits ---
    let tight_cfg = SchedulerCfg::builder().max_live(2).queue_depth(2).build();
    let tight = Scheduler::spawn(pair.clone(), tight_cfg);
    let burst_cfg = SampleCfg { t_end: (t_end / 2.0).max(1.0), ..cfg.clone() };
    let joins: Vec<_> = (0..burst)
        .map(|i| {
            let (pair, c, tight) = (pair.clone(), burst_cfg.clone(), tight.clone());
            std::thread::spawn(move || {
                let sessions = build_sessions(&pair, "sd", 8, c, &[5000 + i as u64])
                    .expect("sessions");
                tight
                    .submit(sessions, true, Some(Duration::from_millis(25)))
                    .map(|_| ())
                    .map_err(|r| r.code().as_str())
            })
        })
        .collect();
    let mut completed = 0usize;
    let mut shed = 0usize;
    let mut expired = 0usize;
    for j in joins {
        match j.join().expect("burst thread") {
            Ok(()) => completed += 1,
            Err("overloaded") => shed += 1,
            Err("expired") => expired += 1,
            Err(other) => panic!("unexpected rejection '{other}'"),
        }
    }
    let shed_rate = (shed + expired) as f64 / burst as f64;
    println!(
        "overload  : burst {burst} vs max_live {}/depth {} → {completed} completed, {shed} shed, \
         {expired} expired (shed rate {shed_rate:.2})",
        tight_cfg.max_live, tight_cfg.queue_depth
    );

    let snapshot = obj(vec![
        ("backend", Json::Str(backend.name().into())),
        ("dataset", Json::Str(dataset.clone())),
        ("encoder", Json::Str(encoder.clone())),
        ("requests", Json::Num(requests as f64)),
        ("arrival_rate_per_s", Json::Num(rate)),
        ("t_end", Json::Num(t_end)),
        ("gamma", Json::Num(gamma as f64)),
        ("scheduler_events_per_s", Json::Num(sched_eps)),
        ("per_request_events_per_s", Json::Num(base_eps)),
        ("throughput_ratio", Json::Num(sched_eps / base_eps)),
        ("scheduler_p50_latency_s", Json::Num(percentile(&sched_lat, 0.5))),
        ("scheduler_p95_latency_s", Json::Num(percentile(&sched_lat, 0.95))),
        ("per_request_p50_latency_s", Json::Num(percentile(&base_lat, 0.5))),
        ("per_request_p95_latency_s", Json::Num(percentile(&base_lat, 0.95))),
        ("burst", Json::Num(burst as f64)),
        ("burst_completed", Json::Num(completed as f64)),
        ("burst_shed", Json::Num(shed as f64)),
        ("burst_expired", Json::Num(expired as f64)),
        ("burst_shed_rate", Json::Num(shed_rate)),
    ]);
    tpp_sd::bench::merge_snapshot(&out_path, "bench_scheduler", snapshot)?;
    println!("snapshot merged into {out_path}");
    // Per-stage latency report — includes the new queue_wait stage.
    println!("{}", tpp_sd::telemetry::report());
    Ok(())
}
