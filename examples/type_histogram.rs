//! **Figure 5**: event-type histograms of AR vs TPP-SD samples on the real
//! datasets, written as CSV per (dataset × encoder).
//!
//!     cargo run --release --example type_histogram -- \
//!         [--datasets taobao_sim,amazon_sim,taxi_sim,stackoverflow_sim]
//!         [--encoders thp,sahp,attnhp] [--out /tmp/type_hist]
//!         [--t-end 50] [--n-seq 2] [--seeds 0,1] [--backend auto|native|xla]

use std::io::Write;

use anyhow::Result;
use tpp_sd::bench::{real_cell, EvalCfg};
use tpp_sd::metrics::emd_types;
use tpp_sd::processes::from_dataset_json;
use tpp_sd::runtime::{Backend, ModelBackend};
use tpp_sd::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let datasets = args.list_or(
        "datasets",
        &["taobao_sim", "amazon_sim", "taxi_sim", "stackoverflow_sim"],
    );
    let encoders = args.list_or("encoders", &["thp", "sahp", "attnhp"]);
    let out_dir = args.str_or("out", "/tmp/type_hist").to_string();
    std::fs::create_dir_all(&out_dir)?;
    let cfg = EvalCfg {
        t_end: args.f64_or("t-end", 50.0),
        n_seq: args.usize_or("n-seq", 2),
        seeds: args
            .list_or("seeds", &["0", "1"])
            .iter()
            .map(|s| s.parse().unwrap())
            .collect(),
        gamma: args.usize_or("gamma", 10),
        ..Default::default()
    };

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;

    for ds in &datasets {
        let spec = backend.dataset_spec(ds)?;
        let process = from_dataset_json(&spec)?;
        let num_types = backend.num_types(ds)?;
        for enc in &encoders {
            let target = backend.load_model(ds, enc, "target")?;
            target.warmup()?;
            let draft = backend.load_model(ds, enc, "draft")?;
            draft.warmup()?;
            let cell = real_cell(&target, &draft, process.as_ref(), num_types, &cfg)?;
            let path = format!("{out_dir}/types_{ds}_{enc}.csv");
            let mut f = std::fs::File::create(&path)?;
            writeln!(f, "type,freq_ar,freq_sd")?;
            for k in 0..num_types {
                writeln!(f, "{k},{:.5},{:.5}", cell.hist_ar[k], cell.hist_sd[k])?;
            }
            println!(
                "{path}: K={num_types} hist-EMD(ar,sd)={:.4}",
                emd_types(&cell.hist_ar, &cell.hist_sd)
            );
        }
    }
    Ok(())
}
