//! Quick distributional sanity check: AR vs TPP-SD count means, interval
//! means and a two-sample KS on intervals (any backend).
//!
//!     cargo run --release --example distcheck -- [--backend auto|native|xla]

use tpp_sd::runtime::Backend;
use tpp_sd::sampler::{sample_ar, sample_sd, Gamma, SampleCfg, SdCfg};
use tpp_sd::util::cli::Args;
use tpp_sd::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;
    let target = backend.load_model("hawkes", "thp", "target")?;
    let draft = backend.load_model("hawkes", "thp", "draft")?;
    let cfg = SampleCfg { num_types: 1, t_end: 10.0, max_events: 4096 };
    let n = 30;
    let mut ar_counts = vec![]; let mut sd_counts = vec![];
    let mut ar_taus = vec![]; let mut sd_taus = vec![];
    for s in 0..n {
        let mut rng = Rng::new(1000 + s);
        let (ev, _) = sample_ar(&target, &cfg, &mut rng)?;
        ar_counts.push(ev.len() as f64);
        ar_taus.extend(tpp_sd::events::intervals(&ev));
        let mut rng = Rng::new(5000 + s);
        let sd_cfg = SdCfg { sample: cfg.clone(), gamma: Gamma::Fixed(10), ..Default::default() };
        let (ev, _) = sample_sd(&target, &draft, &sd_cfg, &mut rng)?;
        sd_counts.push(ev.len() as f64);
        sd_taus.extend(tpp_sd::events::intervals(&ev));
    }
    let m = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
    println!("AR count mean {:.1}  SD count mean {:.1}", m(&ar_counts), m(&sd_counts));
    println!("AR tau mean {:.4} (n={})  SD tau mean {:.4} (n={})", m(&ar_taus), ar_taus.len(), m(&sd_taus), sd_taus.len());
    // two-sample KS on taus
    let mut a = ar_taus.clone(); a.sort_by(|x,y| x.partial_cmp(y).unwrap());
    let ks = tpp_sd::metrics::ks::ks_statistic(&sd_taus, |x| {
        let idx = a.partition_point(|&v| v <= x);
        idx as f64 / a.len() as f64
    });
    let band = 1.36*((a.len()+sd_taus.len()) as f64 /(a.len() as f64*sd_taus.len() as f64)).sqrt();
    println!("two-sample KS {:.4} (95% crit {:.4})", ks, band);
    Ok(())
}
