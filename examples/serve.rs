//! End-to-end serving validation (DESIGN.md §7): start the coordinator's
//! TCP server in-process, drive concurrent sampling sessions against it,
//! and report latency percentiles, events/s throughput, batcher occupancy,
//! and the SD-vs-AR speedup under identical concurrency.
//!
//!     cargo run --release --example serve -- \
//!         [--clients 4] [--requests 3] [--t-end 10] [--gamma 10]
//!         [--datasets hawkes,taxi_sim] [--encoder thp]
//!         [--chaos 'seed=7,err=0.1,loss=0.05']
//!
//! `--chaos` attaches a fault-injection spec to every request (DESIGN.md
//! §13): a recoverable plan changes only the retry/timeout counters
//! reported at the end — never an event.

use std::time::{Duration, Instant};

use anyhow::Result;
use tpp_sd::coordinator::{Client, Request, SampleRequest, Server};
use tpp_sd::util::cli::Args;
use tpp_sd::util::math::{mean, percentile};

fn main() -> Result<()> {
    let args = Args::from_env();
    let clients = args.usize_or("clients", 4);
    let requests = args.usize_or("requests", 3);
    let t_end = args.f64_or("t-end", 10.0);
    let gamma = args.usize_or("gamma", 10);
    let encoder = args.str_or("encoder", "thp").to_string();
    let datasets = args.list_or("datasets", &["hawkes", "taxi_sim"]);
    let window_ms = args.u64_or("batch-window-ms", 2);
    let chaos = args.str_or("chaos", "").to_string();

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;
    let server = Server::bind(backend, "127.0.0.1:0", 8, Duration::from_millis(window_ms))?;
    let addr = server.addr;
    println!("coordinator listening on {addr} (batch window {window_ms}ms)");
    let router = server.router();
    std::thread::spawn(move || server.serve());

    // Pre-route so executor spawn/compile time doesn't pollute latencies.
    for ds in &datasets {
        router.route(ds, &encoder, "draft")?;
    }

    // Dedicated metrics connection: each `delta:true` call reports only
    // the window since the previous one (DESIGN.md §15), so this baseline
    // call makes the first per-method window start at zero.
    let mut metrics_cli = Client::connect(addr)?;
    metrics_cli.call(&Request::Metrics { delta: true })?;

    for method in ["ar", "sd"] {
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..clients {
            let datasets = datasets.clone();
            let encoder = encoder.clone();
            let chaos = chaos.clone();
            handles.push(std::thread::spawn(move || -> Result<(Vec<f64>, usize)> {
                let mut cli = Client::connect(addr)?;
                let mut lat = Vec::new();
                let mut events = 0usize;
                for r in 0..requests {
                    let req = Request::Sample(
                        SampleRequest::builder()
                            .dataset(datasets[(c + r) % datasets.len()].clone())
                            .encoder(encoder.clone())
                            .method(method)
                            .gamma(gamma)
                            .t_end(t_end)
                            .seed((c * 1000 + r) as u64)
                            .chaos(chaos.clone())
                            .build(),
                    );
                    let t = Instant::now();
                    let resp = cli.call(&req)?;
                    lat.push(t.elapsed().as_secs_f64());
                    let (ev, _) = tpp_sd::coordinator::protocol::parse_response(&resp)?;
                    events += ev.len();
                }
                Ok((lat, events))
            }));
        }
        let mut lats = Vec::new();
        let mut events = 0usize;
        for h in handles {
            let (l, e) = h.join().expect("client thread")?;
            lats.extend(l);
            events += e;
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{:<3}  {} sessions × {} reqs: {:6.2}s wall  {:8.1} events/s  \
             p50 {:6.2}s p95 {:6.2}s mean {:6.2}s  ({} events)",
            method,
            clients,
            requests,
            wall,
            events as f64 / wall,
            percentile(&lats, 0.5),
            percentile(&lats, 0.95),
            mean(&lats),
            events,
        );
        // This phase's telemetry window: per-stage latency percentiles +
        // acceptance for the requests above only.
        let window = metrics_cli.call(&Request::Metrics { delta: true })?;
        println!("{method:<3}  window metrics: {}", window.trim());
    }

    // batcher occupancy + reliability + pool/buffer report, one line per
    // executor (retries/timeouts/gave_up are all zero on a healthy backend)
    for ds in &datasets {
        let pair = router.route(ds, &encoder, "draft")?;
        println!("{}", tpp_sd::bench::executor_report(&pair.target.name, &pair.target.stats));
        println!("{}", tpp_sd::bench::executor_report(&pair.draft.name, &pair.draft.stats));
    }
    if !chaos.is_empty() {
        // Chaos traffic runs on dedicated per-spec routers (their retry
        // counters absorb the injected faults); the fault-free executors
        // above must stay clean.
        let mut cli = Client::connect(addr)?;
        let stats = cli.call(&Request::Stats)?;
        println!("chaos spec '{chaos}' active; server stats: {}", stats.trim());
    }
    // Whole-run summary from the same registry the wire snapshots read.
    println!("{}", tpp_sd::telemetry::report());
    Ok(())
}
