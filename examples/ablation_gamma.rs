//! **Figure 3 / Figure 6**: the draft-length ablation — sweep γ and record
//! ΔL, the distance metric (KS for synthetic / D_WS for real), acceptance
//! rate α and the speedup ratio. Also ablates the adaptive-γ extension.
//!
//!     cargo run --release --example ablation_gamma -- \
//!         [--dataset multihawkes] [--encoder attnhp] \
//!         [--gammas 1,2,5,10,20,40,60] [--t-end 50] [--n-seq 2] [--seeds 0,1]
//!         [--with-adaptive] [--backend auto|native|xla]

use anyhow::Result;
use tpp_sd::bench::{synthetic_cell, EvalCfg};
use tpp_sd::processes::from_dataset_json;
use tpp_sd::runtime::{Backend, ModelBackend};
use tpp_sd::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let dataset = args.str_or("dataset", "multihawkes").to_string();
    let encoder = args.str_or("encoder", "attnhp").to_string();
    let gammas: Vec<usize> = args
        .list_or("gammas", &["1", "2", "5", "10", "20", "40", "60"])
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();
    let seeds: Vec<u64> = args
        .list_or("seeds", &["0", "1"])
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;
    let spec = backend.dataset_spec(&dataset)?;
    let process = from_dataset_json(&spec)?;
    let num_types = backend.num_types(&dataset)?;
    let target = backend.load_model(&dataset, &encoder, "target")?;
    target.warmup()?;
    let draft = backend.load_model(&dataset, &encoder, "draft")?;
    draft.warmup()?;

    println!(
        "=== Fig 3/6: draft-length sweep ({dataset}, {encoder}, backend={}, {} seeds) ===",
        backend.name(),
        seeds.len()
    );
    println!(
        "{:>6} {:>9} | {:>8} {:>7} | {:>8} {:>8} | {:>7} {:>6}",
        "γ", "mode", "ΔL_sd", "KS_sd", "T_ar", "T_sd", "speedup", "α"
    );

    let run = |gamma: usize, adaptive: bool| -> Result<()> {
        let cfg = EvalCfg {
            t_end: args.f64_or("t-end", 50.0),
            n_seq: args.usize_or("n-seq", 2),
            seeds: seeds.clone(),
            gamma,
            adaptive,
            ..Default::default()
        };
        let cell = synthetic_cell(&target, &draft, process.as_ref(), num_types, &cfg)?;
        println!(
            "{:>6} {:>9} | {:>8.3} {:>7.3} | {:>7.2}s {:>7.2}s | {:>6.2}x {:>6.2}",
            gamma,
            if adaptive { "adaptive" } else { "fixed" },
            cell.dl_sd,
            cell.ks_sd,
            cell.t_ar,
            cell.t_sd,
            cell.speedup,
            cell.alpha
        );
        Ok(())
    };

    for &g in &gammas {
        run(g, false)?;
    }
    if args.has("with-adaptive") {
        run(args.usize_or("adaptive-init", 10), true)?;
    }
    Ok(())
}
