//! **Table 1** (+ the data behind Figures 2/4): TPP-SD vs AR sampling on
//! the three synthetic datasets across the three Transformer encoders.
//! Each seed's `--n-seq` sequences run in lockstep on the fleet engine
//! (DESIGN.md §11), so the wall-time columns measure batched throughput.
//!
//!     cargo run --release --example synthetic_eval -- \
//!         [--t-end 100] [--n-seq 3] [--seeds 0,1,2] [--gamma 10]
//!         [--datasets poisson,hawkes,multihawkes] [--encoders thp,sahp,attnhp]
//!         [--backend auto|native|xla]

use anyhow::Result;
use tpp_sd::bench::{synthetic_cell, EvalCfg};
use tpp_sd::processes::from_dataset_json;
use tpp_sd::runtime::{Backend, ModelBackend};
use tpp_sd::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cfg = EvalCfg {
        t_end: args.f64_or("t-end", 100.0),
        n_seq: args.usize_or("n-seq", 3),
        seeds: args
            .list_or("seeds", &["0", "1", "2"])
            .iter()
            .map(|s| s.parse().unwrap())
            .collect(),
        gamma: args.usize_or("gamma", 10),
        adaptive: args.has("adaptive"),
        ..Default::default()
    };
    let datasets = args.list_or("datasets", &["poisson", "hawkes", "multihawkes"]);
    let encoders = args.list_or("encoders", &["thp", "sahp", "attnhp"]);

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;

    println!(
        "=== Table 1: synthetic datasets (backend={}, γ={}, T={}, {} seq × {} seeds) ===",
        backend.name(),
        cfg.gamma,
        cfg.t_end,
        cfg.n_seq,
        cfg.seeds.len()
    );
    println!(
        "{:<13} {:<7} | {:>8} {:>8} | {:>7} {:>7} {:>7} | {:>8} {:>8} | {:>7} {:>5}",
        "dataset", "enc", "ΔL_ar", "ΔL_sd", "KS_ar", "KS_sd", "KS_gt", "T_ar", "T_sd", "speedup", "α"
    );

    for ds in &datasets {
        let spec = backend.dataset_spec(ds)?;
        let process = from_dataset_json(&spec)?;
        let num_types = backend.num_types(ds)?;
        for enc in &encoders {
            let target = backend.load_model(ds, enc, "target")?;
            target.warmup()?;
            let draft = backend.load_model(ds, enc, "draft")?;
            draft.warmup()?;
            let cell = synthetic_cell(&target, &draft, process.as_ref(), num_types, &cfg)?;
            println!(
                "{:<13} {:<7} | {:>8.3} {:>8.3} | {:>7.3} {:>7.3} {:>7.3} | {:>7.2}s {:>7.2}s | {:>6.2}x {:>5.2}",
                ds, enc, cell.dl_ar, cell.dl_sd, cell.ks_ar, cell.ks_sd, cell.ks_gt,
                cell.t_ar, cell.t_sd, cell.speedup, cell.alpha
            );
        }
    }
    Ok(())
}
