//! **Table 3 / Table 4**: the draft-model-size ablation — fixed target,
//! draft ∈ {1h1l (draft), 2h4l (draft2), 4h6l (draft3)}; report ΔL,
//! distance, acceptance rate α, wall times and speedup.
//!
//!     cargo run --release --example ablation_draft_size -- \
//!         [--datasets multihawkes,taobao_sim] [--encoders attnhp]
//!         [--gamma 10] [--t-end 50] [--n-seq 2] [--seeds 0,1,2]
//!         [--backend auto|native|xla]

use anyhow::Result;
use tpp_sd::bench::{synthetic_cell, EvalCfg};
use tpp_sd::processes::from_dataset_json;
use tpp_sd::runtime::{Backend, ModelBackend};
use tpp_sd::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let datasets = args.list_or("datasets", &["multihawkes", "taobao_sim"]);
    let encoders = args.list_or("encoders", &["attnhp"]);
    let drafts = args.list_or("draft-sizes", &["draft", "draft2", "draft3"]);
    let cfg0 = EvalCfg {
        t_end: args.f64_or("t-end", 50.0),
        n_seq: args.usize_or("n-seq", 2),
        seeds: args
            .list_or("seeds", &["0", "1", "2"])
            .iter()
            .map(|s| s.parse().unwrap())
            .collect(),
        gamma: args.usize_or("gamma", 10),
        ..Default::default()
    };

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;

    println!(
        "=== Table 3/4: draft-model size ablation (backend={}, γ={}) ===",
        backend.name(),
        cfg0.gamma
    );
    println!(
        "{:<13} {:<7} {:<8} | {:>8} {:>7} | {:>6} | {:>8} {:>8} | {:>7}",
        "dataset", "enc", "draft", "ΔL_sd", "KS_sd", "α", "T_ar", "T_sd", "speedup"
    );

    for ds in &datasets {
        let spec = backend.dataset_spec(ds)?;
        let process = from_dataset_json(&spec)?;
        let num_types = backend.num_types(ds)?;
        for enc in &encoders {
            let target = backend.load_model(ds, enc, "target")?;
            target.warmup()?;
            for dsize in &drafts {
                let draft = backend.load_model(ds, enc, dsize)?;
                draft.warmup()?;
                let cell =
                    synthetic_cell(&target, &draft, process.as_ref(), num_types, &cfg0)?;
                println!(
                    "{:<13} {:<7} {:<8} | {:>8.3} {:>7.3} | {:>6.2} | {:>7.2}s {:>7.2}s | {:>6.2}x",
                    ds, enc, dsize, cell.dl_sd, cell.ks_sd, cell.alpha,
                    cell.t_ar, cell.t_sd, cell.speedup
                );
            }
        }
    }
    Ok(())
}
