//! **Table 2** (+ Figure 5 histogram data): TPP-SD vs AR consistency on the
//! four simulated real-world datasets (Taobao/Amazon/Taxi/StackOverflow
//! stand-ins, DESIGN.md §3) across the three encoders, including the paper's
//! AR-vs-AR stochasticity baseline. Each seed's `--n-seq` sequences run in
//! lockstep on the fleet engine (DESIGN.md §11).
//!
//!     cargo run --release --example real_eval -- \
//!         [--t-end 50] [--n-seq 2] [--seeds 0,1,2] [--gamma 10]
//!         [--backend auto|native|xla]

use anyhow::Result;
use tpp_sd::bench::{real_cell, EvalCfg};
use tpp_sd::processes::from_dataset_json;
use tpp_sd::runtime::{Backend, ModelBackend};
use tpp_sd::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cfg = EvalCfg {
        t_end: args.f64_or("t-end", 50.0),
        n_seq: args.usize_or("n-seq", 2),
        seeds: args
            .list_or("seeds", &["0", "1", "2"])
            .iter()
            .map(|s| s.parse().unwrap())
            .collect(),
        gamma: args.usize_or("gamma", 10),
        adaptive: args.has("adaptive"),
        history_m: args.usize_or("history-m", 100),
        reps_n: args.usize_or("reps-n", 100),
    };
    let datasets = args.list_or(
        "datasets",
        &["taobao_sim", "amazon_sim", "taxi_sim", "stackoverflow_sim"],
    );
    let encoders = args.list_or("encoders", &["thp", "sahp", "attnhp"]);

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;

    println!(
        "=== Table 2: real-data stand-ins (backend={}, γ={}, T={}, M={}, N={}) ===",
        backend.name(),
        cfg.gamma,
        cfg.t_end,
        cfg.history_m,
        cfg.reps_n
    );
    println!(
        "{:<18} {:<7} | {:>8} {:>8} | {:>7} {:>7} | {:>7} {:>7} | {:>8} {:>8} | {:>7} {:>5}",
        "dataset", "enc", "ΔL_sd", "ΔL_base", "DWSt", "DWSt_b", "DWSk", "DWSk_b", "T_ar", "T_sd", "speedup", "α"
    );

    for ds in &datasets {
        let spec = backend.dataset_spec(ds)?;
        let process = from_dataset_json(&spec)?;
        let num_types = backend.num_types(ds)?;
        for enc in &encoders {
            let target = backend.load_model(ds, enc, "target")?;
            target.warmup()?;
            let draft = backend.load_model(ds, enc, "draft")?;
            draft.warmup()?;
            let cell = real_cell(&target, &draft, process.as_ref(), num_types, &cfg)?;
            println!(
                "{:<18} {:<7} | {:>8.3} {:>8.3} | {:>7.3} {:>7.3} | {:>7.3} {:>7.3} | {:>7.2}s {:>7.2}s | {:>6.2}x {:>5.2}",
                ds, enc, cell.dl, cell.dl_ar_baseline, cell.dws_t, cell.dws_t_baseline,
                cell.dws_k, cell.dws_k_baseline, cell.t_ar, cell.t_sd, cell.speedup, cell.alpha
            );
        }
    }
    Ok(())
}
