//! Quickstart: load a target+draft pair from the active backend, sample
//! with AR and TPP-SD, and report the speedup + acceptance rate.
//!
//! Runs out of the box on the native CPU backend (no artifacts needed):
//!
//!     cargo run --release --example quickstart -- \
//!         [--dataset hawkes] [--encoder attnhp] [--gamma 10] [--t-end 30]
//!         [--backend auto|native|xla]

use anyhow::Result;
use tpp_sd::runtime::Backend;
use tpp_sd::sampler::{sample_ar, sample_sd, Gamma, SampleCfg, SdCfg};
use tpp_sd::util::cli::Args;
use tpp_sd::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    let dataset = args.str_or("dataset", "hawkes").to_string();
    let encoder = args.str_or("encoder", "attnhp").to_string();
    let gamma = args.usize_or("gamma", 10);
    let t_end = args.f64_or("t-end", 30.0);
    let seed = args.u64_or("seed", 0);

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;
    let num_types = backend.num_types(&dataset)?;

    println!(
        "tpp-sd quickstart: backend={} dataset={dataset} encoder={encoder} K={num_types} γ={gamma} T={t_end}",
        backend.name()
    );

    let target = backend.load_model(&dataset, &encoder, "target")?;
    let draft = backend.load_model(&dataset, &encoder, "draft")?;

    let cfg = SampleCfg { num_types, t_end, max_events: 4096 };

    let mut rng = Rng::new(seed);
    let (ar_events, ar) = sample_ar(&target, &cfg, &mut rng)?;
    println!(
        "AR     : {:4} events  {:7.2?}  ({} target forwards)",
        ar.events, ar.wall, ar.target_forwards
    );

    let sd_cfg = SdCfg { sample: cfg, gamma: Gamma::Fixed(gamma), ..Default::default() };
    let mut rng = Rng::new(seed + 1);
    let (sd_events, sd) = sample_sd(&target, &draft, &sd_cfg, &mut rng)?;
    println!(
        "TPP-SD : {:4} events  {:7.2?}  ({} target + {} draft forwards, α={:.2})",
        sd.events,
        sd.wall,
        sd.target_forwards,
        sd.draft_forwards,
        sd.acceptance_rate()
    );
    let per_ar = ar.wall.as_secs_f64() / ar.events.max(1) as f64;
    let per_sd = sd.wall.as_secs_f64() / sd.events.max(1) as f64;
    println!("speedup S_AR/SD (per event): {:.2}x", per_ar / per_sd);
    println!(
        "first AR events: {:?}",
        &ar_events[..ar_events.len().min(3)]
    );
    println!(
        "first SD events: {:?}",
        &sd_events[..sd_events.len().min(3)]
    );
    Ok(())
}
