//! **Figure 2 / Figure 4**: KS-plot point series — (F(z), F_n(z)) for
//! TPP-SD, AR sampling and ground-truth thinning, with the 95% confidence
//! band, written as CSV per (dataset × encoder).
//!
//!     cargo run --release --example ks_plots -- \
//!         [--datasets poisson,hawkes,multihawkes] [--encoders attnhp]
//!         [--out /tmp/ks_plots] [--t-end 50] [--n-seq 2] [--seeds 0,1]
//!         [--backend auto|native|xla]
//!
//! `--encoders thp,sahp,attnhp` regenerates the full Figure-4 grid.

use std::io::Write;

use anyhow::Result;
use tpp_sd::bench::{synthetic_cell, EvalCfg};
use tpp_sd::metrics::ks_band;
use tpp_sd::processes::from_dataset_json;
use tpp_sd::runtime::{Backend, ModelBackend};
use tpp_sd::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let datasets = args.list_or("datasets", &["poisson", "hawkes", "multihawkes"]);
    let encoders = args.list_or("encoders", &["attnhp"]);
    let out_dir = args.str_or("out", "/tmp/ks_plots").to_string();
    std::fs::create_dir_all(&out_dir)?;
    let cfg = EvalCfg {
        t_end: args.f64_or("t-end", 50.0),
        n_seq: args.usize_or("n-seq", 2),
        seeds: args
            .list_or("seeds", &["0", "1"])
            .iter()
            .map(|s| s.parse().unwrap())
            .collect(),
        gamma: args.usize_or("gamma", 10),
        ..Default::default()
    };

    let backend = tpp_sd::runtime::backend_from_arg(args.get("backend"))?;

    for ds in &datasets {
        let spec = backend.dataset_spec(ds)?;
        let process = from_dataset_json(&spec)?;
        let num_types = backend.num_types(ds)?;
        for enc in &encoders {
            let target = backend.load_model(ds, enc, "target")?;
            target.warmup()?;
            let draft = backend.load_model(ds, enc, "draft")?;
            draft.warmup()?;
            let cell = synthetic_cell(&target, &draft, process.as_ref(), num_types, &cfg)?;
            let path = format!("{out_dir}/ks_{ds}_{enc}.csv");
            let mut f = std::fs::File::create(&path)?;
            writeln!(f, "series,f_theoretical,f_empirical,band")?;
            let band = ks_band(cell.n_rescaled.max(1));
            for (name, pts) in [
                ("sd", &cell.ks_points_sd),
                ("ar", &cell.ks_points_ar),
                ("gt", &cell.ks_points_gt),
            ] {
                for (x, y) in pts {
                    writeln!(f, "{name},{x:.5},{y:.5},{band:.5}")?;
                }
            }
            let in_band_sd = cell
                .ks_points_sd
                .iter()
                .filter(|(x, y)| (y - x).abs() <= band)
                .count();
            println!(
                "{path}: KS_sd={:.3} KS_ar={:.3} KS_gt={:.3} band={band:.3} \
                 sd-in-band {}/{}",
                cell.ks_sd,
                cell.ks_ar,
                cell.ks_gt,
                in_band_sd,
                cell.ks_points_sd.len()
            );
        }
    }
    Ok(())
}
