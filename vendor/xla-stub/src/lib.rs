//! API **stub** of the [`xla`](https://github.com/LaurentMazare/xla-rs)
//! PJRT bindings — just enough surface for `tpp_sd::runtime::executor` to
//! type-check under `--features xla` in an offline container without the
//! system XLA/PJRT libraries.
//!
//! Every runtime entry point returns [`Error`] explaining that the stub is
//! linked. To actually execute AOT artifacts, point the workspace `xla`
//! dependency at the real crate (see `docs/adr/001-backend-abstraction.md`);
//! the executor code compiles unchanged against either.

#![warn(missing_docs)]

use std::fmt;
use std::path::Path;

/// Error type mirroring the real crate's: all stub entry points return it.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// `Result` alias matching the real crate.
pub type Result<T> = std::result::Result<T, Error>;

fn stub<T>() -> Result<T> {
    Err(Error(
        "built against the vendored XLA API stub (vendor/xla-stub); \
         point the workspace `xla` dependency at the real PJRT crate to \
         execute AOT artifacts (docs/adr/001-backend-abstraction.md)"
            .to_string(),
    ))
}

/// Scalar types a [`Literal`] buffer can hold.
pub trait ElementType: Copy {}
impl ElementType for f32 {}
impl ElementType for f64 {}
impl ElementType for i32 {}
impl ElementType for i64 {}
impl ElementType for u32 {}

/// Array shape of a literal (dimensions only in the stub).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension extents.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side tensor value.
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: ElementType>(_data: &[T]) -> Literal {
        Literal(())
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        stub()
    }

    /// Copy the buffer out as a typed host vector.
    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        stub()
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        stub()
    }

    /// The array shape, if the literal is an array.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        stub()
    }
}

/// Deserialization support (`.npz` archives of named arrays).
pub trait FromRawBytes: Sized {
    /// Extra context threaded through deserialization (unit for literals).
    type Context;

    /// Read a `.npz` archive as `(name, value)` pairs.
    fn read_npz<P: AsRef<Path>>(path: P, ctx: &Self::Context) -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    type Context = ();

    fn read_npz<P: AsRef<Path>>(_path: P, _ctx: &Self::Context) -> Result<Vec<(String, Literal)>> {
        stub()
    }
}

/// Device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Shape of the buffer on device.
    pub fn on_device_shape(&self) -> Result<ArrayShape> {
        stub()
    }

    /// Synchronously copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub()
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with host literals as arguments.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub()
    }

    /// Execute with device buffers as arguments.
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub()
    }
}

/// A PJRT client owning one device.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Open the CPU PJRT client.
    pub fn cpu() -> Result<PjRtClient> {
        stub()
    }

    /// Compile an [`XlaComputation`] to a loaded executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub()
    }

    /// Upload a host literal to the device.
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        stub()
    }

    /// Upload a typed host slice with the given dimensions to the device.
    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        stub()
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO module from its text dump.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        stub()
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_entry_points_error_with_pointer_to_adr() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
        let e = Literal::vec1(&[1.0f32]).to_vec::<f32>().unwrap_err();
        assert!(e.to_string().contains("docs/adr/001"));
    }
}
