//! Offline shim of the [`anyhow`](https://docs.rs/anyhow) API surface this
//! workspace uses: a dynamic [`Error`] carrying a human-readable context
//! chain, the [`Context`] extension trait for `Result`/`Option`, the
//! [`Result`] alias, and the [`anyhow!`]/[`bail!`]/[`ensure!`] macros.
//!
//! The container registry is offline, so this crate is a path dependency
//! (see the workspace `Cargo.toml`). It mirrors the upstream semantics the
//! codebase relies on:
//!
//! * `{e}` displays the outermost context, `{e:#}` the full chain joined
//!   with `": "` (upstream's alternate formatting);
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`,
//!   capturing its `source()` chain;
//! * `Error` itself deliberately does **not** implement `std::error::Error`
//!   so the blanket `From` impl stays coherent (same trick as upstream).

#![warn(missing_docs)]

use std::fmt;

/// A dynamic error: an ordered chain of context strings, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap the error with an outer context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with this crate's [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait attaching context to `Result` and `Option` values.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error (or `None`) with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds (upstream
/// anyhow's `ensure!`, with the same default message form).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn io_err() -> Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn run() -> Result<()> {
            io_err()?;
            Ok(())
        }
        let e = run().unwrap_err();
        assert_eq!(format!("{e}"), "disk on fire");
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let e = io_err()
            .context("reading weights")
            .unwrap_err()
            .context("loading model");
        assert_eq!(format!("{e}"), "loading model");
        assert_eq!(format!("{e:#}"), "loading model: reading weights: disk on fire");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn macros_build_errors() {
        fn run(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed (got {x})");
            }
            Err(anyhow!("always fails: {x}"))
        }
        assert_eq!(format!("{}", run(0).unwrap_err()), "zero not allowed (got 0)");
        assert_eq!(format!("{}", run(3).unwrap_err()), "always fails: 3");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::msg("inner").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by") && dbg.contains("inner"));
    }
}
